"""Paper §III-G analogue: an apparently-faulty node (lac-417) — extreme QoS
degradation in its clique, but stable global medians (claim C4).

Runs on the clique-of-cliques topology with the hierarchical link model, so
"node" means a physical host: every process placed on the faulty host slows
down and every link touching one degrades (runtime.faults.faulty_host).
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.apps.graphcolor import GraphColorApp, GraphColorConfig
from repro.core.modes import AsyncMode
from repro.core.qos import METRICS
from repro.runtime.faults import faulty_host
from repro.runtime.simulator import SimConfig, Simulator
from repro.runtime.topologies import make_topology

from benchmarks.common import emit, save_json

FIELDS = METRICS


def _stats(reports_by_pid, pids):
    out = {}
    for f in FIELDS:
        vals = [getattr(q, f) for p in pids for q in reports_by_pid[p]]
        if not vals:
            # NaN, not 0.0: an empty group must not look like a perfect one
            vals = [float("nan")]
        out[f] = {"mean": float(np.mean(vals)),
                  "median": float(np.median(vals)),
                  "p95": float(np.percentile(vals, 95))}
    return out


def run(n=256, clique_size=8, faulty=None, compute_factor=30.0,
        link_factor=30.0):
    topo = make_topology("cliques", n, clique_size=clique_size)
    if faulty is None:
        faulty = topo.n_nodes // 2
    victims = set(topo.host_pids(faulty))
    clique = set()
    for p in victims:
        clique.update(topo.clique_of(p))

    cfg = SimConfig(mode=AsyncMode.BEST_EFFORT, duration=0.12,
                    base_compute=15e-6, base_latency=550e-6,
                    intra_node_latency=120e-6,
                    snapshot_warmup=0.03, snapshot_interval=0.02)

    app = GraphColorApp(GraphColorConfig(n_processes=n, nodes_per_process=1),
                        topology=topo)
    res_with = Simulator(app, cfg, faulty_host(topo, faulty,
                                               compute_factor,
                                               link_factor)).run()
    app2 = GraphColorApp(GraphColorConfig(n_processes=n, nodes_per_process=1),
                         topology=topo)
    res_wo = Simulator(app2, cfg).run()

    all_pids = list(range(n))
    rest = [p for p in all_pids if p not in clique]
    rows = {
        "topology": topo.name,
        "faulty_host": faulty,
        "with_fault": {
            "global": _stats(res_with.qos_by_process, all_pids),
            "clique": _stats(res_with.qos_by_process, sorted(clique)),
            "rest": _stats(res_with.qos_by_process, rest),
        },
        "without_fault": {
            "global": _stats(res_wo.qos_by_process, all_pids),
        },
        "updates_victims_median": float(np.median(
            [res_with.updates[p] for p in victims])),
        "updates_median": float(np.median(res_with.updates)),
    }
    for label, s in (("with/global", rows["with_fault"]["global"]),
                     ("with/clique", rows["with_fault"]["clique"]),
                     ("with/rest", rows["with_fault"]["rest"]),
                     ("without/global", rows["without_fault"]["global"])):
        emit(f"faulty/{label}", s["simstep_period"]["median"] * 1e6,
             f"median_lat_steps={s['simstep_latency']['median']:.1f} "
             f"p95_period_us={s['simstep_period']['p95'] * 1e6:.1f}")
    emit("faulty/victims", 0.0,
         f"updates={rows['updates_victims_median']:.0f} "
         f"vs median {rows['updates_median']:.0f}")
    save_json("bench_faulty", rows)
    return rows


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--n", type=int, default=256)
    p.add_argument("--clique-size", type=int, default=8)
    p.add_argument("--faulty", type=int, default=None)
    a = p.parse_args()
    run(a.n, a.clique_size, a.faulty)
