"""Paper §III-G analogue: an apparently-faulty node (lac-417) — extreme QoS
degradation in its clique, but stable global medians (claim C4)."""
from __future__ import annotations

import numpy as np

from repro.apps.graphcolor import GraphColorApp, GraphColorConfig
from repro.core.modes import AsyncMode
from repro.runtime.faults import faulty_node
from repro.runtime.simulator import SimConfig, Simulator

from benchmarks.common import emit, save_json

FIELDS = ("simstep_period", "simstep_latency", "walltime_latency",
          "delivery_failure_rate", "delivery_clumpiness")


def _stats(res, exclude=()):
    out = {}
    pids = [p for p in res.qos_by_process if p not in exclude]
    for f in FIELDS:
        vals = [getattr(q, f) for p in pids for q in res.qos_by_process[p]]
        out[f] = {"mean": float(np.mean(vals)), "median": float(np.median(vals))}
    return out


def run(n=256, faulty_pid=17):
    app = GraphColorApp(GraphColorConfig(n_processes=n, nodes_per_process=1))
    topo = app.topology()
    cfg = SimConfig(mode=AsyncMode.BEST_EFFORT, duration=0.12,
                    base_compute=15e-6, base_latency=550e-6,
                    snapshot_warmup=0.03, snapshot_interval=0.02)

    res_with = Simulator(app, cfg,
                         faulty_node(faulty_pid, topo[faulty_pid],
                                     compute_factor=30.0, link_factor=30.0)).run()
    app2 = GraphColorApp(GraphColorConfig(n_processes=n, nodes_per_process=1))
    res_wo = Simulator(app2, cfg).run()

    rows = {
        "with_faulty": _stats(res_with),
        "without_faulty": _stats(res_wo),
        "faulty_node_itself": {
            f: {"median": float(np.median(
                [getattr(q, f) for q in res_with.qos_by_process[faulty_pid]] or [0]))}
            for f in FIELDS},
        "updates_faulty": res_with.updates[faulty_pid],
        "updates_median": float(np.median(res_with.updates)),
    }
    for label, s in (("with", rows["with_faulty"]), ("without", rows["without_faulty"])):
        emit(f"faulty/{label}", s["simstep_period"]["median"] * 1e6,
             f"median_lat_steps={s['simstep_latency']['median']:.1f} "
             f"mean_lat_steps={s['simstep_latency']['mean']:.1f}")
    emit("faulty/node_itself",
         rows["faulty_node_itself"]["simstep_period"]["median"] * 1e6,
         f"updates={rows['updates_faulty']} vs median {rows['updates_median']:.0f}")
    save_json("bench_faulty", rows)
    return rows


if __name__ == "__main__":
    run()
