"""Roofline analysis per (arch × shape × mesh) from dry-run artifacts.

Three terms (seconds per step, TPU v5e constants):
  compute    = MODEL_FLOPS / (chips × 197e12)
  memory     = HLO_bytes / (chips × 819e9)
  collective = Σ payload_bytes × ring_factor / 50e9   (per-chip payloads)

XLA's cost analysis counts while-loop bodies ONCE, so scanned-layer modules
under-report.  We recover exact per-step totals with a TWO-POINT PROBE:
compile the model at 1 and 2 scan periods on the same mesh; then
  body  = cost(2P) - cost(1P),   base = cost(1P) - body,
  total = base + body × n_periods.
The same decomposition applies to the parsed per-collective bytes.

MODEL_FLOPS is the analytic 6·N_active·D (+ attention/SSM sequence-mixing
terms); the ratio MODEL_FLOPS / HLO_FLOPS exposes remat/dispatch waste.

MUST run as its own process (sets XLA_FLAGS before importing jax).
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402
import argparse
import json

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s / chip
LINK_BW = 50e9           # B/s / link

RESULTS_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "results", "roofline")

# ring traffic multipliers on the parsed payload (= max(result, operands))
_COLL_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


# ---------------------------------------------------------------------------
# Analytic model FLOPs
# ---------------------------------------------------------------------------
def model_flops(cfg, shape) -> float:
    """Analytic step FLOPs (global, all chips)."""
    from repro.models import lm
    from repro.models.transformer import block_specs

    B, S = shape.global_batch, shape.seq_len
    n_active = lm.active_param_count(cfg)
    specs = block_specs(cfg)
    n_attn = sum(1 for (m, _) in specs if m == "attn") \
        * (cfg.num_layers // len(specs))
    H, hd = cfg.num_heads, cfg.hd

    if shape.kind == "train":
        tokens = B * S
        flops = 6 * n_active * tokens
        flops += 3 * 2 * B * S * S * H * hd * n_attn  # causal fwd+bwd qk+pv
        return float(flops)
    if shape.kind == "prefill":
        tokens = B * S
        flops = 2 * n_active * tokens
        flops += 2 * B * S * S * H * hd * n_attn / 2 * 2  # qk+pv causal
        return float(flops)
    # decode: one token; attention reads the full cache
    flops = 2 * n_active * B
    flops += 4 * B * S * H * hd * n_attn
    return float(flops)


# ---------------------------------------------------------------------------
# Two-point probe
# ---------------------------------------------------------------------------
def _cell_costs(arch, shape_name, multi_pod, mode, compressor, extra_cfg,
                extra=None):
    from repro.launch import dryrun as dr
    if extra:
        extra_cfg = dict(extra_cfg or {}, **extra)
    lowered, skip = dr.build_lowered(arch, shape_name, multi_pod, mode,
                                     compressor, extra_cfg=extra_cfg)
    if skip:
        return None
    compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    coll = dr.collective_bytes(compiled.as_text())
    mem = {}
    try:
        m = compiled.memory_analysis()
        mem = {"argument_bytes": getattr(m, "argument_size_in_bytes", None),
               "temp_bytes": getattr(m, "temp_size_in_bytes", None),
               "peak_bytes": getattr(m, "peak_memory_in_bytes", None)}
    except Exception:  # noqa: BLE001
        pass
    return {"flops": cost.get("flops", 0.0),
            "bytes": cost.get("bytes accessed", 0.0),
            "collectives": coll, "memory": mem}


def two_point_costs(arch, shape_name, multi_pod, mode=0, compressor=None,
                    extra=None):
    """Exact per-step (flops, bytes, collective bytes) via the 1P/2P probe."""
    from repro.configs import get_config
    from repro.models.transformer import block_specs

    cfg = get_config(arch)
    period = len(block_specs(cfg))
    n_periods = cfg.num_layers // period

    c1 = _cell_costs(arch, shape_name, multi_pod, mode, compressor,
                     {"num_layers": period}, extra)
    if c1 is None:
        return None
    if n_periods == 1:
        c1["probe"] = "exact(single period)"
        return c1
    c2 = _cell_costs(arch, shape_name, multi_pod, mode, compressor,
                     {"num_layers": 2 * period}, extra)

    def extrap(a, b):
        body = b - a
        base = a - body
        return base + body * n_periods

    coll_keys = set(c1["collectives"]) | set(c2["collectives"])
    coll = {k: max(0.0, extrap(c1["collectives"].get(k, 0),
                               c2["collectives"].get(k, 0)))
            for k in coll_keys}
    return {"flops": extrap(c1["flops"], c2["flops"]),
            "bytes": extrap(c1["bytes"], c2["bytes"]),
            "collectives": coll,
            "memory": c1["memory"],  # probe memory is not meaningful
            "probe": "two-point"}


# ---------------------------------------------------------------------------
# Terms
# ---------------------------------------------------------------------------
def roofline_terms(arch, shape_name, multi_pod, *, mode=0, compressor=None,
                   full_record=None, extra=None):
    """Compute the three terms.  ``full_record``: the full-config dry-run
    JSON (for peak memory); probe costs are computed here."""
    from repro.configs import SHAPES, get_config

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    chips = 512 if multi_pod else 256

    costs = two_point_costs(arch, shape_name, multi_pod, mode, compressor,
                            extra)
    if costs is None:
        return None

    mf = model_flops(cfg, shape)
    hlo_flops_global = costs["flops"] * chips  # compiled cost is per-chip
    hlo_bytes_chip = costs["bytes"]

    t_compute = mf / (chips * PEAK_FLOPS)
    t_memory = hlo_bytes_chip / HBM_BW
    t_coll = sum(v * _COLL_FACTOR.get(k, 1.0)
                 for k, v in costs["collectives"].items()) / LINK_BW

    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    bound = dominant.replace("_s", "")
    step_s = max(terms.values())
    out = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips, "mode": mode, "compressor": compressor,
        **terms,
        "dominant": bound,
        "roofline_fraction": t_compute / step_s if step_s > 0 else None,
        "model_flops": mf,
        "hlo_flops_global": hlo_flops_global,
        "useful_flops_ratio": mf / hlo_flops_global if hlo_flops_global else None,
        "collective_bytes_per_chip": costs["collectives"],
        "probe": costs["probe"],
    }
    if full_record and full_record.get("memory"):
        out["peak_bytes_per_chip"] = full_record["memory"].get("peak_bytes")
    out["note"] = _advice(out)
    return out


def _advice(r) -> str:
    d = r["dominant"]
    if d == "compute":
        return ("compute-bound: raise MXU utilization (fused flash-attention "
                "kernel, larger per-chip batch) — already at the right wall")
    if d == "memory":
        return ("HBM-bound: cut bytes/step — fuse attention (flash kernel "
                "avoids score materialization), reduce remat recompute, "
                "keep activations bf16")
    return ("collective-bound: cut cross-chip bytes — delay/overlap the "
            "cross-pod reduce (mode 3), compress payloads (int8/topk), or "
            "reshard to trade all-gathers for reduce-scatters")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--mode", type=int, default=0)
    ap.add_argument("--compressor", default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--extra", default="",
                    help="cfg overrides k=v,k=v (perf experiments)")
    args = ap.parse_args()
    extra = {}
    for kv in filter(None, args.extra.split(",")):
        k, v = kv.split("=")
        extra[k] = {"true": True, "false": False}.get(
            v.lower(), int(v) if v.lstrip("-").isdigit() else v)

    from repro.configs import ARCHS, SHAPES
    archs = ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    os.makedirs(RESULTS_DIR, exist_ok=True)

    for arch in archs:
        for shape in shapes:
            try:
                r = roofline_terms(arch, shape, args.mesh == "multi",
                                   mode=args.mode, compressor=args.compressor,
                                   extra=extra or None)
            except Exception as e:  # noqa: BLE001
                print(f"[roofline] {arch}/{shape}: ERROR {e}", flush=True)
                continue
            if r is None:
                print(f"[roofline] {arch}/{shape}: skip", flush=True)
                continue
            r["tag"] = args.tag
            name = f"{arch}__{shape}__{r['mesh']}"
            name += f"__{args.tag}" if args.tag else ""
            with open(os.path.join(RESULTS_DIR, name + ".json"), "w") as f:
                json.dump(r, f, indent=1)
            print(f"[roofline] {arch}/{shape}/{r['mesh']}: "
                  f"compute={r['compute_s']*1e3:.2f}ms "
                  f"memory={r['memory_s']*1e3:.2f}ms "
                  f"collective={r['collective_s']*1e3:.2f}ms "
                  f"dominant={r['dominant']} "
                  f"frac={r['roofline_fraction']:.2f}", flush=True)


if __name__ == "__main__":
    main()
