"""Time-resolved QoS under the self-paced superstep scheduler (DESIGN.md §9).

The paper argues that a complete picture of best-effort scalability needs QoS
*over time*, not just end-of-run aggregates.  This point measures both halves
of the superstep claim at the sharded torus point:

  * updates/sec with ``superstep_windows`` W=1 (per-window exchange, the
    hidden barrier) vs W>1 (one packed ppermute per superstep) — the
    amortization win, with the analytic collectives-per-window count;
  * the per-interval QoS stream (``core.qos.aggregate_timeseries``) — median
    period/latency/failure/clumpiness per snapshot interval, which must stay
    flat across W.

Run: PYTHONPATH=src:. python benchmarks/bench_qos_timeseries.py \
         --procs 4096 --shards 8 --superstep 1 8 --force-host-devices 8

Writes ``benchmarks/results/BENCH_qos_timeseries.json``.  CI's multidevice
job replays a small point (256 procs, 8 shards) and uploads the JSON.
"""

from __future__ import annotations

import argparse
import os
import time


def bench_point(
    n: int,
    shards: int,
    superstep: int,
    duration: float,
    topology: str = "torus",
    qos_interval: float | None = None,
    warmup: bool = True,
):
    from repro.apps.graphcolor import GraphColorApp, GraphColorConfig
    from repro.core.qos import aggregate_reports, aggregate_timeseries
    from repro.runtime.config import RunConfig
    from repro.runtime.engine import make_engine
    from repro.runtime.simulator import SimConfig
    from repro.runtime.topologies import make_topology

    topo = make_topology(topology, n)
    app = GraphColorApp(GraphColorConfig(n_processes=n, nodes_per_process=1), topology=topo)
    rc = RunConfig(engine="jax", shards=shards, superstep_windows=superstep,
                   qos_interval=qos_interval)
    interval = rc.qos_interval if rc.qos_interval else duration / 12
    cfg = SimConfig(duration=duration, snapshot_warmup=duration / 6, snapshot_interval=interval)
    eng = make_engine(rc, app, cfg)
    if warmup:
        eng.run()  # first run pays jit compilation; the timed run below does not
    t0 = time.perf_counter()
    res = eng.run()
    wall = time.perf_counter() - t0
    updates = sum(res.updates)
    # 2 collectives (payload hop + accept hop) per boundary shard-offset per
    # superstep, amortized over the W windows the superstep advances
    offsets = len(getattr(eng, "_offsets", ()))
    return dict(
        n=n,
        shards=shards,
        superstep_windows=superstep,
        run=rc.to_dict(),
        topology=topo.name,
        duration=duration,
        qos_interval=interval,
        warm=bool(warmup),
        wall_seconds=wall,
        updates=updates,
        updates_per_sec=updates / wall,
        delivery_failure_rate=res.delivery_failure_rate,
        collectives_per_window=2 * offsets / superstep,
        qos=aggregate_reports(res.qos),
        qos_timeseries=aggregate_timeseries(res.qos_by_process.values()),
    )


def run(
    procs=(4096,),
    shards: int = 8,
    supersteps=(1, 8),
    duration: float = 0.02,
    topology: str = "torus",
    qos_interval: float | None = None,
    warmup: bool = True,
):
    from benchmarks.common import emit, save_json

    rows = []
    for n in procs:
        for w in supersteps:
            row = bench_point(n, shards, w, duration, topology, qos_interval, warmup)
            rows.append(row)
            emit(
                f"qos_timeseries/n{n}/s{shards}/w{w}",
                row["wall_seconds"] * 1e6,
                f"upd_per_sec={row['updates_per_sec']:.0f} "
                f"collectives_per_window={row['collectives_per_window']:.2f} "
                f"intervals={len(row['qos_timeseries'])}",
            )
    summary = {}
    for n in procs:
        base = next((r for r in rows if r["n"] == n and r["superstep_windows"] == 1), None)
        best = max(
            (r for r in rows if r["n"] == n and r["superstep_windows"] > 1),
            key=lambda r: r["superstep_windows"],
            default=None,
        )
        if base and best:
            w = best["superstep_windows"]
            med = lambda r: r["qos"]["simstep_period"]["median"]
            summary[f"n{n}"] = dict(
                superstep_windows=w,
                speedup=best["updates_per_sec"] / base["updates_per_sec"],
                collective_cut=base["collectives_per_window"]
                / max(best["collectives_per_window"], 1e-12),
                median_period_drift=abs(med(best) - med(base)) / med(base),
            )
            emit(
                f"qos_timeseries/summary/n{n}",
                0.0,
                f"w{w}_over_w1={summary[f'n{n}']['speedup']:.3f}x "
                f"collective_cut={summary[f'n{n}']['collective_cut']:.1f}x",
            )
    save_json("BENCH_qos_timeseries", {"rows": rows, "summary": summary})
    return rows


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--procs", type=int, nargs="+", default=[4096])
    p.add_argument("--shards", type=int, default=8)
    p.add_argument("--superstep", type=int, nargs="+", default=[1, 8])
    p.add_argument("--duration", type=float, default=0.02)
    p.add_argument("--topology", default="torus")
    p.add_argument("--qos-interval", type=float, default=None)
    p.add_argument(
        "--force-host-devices",
        type=int,
        default=0,
        help="set XLA_FLAGS=--xla_force_host_platform_device_count=N before jax initializes",
    )
    p.add_argument("--no-warmup", action="store_true")
    a = p.parse_args()
    if a.force_host_devices:
        flags = os.environ.get("XLA_FLAGS", "")
        extra = f"--xla_force_host_platform_device_count={a.force_host_devices}"
        os.environ["XLA_FLAGS"] = f"{flags} {extra}".strip()
    run(
        tuple(a.procs),
        a.shards,
        tuple(a.superstep),
        a.duration,
        a.topology,
        a.qos_interval,
        not a.no_warmup,
    )
