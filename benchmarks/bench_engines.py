"""Event vs jax engine: wall-clock and updates/sec at matched configs.

The acceptance gate for the vectorized engine (DESIGN.md §7): the
4096-process torus weak-scaling point must complete >= 10x faster than the
discrete-event engine on the same machine, while total simulated updates
agree within 2%.

Run: PYTHONPATH=src:. python benchmarks/bench_engines.py \
         [--procs 256 1024 4096] [--engines event jax] [--duration 0.05]

Sharded points (DESIGN.md §8) partition the population over a device mesh;
on CPU, force host devices before jax initializes:

    PYTHONPATH=src:. python benchmarks/bench_engines.py \
        --engines jax --procs 65536 --shards 8 --force-host-devices 8 \
        --duration 0.01

(the 65k-process torus is the target scale for the sharded path; the
single-device engine tops out around 16k before window dispatches dominate).

Writes ``benchmarks/results/BENCH_engines.json`` (benchmarks/report.py
conventions: CSV-ish stdout via ``emit``, JSON artifact via ``save_json``).
CI's perf job replays the small 256-process jax point and compares
updates/sec against the checked-in JSON via ``check_regression.py``.
Event-engine points above ``--event-cap`` processes are skipped by default
because they take minutes; pass a larger cap to measure the full matrix.
"""
from __future__ import annotations

import argparse
import os
import time

PROC_COUNTS = (256, 1024, 4096)


def bench_point(engine: str, n: int, duration: float, topology: str,
                shards: int = 1, warmup: bool = False):
    from repro.apps.graphcolor import GraphColorApp, GraphColorConfig
    from repro.runtime.engine import make_engine
    from repro.runtime.simulator import SimConfig
    from repro.runtime.topologies import make_topology

    topo = make_topology(topology, n)
    app = GraphColorApp(GraphColorConfig(n_processes=n, nodes_per_process=1),
                        topology=topo)
    cfg = SimConfig(duration=duration, snapshot_warmup=duration / 6,
                    snapshot_interval=duration / 12)
    kwargs = {"shards": shards} if shards > 1 else {}
    eng = make_engine(engine, app, cfg, **kwargs)
    if warmup and engine == "jax":
        # first run pays jit compilation; the timed run below reuses the
        # cached runner, so updates/sec measures simulation throughput —
        # what the CI regression guard wants to compare across machines
        eng.run()
    t0 = time.perf_counter()
    res = eng.run()
    wall = time.perf_counter() - t0
    updates = sum(res.updates)
    return dict(engine=engine, n=n, shards=shards, topology=topo.name,
                duration=duration, warm=bool(warmup and engine == "jax"),
                wall_seconds=wall, updates=updates,
                updates_per_sec=updates / wall,
                delivery_failure_rate=res.delivery_failure_rate)


def run(proc_counts=PROC_COUNTS, engines=("event", "jax"),
        duration: float = 0.05, topology: str = "torus",
        event_cap: int = 1024, shards: int = 1, warmup: bool = False):
    from benchmarks.common import emit, save_json

    rows = []
    for n in proc_counts:
        for engine in engines:
            if engine == "event" and n > event_cap:
                emit(f"engines/{engine}/n{n}", 0.0,
                     f"skipped (> --event-cap {event_cap}; "
                     "the event engine needs minutes at this scale)")
                continue
            point_shards = shards if engine == "jax" else 1
            row = bench_point(engine, n, duration, topology, point_shards,
                              warmup)
            rows.append(row)
            tag = f"engines/{engine}/n{n}" + (
                f"/s{point_shards}" if point_shards > 1 else "")
            emit(tag, row["wall_seconds"] * 1e6,
                 f"updates={row['updates']} "
                 f"upd_per_sec={row['updates_per_sec']:.0f} "
                 f"fail={row['delivery_failure_rate']:.3f}")
    # speedup summary wherever both engines ran the same point
    summary = {}
    for n in proc_counts:
        ev = next((r for r in rows
                   if r["engine"] == "event" and r["n"] == n), None)
        jx = next((r for r in rows
                   if r["engine"] == "jax" and r["n"] == n), None)
        if ev and jx:
            summary[f"n{n}"] = dict(
                speedup=ev["wall_seconds"] / jx["wall_seconds"],
                updates_agree=abs(jx["updates"] - ev["updates"])
                <= 0.02 * ev["updates"])
            emit(f"engines/speedup/n{n}", 0.0,
                 f"jax_over_event={summary[f'n{n}']['speedup']:.1f}x")
    save_json("BENCH_engines", {"rows": rows, "summary": summary})
    return rows


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--procs", type=int, nargs="+", default=list(PROC_COUNTS))
    p.add_argument("--engines", nargs="+", default=["event", "jax"],
                   choices=["event", "jax"])
    p.add_argument("--duration", type=float, default=0.05)
    p.add_argument("--topology", default="torus")
    p.add_argument("--event-cap", type=int, default=1024,
                   help="skip event-engine points above this process count")
    p.add_argument("--shards", type=int, default=1,
                   help="device-mesh shards for the jax engine points")
    p.add_argument("--force-host-devices", type=int, default=0,
                   help="set XLA_FLAGS=--xla_force_host_platform_device_"
                        "count=N (must run before jax initializes devices)")
    p.add_argument("--warmup", action="store_true",
                   help="pre-run jax points once so the timed run excludes "
                        "jit compilation (used by the CI perf guard)")
    a = p.parse_args()
    if a.force_host_devices:
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count="
            f"{a.force_host_devices}").strip()
    run(tuple(a.procs), tuple(a.engines), a.duration, a.topology,
        a.event_cap, a.shards, a.warmup)
