"""Event vs jax engine: wall-clock and updates/sec at matched configs.

The acceptance gate for the vectorized engine (DESIGN.md §7): the
4096-process torus weak-scaling point must complete >= 10x faster than the
discrete-event engine on the same machine, while total simulated updates
agree within 2%.  The dense duct layout (DESIGN.md §10) adds a second
gate: at the same 4096-process torus point, ``--layout dense`` must reach
>= 1.3x the updates/sec of ``--layout edge`` in the same run, with update
counts agreeing bitwise.

Run: PYTHONPATH=src:. python benchmarks/bench_engines.py \
         [--procs 256 1024 4096] [--engines event jax] [--duration 0.05] \
         [--layout edge dense]

``--layout`` takes one or more layouts; each jax point runs once per
layout (the event engine has no layout axis and runs once).  Sharded
points (DESIGN.md §8) partition the population over a device mesh; on
CPU, force host devices before jax initializes:

    PYTHONPATH=src:. python benchmarks/bench_engines.py \
        --engines jax --procs 65536 --shards 8 --force-host-devices 8 \
        --duration 0.01

(the 65k-process torus is the target scale for the sharded path; the
single-device engine tops out around 16k before window dispatches dominate).

``--scheduler superstep|pipelined`` (with ``--superstep-windows W``)
benches the sharded exchange schedulers (DESIGN.md §9/§12); those runs
also bench the unsharded dense point at ``n / shards`` and record the
equal-per-shard-population throughput ratio in the summary — the overlap
scheduler's acceptance number.  With ``--shards 1``, ``--scheduler
superstep --superstep-windows W`` benches the unsharded W-fused dense
megakernel (DESIGN.md §13) and records its speedup over the per-window
dense engine at the same n (``wfused_over_dense``; gate: >= 1.3x at the
4096-process torus point, update counts bitwise).

Writes ``benchmarks/results/BENCH_engines.json`` (benchmarks/report.py
conventions: CSV-ish stdout via ``emit``, JSON artifact via ``save_json``).
CI's perf job replays the small 256-process jax point per layout and
compares updates/sec against the checked-in JSON via ``check_regression.py``
(points key on engine/n/shards/layout/scheduler).
Event-engine points above ``--event-cap`` processes are skipped by default
because they take minutes; pass a larger cap to measure the full matrix.
"""
from __future__ import annotations

import argparse
import os
import time

PROC_COUNTS = (256, 1024, 4096)


def bench_point(engine: str, n: int, duration: float, topology: str,
                shards: int = 1, warmup: bool = False,
                layout: str = "auto", scheduler: str = "auto",
                superstep_windows: int = 1):
    from repro.apps.graphcolor import GraphColorApp, GraphColorConfig
    from repro.runtime.config import RunConfig
    from repro.runtime.engine import make_engine
    from repro.runtime.simulator import SimConfig
    from repro.runtime.topologies import make_topology

    topo = make_topology(topology, n)
    app = GraphColorApp(GraphColorConfig(n_processes=n, nodes_per_process=1),
                        topology=topo)
    cfg = SimConfig(duration=duration, snapshot_warmup=duration / 6,
                    snapshot_interval=duration / 12)
    # one frozen strategy carrier per point; the event engine has no
    # layout/scheduler axes, so those stay at their defaults there
    is_jax = engine == "jax"
    rc = RunConfig(engine=engine, shards=shards,
                   layout=layout if is_jax else "auto",
                   scheduler=scheduler if is_jax else "auto",
                   superstep_windows=superstep_windows if is_jax else 1)
    eng = make_engine(rc, app, cfg)
    if warmup and engine == "jax":
        # first run pays jit compilation; the timed run below reuses the
        # cached runner, so updates/sec measures simulation throughput —
        # what the CI regression guard wants to compare across machines
        eng.run()
    t0 = time.perf_counter()
    res = eng.run()
    wall = time.perf_counter() - t0
    updates = sum(res.updates)
    resolved = getattr(eng, "layout", "event")
    # the unsharded jax engine has exactly one scheduler; sharded engines
    # record what the registry resolved ("window"/"superstep"/"pipelined")
    sched = (getattr(eng, "scheduler", "window") if engine == "jax"
             else "event")
    return dict(engine=engine, n=n, shards=shards, topology=topo.name,
                layout=layout if engine == "jax" else "event",
                resolved_layout=resolved,
                scheduler=sched, superstep_windows=superstep_windows,
                run=rc.to_dict(),
                duration=duration, warm=bool(warmup and engine == "jax"),
                wall_seconds=wall, updates=updates,
                updates_per_sec=updates / wall,
                delivery_failure_rate=res.delivery_failure_rate)


def run(proc_counts=PROC_COUNTS, engines=("event", "jax"),
        duration: float = 0.05, topology: str = "torus",
        event_cap: int = 1024, shards: int = 1, warmup: bool = False,
        layouts=("auto",), scheduler: str = "auto",
        superstep_windows: int = 1):
    from benchmarks.common import emit, save_json

    rows = []
    for n in proc_counts:
        for engine in engines:
            if engine == "event" and n > event_cap:
                emit(f"engines/{engine}/n{n}", 0.0,
                     f"skipped (> --event-cap {event_cap}; "
                     "the event engine needs minutes at this scale)")
                continue
            point_shards = shards if engine == "jax" else 1
            point_layouts = layouts if engine == "jax" else ("event",)
            for layout in point_layouts:
                row = bench_point(engine, n, duration, topology,
                                  point_shards, warmup, layout,
                                  scheduler, superstep_windows)
                rows.append(row)
                tag = f"engines/{engine}/n{n}" + (
                    f"/s{point_shards}" if point_shards > 1 else "") + (
                    f"/{layout}" if engine == "jax" else "") + (
                    f"/{row['scheduler']}W{superstep_windows}"
                    if engine == "jax" and row["scheduler"] != "window"
                    else "")
                emit(tag, row["wall_seconds"] * 1e6,
                     f"updates={row['updates']} "
                     f"upd_per_sec={row['updates_per_sec']:.0f} "
                     f"fail={row['delivery_failure_rate']:.3f}")
    summary = {}
    # summary keys stay bare ("n256_...") on the torus for continuity with
    # older artifacts; other topologies prefix their name so a merged
    # multi-topology JSON keeps one entry per (topology, n) point
    pfx = "" if topology == "torus" else f"{topology}_"
    if scheduler == "superstep" and shards == 1 and superstep_windows > 1 \
            and "jax" in engines:
        # W-fused megakernel acceptance point (DESIGN.md §13): the fused
        # superstep engine vs the per-window dense engine at the same n —
        # same trajectory bitwise, so the ratio is pure execution-strategy
        # speedup (gate: >= 1.3x at the 4096-proc torus point)
        for n in proc_counts:
            ref = bench_point("jax", n, duration, topology, 1, warmup,
                              "dense")
            rows.append(ref)
            emit(f"engines/jax/n{n}/dense",
                 ref["wall_seconds"] * 1e6,
                 f"updates={ref['updates']} "
                 f"upd_per_sec={ref['updates_per_sec']:.0f} "
                 f"(per-window dense reference)")
            fz = next((r for r in rows if r["engine"] == "jax"
                       and r["n"] == n and r["shards"] == 1
                       and r["scheduler"] == "superstep"
                       and r["superstep_windows"] == superstep_windows),
                      None)
            if fz:
                key = f"{pfx}n{n}_wfused_over_dense"
                summary[key] = dict(
                    speedup=fz["updates_per_sec"] / ref["updates_per_sec"],
                    superstep_windows=superstep_windows,
                    updates_agree=fz["updates"] == ref["updates"])
                emit(f"engines/wfused_over_dense/n{n}", 0.0,
                     f"speedup={summary[key]['speedup']:.2f}x "
                     f"(W={superstep_windows}) updates_bitwise="
                     f"{summary[key]['updates_agree']}")
    if scheduler in ("superstep", "pipelined") and shards > 1 \
            and "jax" in engines:
        # overlap-scheduler acceptance point (DESIGN.md §12): compare the
        # sharded run against the unsharded dense engine at EQUAL
        # PER-SHARD POPULATION (n / shards).  On real parallel devices the
        # sharded run covers `shards` x the population in the same wall
        # clock; on a single-core host the shards timeshare one CPU, so
        # the ratio's ceiling is ~1.0 minus dispatch overhead — record the
        # measured ratio honestly either way.
        for n in proc_counts:
            ref_n = n // shards
            ref = bench_point("jax", ref_n, duration, topology, 1, warmup,
                              "dense")
            rows.append(ref)
            emit(f"engines/jax/n{ref_n}/dense",
                 ref["wall_seconds"] * 1e6,
                 f"updates={ref['updates']} "
                 f"upd_per_sec={ref['updates_per_sec']:.0f} "
                 f"(per-shard-population reference)")
            pz = next((r for r in rows if r["engine"] == "jax"
                       and r["n"] == n and r["shards"] == shards), None)
            if pz:
                key = f"{pfx}n{n}_{scheduler}_vs_per_shard"
                summary[key] = dict(
                    ratio=pz["updates_per_sec"] / ref["updates_per_sec"],
                    per_shard_n=ref_n, shards=shards,
                    superstep_windows=superstep_windows)
                emit(f"engines/{scheduler}_vs_per_shard/n{n}", 0.0,
                     f"ratio={summary[key]['ratio']:.2f}x vs unsharded "
                     f"dense n={ref_n} (W={superstep_windows})")
    for n in proc_counts:
        # event-vs-jax speedup wherever both engines ran the same point;
        # with several layouts benched, the jax side is chosen by a fixed
        # preference (auto, then edge, then dense) — independent of the
        # --layout CLI order — and recorded in the summary
        ev = next((r for r in rows
                   if r["engine"] == "event" and r["n"] == n), None)
        jx = next((r for pick in ("auto", "edge", "dense") for r in rows
                   if r["engine"] == "jax" and r["n"] == n
                   and r["layout"] == pick), None)
        if ev and jx:
            summary[f"{pfx}n{n}"] = dict(
                speedup=ev["wall_seconds"] / jx["wall_seconds"],
                jax_layout=jx["layout"],
                updates_agree=abs(jx["updates"] - ev["updates"])
                <= 0.02 * ev["updates"])
            emit(f"engines/speedup/n{n}", 0.0,
                 f"jax_over_event={summary[f'{pfx}n{n}']['speedup']:.1f}x "
                 f"(jax layout {jx['layout']})")
        # dense-vs-edge layout speedup in the same run (DESIGN.md §10 gate:
        # >= 1.3x at the 4096-proc torus point, update counts bitwise)
        de = next((r for r in rows if r["engine"] == "jax"
                   and r["n"] == n and r["layout"] == "dense"), None)
        ed = next((r for r in rows if r["engine"] == "jax"
                   and r["n"] == n and r["layout"] == "edge"), None)
        if de and ed:
            key = f"{pfx}n{n}_dense_over_edge"
            summary[key] = dict(
                speedup=de["updates_per_sec"] / ed["updates_per_sec"],
                updates_agree=de["updates"] == ed["updates"])
            emit(f"engines/layout_speedup/n{n}", 0.0,
                 f"dense_over_edge={summary[key]['speedup']:.2f}x "
                 f"updates_bitwise={summary[key]['updates_agree']}")
    save_json("BENCH_engines", {"rows": rows, "summary": summary})
    return rows


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--procs", type=int, nargs="+", default=list(PROC_COUNTS))
    p.add_argument("--engines", nargs="+", default=["event", "jax"],
                   choices=["event", "jax"])
    p.add_argument("--duration", type=float, default=0.05)
    p.add_argument("--topology", default="torus")
    p.add_argument("--event-cap", type=int, default=1024,
                   help="skip event-engine points above this process count")
    p.add_argument("--shards", type=int, default=1,
                   help="device-mesh shards for the jax engine points")
    p.add_argument("--layout", nargs="+", default=["auto"],
                   choices=["auto", "dense", "edge"],
                   help="duct layouts to bench per jax point (DESIGN.md "
                        "§10); pass 'edge dense' to measure the dense-"
                        "layout speedup in one run")
    p.add_argument("--force-host-devices", type=int, default=0,
                   help="set XLA_FLAGS=--xla_force_host_platform_device_"
                        "count=N (must run before jax initializes devices)")
    p.add_argument("--scheduler", default="auto",
                   choices=["auto", "window", "superstep", "pipelined"],
                   help="exchange cadence for sharded jax points "
                        "(DESIGN.md §11/§12); superstep/pipelined also "
                        "bench the unsharded dense point at n/shards and "
                        "record the equal-per-shard-population ratio in "
                        "the summary")
    p.add_argument("--superstep-windows", type=int, default=1,
                   help="windows per superstep: with --shards > 1 the "
                        "sharded exchange schedulers, with --shards 1 "
                        "and --scheduler superstep the unsharded W-fused "
                        "dense megakernel (DESIGN.md §13)")
    p.add_argument("--warmup", action="store_true",
                   help="pre-run jax points once so the timed run excludes "
                        "jit compilation (used by the CI perf guard)")
    a = p.parse_args()
    if a.force_host_devices:
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count="
            f"{a.force_host_devices}").strip()
    run(tuple(a.procs), tuple(a.engines), a.duration, a.topology,
        a.event_cap, a.shards, a.warmup, tuple(a.layout),
        a.scheduler, a.superstep_windows)
