"""HLO inspection helpers for the perf hillclimb: attribute collective bytes
to model components via op metadata, and diff before/after changes.

Run: PYTHONPATH=src python -m benchmarks.hlo_tools --arch X --shape Y
(sets XLA_FLAGS itself; run as its own process).
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402
import argparse
import re
from collections import defaultdict

_COLL_LINE = re.compile(
    r"=\s*(?P<result>\([^)]*\)|\S+)\s+"
    r"(?P<kind>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\((?P<operands>[^)]*)\)")
_SHAPE_RE = re.compile(
    r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)\[([0-9,]*)\]")
_META_RE = re.compile(r'op_name="([^"]+)"')
_DTYPE_BYTES = {"f64": 8, "s64": 8, "f32": 4, "s32": 4, "u32": 4, "bf16": 2,
                "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}


def _bytes(segment):
    total = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_GROUPS_IOTA = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([0-9,{} ]+)\}\}")


def group_spans_pods(line: str, pod_stride: int = 256) -> bool:
    """True if the collective's replica groups contain devices from more
    than one pod (device id // pod_stride differs within a group).

    Reconstructs groups from the HLO iota notation
    ``[G,S]<=[dims]T(perm)`` (or an explicit group list).
    """
    import numpy as np
    m = _GROUPS_IOTA.search(line)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(p) for p in m.group(4).split(",")]
            ids = ids.transpose(perm)
        groups = ids.reshape(g, s)
        pods = groups // pod_stride
        return bool((pods != pods[:, :1]).any())
    m = _GROUPS_LIST.search(line)
    if m:
        for grp in m.group(1).split("},{"):
            ids = [int(x) for x in grp.replace("{", "").replace("}", "").split(",")
                   if x.strip()]
            if len({i // pod_stride for i in ids}) > 1:
                return True
        return False
    return False


def _bucket(op_name: str) -> str:
    for key in ("moe", "router", "mamba", "mlstm", "slstm", "attention",
                "bkgqs", "bqkgd", "bskd", "flash", "unembed", "logsumexp",
                "embed", "rms", "adamw", "mul", "transpose", "checkpoint"):
        if key in op_name.lower():
            return key
    parts = op_name.split("/")
    return parts[-1][:30] if parts else "?"


def attribute_collectives(hlo_text: str, top: int = 25):
    """(kind, source-bucket) -> bytes, sorted desc."""
    agg = defaultdict(float)
    for line in hlo_text.splitlines():
        m = _COLL_LINE.search(line)
        if not m:
            continue
        payload = max(_bytes(m.group("result")), _bytes(m.group("operands")))
        meta = _META_RE.search(line)
        src = _bucket(meta.group(1)) if meta else "?"
        full = (_META_RE.search(line).group(1)[-80:] if meta else "?")
        agg[(m.group("kind"), src, full)] += payload
    return sorted(agg.items(), key=lambda kv: -kv[1])[:top]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", type=int, default=0)
    ap.add_argument("--compressor", default=None)
    ap.add_argument("--periods", type=int, default=1,
                    help="scan periods to compile (small = fast)")
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--extra", default="",
                    help="cfg overrides k=v,k=v (ints/floats/bools)")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.launch import dryrun as dr
    from repro.models.transformer import block_specs

    cfg = get_config(args.arch)
    period = len(block_specs(cfg))
    extra = {"num_layers": period * args.periods}
    for kv in filter(None, args.extra.split(",")):
        k, v = kv.split("=")
        extra[k] = {"true": True, "false": False}.get(
            v.lower(), int(v) if v.lstrip("-").isdigit() else v)

    lowered, skip = dr.build_lowered(args.arch, args.shape, args.multi_pod,
                                     args.mode, args.compressor,
                                     extra_cfg=extra)
    if skip:
        print("skip:", skip)
        return
    compiled = lowered.compile()
    text = compiled.as_text()
    total = 0.0
    print(f"# {args.arch}/{args.shape} periods={args.periods} "
          f"mode={args.mode} — top collective sources (per-chip bytes)")
    for (kind, src, full), b in attribute_collectives(text, args.top):
        total += b
        print(f"{b/1e6:10.1f} MB  {kind:20s} {src:12s} {full}")
    print(f"{total/1e6:10.1f} MB  TOTAL (top {args.top})")


if __name__ == "__main__":
    main()
