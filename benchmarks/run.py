"""Benchmark harness — one section per paper table/figure + roofline summary.

  modes         — paper Figs 2/3 (update rate + solution quality vs mode/scale)
  qos           — paper §III-C/D (QoS vs compute intensity, placement, buffers)
  weak_scaling  — paper §III-F (QoS stability 16->64->256 procs)
  faulty        — paper §III-G (faulty node, stable medians)
  kernels       — Pallas kernel oracle microbench (CPU wall time)
  roofline      — summary table from dry-run artifacts (if generated)

CSV convention: ``name,us_per_call,derived``.
Run: PYTHONPATH=src python -m benchmarks.run [--quick] [--only SECTION]
"""
from __future__ import annotations

import argparse
import json
import os
import time


def bench_kernels():
    import jax
    from repro.kernels.decode_attention import decode_attention_ref
    from repro.kernels.flash_attention import flash_attention_ref
    from repro.kernels.quantize import quantize_ref
    from repro.kernels.topk_compress import topk_compress_ref
    from benchmarks.common import emit

    key = jax.random.PRNGKey(0)

    def timeit(fn, *args, n=5):
        jax.tree.flatten(fn(*args))[0][0].block_until_ready()
        t0 = time.perf_counter()
        for _ in range(n):
            r = fn(*args)
            jax.tree.flatten(r)[0][0].block_until_ready()
        return (time.perf_counter() - t0) / n * 1e6

    q = jax.random.normal(key, (4, 2, 512, 64))
    k = jax.random.normal(key, (4, 512, 64))
    fa = jax.jit(lambda q, k: flash_attention_ref(q, k, k))
    emit("kernels/flash_attention_ref/cpu", timeit(fa, q, k),
         "S=512 hd=64 (oracle wall time; TPU kernel validated in tests)")

    qd = jax.random.normal(key, (8, 4, 64))
    kd = jax.random.normal(key, (8, 4096, 64))
    da = jax.jit(lambda q, k: decode_attention_ref(q, k, k))
    emit("kernels/decode_attention_ref/cpu", timeit(da, qd, kd), "S=4096")

    x = jax.random.normal(key, (64, 1024))
    tk = jax.jit(lambda x: topk_compress_ref(x, 16))
    emit("kernels/topk_ref/cpu", timeit(tk, x), "64x1024 k=16")
    qz = jax.jit(quantize_ref)
    emit("kernels/quantize_ref/cpu", timeit(qz, x), "64x1024 int8")

    from repro.kernels.mlstm_attention import mlstm_attention_ref
    from repro.kernels.mamba_scan import mamba_scan_ref
    import jax.numpy as jnp
    qm = jax.random.normal(key, (4, 256, 64))
    F = jnp.cumsum(jax.nn.log_sigmoid(jax.random.normal(key, (4, 256)) + 3), 1)
    I = jax.random.normal(key, (4, 256)) * 0.5
    ml = jax.jit(lambda q, F, I: mlstm_attention_ref(q, q * 0.125, q, F, I))
    emit("kernels/mlstm_ref/cpu", timeit(ml, qm, F, I), "S=256 hd=64")
    xs = jax.random.normal(key, (2, 128, 64)) * 0.5
    dts = jax.nn.softplus(jax.random.normal(key, (2, 128, 64)) - 1)
    Bs = jax.random.normal(key, (2, 128, 8)) * 0.5
    A = -jnp.exp(jax.random.normal(key, (64, 8)) * 0.3)
    ms = jax.jit(lambda x, dt, B, A: mamba_scan_ref(x, dt, B, B, A))
    emit("kernels/mamba_scan_ref/cpu", timeit(ms, xs, dts, Bs, A),
         "S=128 di=64 N=8")
    return []


def bench_roofline_summary():
    from benchmarks.common import emit
    rdir = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "results", "roofline")
    if not os.path.isdir(rdir):
        print("# roofline artifacts not found — run benchmarks/roofline.py")
        return []
    rows = []
    for f in sorted(os.listdir(rdir)):
        if not f.endswith(".json"):
            continue
        r = json.load(open(os.path.join(rdir, f)))
        rows.append(r)
        tag = f"/{r['tag']}" if r.get("tag") else ""
        emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}{tag}",
             max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
             f"dominant={r['dominant']} frac={r['roofline_fraction']:.2f} "
             f"c/m/x_ms={r['compute_s']*1e3:.1f}/{r['memory_s']*1e3:.1f}/"
             f"{r['collective_s']*1e3:.1f}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller proc counts / fewer replicates")
    ap.add_argument("--only", default=None,
                    help="modes|qos|weak|faulty|kernels|roofline")
    args = ap.parse_args()

    sections = {}
    if args.only in (None, "modes"):
        from benchmarks import bench_modes
        if args.quick:
            rows = (bench_modes.run_graphcolor(replicates=1, proc_counts=(1, 16))
                    + bench_modes.run_evo(replicates=1, proc_counts=(1, 16)))
            sections["modes"] = {"rows": rows,
                                 "summary": bench_modes.summarize(rows)}
        else:
            sections["modes"] = bench_modes.run()
    if args.only in (None, "qos"):
        from benchmarks import bench_qos
        sections["qos"] = bench_qos.run()
    if args.only in (None, "weak"):
        from benchmarks import bench_weak_scaling
        counts = (16, 64) if args.quick else (16, 64, 256)
        sections["weak"] = bench_weak_scaling.run(proc_counts=counts)
    if args.only in (None, "faulty"):
        from benchmarks import bench_faulty
        sections["faulty"] = bench_faulty.run(n=64 if args.quick else 256)
    if args.only in (None, "kernels"):
        sections["kernels"] = bench_kernels()
    if args.only in (None, "roofline"):
        sections["roofline"] = bench_roofline_summary()
    print("# benchmark harness complete:", ", ".join(sections))


if __name__ == "__main__":
    main()
