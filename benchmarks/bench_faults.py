"""Quarantine A/B bench: barrier release policy under a crashed clique.

The robustness acceptance experiment for DESIGN.md §14: every arm runs the
SAME workload (graph coloring on a torus, matched seeds) with every process
on host ``n_nodes // 2`` crashed from t=0 (``runtime.faults.crashed_host``
— the topology is untouched, so the clique's survivors keep sending into
dead ducts).  Only the barrier release policy differs:

  ``barrier_plain``       BARRIER_EVERY_STEP with ``barrier_timeout=0``.
                          The cohort waits for arrivals that never come:
                          the swarm stalls after its first step and the
                          engine's window budget bounds the run, so the
                          arm terminates with near-zero throughput — the
                          failure mode the paper's best-effort design
                          exists to avoid.
  ``barrier_quarantine``  BARRIER_EVERY_STEP with ``barrier_timeout > 0``.
                          Once the crashed clique's (never-coming, +inf)
                          arrivals lag the cohort front by the timeout,
                          releases exclude it and the survivors keep
                          stepping in lockstep — degraded, not dead.
  ``best_effort``         No barrier at all: the throughput upper bound.

Per arm, ``--replicates`` seeds run as one vmapped dispatch; the recorded
``updates_per_sec`` (with a bootstrap CI over replicates) feeds the CI
regression gate — ``check_regression.py`` keys rows by the arm name in the
``mode`` field, so all three arms share the (engine, n, scheduler) point
without colliding.  Drop attribution (``dropped_dead`` vs ``dropped_loss``
vs capacity) rides along per row, and the summary pins the headline
ordering::

    barrier_plain  <  barrier_quarantine  <  best_effort   (updates/sec)

Run: PYTHONPATH=src:. python benchmarks/bench_faults.py \
         [--procs 64] [--duration 0.02] [--replicates 5] \
         [--barrier-timeout 1.5e-3] [--warmup]

Writes ``benchmarks/results/BENCH_faults.json``.  CI replays the n=64 jax
arms and gates ``updates_per_sec`` against the checked-in baseline.
"""
from __future__ import annotations

import argparse
import os
import time

#: default quarantine timeout (virtual seconds): an order of magnitude
#: above the worst healthy straggle under the default jitter model
#: (stall_factor x jitter on a 15us step is ~0.2ms), an order of
#: magnitude below the 20ms bench horizon — only the crashed clique's
#: +inf arrivals ever lag the cohort front this far
DEFAULT_TIMEOUT = 1.5e-3


def _bootstrap_ci(vals, n_boot: int = 1000, q=(2.5, 97.5), seed: int = 0):
    """Percentile bootstrap CI for the mean of ``vals``."""
    import numpy as np

    arr = np.asarray(vals, float)
    if arr.size < 2:
        v = float(arr.mean()) if arr.size else 0.0
        return v, v
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, arr.size, size=(n_boot, arr.size))
    means = arr[idx].mean(axis=1)
    lo, hi = np.percentile(means, q)
    return float(lo), float(hi)


def bench_arm(engine: str, arm: str, mode, barrier_timeout: float, n: int,
              duration: float, topology: str, shards: int, replicates: int,
              seed: int, warmup: bool):
    from repro.apps.graphcolor import GraphColorApp, GraphColorConfig
    from repro.core.qos import median_of_process_medians
    from repro.runtime.config import RunConfig
    from repro.runtime.engine import run_replicates
    from repro.runtime.faults import crashed_host
    from repro.runtime.simulator import SimConfig
    from repro.runtime.topologies import make_topology

    topo = make_topology(topology, n)
    host = topo.n_nodes // 2
    victims = sorted(set(topo.host_pids(host)))
    faults = crashed_host(topo, host)

    def make_app(s: int):
        return GraphColorApp(
            GraphColorConfig(n_processes=n, nodes_per_process=1, seed=s),
            topology=topo)

    cfg = SimConfig(mode=mode, duration=duration,
                    snapshot_warmup=duration / 6,
                    snapshot_interval=duration / 12, seed=seed,
                    barrier_timeout=barrier_timeout)
    rc = RunConfig(engine=engine, shards=shards, replicates=replicates)
    if warmup and engine == "jax":
        run_replicates(rc, make_app, cfg, faults=faults)
    t0 = time.perf_counter()
    results = run_replicates(rc, make_app, cfg, faults=faults)
    wall = time.perf_counter() - t0
    per_rep_rate = [sum(r.updates) / (wall / len(results)) for r in results]
    updates = sum(sum(r.updates) for r in results)
    lo, hi = _bootstrap_ci(per_rep_rate)
    # QoS medians over the SURVIVORS only: crashed processes take no
    # snapshots, so their (empty) report lists would poison the pool.
    # The medians can still be None — the barrier_plain arm stalls before
    # its first snapshot, which is exactly the story the row tells
    survivors = [p for p in range(n) if p not in victims]
    all_qos = {}
    for res in results:
        for pid in survivors:
            all_qos.setdefault(pid, []).extend(res.qos_by_process[pid])
    return dict(
        engine=engine, n=n, shards=shards, topology=topo.name,
        scheduler="window", superstep_windows=1,
        mode=arm, barrier_timeout=barrier_timeout,
        crashed_host=host, crashed_pids=len(victims),
        duration=duration, replicates=replicates,
        warm=bool(warmup and engine == "jax"),
        wall_seconds=wall, updates=updates,
        updates_per_sec=updates / wall,
        updates_per_sec_ci=[lo, hi],
        dropped=sum(r.dropped for r in results),
        dropped_dead=sum(r.dropped_dead for r in results),
        dropped_loss=sum(r.dropped_loss for r in results),
        simstep_period_p50=median_of_process_medians(
            all_qos, "simstep_period"),
        simstep_latency_p50=median_of_process_medians(
            all_qos, "simstep_latency"),
        delivery_failure_p50=median_of_process_medians(
            all_qos, "delivery_failure_rate"),
    )


def run(n: int = 64, duration: float = 0.02, topology: str = "torus",
        replicates: int = 5, barrier_timeout: float = DEFAULT_TIMEOUT,
        shards: int = 1, seed: int = 0, warmup: bool = False,
        engine: str = "jax"):
    from benchmarks.common import emit, save_json
    from repro.core.modes import AsyncMode

    arms = [
        ("barrier_plain", AsyncMode.BARRIER_EVERY_STEP, 0.0),
        ("barrier_quarantine", AsyncMode.BARRIER_EVERY_STEP,
         barrier_timeout),
        ("best_effort", AsyncMode.BEST_EFFORT, 0.0),
    ]
    rows = []
    for arm, mode, tau in arms:
        row = bench_arm(engine, arm, mode, tau, n, duration, topology,
                        shards, replicates, seed, warmup)
        rows.append(row)
        fail = row["delivery_failure_p50"]
        emit(f"faults/{arm}/n{n}", row["wall_seconds"] * 1e6,
             f"upd_per_sec={row['updates_per_sec']:.0f} "
             f"ci=[{row['updates_per_sec_ci'][0]:.0f},"
             f"{row['updates_per_sec_ci'][1]:.0f}] "
             f"dropped_dead={row['dropped_dead']} "
             f"fail_p50={'stalled' if fail is None else f'{fail:.3f}'}")
    by = {r["mode"]: r for r in rows}
    plain, quar, be = (by["barrier_plain"], by["barrier_quarantine"],
                       by["best_effort"])
    summary = {
        f"n{n}_quarantine_over_plain":
            quar["updates_per_sec"] / max(plain["updates_per_sec"], 1e-9),
        f"n{n}_best_effort_over_quarantine":
            be["updates_per_sec"] / max(quar["updates_per_sec"], 1e-9),
        f"n{n}_ordering_holds": bool(
            plain["updates_per_sec"] < quar["updates_per_sec"]
            < be["updates_per_sec"]),
    }
    emit(f"faults/ab/n{n}", 0.0,
         f"quarantine_over_plain="
         f"{summary[f'n{n}_quarantine_over_plain']:.1f}x "
         f"best_effort_over_quarantine="
         f"{summary[f'n{n}_best_effort_over_quarantine']:.2f}x "
         f"ordering_holds={summary[f'n{n}_ordering_holds']}")
    save_json("BENCH_faults", {"rows": rows, "summary": summary})
    if not summary[f"n{n}_ordering_holds"]:
        raise SystemExit(
            "bench_faults: throughput ordering violated — expected "
            f"barrier_plain ({plain['updates_per_sec']:.0f}) < "
            f"barrier_quarantine ({quar['updates_per_sec']:.0f}) < "
            f"best_effort ({be['updates_per_sec']:.0f}) updates/sec")
    return rows


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--procs", type=int, default=64)
    p.add_argument("--duration", type=float, default=0.02)
    p.add_argument("--topology", default="torus")
    p.add_argument("--replicates", type=int, default=5)
    p.add_argument("--barrier-timeout", type=float, default=DEFAULT_TIMEOUT)
    p.add_argument("--shards", type=int, default=1)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--engine", default="jax", choices=["event", "jax"])
    p.add_argument("--force-host-devices", type=int, default=0,
                   help="set XLA_FLAGS=--xla_force_host_platform_device_"
                        "count=N (must run before jax initializes devices)")
    p.add_argument("--warmup", action="store_true",
                   help="pre-run each arm once so the timed run excludes "
                        "jit compilation (used by the CI perf guard)")
    a = p.parse_args()
    if a.force_host_devices:
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count="
            f"{a.force_host_devices}").strip()
    run(a.procs, a.duration, a.topology, a.replicates, a.barrier_timeout,
        a.shards, a.seed, a.warmup, a.engine)
