"""Paper §III-C/D/E analogue: QoS vs compute intensity, intranode vs
internode placement, and buffer sizing (threading-vs-processing analogue).

Graph coloring with ONE simulation element per CPU — maximal communication
intensity — so QoS is maximally sensitive to the manipulations.
"""
from __future__ import annotations

import numpy as np

from repro.apps.graphcolor import GraphColorApp, GraphColorConfig
from repro.core.modes import AsyncMode
from repro.runtime.simulator import SimConfig, Simulator

from benchmarks.common import emit, save_json

WORK_UNITS = (0, 64, 4096, 262144, 16777216)


def _qos_stats(res):
    stats = {}
    for field in ("simstep_period", "simstep_latency", "walltime_latency",
                  "delivery_failure_rate", "delivery_clumpiness"):
        vals = [getattr(q, field) for q in res.qos]
        stats[field] = {"mean": float(np.mean(vals)) if vals else None,
                        "median": float(np.median(vals)) if vals else None}
    return stats


def _run(work_units=0, latency=550e-6, buffer_capacity=64, duration=None,
         n=2, seed=0):
    app = GraphColorApp(GraphColorConfig(n_processes=n, nodes_per_process=1,
                                         seed=seed))
    step = 15e-6 + work_units * 35e-9
    duration = duration or max(0.3, 300 * step)
    cfg = SimConfig(mode=AsyncMode.BEST_EFFORT, duration=duration,
                    base_compute=15e-6, work_units=work_units,
                    base_latency=latency, buffer_capacity=buffer_capacity,
                    snapshot_warmup=duration * 0.2,
                    snapshot_interval=duration * 0.15, seed=seed)
    return Simulator(app, cfg).run()


def run_compute_sweep():
    """More compute per update -> longer period, fewer simsteps of latency,
    lower clumpiness (paper §III-C)."""
    rows = []
    for w in WORK_UNITS:
        res = _run(work_units=w)
        s = _qos_stats(res)
        rows.append(dict(treatment="work_units", value=w, **s))
        emit(f"qos/work{w}", s["simstep_period"]["median"] * 1e6,
             f"lat_steps={s['simstep_latency']['median']:.1f} "
             f"clump={s['delivery_clumpiness']['median']:.2f} "
             f"fail={s['delivery_failure_rate']['median']:.3f}")
    return rows


def run_placement():
    """Intranode (~7us link) vs internode (~550us link), paper §III-D."""
    rows = []
    for name, lat in (("intranode", 7e-6), ("internode", 550e-6)):
        res = _run(latency=lat)
        s = _qos_stats(res)
        rows.append(dict(treatment="placement", value=name, **s))
        emit(f"qos/{name}", s["simstep_period"]["median"] * 1e6,
             f"wall_lat_us={s['walltime_latency']['median']*1e6:.1f} "
             f"lat_steps={s['simstep_latency']['median']:.2f} "
             f"clump={s['delivery_clumpiness']['median']:.2f}")
    return rows


def run_buffer_sizing():
    """Small send buffers drop messages under pressure (the paper's
    threading-vs-processing / buffer-stability observation)."""
    rows = []
    for cap in (2, 64):
        res = _run(buffer_capacity=cap, latency=550e-6)
        s = _qos_stats(res)
        s["total_drop_rate"] = res.delivery_failure_rate
        rows.append(dict(treatment="buffer", value=cap, **s))
        emit(f"qos/buffer{cap}", s["simstep_period"]["median"] * 1e6,
             f"fail={res.delivery_failure_rate:.3f}")
    return rows


def run():
    rows = run_compute_sweep() + run_placement() + run_buffer_sizing()
    save_json("bench_qos", rows)
    return rows


if __name__ == "__main__":
    run()
