"""Paper Figs 2/3 analogue: update rate + solution quality across
asynchronicity modes and CPU counts (claims C1/C2).

Weak scaling: problem size per process held constant.  Graph coloring =
communication-intensive; digital evolution = computation-intensive.
"""
from __future__ import annotations

import numpy as np

from repro.apps.evo import EvoApp, EvoConfig
from repro.apps.graphcolor import GraphColorApp, GraphColorConfig
from repro.core.modes import AsyncMode
from repro.runtime.simulator import SimConfig, Simulator

from benchmarks.common import emit, save_json

PROC_COUNTS = (1, 4, 16, 64)
MODES = tuple(AsyncMode)
REPLICATES = 2


def run_graphcolor(replicates=REPLICATES, proc_counts=PROC_COUNTS):
    rows = []
    for n in proc_counts:
        for mode in MODES:
            rates, quals = [], []
            for rep in range(replicates):
                app = GraphColorApp(GraphColorConfig(
                    n_processes=n, nodes_per_process=256, seed=rep))
                cfg = SimConfig(mode=mode, duration=0.03, seed=rep,
                                base_compute=15e-6, base_latency=100e-6,
                                rolling_quantum=0.01, fixed_interval=0.01)
                res = Simulator(app, cfg).run()
                rates.append(res.update_rate_per_cpu)
                quals.append(res.quality)
            row = dict(bench="graphcolor", n=n, mode=int(mode),
                       rate_per_cpu=float(np.mean(rates)),
                       conflicts=float(np.mean(quals)))
            rows.append(row)
            emit(f"modes/graphcolor/n{n}/mode{int(mode)}",
                 1e6 / row["rate_per_cpu"],
                 f"rate={row['rate_per_cpu']:.0f}/s conflicts={row['conflicts']:.0f}")
    return rows


def run_evo(replicates=REPLICATES, proc_counts=PROC_COUNTS):
    rows = []
    for n in proc_counts:
        for mode in MODES:
            rates, quals = [], []
            for rep in range(replicates):
                app = EvoApp(EvoConfig(n_processes=n, cells_per_process=400,
                                       exec_rounds=4, seed=rep))
                cfg = SimConfig(mode=mode, duration=0.1, seed=rep,
                                base_compute=1e-3, base_latency=100e-6,
                                rolling_quantum=0.1, fixed_interval=0.05,
                                stall_prob=0.02, stall_factor=6.0)
                res = Simulator(app, cfg).run()
                rates.append(res.update_rate_per_cpu)
                quals.append(res.quality)
            row = dict(bench="evo", n=n, mode=int(mode),
                       rate_per_cpu=float(np.mean(rates)),
                       fitness=float(np.mean(quals)))
            rows.append(row)
            emit(f"modes/evo/n{n}/mode{int(mode)}",
                 1e6 / row["rate_per_cpu"],
                 f"rate={row['rate_per_cpu']:.1f}/s fitness={row['fitness']:.3f}")
    return rows


def summarize(rows):
    """Paper headline numbers: speedup mode3/mode0 and retention vs n=1."""
    out = {}
    for bench in ("graphcolor", "evo"):
        sub = [r for r in rows if r["bench"] == bench]
        if not sub:
            continue
        nmax = max(r["n"] for r in sub)
        r0 = next(r for r in sub if r["n"] == nmax and r["mode"] == 0)
        r3 = next(r for r in sub if r["n"] == nmax and r["mode"] == 3)
        r1p = next(r for r in sub if r["n"] == 1 and r["mode"] == 3)
        out[bench] = {
            "n": nmax,
            "speedup_mode3_vs_mode0": r3["rate_per_cpu"] / r0["rate_per_cpu"],
            "retention_vs_single": r3["rate_per_cpu"] / r1p["rate_per_cpu"],
        }
    return out


def run():
    rows = run_graphcolor() + run_evo()
    summary = summarize(rows)
    save_json("bench_modes", {"rows": rows, "summary": summary})
    for bench, s in summary.items():
        emit(f"modes/{bench}/summary", 0.0,
             f"speedup_x={s['speedup_mode3_vs_mode0']:.1f} "
             f"retention={s['retention_vs_single']:.2f} at n={s['n']}")
    return rows


if __name__ == "__main__":
    run()
