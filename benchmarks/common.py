"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import json
import os
import time

RESULTS_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "results")


def save_json(name: str, rows):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1, default=float)
    return path


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.3f},{derived}")


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0
