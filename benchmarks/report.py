"""Generate markdown tables for EXPERIMENTS.md from dry-run / roofline
artifacts.

Run: PYTHONPATH=src python -m benchmarks.report [--section dryrun|roofline]
"""
from __future__ import annotations

import argparse
import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))


def _load(dirname):
    d = os.path.join(HERE, "results", dirname)
    out = []
    if not os.path.isdir(d):
        return out
    for f in sorted(os.listdir(d)):
        if f.endswith(".json"):
            out.append(json.load(open(os.path.join(d, f))))
    return out


def _fmt(x, unit=""):
    if x is None:
        return "—"
    if x >= 1e12:
        return f"{x/1e12:.2f}T{unit}"
    if x >= 1e9:
        return f"{x/1e9:.2f}G{unit}"
    if x >= 1e6:
        return f"{x/1e6:.2f}M{unit}"
    if x >= 1e3:
        return f"{x/1e3:.2f}k{unit}"
    return f"{x:.2f}{unit}"


def dryrun_table():
    rows = _load("dryrun")
    base = [r for r in rows if not r.get("tag")]
    print("| arch | shape | mesh | status | HLO GFLOP/chip* "
          "| coll bytes/chip | args GB/chip | lower+compile s |")
    print("|---|---|---|---|---|---|---|---|")
    for r in base:
        if r["status"] == "ok":
            coll = sum(r.get("collectives", {}).values())
            args_b = (r.get("memory") or {}).get("argument_bytes")
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                  f"{r['flops']/1e9:.1f} | {_fmt(coll, 'B')} | "
                  f"{args_b/1e9 if args_b else float('nan'):.2f} | "
                  f"{r.get('lower_s', 0)}+{r.get('compile_s', 0)} |")
        else:
            reason = r.get("reason", r.get("error", ""))[:40]
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"{r['status']} ({reason}) | — | — | — | — |")
    ok = sum(r["status"] == "ok" for r in base)
    sk = sum(r["status"] == "skipped" for r in base)
    er = sum(r["status"] == "error" for r in base)
    print(f"\n**{ok} compiled, {sk} skipped (documented), {er} errors.** "
          "*HLO flops count scanned loop bodies once (see roofline "
          "two-point probe for exact per-step totals).")


def roofline_table(tag=None):
    rows = [r for r in _load("roofline")
            if (r.get("tag") or None) == tag or (tag is None and not r.get("tag"))]
    rows = _load("roofline")
    print("| arch | shape | compute s | memory s | collective s | dominant | "
          "roofline frac | useful-FLOPs ratio |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4g} | "
              f"{r['memory_s']:.4g} | {r['collective_s']:.4g} | "
              f"{r['dominant']} | {r['roofline_fraction']:.2f} | "
              f"{(r['useful_flops_ratio'] or 0):.2f} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all",
                    choices=["dryrun", "roofline", "all"])
    args = ap.parse_args()
    if args.section in ("dryrun", "all"):
        print("### Dry-run table\n")
        dryrun_table()
        print()
    if args.section in ("roofline", "all"):
        print("### Roofline table\n")
        roofline_table()


if __name__ == "__main__":
    main()
