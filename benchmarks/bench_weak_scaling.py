"""Paper §III-F analogue: QoS under weak scaling (claim C3).

16 -> 64 -> 256 processes (optionally 1024), at one simel/CPU (maximal
communication intensity) and 2048 simels/CPU (the benchmark
parameterization), over any registered topology (runtime/topologies).
The claim: median QoS metrics are stable scaling 64 -> 256.
"""
from __future__ import annotations

import argparse

from repro.apps.graphcolor import GraphColorApp, GraphColorConfig
from repro.core.modes import AsyncMode
from repro.core.qos import METRICS, aggregate_reports, median_of_process_medians
from repro.runtime.simulator import SimConfig, Simulator
from repro.runtime.topologies import make_topology

from benchmarks.common import emit, save_json

PROC_COUNTS = (16, 64, 256)
FIELDS = METRICS


def run(proc_counts=PROC_COUNTS, topology: str = "torus",
        intra_latency=None):
    rows = []
    for simels in (1, 2048):
        for n in proc_counts:
            base = 15e-6 if simels == 1 else 200e-6
            topo = make_topology(topology, n)
            app = GraphColorApp(GraphColorConfig(
                n_processes=n, nodes_per_process=simels), topology=topo)
            cfg = SimConfig(mode=AsyncMode.BEST_EFFORT, duration=0.12,
                            base_compute=base, base_latency=550e-6,
                            intra_node_latency=intra_latency,
                            snapshot_warmup=0.03, snapshot_interval=0.02,
                            buffer_capacity=64)
            res = Simulator(app, cfg).run()
            row = dict(simels=simels, n=n, topology=topo.name,
                       rate_per_cpu=res.update_rate_per_cpu,
                       distributions=aggregate_reports(res.qos, (50, 95)))
            for f in FIELDS:
                row[f"median_{f}"] = median_of_process_medians(
                    res.qos_by_process, f)
            rows.append(row)
            emit(f"weak_scaling/{topo.name}/simels{simels}/n{n}",
                 row["median_simstep_period"] * 1e6,
                 f"lat_steps={row['median_simstep_latency']:.1f} "
                 f"clump={row['median_delivery_clumpiness']:.2f} "
                 f"fail={row['median_delivery_failure_rate']:.3f}")
    # stability check across the two largest scales (claim C3: 64 -> 256)
    summary = {}
    scales = sorted(proc_counts)[-2:]
    for simels in (1, 2048):
        lo = next(r for r in rows
                  if r["simels"] == simels and r["n"] == scales[0])
        hi = next(r for r in rows
                  if r["simels"] == simels and r["n"] == scales[-1])
        degr = {f: (hi[f"median_{f}"] / lo[f"median_{f}"]
                    if lo[f"median_{f}"] else None)
                for f in ("simstep_period", "simstep_latency")}
        summary[f"simels{simels}"] = degr
        emit(f"weak_scaling/simels{simels}/stability_{scales[0]}_to_{scales[-1]}",
             0.0, " ".join(f"{k}_ratio={v:.2f}" for k, v in degr.items() if v))
    save_json("bench_weak_scaling", {"rows": rows, "summary": summary})
    return rows


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--topology", default="torus")
    p.add_argument("--procs", type=int, nargs="+", default=list(PROC_COUNTS))
    p.add_argument("--intra-latency", type=float, default=None)
    a = p.parse_args()
    run(tuple(a.procs), a.topology, a.intra_latency)
