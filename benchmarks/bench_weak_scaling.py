"""Paper §III-F analogue: QoS under weak scaling (claim C3).

16 -> 64 -> 256 processes, at one simel/CPU (maximal communication
intensity) and 2048 simels/CPU (the benchmark parameterization).  The claim:
median QoS metrics are stable scaling 64 -> 256.
"""
from __future__ import annotations

import numpy as np

from repro.apps.graphcolor import GraphColorApp, GraphColorConfig
from repro.core.modes import AsyncMode
from repro.runtime.simulator import SimConfig, Simulator

from benchmarks.common import emit, save_json

PROC_COUNTS = (16, 64, 256)
FIELDS = ("simstep_period", "simstep_latency", "walltime_latency",
          "delivery_failure_rate", "delivery_clumpiness")


def _median_of_process_medians(res, field):
    meds = []
    for p, reps in res.qos_by_process.items():
        if reps:
            meds.append(np.median([getattr(q, field) for q in reps]))
    return float(np.median(meds)) if meds else None


def run(proc_counts=PROC_COUNTS):
    rows = []
    for simels in (1, 2048):
        for n in proc_counts:
            base = 15e-6 if simels == 1 else 200e-6
            app = GraphColorApp(GraphColorConfig(
                n_processes=n, nodes_per_process=simels))
            cfg = SimConfig(mode=AsyncMode.BEST_EFFORT, duration=0.12,
                            base_compute=base, base_latency=550e-6,
                            snapshot_warmup=0.03, snapshot_interval=0.02,
                            buffer_capacity=64)
            res = Simulator(app, cfg).run()
            row = dict(simels=simels, n=n,
                       rate_per_cpu=res.update_rate_per_cpu)
            for f in FIELDS:
                row[f"median_{f}"] = _median_of_process_medians(res, f)
            rows.append(row)
            emit(f"weak_scaling/simels{simels}/n{n}",
                 row["median_simstep_period"] * 1e6,
                 f"lat_steps={row['median_simstep_latency']:.1f} "
                 f"clump={row['median_delivery_clumpiness']:.2f} "
                 f"fail={row['median_delivery_failure_rate']:.3f}")
    # stability check 64 -> 256 (claim C3)
    summary = {}
    for simels in (1, 2048):
        r64 = next(r for r in rows if r["simels"] == simels and r["n"] == 64)
        r256 = next(r for r in rows if r["simels"] == simels and r["n"] == 256)
        degr = {f: (r256[f"median_{f}"] / r64[f"median_{f}"]
                    if r64[f"median_{f}"] else None)
                for f in ("simstep_period", "simstep_latency")}
        summary[f"simels{simels}"] = degr
        emit(f"weak_scaling/simels{simels}/stability_64_to_256", 0.0,
             " ".join(f"{k}_ratio={v:.2f}" for k, v in degr.items() if v))
    save_json("bench_weak_scaling", {"rows": rows, "summary": summary})
    return rows


if __name__ == "__main__":
    run()
