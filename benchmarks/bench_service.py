"""A/B service bench: best-effort vs barrier modes under identical load.

The live-service acceptance experiment (runtime/service.py): every arm
replays the SAME open-loop arrival trace (the cumulative arrival table is
a pure function of ``(cfg, seed)`` and ignores the async mode), so the
comparison isolates the communication discipline — best-effort vs
barrier-every-step — and the exchange scheduler — per-window vs the
W-fused superstep (and the pipelined overlap when ``--shards`` > 1) —
at matched demand.

Per arm, ``--replicates`` seeds run as one vmapped dispatch; the recorded
``updates_per_sec`` (and its bootstrap percentile CI over replicates)
feeds the CI regression gate (``check_regression.py`` keys service rows
by mode + traffic on top of the engine/n/scheduler point).  Served-item
throughput and end-of-run QoS medians ride along so the A/B table reads
as the paper's payoff/price split: period = payoff, latency = price.

Run: PYTHONPATH=src:. python benchmarks/bench_service.py \
         [--procs 64] [--duration 0.02] [--traffic poisson] \
         [--replicates 5] [--superstep-windows 4] [--shards 1]

Writes ``benchmarks/results/BENCH_service.json``.  CI replays the n=64
jax arms and gates ``updates_per_sec`` against the checked-in baseline.
"""
from __future__ import annotations

import argparse
import os
import time


def _bootstrap_ci(vals, n_boot: int = 1000, q=(2.5, 97.5), seed: int = 0):
    """Percentile bootstrap CI for the mean of ``vals``."""
    import numpy as np

    arr = np.asarray(vals, float)
    if arr.size < 2:
        v = float(arr.mean()) if arr.size else 0.0
        return v, v
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, arr.size, size=(n_boot, arr.size))
    means = arr[idx].mean(axis=1)
    lo, hi = np.percentile(means, q)
    return float(lo), float(hi)


def bench_arm(engine: str, mode, scheduler: str, superstep_windows: int,
              n: int, duration: float, topology: str, traffic: str,
              arrival_rate: float, shards: int, replicates: int,
              seed: int, warmup: bool):
    from repro.apps.graphcolor import GraphColorApp, GraphColorConfig
    from repro.core.qos import median_of_process_medians
    from repro.runtime.config import RunConfig
    from repro.runtime.engine import run_replicates
    from repro.runtime.simulator import SimConfig
    from repro.runtime.topologies import make_topology

    topo = make_topology(topology, n)

    def make_app(s: int):
        return GraphColorApp(
            GraphColorConfig(n_processes=n, nodes_per_process=1, seed=s),
            topology=topo)

    cfg = SimConfig(mode=mode, duration=duration,
                    snapshot_warmup=duration / 6,
                    snapshot_interval=duration / 12, seed=seed,
                    arrival_rate=arrival_rate, arrival_shape=traffic)
    rc = RunConfig(engine=engine, shards=shards, scheduler=scheduler,
                   superstep_windows=superstep_windows,
                   replicates=replicates)
    if warmup and engine == "jax":
        run_replicates(rc, make_app, cfg)
    t0 = time.perf_counter()
    results = run_replicates(rc, make_app, cfg)
    wall = time.perf_counter() - t0
    per_rep_rate = [sum(r.updates) / (wall / len(results)) for r in results]
    updates = sum(sum(r.updates) for r in results)
    served = sum(sum(r.service["served"]) for r in results if r.service)
    arrivals = sum(sum(r.service["arrivals"]) for r in results if r.service)
    lo, hi = _bootstrap_ci(per_rep_rate)
    all_qos = {}
    for res in results:
        for pid, reps in res.qos_by_process.items():
            all_qos.setdefault(pid, []).extend(reps)
    resolved = "superstep" if scheduler == "auto" and superstep_windows > 1 \
        else ("window" if scheduler == "auto" else scheduler)
    return dict(
        engine=engine, n=n, shards=shards, topology=topo.name,
        scheduler=resolved, superstep_windows=superstep_windows,
        mode=mode.name.lower(), traffic=traffic,
        arrival_rate=arrival_rate, duration=duration,
        replicates=replicates, warm=bool(warmup and engine == "jax"),
        wall_seconds=wall, updates=updates,
        updates_per_sec=updates / wall,
        updates_per_sec_ci=[lo, hi],
        served=served, arrivals=arrivals,
        served_per_sec=served / wall,
        backlog_fraction=(arrivals - served) / max(arrivals, 1),
        simstep_period_p50=median_of_process_medians(
            all_qos, "simstep_period"),
        simstep_latency_p50=median_of_process_medians(
            all_qos, "simstep_latency"),
        delivery_failure_p50=median_of_process_medians(
            all_qos, "delivery_failure_rate"),
    )


def run(n: int = 64, duration: float = 0.02, topology: str = "torus",
        traffic: str = "poisson", arrival_rate: float = 1e5,
        replicates: int = 5, superstep_windows: int = 4, shards: int = 1,
        seed: int = 0, warmup: bool = False, engine: str = "jax"):
    from benchmarks.common import emit, save_json
    from repro.core.modes import AsyncMode

    arms = [
        (AsyncMode.BEST_EFFORT, "window", 1),
        (AsyncMode.BARRIER_EVERY_STEP, "window", 1),
        (AsyncMode.BEST_EFFORT, "superstep", superstep_windows),
        (AsyncMode.BARRIER_EVERY_STEP, "superstep", superstep_windows),
    ]
    if shards > 1:
        arms += [
            (AsyncMode.BEST_EFFORT, "pipelined", superstep_windows),
            (AsyncMode.BARRIER_EVERY_STEP, "pipelined", superstep_windows),
        ]
    rows = []
    for mode, scheduler, w in arms:
        row = bench_arm(engine, mode, scheduler, w, n, duration, topology,
                        traffic, arrival_rate, shards, replicates, seed,
                        warmup)
        rows.append(row)
        emit(f"service/{row['mode']}/{row['scheduler']}W{w}/n{n}",
             row["wall_seconds"] * 1e6,
             f"upd_per_sec={row['updates_per_sec']:.0f} "
             f"ci=[{row['updates_per_sec_ci'][0]:.0f},"
             f"{row['updates_per_sec_ci'][1]:.0f}] "
             f"served_per_sec={row['served_per_sec']:.0f} "
             f"backlog={row['backlog_fraction']:.3f} "
             f"fail_p50={row['delivery_failure_p50']:.3f}")
    # A/B headline: best-effort over barrier at matched arrival trace,
    # per scheduler (the paper's C1 claim, live-service edition)
    summary = {}
    for scheduler in {r["scheduler"] for r in rows}:
        be = next(r for r in rows if r["scheduler"] == scheduler
                  and r["mode"] == "best_effort")
        ba = next(r for r in rows if r["scheduler"] == scheduler
                  and r["mode"] == "barrier_every_step")
        key = f"n{n}_{scheduler}_best_effort_over_barrier"
        summary[key] = dict(
            speedup=be["updates_per_sec"] / ba["updates_per_sec"],
            served_ratio=be["served"] / max(ba["served"], 1),
            superstep_windows=be["superstep_windows"])
        emit(f"service/ab/{scheduler}/n{n}", 0.0,
             f"best_effort_over_barrier={summary[key]['speedup']:.2f}x "
             f"served_ratio={summary[key]['served_ratio']:.2f}x")
    save_json("BENCH_service", {"rows": rows, "summary": summary})
    return rows


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--procs", type=int, default=64)
    p.add_argument("--duration", type=float, default=0.02)
    p.add_argument("--topology", default="torus")
    p.add_argument("--traffic", default="poisson",
                   choices=["poisson", "bursty", "diurnal"])
    p.add_argument("--arrival-rate", type=float, default=1e5)
    p.add_argument("--replicates", type=int, default=5)
    p.add_argument("--superstep-windows", type=int, default=4)
    p.add_argument("--shards", type=int, default=1)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--engine", default="jax", choices=["event", "jax"])
    p.add_argument("--force-host-devices", type=int, default=0,
                   help="set XLA_FLAGS=--xla_force_host_platform_device_"
                        "count=N (must run before jax initializes devices)")
    p.add_argument("--warmup", action="store_true",
                   help="pre-run each arm once so the timed run excludes "
                        "jit compilation (used by the CI perf guard)")
    a = p.parse_args()
    if a.force_host_devices:
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count="
            f"{a.force_host_devices}").strip()
    run(a.procs, a.duration, a.topology, a.traffic, a.arrival_rate,
        a.replicates, a.superstep_windows, a.shards, a.seed, a.warmup,
        a.engine)
