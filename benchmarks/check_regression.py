"""Perf-regression guard over ``bench_engines`` JSON artifacts.

Compares a freshly measured ``BENCH_engines.json`` against the checked-in
baseline (``benchmarks/results/BENCH_engines.json``): for every
``(engine, n, shards, layout, scheduler, topology, superstep_windows)``
point present in BOTH files,
the fresh ``updates_per_sec`` must be at least ``(1 - tolerance)`` of the
baseline.
The layout component uses each row's *resolved* duct layout (DESIGN.md
§10), so default ``--layout auto`` replays compare against the explicit
edge/dense baseline points.  Points only present on one side are reported
and skipped, so the baseline can carry a wider matrix than a quick CI
replay.

The tolerance is deliberately generous (default 40%): the baseline is
recorded on a developer machine while CI replays on shared runners, so
the guard is meant to catch order-of-magnitude path regressions (a fallen
jit cache, accidental host sync per window, quadratic setup), not a few
percent of noise.

Run (CI copies the baseline aside first, since the bench overwrites it):

    cp benchmarks/results/BENCH_engines.json /tmp/bench_baseline.json
    PYTHONPATH=src:. python benchmarks/bench_engines.py \
        --engines jax --procs 256 --duration 0.02
    python benchmarks/check_regression.py \
        --baseline /tmp/bench_baseline.json \
        --fresh benchmarks/results/BENCH_engines.json

Exits non-zero on any regression.
"""
from __future__ import annotations

import argparse
import json
import sys


def _points(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    rows = data["rows"] if isinstance(data, dict) else data
    # layout joined the point key with the dense duct layout (DESIGN.md
    # §10).  Key on the RESOLVED layout so a default `--layout auto` run
    # still shares points with a baseline recorded via explicit layouts
    # (auto resolves to dense on the bench torus); rows from pre-layout
    # baselines key as "auto" and simply stop being shared once replaced.
    points = {}
    for r in rows:
        # scheduler joined the key with the sharded exchange schedulers
        # (DESIGN.md §9/§12); rows from older baselines carry no scheduler
        # field and key as "window" — the per-window default they measured.
        # topology and superstep_windows joined with the bucketed dense
        # layout (DESIGN.md §13): smallworld/cliques dense points and the
        # W-fused unsharded point share n with the torus matrix and would
        # otherwise collide.  Older rows default to the values those
        # baselines actually measured (bench torus, per-window W=1).
        # mode and traffic joined the key with the live-service A/B bench
        # (bench_service.py): its arms differ only by async mode (and
        # arrival shape) at one (engine, n, scheduler) point.  Batch rows
        # carry neither field and key on the defaults they measured.
        key = (
            r["engine"],
            r["n"],
            r.get("shards", 1),
            r.get("resolved_layout", r.get("layout", "auto")),
            r.get("scheduler", "window"),
            r.get("topology", "torus"),
            r.get("superstep_windows", 1),
            r.get("mode", "-"),
            r.get("traffic", "-"),
        )
        if key in points:
            # e.g. a run benching both "auto" and the layout it resolves
            # to — keep the later row, but say so instead of silently
            # dropping a measurement from the comparison
            print(
                f"  note {key}: duplicate resolved point in {path}; "
                "keeping the last row"
            )
        points[key] = r
    return points


def check(
    baseline_path: str,
    fresh_path: str,
    tolerance: float = 0.40,
    metric: str = "updates_per_sec",
) -> int:
    base = _points(baseline_path)
    fresh = _points(fresh_path)
    shared = sorted(set(base) & set(fresh))
    if not shared:
        print(
            "check_regression: no shared (engine, n, shards, layout, "
            "scheduler, topology, superstep_windows) points between "
            f"{baseline_path} and {fresh_path}"
        )
        return 2
    for key in sorted(set(base) - set(fresh)):
        print(f"  skip {key}: baseline-only point")
    for key in sorted(set(fresh) - set(base)):
        print(f"  skip {key}: fresh-only point (new in this run)")
    failures = 0
    for key in shared:
        b, f = base[key][metric], fresh[key][metric]
        floor = b * (1.0 - tolerance)
        status = "OK" if f >= floor else "REGRESSION"
        if f < floor:
            failures += 1
        engine, n, shards, layout, sched, topo, w, mode, traffic = key
        ab = f"/{mode}/{traffic}" if mode != "-" else ""
        print(
            f"  {status:<10} {engine}/{topo}/n{n}/s{shards}/{layout}/"
            f"{sched}W{w}{ab}: "
            f"{metric} fresh={f:.0f} baseline={b:.0f} "
            f"floor={floor:.0f} ({f / b:.2f}x)"
        )
    if failures:
        print(
            f"check_regression: {failures}/{len(shared)} point(s) "
            f"regressed beyond the {tolerance:.0%} tolerance"
        )
        return 1
    print(f"check_regression: {len(shared)} point(s) within tolerance")
    return 0


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--baseline", required=True)
    p.add_argument("--fresh", required=True)
    p.add_argument("--tolerance", type=float, default=0.40)
    p.add_argument("--metric", default="updates_per_sec")
    a = p.parse_args()
    sys.exit(check(a.baseline, a.fresh, a.tolerance, a.metric))
