"""Sharded checkpointing with elastic restore.

Local-failure/local-recovery-friendly design (paper §I discussion):
  - atomic directory commit (write to tmp, rename) — a crash mid-save never
    corrupts the latest checkpoint;
  - the manifest stores the flattened param paths + shapes, so restore can
    target a DIFFERENT mesh: leaves are device_put with the *new* sharding
    (elastic scaling across pod counts);
  - background-thread saves keep the train loop running (best-effort
    persistence off the critical path).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import ml_dtypes  # noqa: F401  (registers bfloat16/float8 numpy dtypes)
import numpy as np


def _np_dtype(name: str):
    """Resolve a dtype string, including ml_dtypes extension types."""
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, state, step: int, blocking: bool = True):
    """Serialize a pytree to ``ckpt_dir/step_<k>`` atomically."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    # snapshot to host memory synchronously (cheap), write in background
    flat = {k: np.asarray(v) for k, v in _flatten(state).items()}

    def _write():
        os.makedirs(tmp, exist_ok=True)
        # npz can't serialize ml_dtypes (bf16/fp8): store raw byte views and
        # record true dtypes in the manifest
        raw = {k: np.atleast_1d(v).view(np.uint8).reshape(-1)
               for k, v in flat.items()}
        np.savez(os.path.join(tmp, "arrays.npz"), **raw)
        manifest = {
            "step": step,
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in flat.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like, shardings=None):
    """Restore into the structure of ``like`` (a pytree or eval_shape tree).

    ``shardings``: optional matching pytree of NamedShardings for the TARGET
    mesh — this is the elastic-rescale path (checkpoint written on one mesh,
    restored onto another).
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {}
        for k in z.files:
            meta = manifest["leaves"][k]
            arrays[k] = z[k].view(_np_dtype(meta["dtype"])).reshape(meta["shape"])

    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(flat_like))
    out = []
    for (pth, leaf), shard in zip(flat_like, shard_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in pth)
        arr = arrays[key]
        expect = tuple(leaf.shape)
        assert tuple(arr.shape) == expect, (key, arr.shape, expect)
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, shard) if shard is not None else
                   jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(jax.tree.structure(like), out)


def prune(ckpt_dir: str, keep: int = 3):
    steps = sorted(s for s in (latest_step(ckpt_dir),) if s is not None)
    if not os.path.isdir(ckpt_dir):
        return
    all_steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
    for s in all_steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
