"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be the process entrypoint (``python -m repro.launch.dryrun``): the
XLA_FLAGS below force 512 host devices and must be set before jax
initializes.  Produces per-cell JSON artifacts (memory analysis, HLO
FLOPs/bytes, per-collective byte counts) consumed by benchmarks/roofline.py
and EXPERIMENTS.md.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.core.modes import AsyncMode
from repro.launch import serve as serve_mod
from repro.launch import train as train_mod
from repro.launch.mesh import make_production_mesh, pod_count, rules_for
from repro.launch.sharding import (param_specs, shardings_from_specs,
                                   with_pod_dim)
from repro.models import lm, modality, partitioning

RESULTS_DIR = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))))), "benchmarks", "results",
    "dryrun")

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(
    r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64"
    r"|f8e4m3|f8e5m2)\[([0-9,]*)\]")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective per-device payload bytes from post-SPMD HLO."""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        result_seg, kind = m.group(1), m.group(2)
        b = _shape_bytes(result_seg)
        if kind.endswith("-done"):
            continue
        out[kind] = out.get(kind, 0) + b
    return out


# ---------------------------------------------------------------------------
def input_specs(arch: str, shape_name: str, multi_pod: bool = False):
    """ShapeDtypeStruct stand-ins for every model input of this cell
    (weak-type-correct, shardable, no device allocation)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    # dp_only is a training-layout decision; serve shapes keep the 2-D
    # layout (decode batch typically not divisible by all 256 chips)
    profile = cfg.sharding_profile if shape.kind == "train" else "2d"
    rules = rules_for(mesh, long_context=(shape.name == "long_500k"),
                      pod_stacked=(shape.kind == "train"), profile=profile)
    n_pods = pod_count(mesh)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        out = {
            "tokens": jax.ShapeDtypeStruct((n_pods, B // n_pods, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((n_pods, B // n_pods, S), jnp.int32),
        }
        if cfg.frontend:
            out[modality.frontend_input_name(cfg)] = jax.ShapeDtypeStruct(
                (n_pods, B // n_pods, cfg.frontend_len, cfg.d_model),
                jnp.bfloat16)
        return out
    inputs, _ = serve_mod.serve_input_specs(cfg, shape, rules)
    return inputs


def build_lowered(arch: str, shape_name: str, multi_pod: bool,
                  mode: int = 0, compressor=None, grad_accum: int = 1,
                  remat: bool = True, extra_cfg=None):
    """Construct and lower the step function for one cell."""
    cfg = get_config(arch)
    if grad_accum > 1:
        cfg = cfg.replace(grad_accum=grad_accum)
    if not remat:
        cfg = cfg.replace(remat=False)
    if extra_cfg:
        cfg = cfg.replace(**extra_cfg)
    shape = SHAPES[shape_name]
    if not shape_applicable(cfg, shape):
        return None, "skip: long_500k needs sub-quadratic mixing"

    mesh = make_production_mesh(multi_pod=multi_pod)
    # dp_only is a training-layout decision; serve shapes keep the 2-D
    # layout (decode batch typically not divisible by all 256 chips)
    profile = cfg.sharding_profile if shape.kind == "train" else "2d"
    rules = rules_for(mesh, long_context=(shape.name == "long_500k"),
                      pod_stacked=(shape.kind == "train"), profile=profile)
    n_pods = pod_count(mesh)

    with partitioning.use_rules(rules):
        if shape.kind == "train":
            spec = train_mod.TrainSpec(mode=AsyncMode(mode),
                                       compressor=compressor)
            state_like = train_mod.abstract_train_state(cfg, spec, n_pods)
            pspecs = with_pod_dim(param_specs(lm.abstract_params(cfg), rules))
            state_specs = {
                "params": pspecs,
                "opt": {"m": pspecs, "v": pspecs, "step": P("pod" if multi_pod else None)},
                "step": P(),
            }
            if spec.mode == AsyncMode.BEST_EFFORT:
                state_specs["others"] = pspecs
                if compressor:
                    state_specs["residuals"] = pspecs
            if spec.mode in (AsyncMode.ROLLING_BARRIER, AsyncMode.FIXED_BARRIER):
                state_specs["outer"] = {"anchor": pspecs, "momentum": pspecs}
            if not multi_pod:
                # no pod axis on this mesh: pod-stacked dims (size 1) unsharded
                def strip_pod(s):
                    return P(*(None if a == "pod" else a for a in s))
                state_specs = jax.tree.map(
                    strip_pod, state_specs, is_leaf=lambda x: isinstance(x, P))

            B, S = shape.global_batch, shape.seq_len
            assert B % n_pods == 0
            batch_like = {
                "tokens": jax.ShapeDtypeStruct((n_pods, B // n_pods, S), jnp.int32),
                "labels": jax.ShapeDtypeStruct((n_pods, B // n_pods, S), jnp.int32),
            }
            batch_specs = train_mod.make_batch_specs(cfg, rules, n_pods)
            if cfg.frontend:
                batch_like[modality.frontend_input_name(cfg)] = \
                    jax.ShapeDtypeStruct(
                        (n_pods, B // n_pods, cfg.frontend_len, cfg.d_model),
                        jnp.bfloat16)

            step_fn = train_mod.make_train_step(
                cfg, spec, n_pods,
                param_specs=param_specs(lm.abstract_params(cfg), rules))
            jitted = jax.jit(
                step_fn,
                in_shardings=(shardings_from_specs(state_specs, mesh),
                              shardings_from_specs(batch_specs, mesh)),
                out_shardings=(shardings_from_specs(state_specs, mesh), None),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state_like, batch_like)

        elif shape.kind == "prefill":
            params_like = lm.abstract_params(cfg)
            pspecs = param_specs(params_like, rules)
            inputs, in_specs = serve_mod.serve_input_specs(cfg, shape, rules)
            step_fn = serve_mod.make_prefill_step(cfg, param_specs=pspecs)
            args = [params_like, inputs["tokens"]]
            arg_specs = [pspecs, in_specs["tokens"]]
            if cfg.frontend:
                args.append(inputs[modality.frontend_input_name(cfg)])
                arg_specs.append(in_specs[modality.frontend_input_name(cfg)])
            jitted = jax.jit(
                step_fn,
                in_shardings=tuple(shardings_from_specs(s, mesh)
                                   for s in arg_specs))
            lowered = jitted.lower(*args)

        else:  # decode
            params_like = lm.abstract_params(cfg)
            pspecs = param_specs(params_like, rules)
            inputs, in_specs = serve_mod.serve_input_specs(cfg, shape, rules)
            step_fn = serve_mod.make_decode_step(cfg, shape.seq_len - 1,
                                                 param_specs=pspecs)
            cache_sh = shardings_from_specs(in_specs["caches"], mesh)
            jitted = jax.jit(
                step_fn,
                in_shardings=(shardings_from_specs(pspecs, mesh),
                              shardings_from_specs(in_specs["tokens"], mesh),
                              cache_sh),
                out_shardings=(None, None, cache_sh),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(params_like, inputs["tokens"],
                                   inputs["caches"])
    return lowered, None


def run_cell(arch: str, shape_name: str, multi_pod: bool, mode: int = 0,
             compressor=None, tag: str = "", **kw) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    label = f"{arch}/{shape_name}/{mesh_name}" + (f"/{tag}" if tag else "")
    t0 = time.time()
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "mode": mode, "compressor": compressor, "tag": tag}
    try:
        lowered, skip = build_lowered(arch, shape_name, multi_pod, mode,
                                      compressor, **kw)
        if skip:
            record["status"] = "skipped"
            record["reason"] = skip
            print(f"[dryrun] {label}: SKIP ({skip})", flush=True)
            return record
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        cost = compiled.cost_analysis() or {}
        try:
            mem = compiled.memory_analysis()
            mem_stats = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            }
        except Exception as e:  # noqa: BLE001 — CPU backend may not support
            mem_stats = {"error": str(e)}
        coll = collective_bytes(compiled.as_text())

        record.update({
            "status": "ok",
            "flops": cost.get("flops"),
            "bytes_accessed": cost.get("bytes accessed"),
            "memory": mem_stats,
            "collectives": coll,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
        })
        print(f"[dryrun] {label}: OK flops={cost.get('flops', 0):.3e} "
              f"coll={sum(coll.values()):.3e}B "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)", flush=True)
    except Exception as e:  # noqa: BLE001
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-3000:]
        print(f"[dryrun] {label}: ERROR {type(e).__name__}: {str(e)[:300]}",
              flush=True)
    return record


def save_record(record: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    name = (f"{record['arch']}__{record['shape']}__{record['mesh']}"
            + (f"__{record['tag']}" if record.get("tag") else "") + ".json")
    path = os.path.join(RESULTS_DIR, name)
    slim = {k: v for k, v in record.items() if k != "traceback"}
    with open(path, "w") as f:
        json.dump(slim, f, indent=1)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="shape name or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--mode", type=int, default=0,
                    help="asynchronicity mode for train cells")
    ap.add_argument("--compressor", default=None)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    ok = err = skip = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                rec = run_cell(arch, shape, multi, args.mode, args.compressor,
                               tag=args.tag, grad_accum=args.grad_accum)
                save_record(rec)
                ok += rec["status"] == "ok"
                err += rec["status"] == "error"
                skip += rec["status"] == "skipped"
    print(f"[dryrun] done: {ok} ok, {skip} skipped, {err} errors", flush=True)
    return 0 if err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
