from repro.launch import mesh, serve, sharding, train  # noqa: F401
