"""Production meshes and logical-axis rules.

Single pod: (16, 16) over ("data", "model") — 256 chips (TPU v5e pod).
Multi-pod:  (2, 16, 16) over ("pod", "data", "model") — 512 chips; the
"pod" axis is the best-effort boundary (DESIGN.md §2).

Defined as functions, not module constants, so importing never touches jax
device state.
"""
from __future__ import annotations

import jax

from repro.models.partitioning import MeshRules


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for multi-device CPU tests."""
    return jax.make_mesh(shape, axes)


def rules_for(mesh, *, long_context: bool = False,
              pod_stacked: bool = False, profile: str = "2d") -> MeshRules:
    """Logical-role mapping for a mesh.

    long_context: batch=1 decode — every axis goes to the KV-cache sequence
    dim ("sp"), nothing to batch ("dp").
    pod_stacked: train state carries an explicit leading pod dim, so the
    FSDP role must exclude "pod" (it shards the stack dim instead).
    profile: "2d" (FSDP x TP) or "dp_only" (pure DP, params replicated).
    """
    names = mesh.axis_names
    if profile == "dp_only":
        dp = tuple(n for n in names if n != "pod" or not pod_stacked)
        if pod_stacked:
            dp = tuple(n for n in names if n != "pod")
        if long_context:
            return MeshRules(mesh, dp=(), tp=None, sp=tuple(names))
        return MeshRules(mesh, dp=dp, tp=None, sp=None)
    dp = tuple(n for n in names if n in ("pod", "data"))
    if pod_stacked:
        dp = tuple(n for n in dp if n != "pod")
    tp = "model" if "model" in names else None
    if long_context:
        return MeshRules(mesh, dp=(), tp=tp, sp=tuple(names))
    return MeshRules(mesh, dp=dp, tp=tp, sp=tp)


def pod_count(mesh) -> int:
    return mesh.shape.get("pod", 1)
