"""Production meshes and logical-axis rules.

Single pod: (16, 16) over ("data", "model") — 256 chips (TPU v5e pod).
Multi-pod:  (2, 16, 16) over ("pod", "data", "model") — 512 chips; the
"pod" axis is the best-effort boundary (DESIGN.md §2).

Defined as functions, not module constants, so importing never touches jax
device state.
"""
from __future__ import annotations

import jax

from repro.models.partitioning import MeshRules


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for multi-device CPU tests."""
    return jax.make_mesh(shape, axes)


#: Mesh axis the sharded simulation engine partitions the population over.
SHARD_AXIS = "shard"


def make_shard_mesh(n_shards: int):
    """1-D mesh over ``SHARD_AXIS`` for the sharded vectorized engine.

    CI forces host-platform devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so this path is
    exercised continuously without accelerators (DESIGN.md §8).
    """
    n_dev = len(jax.devices())
    if n_shards > n_dev:
        raise ValueError(
            f"requested {n_shards} shards but only {n_dev} JAX device(s) "
            "are visible; on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_shards} before "
            "importing jax")
    return jax.make_mesh((n_shards,), (SHARD_AXIS,))


def shard_map(f, mesh, in_specs, out_specs, axis_names=None):
    """Version-compat ``shard_map``: top-level ``jax.shard_map`` on current
    jax, ``jax.experimental.shard_map`` on older releases.  Replication
    checking is disabled either way — the sharded engine's bodies mix
    per-shard state with cross-shard collectives, which the static checker
    over-rejects.

    ``axis_names`` restricts manual axes (partial-auto sharding): passed
    through on current jax, translated to the legacy ``auto=`` complement
    on older releases.
    """
    if hasattr(jax, "shard_map"):
        kwargs = {} if axis_names is None else {"axis_names": axis_names}
        # the check flag was renamed check_rep -> check_vma across jax
        # releases; keep checking OFF whichever spelling this jax takes
        for check_kw in ({"check_vma": False}, {"check_rep": False}, {}):
            try:
                return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                     out_specs=out_specs, **check_kw,
                                     **kwargs)
            except TypeError:
                continue
    from jax.experimental.shard_map import shard_map as _shard_map
    kwargs = {}
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - set(axis_names)
        if auto:
            kwargs["auto"] = auto
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False, **kwargs)


def rules_for(mesh, *, long_context: bool = False,
              pod_stacked: bool = False, profile: str = "2d") -> MeshRules:
    """Logical-role mapping for a mesh.

    long_context: batch=1 decode — every axis goes to the KV-cache sequence
    dim ("sp"), nothing to batch ("dp").
    pod_stacked: train state carries an explicit leading pod dim, so the
    FSDP role must exclude "pod" (it shards the stack dim instead).
    profile: "2d" (FSDP x TP) or "dp_only" (pure DP, params replicated).
    """
    names = mesh.axis_names
    if profile == "dp_only":
        dp = tuple(n for n in names if n != "pod" or not pod_stacked)
        if pod_stacked:
            dp = tuple(n for n in names if n != "pod")
        if long_context:
            return MeshRules(mesh, dp=(), tp=None, sp=tuple(names))
        return MeshRules(mesh, dp=dp, tp=None, sp=None)
    dp = tuple(n for n in names if n in ("pod", "data"))
    if pod_stacked:
        dp = tuple(n for n in dp if n != "pod")
    tp = "model" if "model" in names else None
    if long_context:
        return MeshRules(mesh, dp=(), tp=tp, sp=tuple(names))
    return MeshRules(mesh, dp=dp, tp=tp, sp=tp)


def pod_count(mesh) -> int:
    return mesh.shape.get("pod", 1)
