"""Serve-step factories: prefill and decode (single token vs a KV cache).

Serving params are shared (not pod-stacked): multi-pod serving is
data-parallel over request batches; the best-effort angle is on the training
path.  ``decode_32k`` / ``long_500k`` lower the decode step: one new token
against a pre-filled cache of seq_len (written at index seq_len - 1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import lm, modality, transformer


def make_prefill_step(cfg, param_specs=None):
    def prefill_step(params, tokens, frontend_embeds=None):
        return lm.prefill_step(params, tokens, cfg, frontend_embeds,
                               param_specs=param_specs)
    return prefill_step


def make_decode_step(cfg, write_idx: int, param_specs=None):
    def decode_step(params, tokens, caches):
        return lm.decode_step(params, tokens, caches, cfg, write_idx,
                              param_specs=param_specs)
    return decode_step


# ---------------------------------------------------------------------------
# Cache sharding rules
# ---------------------------------------------------------------------------
def _cache_rule(name: str, shape) -> tuple:
    nd = len(shape)
    if name in ("k", "v") and nd == 5:          # attn KV (P,B,S,KH,hd)
        return (None, "dp", "sp", None, None)
    if name == "C" and nd == 5:                  # mlstm matrix memory
        return (None, "dp", None, None, "tp")
    if name == "conv" and nd == 4:               # mamba/mlstm conv window
        return (None, "dp", None, "tp")
    if name == "h" and nd == 4:
        # mamba h (P,B,di,N): tiny state dim last; slstm h (P,B,H,hd)
        if shape[-1] <= 64:
            return (None, "dp", "tp", None)
        return (None, "dp", None, "tp")
    if name in ("c", "n", "h", "m") and nd == 4:  # slstm / mlstm vectors
        return (None, "dp", None, "tp")
    if name == "m" and nd == 3:                   # mlstm stabilizer (P,B,H)
        return (None, "dp", None)
    return (None,) * nd


def cache_specs(cfg, caches_like, rules):
    from repro.launch.sharding import _divisible

    def visit(path, leaf):
        name = str(getattr(path[-1], "key", getattr(path[-1], "idx", path[-1])))
        rule = _cache_rule(name, leaf.shape)
        resolved = []
        for dim, role in zip(leaf.shape, rule):
            axes = rules.resolve(role)
            resolved.append(axes if _divisible(dim, axes, rules.mesh) else None)
        return P(*resolved)

    return jax.tree_util.tree_map_with_path(visit, caches_like)


def abstract_caches(cfg, batch: int, seq: int, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: transformer.init_caches(cfg, batch, seq, dtype))


def serve_input_specs(cfg, shape_cfg, rules):
    """(ShapeDtypeStructs, PartitionSpecs) for the serve path."""
    B, S = shape_cfg.global_batch, shape_cfg.seq_len
    if shape_cfg.kind == "prefill":
        inputs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        specs = {"tokens": P(rules.roles["dp"] or None, None)}
        if cfg.frontend:
            inputs[modality.frontend_input_name(cfg)] = \
                jax.ShapeDtypeStruct((B, cfg.frontend_len, cfg.d_model),
                                     jnp.bfloat16)
            specs[modality.frontend_input_name(cfg)] = \
                P(rules.roles["dp"] or None, None, None)
        return inputs, specs
    assert shape_cfg.kind == "decode"
    caches = abstract_caches(cfg, B, S)
    inputs = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
              "caches": caches}
    dp = rules.roles["dp"] or None
    specs = {"tokens": P(dp, None), "caches": cache_specs(cfg, caches, rules)}
    return inputs, specs
