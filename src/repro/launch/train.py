"""Train-step factory: best-effort asynchronicity modes on the pod axis.

Multi-pod train state is POD-STACKED: every state leaf carries a leading
``n_pods`` dim sharded over the "pod" mesh axis, so per-pod parameter
divergence (the essence of modes 1–4) is explicit and GSPMD-lowerable:

  mode 0 — per-step gradient mean over the pod dim (XLA: cross-pod
           all-reduce): the BSP baseline; params stay bit-identical.
  mode 1/2 — no per-step cross-pod traffic; every K steps the outer
           optimizer syncs params (local SGD / rolling vs fixed barrier).
  mode 3 — staleness-1 delayed cross-pod gradient sum, optionally
           compressed (int8/top-k with error feedback).  The cross-pod
           reduce feeds only the *next* step's update, so the scheduler
           overlaps it with this step's compute.
  mode 4 — fully independent pods (roofline control).

On a single-pod mesh n_pods == 1 and all modes coincide.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.modes import AsyncMode
from repro.models import lm, modality
from repro.optim import adamw as adamw_mod
from repro.optim import outer as outer_mod
from repro.optim.adamw import AdamWConfig
from repro.optim.outer import OuterConfig


@dataclasses.dataclass(frozen=True)
class TrainSpec:
    mode: AsyncMode = AsyncMode.BARRIER_EVERY_STEP
    adamw: AdamWConfig = AdamWConfig()
    outer: OuterConfig = OuterConfig()
    compressor: Optional[str] = None     # None | "int8" | "topk"
    compress_ratio: float = 0.01         # topk ratio
    quant_block: int = 1024


# ---------------------------------------------------------------------------
# State
# ---------------------------------------------------------------------------
def init_train_state(key, cfg, spec: TrainSpec, n_pods: int = 1):
    params = lm.init_params(key, cfg)
    state = {
        "params": params,
        "opt": adamw_mod.init_opt_state(params),
        "step": jnp.zeros((), jnp.int32),
    }
    if spec.mode == AsyncMode.BEST_EFFORT:
        state["others"] = jax.tree.map(jnp.zeros_like, params)
        if spec.compressor is not None:
            state["residuals"] = jax.tree.map(jnp.zeros_like, params)
    if spec.mode in (AsyncMode.ROLLING_BARRIER, AsyncMode.FIXED_BARRIER):
        state["outer"] = outer_mod.init_outer_state(params)
    # pod-stack every leaf except the step counter
    if n_pods >= 1:
        state = {k: (v if k == "step" else jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_pods,) + a.shape), v))
            for k, v in state.items()}
    return state


def abstract_train_state(cfg, spec: TrainSpec, n_pods: int = 1):
    return jax.eval_shape(
        lambda k: init_train_state(k, cfg, spec, n_pods), jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Compression along the pod-stacked dim (explicit small-payload gather)
# ---------------------------------------------------------------------------
def _compressed_total(grads, residuals, spec: TrainSpec):
    """Cross-pod sum with lossy payload: returns (total (1,...), residuals).

    The compact payload (int8 / top-k values+indices) is all-gathered across
    the pod dim — forced by a replication sharding-constraint on the payload
    — then decoded and summed locally, so the cross-pod collective moves the
    COMPRESSED bytes (see roofline collective term).
    """
    from repro.models import partitioning
    from repro.optim.compression import Int8Compressor, TopKCompressor
    comp = (Int8Compressor(block=spec.quant_block) if spec.compressor == "int8"
            else TopKCompressor(ratio=spec.compress_ratio))
    rules = partitioning.active()

    def replicate(p):
        # force the cross-POD all-gather onto the compact payload: pod dim
        # replicated, all other dims keep their inferred (data/model)
        # sharding — otherwise the payload is gathered across every axis
        # (measured 4x regression before this fix; see §Perf cell C)
        if rules is None:
            return p
        spec = P(None, *([P.UNCONSTRAINED] * (p.ndim - 1)))
        return jax.lax.with_sharding_constraint(
            p, jax.sharding.NamedSharding(rules.mesh, spec))

    def leaf(g, res):
        carry = g + res
        payload, new_res = jax.vmap(comp.encode)(carry)
        payload = jax.tree.map(replicate, payload)
        total = comp.decode_sum(payload, g.shape[1:], g.dtype)
        return total[None], new_res

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    out = [leaf(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------
def make_train_step(cfg, spec: TrainSpec, n_pods: int = 1, param_specs=None):
    mode = spec.mode

    def pod_loss(params, batch):
        return lm.loss_fn(params, batch, cfg, param_specs=param_specs)

    grad_fn = jax.grad(pod_loss, has_aux=True)

    def pod_grads(params, batch):
        if cfg.grad_accum <= 1:
            return grad_fn(params, batch)
        # microbatch accumulation: (A, B/A, ...) scan keeps live activations
        # to one microbatch
        def resplit(x):
            return x.reshape((cfg.grad_accum, x.shape[0] // cfg.grad_accum)
                             + x.shape[1:])
        micro = jax.tree.map(resplit, batch)

        def body(acc, mb):
            g, m = grad_fn(params, mb)
            return jax.tree.map(jnp.add, acc, (g, m)), None

        zeros = jax.eval_shape(lambda: grad_fn(params, jax.tree.map(
            lambda x: x[0], micro)))
        acc0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), zeros)
        (g, m), _ = jax.lax.scan(body, acc0, micro)
        inv = 1.0 / cfg.grad_accum
        return (jax.tree.map(lambda x: x * inv, g),
                jax.tree.map(lambda x: x * inv, m))

    def train_step(state, batch):
        step = state["step"]
        grads, metrics = jax.vmap(pod_grads)(state["params"], batch)

        # ---- cross-pod exchange (along the stacked pod dim) --------------
        new_state = dict(state)
        if mode == AsyncMode.BARRIER_EVERY_STEP:
            mean = jax.tree.map(lambda g: jnp.mean(g, 0, keepdims=True), grads)
            eff = jax.tree.map(
                lambda m, g: jnp.broadcast_to(m, g.shape), mean, grads)
        elif mode == AsyncMode.BEST_EFFORT:
            if spec.compressor is None:
                total = jax.tree.map(
                    lambda g: jnp.sum(g, 0, keepdims=True), grads)
            else:
                total, new_res = _compressed_total(
                    grads, state["residuals"], spec)
                new_state["residuals"] = new_res
            eff = jax.tree.map(
                lambda g, o: (g + o) / n_pods, grads, state["others"])
            new_state["others"] = jax.tree.map(
                lambda t, g: t - g, total, grads)
        else:  # modes 1, 2, 4: pod-local gradients
            eff = grads

        # ---- inner optimizer (vmapped over pods) --------------------------
        params, opt, opt_metrics = jax.vmap(
            lambda p, g, o: adamw_mod.apply_updates(p, g, o, spec.adamw)
        )(state["params"], eff, state["opt"])

        # ---- outer sync for modes 1/2 -------------------------------------
        if mode in (AsyncMode.ROLLING_BARRIER, AsyncMode.FIXED_BARRIER):
            period = spec.outer.sync_period
            do_sync = (step % period) == (period - 1)
            anchor = state["outer"]["anchor"]
            delta = jax.tree.map(
                lambda a, p: a - p.astype(jnp.float32), anchor, params)
            mean_delta = jax.tree.map(
                lambda d: jnp.broadcast_to(jnp.mean(d, 0, keepdims=True),
                                           d.shape), delta)
            synced_p, synced_o = jax.vmap(
                lambda p, o_st, d: outer_mod.outer_step(p, o_st, d, spec.outer)
            )(params, state["outer"], mean_delta)
            sel = lambda a, b: jax.tree.map(
                lambda x, y: jnp.where(do_sync, x, y), a, b)
            params = sel(synced_p, params)
            new_state["outer"] = sel(synced_o, state["outer"])

        new_state["params"] = params
        new_state["opt"] = opt
        new_state["step"] = step + 1
        out_metrics = {
            "loss": jnp.mean(metrics["ce"]),
            "aux": jnp.mean(metrics["aux"]),
            "grad_norm": jnp.mean(opt_metrics["grad_norm"]),
            "lr": opt_metrics["lr"][0],
        }
        return new_state, out_metrics

    return train_step


def make_batch_specs(cfg, rules, n_pods: int):
    """PartitionSpecs for the pod-stacked batch."""
    pod = "pod" if "pod" in rules.mesh.axis_names else None
    specs = {
        "tokens": P(pod, "data", None),
        "labels": P(pod, "data", None),
    }
    if cfg.frontend:
        specs[modality.frontend_input_name(cfg)] = P(pod, "data", None, None)
    return specs


# ---------------------------------------------------------------------------
# Training driver (fault-tolerant loop; used by examples and tests)
# ---------------------------------------------------------------------------
def run_training(cfg, spec: TrainSpec, data_cfg, *, steps: int,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
                 n_pods: int = 1, log_every: int = 10, log=print):
    """Train for ``steps`` steps with checkpoint/restart.

    Restores from the latest checkpoint in ``ckpt_dir`` if one exists (crash
    recovery / elastic restart); the deterministic per-step data stream
    resumes exactly.  Returns (state, history).
    """
    from repro import checkpoint as ckpt_mod
    from repro.data.synthetic import SyntheticLM

    source = SyntheticLM(data_cfg)
    state = init_train_state(jax.random.PRNGKey(cfg.vocab_size), cfg, spec,
                             n_pods)
    start = 0
    if ckpt_dir is not None:
        last = ckpt_mod.latest_step(ckpt_dir)
        if last is not None:
            state = ckpt_mod.restore(ckpt_dir, last,
                                     jax.eval_shape(lambda: state))
            start = last
            log(f"[train] restored checkpoint at step {last}")

    step_fn = jax.jit(make_train_step(cfg, spec, n_pods), donate_argnums=0)
    history = []

    def pod_batch(k):
        b = source.batch_for_step(k)
        out = {key: jnp.asarray(v).reshape((n_pods, v.shape[0] // n_pods)
                                           + v.shape[1:])
               for key, v in b.items()}
        if cfg.frontend:
            fe = source.frontend_for_step(k, cfg.frontend_len, cfg.d_model)
            out[modality.frontend_input_name(cfg)] = jnp.asarray(fe).reshape(
                (n_pods, fe.shape[0] // n_pods) + fe.shape[1:])
        return out

    for k in range(start, steps):
        state, metrics = step_fn(state, pod_batch(k))
        if (k + 1) % log_every == 0 or k == steps - 1:
            m = {key: float(v) for key, v in metrics.items()}
            history.append({"step": k + 1, **m})
            log(f"[train] step {k+1}: loss={m['loss']:.4f} "
                f"grad_norm={m['grad_norm']:.3f} lr={m['lr']:.2e}")
        if ckpt_dir is not None and (k + 1) % ckpt_every == 0:
            ckpt_mod.save(ckpt_dir, state, k + 1)
            ckpt_mod.prune(ckpt_dir, keep=2)
    return state, history
