"""Parameter/state PartitionSpec rules: FSDP over data axes × TP/EP over the
model axis, with automatic replication fallback on indivisible dims.
"""
from __future__ import annotations


import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.partitioning import MeshRules

# rules keyed by parameter name: logical spec for the UNSCANNED shape.
# "dp" = fsdp axes, "tp" = model axis, None = replicated.
_RULES = {
    # embeddings
    "embed": ("tp", "dp"),
    "unembed": ("tp", "dp"),
    "final_norm": (None,),
    # attention
    "wq": ("dp", "tp"), "wk": ("dp", "tp"), "wv": ("dp", "tp"),
    "wo": ("tp", "dp"),
    "bq": ("tp",), "bk": ("tp",), "bv": ("tp",),
    "q_norm": (None,), "k_norm": (None,), "out_norm": (None,),
    "mixer_norm": (None,), "ffn_norm": (None,),
    # dense mlp / shared expert
    "gate": ("dp", "tp"), "up": ("dp", "tp"), "down": ("tp", "dp"),
    # moe (expert-stacked 3-D weights; expert dim -> EP over model axis)
    "router": ("dp", None),
    "gate3": ("tp", "dp", None), "up3": ("tp", "dp", None),
    "down3": ("tp", "dp", None),
    # mamba
    "in_proj": ("dp", "tp"), "conv_w": (None, "tp"), "conv_b": ("tp",),
    "x_proj": ("tp", None), "dt_proj": (None, "tp"), "dt_bias": ("tp",),
    "A_log": ("tp", None), "D": ("tp",), "out_proj": ("tp", "dp"),
    # xlstm
    "up_proj": ("dp", "tp"), "down_proj": ("tp", "dp"),
    "w_if": ("tp", None), "b_if": (None,),
    "w": ("dp", "tp"), "r": (None, None, None, "tp"), "b": (None,),
}


def _logical_spec(path_names, shape) -> tuple:
    name = path_names[-1]
    if name in ("gate", "up", "down") and len(shape) >= 3 and "ffn" in path_names:
        # expert-stacked MoE weight (possibly with a leading scan dim)
        base = _RULES[name + "3"]
    elif name in _RULES:
        base = _RULES[name]
    else:
        base = (None,) * len(shape)
    # leading scan (period) dim -> None
    pad = len(shape) - len(base)
    assert pad >= 0, (path_names, shape, base)
    return (None,) * pad + tuple(base)


def _divisible(dim_size: int, axes, mesh) -> bool:
    if axes is None:
        return True
    axes = axes if isinstance(axes, tuple) else (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return dim_size % n == 0


def param_specs(params_like, rules: MeshRules):
    """Pytree of PartitionSpec matching ``params_like`` (arrays or
    ShapeDtypeStructs)."""

    def visit(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        logical = _logical_spec(names, leaf.shape)
        resolved = []
        for dim, role in zip(leaf.shape, logical):
            axes = rules.resolve(role)
            resolved.append(axes if _divisible(dim, axes, rules.mesh) else None)
        return P(*resolved)

    return jax.tree_util.tree_map_with_path(visit, params_like)


def param_shardings(params_like, rules: MeshRules):
    return jax.tree.map(lambda s: NamedSharding(rules.mesh, s),
                        param_specs(params_like, rules))


def with_pod_dim(spec_tree):
    """Prepend a "pod" axis to every spec (pod-stacked train state)."""
    return jax.tree.map(
        lambda s: P("pod", *s) if isinstance(s, P) else s, spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def shardings_from_specs(spec_tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
