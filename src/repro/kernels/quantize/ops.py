"""Public wrappers with padding/blocking + interpret fallback."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.quantize.kernel import dequantize_kernel, quantize_kernel


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def quantize(x, *, block: int = 1024, interpret=None):
    """Arbitrary tensor -> (q (nb,block) int8, scale (nb,1), orig_size)."""
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    padded = jnp.pad(flat, (0, pad)).reshape(-1, block)
    q, scale = quantize_kernel(padded, interpret=_auto_interpret(interpret))
    return q, scale, flat.size


def dequantize(q, scale, orig_size: int, shape=None, *, interpret=None):
    out = dequantize_kernel(q, scale, interpret=_auto_interpret(interpret))
    flat = out.reshape(-1)[:orig_size]
    return flat.reshape(shape) if shape is not None else flat
