from repro.kernels.quantize.ops import dequantize, quantize  # noqa: F401
from repro.kernels.quantize.ref import dequantize_ref, quantize_ref  # noqa: F401
