"""Blockwise symmetric int8 quantization — Pallas TPU kernel.

Halves (bf16) or quarters (fp32) the bytes of the cross-pod gradient payload.
Each VMEM block computes its own absmax scale; dequant is a fused multiply.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[0].astype(jnp.float32)
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q_ref[0] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s_ref[0, 0] = scale


def _dequant_kernel(q_ref, s_ref, o_ref):
    o_ref[0] = q_ref[0].astype(jnp.float32) * s_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_kernel(x, *, interpret: bool = False):
    """x: (nb, block) -> (q int8 (nb,block), scale fp32 (nb,1))."""
    nb, block = x.shape
    return pl.pallas_call(
        _quant_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, block), jnp.int8),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequantize_kernel(q, scale, *, interpret: bool = False):
    nb, block = q.shape
    return pl.pallas_call(
        _dequant_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(q, scale)
