"""Pure-jnp oracle for blockwise int8 quantization."""
from __future__ import annotations

import jax.numpy as jnp


def quantize_ref(x):
    """x: (nb, block) -> (q int8 (nb,block), scale fp32 (nb,1))."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_ref(q, scale):
    return q.astype(jnp.float32) * scale
