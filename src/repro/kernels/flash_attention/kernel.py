"""Causal GQA flash attention forward — Pallas TPU kernel.

Online-softmax accumulation over key blocks.  Grid: (BK, G, nq, nk) with the
key-block dim innermost; VMEM scratch carries the running (acc, m, l) across
key blocks of one query block.  Block shapes are MXU-aligned (multiples of
128 on the matmul dims; hd is the lane dim).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                  *, bq: int, bk: int, scale: float, causal: bool):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: key block strictly above the diagonal contributes nothing
    block_live = (not causal) or (ki * bk <= qi * bq + bq - 1)

    @pl.when(block_live if isinstance(block_live, bool) else block_live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0].astype(jnp.float32)               # (bk, hd)
        v = v_ref[0].astype(jnp.float32)               # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)

        m_prev = m_ref[...]                            # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                         # (bq, bk)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention_kernel(q, k, v, *, causal: bool = True, bq: int = 128,
                           bk: int = 128, interpret: bool = False):
    """q: (BK, G, S, hd); k, v: (BK, S, hd) -> (BK, G, S, hd)."""
    BK, G, S, hd = q.shape
    scale = hd ** -0.5
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    nq, nk = S // bq, S // bk

    grid = (BK, G, nq, nk)
    return pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, scale=scale,
                          causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, g, qi, ki: (b, g, qi, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, g, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, g, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, g, qi, ki: (b, g, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
