"""Pure-jnp oracle for causal GQA flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, scale=None):
    """q: (BK, G, S, hd); k, v: (BK, S, hd).  BK = batch * kv_heads;
    G = query heads per kv head.  Returns (BK, G, S, hd)."""
    BK, G, S, hd = q.shape
    scale = hd ** -0.5 if scale is None else scale
    s = jnp.einsum("bgqd,bkd->bgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bgqk,bkd->bgqd", p, v.astype(jnp.float32)).astype(q.dtype)
