"""Public wrapper: GQA layout handling + CPU interpret fallback."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention_kernel


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bk: int = 128, interpret=None):
    """Flash attention in model layout.

    q: (B, S, KH, G, hd); k, v: (B, S, KH, hd).  Returns (B, S, KH, G, hd).
    """
    B, S, KH, G, hd = q.shape
    qk = q.transpose(0, 2, 3, 1, 4).reshape(B * KH, G, S, hd)
    kk = k.transpose(0, 2, 1, 3).reshape(B * KH, S, hd)
    vk = v.transpose(0, 2, 1, 3).reshape(B * KH, S, hd)
    o = flash_attention_kernel(qk, kk, vk, causal=causal, bq=bq, bk=bk,
                               interpret=_auto_interpret(interpret))
    return o.reshape(B, KH, G, S, hd).transpose(0, 3, 1, 2, 4)
