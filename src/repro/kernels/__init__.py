"""Pallas TPU kernels for the perf-critical compute hot spots.

Each kernel subpackage has: ``kernel.py`` (pl.pallas_call + BlockSpec VMEM
tiling), ``ops.py`` (jit'd public wrapper; interpret-mode on CPU), and
``ref.py`` (pure-jnp oracle used by tests and the GSPMD dry-run path).

Kernels: flash_attention (train/prefill hot spot), decode_attention
(flash-decoding for 32k/500k KV), mlstm_attention (fused xLSTM sequence mix
— the §Perf cell-A identified fix), mamba_scan (VMEM-resident selective
scan — the cell-B identified fix), topk_compress + quantize (the
best-effort gradient-compression encode path).
"""
from repro.kernels import (  # noqa: F401
    decode_attention,
    flash_attention,
    mamba_scan,
    mlstm_attention,
    quantize,
    topk_compress,
)
