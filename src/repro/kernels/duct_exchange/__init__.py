from repro.kernels.duct_exchange.ops import (  # noqa: F401
    dense_halo_select,
    dense_stage,
    duct_commit,
    duct_commit_jnp,
    duct_drain,
    duct_exchange,
    duct_exchange_jnp,
    duct_send,
    duct_window,
    duct_window_jnp,
)
from repro.kernels.duct_exchange.ref import (  # noqa: F401
    duct_commit_ref,
    duct_exchange_ref,
    duct_window_ref,
)
