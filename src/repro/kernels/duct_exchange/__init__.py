from repro.kernels.duct_exchange.ops import (  # noqa: F401
    duct_drain,
    duct_exchange,
    duct_exchange_jnp,
    duct_send,
)
from repro.kernels.duct_exchange.ref import duct_exchange_ref  # noqa: F401
