"""Pure-numpy oracle for the fused best-effort duct exchange.

One lockstep window of duct traffic over a batch of directed edges, each
with a bounded ring buffer of in-flight messages (DESIGN.md §7):

  drain   the receiver pops FIFO messages whose availability time has
          passed — at most ``max_pops`` per window, and never past a
          not-yet-available head (Conduit's MPI_Testsome semantics)
  send    the sender then attempts one push; a full buffer means the
          message is DROPPED (best-effort, no retry); accepted messages
          are stamped ``send_now + send_lat`` (latency-delayed availability)

Payloads ride outside the op: callers move them with the returned
``pop_pos`` / ``push_pos`` ring indices, so one oracle covers scalar colors
and halo rows alike.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class ExchangeResult(NamedTuple):
    q_avail: np.ndarray     # (E, C) availability times (inf = empty slot)
    q_touch: np.ndarray     # (E, C) touch stamps
    head: np.ndarray        # (E,)   FIFO head slot
    size: np.ndarray        # (E,)   occupancy
    drained: np.ndarray     # (E,)   messages popped this window
    recv_touch: np.ndarray  # (E,)   touch of the freshest popped (0 if none)
    pop_pos: np.ndarray     # (E,)   ring slot of the freshest popped
    accepted: np.ndarray    # (E,)   bool: push accepted (not dropped)
    push_pos: np.ndarray    # (E,)   ring slot the push landed in


def duct_exchange_ref(q_avail, q_touch, head, size,
                      recv_now, recv_active,
                      send_now, send_active, send_lat, send_touch,
                      *, capacity: int, max_pops: int) -> ExchangeResult:
    q_avail = np.array(q_avail, dtype=np.float32, copy=True)
    q_touch = np.array(q_touch, dtype=np.int32, copy=True)
    head = np.array(head, dtype=np.int32, copy=True)
    size = np.array(size, dtype=np.int32, copy=True)
    E, C = q_avail.shape
    drained = np.zeros(E, dtype=np.int32)
    recv_touch = np.zeros(E, dtype=np.int32)
    pop_pos = np.array(head, copy=True)
    accepted = np.zeros(E, dtype=bool)
    push_pos = np.zeros(E, dtype=np.int32)

    for e in range(E):
        # -- drain: FIFO pops, head-blocking, bounded per window ------------
        if recv_active[e]:
            while (drained[e] < min(size[e], max_pops)
                   and q_avail[e, (head[e] + drained[e]) % C] <= recv_now[e]):
                pos = (head[e] + drained[e]) % C
                recv_touch[e] = q_touch[e, pos]
                pop_pos[e] = pos
                q_avail[e, pos] = np.inf
                drained[e] += 1
            head[e] = (head[e] + drained[e]) % C
            size[e] -= drained[e]
        # -- send attempt: drop iff the buffer is full ----------------------
        if send_active[e]:
            if size[e] < capacity:
                pos = (head[e] + size[e]) % C
                q_avail[e, pos] = send_now[e] + send_lat[e]
                q_touch[e, pos] = send_touch[e]
                push_pos[e] = pos
                size[e] += 1
                accepted[e] = True
    return ExchangeResult(q_avail, q_touch, head, size, drained,
                          recv_touch, pop_pos, accepted, push_pos)
