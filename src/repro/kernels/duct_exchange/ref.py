"""Pure-numpy oracle for the fused best-effort duct exchange.

One lockstep window of duct traffic over a batch of directed edges, each
with a bounded ring buffer of in-flight messages (DESIGN.md §7):

  drain   the receiver pops FIFO messages whose availability time has
          passed — at most ``max_pops`` per window, and never past a
          not-yet-available head (Conduit's MPI_Testsome semantics)
  send    the sender then attempts one push; a full buffer means the
          message is DROPPED (best-effort, no retry); accepted messages
          are stamped ``send_now + send_lat`` (latency-delayed availability)

Payloads ride outside the op: callers move them with the returned
``pop_pos`` / ``push_pos`` ring indices, so one oracle covers scalar colors
and halo rows alike.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class ExchangeResult(NamedTuple):
    q_avail: np.ndarray     # (E, C) availability times (inf = empty slot)
    q_touch: np.ndarray     # (E, C) touch stamps
    head: np.ndarray        # (E,)   FIFO head slot
    size: np.ndarray        # (E,)   occupancy
    drained: np.ndarray     # (E,)   messages popped this window
    recv_touch: np.ndarray  # (E,)   touch of the freshest popped (0 if none)
    pop_pos: np.ndarray     # (E,)   ring slot of the freshest popped
    accepted: np.ndarray    # (E,)   bool: push accepted (not dropped)
    push_pos: np.ndarray    # (E,)   ring slot the push landed in


def duct_exchange_ref(q_avail, q_touch, head, size,
                      recv_now, recv_active,
                      send_now, send_active, send_lat, send_touch,
                      *, capacity: int, max_pops: int) -> ExchangeResult:
    q_avail = np.array(q_avail, dtype=np.float32, copy=True)
    q_touch = np.array(q_touch, dtype=np.int32, copy=True)
    head = np.array(head, dtype=np.int32, copy=True)
    size = np.array(size, dtype=np.int32, copy=True)
    E, C = q_avail.shape
    drained = np.zeros(E, dtype=np.int32)
    recv_touch = np.zeros(E, dtype=np.int32)
    pop_pos = np.array(head, copy=True)
    accepted = np.zeros(E, dtype=bool)
    push_pos = np.zeros(E, dtype=np.int32)

    for e in range(E):
        # -- drain: FIFO pops, head-blocking, bounded per window ------------
        if recv_active[e]:
            while (drained[e] < min(size[e], max_pops)
                   and q_avail[e, (head[e] + drained[e]) % C] <= recv_now[e]):
                pos = (head[e] + drained[e]) % C
                recv_touch[e] = q_touch[e, pos]
                pop_pos[e] = pos
                q_avail[e, pos] = np.inf
                drained[e] += 1
            head[e] = (head[e] + drained[e]) % C
            size[e] -= drained[e]
        # -- send attempt: drop iff the buffer is full ----------------------
        if send_active[e]:
            if size[e] < capacity:
                pos = (head[e] + size[e]) % C
                q_avail[e, pos] = send_now[e] + send_lat[e]
                q_touch[e, pos] = send_touch[e]
                push_pos[e] = pos
                size[e] += 1
                accepted[e] = True
    return ExchangeResult(q_avail, q_touch, head, size, drained,
                          recv_touch, pop_pos, accepted, push_pos)


class WindowResult(NamedTuple):
    q_avail: np.ndarray    # (n, d, C) availability times
    q_touch: np.ndarray    # (n, d, C) touch stamps
    q_pay: np.ndarray      # (n, d, C, L) payloads
    head: np.ndarray       # (n, d) FIFO head slot
    size: np.ndarray       # (n, d) occupancy (push already counted by caller)
    drained: np.ndarray    # (n, d) messages popped this window
    recv_touch: np.ndarray  # (n, d) touch of the freshest popped (0 if none)
    halo_pay: np.ndarray   # (n, 4, L) freshest payload per halo slot
    halo_win: np.ndarray   # (n, 4) bool: slot refreshed this window


def duct_window_ref(q_avail, q_touch, q_pay, head, size,
                    push_pos, push_acc, push_avail, push_touch, push_pay,
                    recv_now, recv_active,
                    *, max_pops: int) -> WindowResult:
    """Oracle for the fused dense-layout window op (DESIGN.md §10).

    One lockstep window over a degree-regular receiver-major layout:
    receiver ``p`` owns rows ``(p, 0..d-1)``, its in-edge rings in
    sorted-source (= canonical edge id) order.  Three fused phases:

      push    apply the *previous* window's staged sends.  The send
              decision (drop-iff-full against post-drain occupancy, slot
              position, occupancy bump) was made eagerly by the caller at
              stage time, so the op only writes the accepted slots —
              ``size`` on entry already counts them
      drain   bounded FIFO pops at the receiver's clock (head-blocking)
      select  per receiver and halo slot ``s``, the freshest payload of
              the highest delivering row ``j`` with ``j % 4 == s`` —
              canonical-id tie-breaking as a register select

    Regrouping windows as (send_{k-1}; drain_k) pairs leaves the global
    drain/send sequence identical to the two-phase engine, so trajectories
    agree bitwise with the edge-major path.
    """
    q_avail = np.array(q_avail, dtype=np.float32, copy=True)
    q_touch = np.array(q_touch, dtype=np.int32, copy=True)
    q_pay = np.array(q_pay, copy=True)
    head = np.array(head, dtype=np.int32, copy=True)
    size = np.array(size, dtype=np.int32, copy=True)
    n, d, C = q_avail.shape
    L = q_pay.shape[-1]
    drained = np.zeros((n, d), np.int32)
    recv_touch = np.zeros((n, d), np.int32)
    halo_pay = np.zeros((n, 4, L), q_pay.dtype)
    halo_win = np.zeros((n, 4), bool)

    for p in range(n):
        for j in range(d):
            # -- push: apply the staged (already-accepted) write ----------
            if push_acc[p, j]:
                pos = int(push_pos[p, j])
                q_avail[p, j, pos] = push_avail[p, j]
                q_touch[p, j, pos] = push_touch[p, j]
                q_pay[p, j, pos] = push_pay[p, j]
            # -- drain: FIFO pops, head-blocking, bounded per window ------
            fresh_pay = None
            if recv_active[p]:
                while (drained[p, j] < min(size[p, j], max_pops)
                       and q_avail[p, j, (head[p, j] + drained[p, j]) % C]
                       <= recv_now[p]):
                    pos = (head[p, j] + drained[p, j]) % C
                    recv_touch[p, j] = q_touch[p, j, pos]
                    fresh_pay = q_pay[p, j, pos].copy()
                    q_avail[p, j, pos] = np.inf
                    drained[p, j] += 1
                head[p, j] = (head[p, j] + drained[p, j]) % C
                size[p, j] -= drained[p, j]
            # -- select: ascending j, so the highest delivering row wins --
            if fresh_pay is not None:
                halo_pay[p, j % 4] = fresh_pay
                halo_win[p, j % 4] = True
    return WindowResult(q_avail, q_touch, q_pay, head, size, drained,
                        recv_touch, halo_pay, halo_win)


class CommitResult(NamedTuple):
    q_avail: np.ndarray    # (R, C) availability times
    q_touch: np.ndarray    # (R, C) touch stamps
    q_pay: np.ndarray      # (R, C, L) payloads


def duct_commit_ref(q_avail, q_touch, q_pay, head, size0, pb_cnt,
                    pb_avail, pb_touch, pb_pay) -> CommitResult:
    """Oracle for the superstep commit (DESIGN.md §13).

    During a W-fused superstep the base ring arrays are frozen; this op
    folds the compact pushbuf — the superstep's accepted sends, in stage
    order — back into the ring.  Push ``j`` of ring ``r`` lands at slot
    ``(head[r] + size0[r] + j) % C``, exactly where the per-window path
    would have written it: FIFO order means the superstep's pops consume
    base entries before any pushbuf entry, so the tail slots are live (or
    provably popped, when the write wraps) regardless of interleaving.
    """
    q_avail = np.array(q_avail, dtype=np.float32, copy=True)
    q_touch = np.array(q_touch, dtype=np.int32, copy=True)
    q_pay = np.array(q_pay, copy=True)
    R, C = q_avail.shape
    for r in range(R):
        for j in range(int(pb_cnt[r])):
            slot = (int(head[r]) + int(size0[r]) + j) % C
            q_avail[r, slot] = pb_avail[r, j]
            q_touch[r, slot] = pb_touch[r, j]
            q_pay[r, slot] = pb_pay[r, j]
    return CommitResult(q_avail, q_touch, q_pay)
