"""Fused best-effort duct exchange — Pallas TPU kernel.

One lockstep window of duct traffic for a block of directed edges: the
send-attempt → capacity-drop → latency-stamp → drain pass fused into a
single VMEM-resident sweep.  Unlike the CPU jnp twin (which unrolls
``max_pops`` gather/scatter rounds), the kernel is gather-free: FIFO
offsets are recovered from a broadcasted lane iota, the drained prefix is
found with a row-min over blocked offsets, and pops/pushes are applied as
masked writes over the whole (block, capacity) tile — VPU-shaped work.

Grid is 1-D over edge blocks; each edge's ring is one tile row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.duct_exchange.ops import dense_halo_select

_BLOCK_EDGES = 256

# jax renamed TPUCompilerParams -> CompilerParams across releases
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _duct_kernel(qa_ref, qt_ref, head_ref, size_ref,
                 rnow_ref, ract_ref, snow_ref, sact_ref, slat_ref, stouch_ref,
                 qa_out, qt_out, head_out, size_out,
                 drained_out, rtouch_out, pop_pos_out,
                 accepted_out, push_pos_out,
                 *, capacity: int, max_pops: int):
    qa = qa_ref[...]                 # (B, C) availability times
    qt = qt_ref[...]                 # (B, C) touch stamps
    head = head_ref[...]             # (B, 1)
    size = size_ref[...]             # (B, 1)
    rnow, ract = rnow_ref[...], ract_ref[...]
    snow, sact = snow_ref[...], sact_ref[...]
    slat, stouch = slat_ref[...], stouch_ref[...]
    B, C = qa.shape

    col = jax.lax.broadcasted_iota(jnp.int32, (B, C), dimension=1)
    off = (col - head) % C           # FIFO offset of every ring slot
    valid = off < size
    # --- drain: longest available FIFO prefix, head-blocking, bounded -----
    blocked = valid & (qa > rnow)
    blocked_off = jnp.min(jnp.where(blocked, off, C), axis=1, keepdims=True)
    d = jnp.minimum(jnp.minimum(blocked_off, size), max_pops)
    d = jnp.where(ract > 0, d, 0)
    popped = valid & (off < d)
    rtouch = jnp.sum(jnp.where(popped & (off == d - 1), qt, 0),
                     axis=1, keepdims=True)
    pop_pos = jnp.where(d > 0, (head + d - 1) % C, head)
    qa = jnp.where(popped, jnp.inf, qa)
    head2 = (head + d) % C
    size2 = size - d
    # --- send attempt: drop iff full, stamp latency-delayed availability --
    acc = (sact > 0) & (size2 < capacity)
    slot = (head2 + size2) % C
    at_slot = acc & (col == slot)
    qa = jnp.where(at_slot, snow + slat, qa)
    qt = jnp.where(at_slot, jnp.broadcast_to(stouch, (B, C)), qt)
    push_pos = jnp.where(acc, slot, 0)
    size3 = size2 + acc

    qa_out[...] = qa
    qt_out[...] = qt
    head_out[...] = head2
    size_out[...] = size3
    drained_out[...] = d
    rtouch_out[...] = rtouch
    pop_pos_out[...] = pop_pos
    accepted_out[...] = acc.astype(jnp.int32)
    push_pos_out[...] = push_pos


def _window_kernel(qa_ref, qt_ref, qp_ref, head_ref, size_ref,
                   ppos_ref, pacc_ref, pav_ref, ptch_ref, ppay_ref,
                   rnow_ref, ract_ref,
                   qa_out, qt_out, qp_out, head_out, size_out,
                   drained_out, rtouch_out, hpay_out, hwin_out,
                   *, max_pops: int):
    """Fused dense-layout window: push-apply -> drain -> halo-select, one
    VMEM-resident sweep over a block of receivers' (d, C) ring tiles.

    The push phase only applies sends the engine already accepted (the
    drop-iff-full decision and occupancy bump happened eagerly at stage
    time), so the whole window's ring-state HBM traffic is this single
    read-modify-write pass.
    """
    qa = qa_ref[...]                 # (B, d, C) availability times
    qt = qt_ref[...]                 # (B, d, C) touch stamps
    qp = qp_ref[...]                 # (B, d, C, L) payloads
    head = head_ref[...]             # (B, d)
    size = size_ref[...]             # (B, d) — staged pushes already counted
    ppos, pacc = ppos_ref[...], pacc_ref[...]
    pav, ptch, ppay = pav_ref[...], ptch_ref[...], ppay_ref[...]
    rnow, ract = rnow_ref[...], ract_ref[...]   # (B, 1)
    B, d, C = qa.shape

    col = jax.lax.broadcasted_iota(jnp.int32, (B, d, C), dimension=2)
    # --- push: masked writes at the staged slots --------------------------
    at = (pacc > 0)[:, :, None] & (col == ppos[:, :, None])
    qa = jnp.where(at, pav[:, :, None], qa)
    qt = jnp.where(at, ptch[:, :, None], qt)
    qp = jnp.where(at[..., None], ppay[:, :, None, :], qp)
    # --- drain: longest available FIFO prefix, head-blocking, bounded -----
    off = (col - head[:, :, None]) % C
    valid = off < size[:, :, None]
    blocked = valid & (qa > rnow[:, :, None])
    blocked_off = jnp.min(jnp.where(blocked, off, C), axis=2)
    dr = jnp.minimum(jnp.minimum(blocked_off, size), max_pops)
    dr = jnp.where(ract > 0, dr, 0)
    popped = valid & (off < dr[:, :, None])
    fresh = popped & (off == dr[:, :, None] - 1)
    rtouch = jnp.sum(jnp.where(fresh, qt, 0), axis=2)
    fpay = jnp.sum(jnp.where(fresh[..., None], qp,
                             jnp.zeros((), qp.dtype)), axis=2)  # (B, d, L)
    qa = jnp.where(popped, jnp.inf, qa)
    # --- halo select: the shared ascending-j unrolled select --------------
    hpay, hwin = dense_halo_select(dr > 0, fpay)

    qa_out[...] = qa
    qt_out[...] = qt
    qp_out[...] = qp
    head_out[...] = (head + dr) % C
    size_out[...] = size - dr
    drained_out[...] = dr
    rtouch_out[...] = rtouch
    hpay_out[...] = hpay
    hwin_out[...] = hwin.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("max_pops", "interpret"))
def duct_window_kernel(q_avail, q_touch, q_pay, head, size,
                       push_pos, push_acc, push_avail, push_touch, push_pay,
                       recv_now, recv_active,
                       *, max_pops: int, interpret: bool = False):
    """Fused window megakernel over all receivers.  Returns the same tuple
    layout as ``ops.WindowResult`` (halo_win as bool)."""
    n, d, C = q_avail.shape
    L = q_pay.shape[-1]
    B = max(1, min(_BLOCK_EDGES // max(d, 1), n))
    pad = (-n) % B
    nb = (n + pad) // B

    def prep(x, dtype, tail=()):
        x = jnp.asarray(x, dtype).reshape((n,) + tail)
        return jnp.pad(x, ((0, pad),) + ((0, 0),) * len(tail))

    args = (prep(q_avail, jnp.float32, (d, C)),
            prep(q_touch, jnp.int32, (d, C)),
            prep(q_pay, q_pay.dtype, (d, C, L)),
            prep(head, jnp.int32, (d,)), prep(size, jnp.int32, (d,)),
            prep(push_pos, jnp.int32, (d,)),
            prep(push_acc, jnp.int32, (d,)),
            prep(push_avail, jnp.float32, (d,)),
            prep(push_touch, jnp.int32, (d,)),
            prep(push_pay, q_pay.dtype, (d, L)),
            prep(recv_now, jnp.float32, (1,)),
            prep(recv_active, jnp.int32, (1,)))

    spec = lambda *tail: pl.BlockSpec((B,) + tail,  # noqa: E731
                                      lambda i: (i,) + (0,) * len(tail))
    out = pl.pallas_call(
        functools.partial(_window_kernel, max_pops=max_pops),
        grid=(nb,),
        in_specs=[spec(d, C), spec(d, C), spec(d, C, L), spec(d), spec(d),
                  spec(d), spec(d), spec(d), spec(d), spec(d, L),
                  spec(1), spec(1)],
        out_specs=[spec(d, C), spec(d, C), spec(d, C, L), spec(d), spec(d),
                   spec(d), spec(d), spec(4, L), spec(4)],
        out_shape=[
            jax.ShapeDtypeStruct((n + pad, d, C), jnp.float32),
            jax.ShapeDtypeStruct((n + pad, d, C), jnp.int32),
            jax.ShapeDtypeStruct((n + pad, d, C, L), q_pay.dtype),
            jax.ShapeDtypeStruct((n + pad, d), jnp.int32),
            jax.ShapeDtypeStruct((n + pad, d), jnp.int32),
            jax.ShapeDtypeStruct((n + pad, d), jnp.int32),
            jax.ShapeDtypeStruct((n + pad, d), jnp.int32),
            jax.ShapeDtypeStruct((n + pad, 4, L), q_pay.dtype),
            jax.ShapeDtypeStruct((n + pad, 4), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(*args)
    qa2, qt2, qp2, head2, size2, drained, rtouch, hpay, hwin = out
    return (qa2[:n], qt2[:n], qp2[:n], head2[:n], size2[:n], drained[:n],
            rtouch[:n], hpay[:n], hwin[:n].astype(bool))


def _commit_kernel(qa_ref, qt_ref, qp_ref, head_ref, size0_ref, cnt_ref,
                   pav_ref, ptch_ref, ppay_ref,
                   qa_out, qt_out, qp_out):
    """Superstep commit: fold each ring's compact pushbuf (up to W staged
    pushes) into the base ring at the live-tail slots.  Gather-free: every
    ring slot recovers its pushbuf index from a column iota and the write
    is an ascending-j unrolled masked select — dead (j >= cnt) slots keep
    their base values.
    """
    qa = qa_ref[...]                 # (B, C)
    qt = qt_ref[...]                 # (B, C)
    qp = qp_ref[...]                 # (B, C, L)
    head = head_ref[...]             # (B, 1)
    size0 = size0_ref[...]           # (B, 1)
    cnt = cnt_ref[...]               # (B, 1)
    pav, ptch, ppay = pav_ref[...], ptch_ref[...], ppay_ref[...]
    B, C = qa.shape
    W = pav.shape[1]

    col = jax.lax.broadcasted_iota(jnp.int32, (B, C), dimension=1)
    for j in range(W):
        at = (col == (head + size0 + j) % C) & (j < cnt)
        qa = jnp.where(at, pav[:, j:j + 1], qa)
        qt = jnp.where(at, ptch[:, j:j + 1], qt)
        qp = jnp.where(at[..., None], ppay[:, j:j + 1, :], qp)
    qa_out[...] = qa
    qt_out[...] = qt
    qp_out[...] = qp


@functools.partial(jax.jit, static_argnames=("interpret",))
def duct_commit_kernel(q_avail, q_touch, q_pay, head, size0, pb_cnt,
                       pb_avail, pb_touch, pb_pay, *,
                       interpret: bool = False):
    """Fused superstep commit over all rings.  Returns the same tuple
    layout as ``ops.CommitResult``."""
    R, C = q_avail.shape
    W = pb_avail.shape[1]
    L = q_pay.shape[-1]
    B = min(_BLOCK_EDGES, R)
    pad = (-R) % B
    nb = (R + pad) // B

    def prep(x, dtype, tail=()):
        x = jnp.asarray(x, dtype).reshape((R,) + tail)
        return jnp.pad(x, ((0, pad),) + ((0, 0),) * len(tail))

    args = (prep(q_avail, jnp.float32, (C,)),
            prep(q_touch, jnp.int32, (C,)),
            prep(q_pay, q_pay.dtype, (C, L)),
            prep(head, jnp.int32, (1,)), prep(size0, jnp.int32, (1,)),
            prep(pb_cnt, jnp.int32, (1,)),
            prep(pb_avail, jnp.float32, (W,)),
            prep(pb_touch, jnp.int32, (W,)),
            prep(pb_pay, q_pay.dtype, (W, L)))

    spec = lambda *tail: pl.BlockSpec((B,) + tail,  # noqa: E731
                                      lambda i: (i,) + (0,) * len(tail))
    out = pl.pallas_call(
        _commit_kernel,
        grid=(nb,),
        in_specs=[spec(C), spec(C), spec(C, L), spec(1), spec(1), spec(1),
                  spec(W), spec(W), spec(W, L)],
        out_specs=[spec(C), spec(C), spec(C, L)],
        out_shape=[
            jax.ShapeDtypeStruct((R + pad, C), jnp.float32),
            jax.ShapeDtypeStruct((R + pad, C), jnp.int32),
            jax.ShapeDtypeStruct((R + pad, C, L), q_pay.dtype),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(*args)
    qa2, qt2, qp2 = out
    return qa2[:R], qt2[:R], qp2[:R]


@functools.partial(jax.jit,
                   static_argnames=("capacity", "max_pops", "interpret"))
def duct_exchange_kernel(q_avail, q_touch, head, size,
                         recv_now, recv_active,
                         send_now, send_active, send_lat, send_touch,
                         *, capacity: int, max_pops: int,
                         interpret: bool = False):
    """Fused drain→send over all edges.  Returns the same tuple layout as
    ``ops.ExchangeResult`` (accepted as bool)."""
    E, C = q_avail.shape
    B = min(_BLOCK_EDGES, E)
    pad = (-E) % B
    nb = (E + pad) // B

    def col1(x, dtype):
        x = jnp.asarray(x, dtype).reshape(E, 1)
        return jnp.pad(x, ((0, pad), (0, 0)))

    qa = jnp.pad(jnp.asarray(q_avail, jnp.float32), ((0, pad), (0, 0)))
    qt = jnp.pad(jnp.asarray(q_touch, jnp.int32), ((0, pad), (0, 0)))
    args = (qa, qt, col1(head, jnp.int32), col1(size, jnp.int32),
            col1(recv_now, jnp.float32), col1(recv_active, jnp.int32),
            col1(send_now, jnp.float32), col1(send_active, jnp.int32),
            col1(send_lat, jnp.float32), col1(send_touch, jnp.int32))

    ring = lambda i: (i, 0)  # noqa: E731 — shared index map
    ring_spec = lambda: pl.BlockSpec((B, C), ring)       # noqa: E731
    vec_spec = lambda: pl.BlockSpec((B, 1), ring)        # noqa: E731
    out = pl.pallas_call(
        functools.partial(_duct_kernel, capacity=capacity,
                          max_pops=max_pops),
        grid=(nb,),
        in_specs=[ring_spec(), ring_spec()] + [vec_spec()] * 8,
        out_specs=[ring_spec(), ring_spec()] + [vec_spec()] * 7,
        out_shape=[
            jax.ShapeDtypeStruct((E + pad, C), jnp.float32),
            jax.ShapeDtypeStruct((E + pad, C), jnp.int32),
        ] + [jax.ShapeDtypeStruct((E + pad, 1), jnp.int32)] * 7,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(*args)
    qa2, qt2, head2, size2, drained, rtouch, pop_pos, acc, push_pos = out
    flat = lambda x: x[:E, 0]  # noqa: E731
    return (qa2[:E], qt2[:E], flat(head2), flat(size2), flat(drained),
            flat(rtouch), flat(pop_pos), flat(acc).astype(bool),
            flat(push_pos))
