"""Public duct-exchange wrappers: jnp twins + backend dispatch.

``duct_drain`` / ``duct_send`` are the two phases as pure-jnp functions —
the vectorized engine calls them separately around the application step
(drain feeds the halos the step consumes; the step's outputs feed the
send).  ``duct_exchange`` is the fused drain→send pass: the Pallas kernel
implements it in one VMEM-resident sweep on TPU, with the jnp composition
as the CPU/GPU path.  All three agree slot-for-slot with
``ref.duct_exchange_ref``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class DrainResult(NamedTuple):
    q_avail: jax.Array
    q_touch: jax.Array
    head: jax.Array
    size: jax.Array
    drained: jax.Array     # (E,) i32 messages popped
    recv_touch: jax.Array  # (E,) i32 touch of freshest popped (0 if none)
    pop_pos: jax.Array     # (E,) i32 ring slot of freshest popped


class SendResult(NamedTuple):
    q_avail: jax.Array
    q_touch: jax.Array
    size: jax.Array
    accepted: jax.Array    # (E,) bool — push accepted (False = dropped)
    push_pos: jax.Array    # (E,) i32 ring slot the push landed in


def duct_drain(q_avail, q_touch, head, size, recv_now, recv_active,
               *, max_pops: int, clear_popped: bool = True) -> DrainResult:
    """Bounded FIFO drain: pop while the head message is available.

    ``max_pops`` sequential pop attempts are unrolled; a pop chain stops at
    the first slot that is empty or not yet available (head-blocking, as in
    the event engine's ``Duct.latest``).

    ``clear_popped=False`` skips resetting popped availability slots to inf
    — a hot-loop optimization: slots outside ``[head, head+size)`` are
    never read, so only callers comparing raw ring state (parity tests)
    need the reset.
    """
    E, C = q_avail.shape
    rows = jnp.arange(E)
    drained = jnp.zeros(E, dtype=jnp.int32)
    alive = recv_active
    for i in range(max_pops):
        avail_i = q_avail[rows, (head + i) % C]
        can = alive & (i < size) & (avail_i <= recv_now)
        drained = drained + can
        alive = can
    delivered = drained > 0
    pop_pos = jnp.where(delivered, (head + drained - 1) % C,
                        head).astype(jnp.int32)
    recv_touch = jnp.where(delivered, q_touch[rows, pop_pos], 0)
    if clear_popped:
        off = (jnp.arange(C)[None, :] - head[:, None]) % C
        q_avail = jnp.where(off < drained[:, None], jnp.inf, q_avail)
    return DrainResult(q_avail, q_touch, (head + drained) % C,
                       size - drained, drained, recv_touch, pop_pos)


def duct_send(q_avail, q_touch, head, size,
              send_now, send_active, send_lat, send_touch,
              *, capacity: int) -> SendResult:
    """Best-effort push: drop iff the buffer is full; stamp latency."""
    E, C = q_avail.shape
    rows = jnp.arange(E)
    accepted = send_active & (size < capacity)
    pos = (head + size) % C
    # drop-mode scatter: rejected rows index out of bounds instead of
    # gathering old values for a where()
    safe_rows = jnp.where(accepted, rows, E)
    q_avail = q_avail.at[safe_rows, pos].set(send_now + send_lat,
                                             mode="drop")
    q_touch = q_touch.at[safe_rows, pos].set(send_touch, mode="drop")
    push_pos = jnp.where(accepted, pos, 0).astype(jnp.int32)
    return SendResult(q_avail, q_touch, size + accepted, accepted, push_pos)


class ExchangeResult(NamedTuple):
    q_avail: jax.Array
    q_touch: jax.Array
    head: jax.Array
    size: jax.Array
    drained: jax.Array
    recv_touch: jax.Array
    pop_pos: jax.Array
    accepted: jax.Array
    push_pos: jax.Array


def duct_exchange_jnp(q_avail, q_touch, head, size,
                      recv_now, recv_active,
                      send_now, send_active, send_lat, send_touch,
                      *, capacity: int, max_pops: int) -> ExchangeResult:
    """Fused drain→send as the composition of the two jnp phases."""
    d = duct_drain(q_avail, q_touch, head, size, recv_now, recv_active,
                   max_pops=max_pops)
    s = duct_send(d.q_avail, d.q_touch, d.head, d.size,
                  send_now, send_active, send_lat, send_touch,
                  capacity=capacity)
    return ExchangeResult(s.q_avail, s.q_touch, d.head, s.size, d.drained,
                          d.recv_touch, d.pop_pos, s.accepted, s.push_pos)


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def duct_exchange(q_avail, q_touch, head, size,
                  recv_now, recv_active,
                  send_now, send_active, send_lat, send_touch,
                  *, capacity: int, max_pops: int,
                  use_pallas: bool = None,
                  interpret=None) -> ExchangeResult:
    """Backend dispatch: Pallas kernel on TPU, jnp twin elsewhere.

    ``use_pallas=True`` forces the kernel (with ``interpret`` controlling
    the Pallas interpreter, for CPU parity tests).
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return duct_exchange_jnp(
            q_avail, q_touch, head, size, recv_now, recv_active,
            send_now, send_active, send_lat, send_touch,
            capacity=capacity, max_pops=max_pops)
    from repro.kernels.duct_exchange.kernel import duct_exchange_kernel
    return ExchangeResult(*duct_exchange_kernel(
        q_avail, q_touch, head, size, recv_now, recv_active,
        send_now, send_active, send_lat, send_touch,
        capacity=capacity, max_pops=max_pops,
        interpret=_auto_interpret(interpret)))
