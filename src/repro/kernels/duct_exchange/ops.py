"""Public duct-exchange wrappers: jnp twins + backend dispatch.

``duct_drain`` / ``duct_send`` are the two phases as pure-jnp functions —
the vectorized engine calls them separately around the application step
(drain feeds the halos the step consumes; the step's outputs feed the
send).  ``duct_exchange`` is the fused drain→send pass: the Pallas kernel
implements it in one VMEM-resident sweep on TPU, with the jnp composition
as the CPU/GPU path.  All three agree slot-for-slot with
``ref.duct_exchange_ref``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class DrainResult(NamedTuple):
    q_avail: jax.Array
    q_touch: jax.Array
    head: jax.Array
    size: jax.Array
    drained: jax.Array     # (E,) i32 messages popped
    recv_touch: jax.Array  # (E,) i32 touch of freshest popped (0 if none)
    pop_pos: jax.Array     # (E,) i32 ring slot of freshest popped


class SendResult(NamedTuple):
    q_avail: jax.Array
    q_touch: jax.Array
    size: jax.Array
    accepted: jax.Array    # (E,) bool — push accepted (False = dropped)
    push_pos: jax.Array    # (E,) i32 ring slot the push landed in


def duct_drain(q_avail, q_touch, head, size, recv_now, recv_active,
               *, max_pops: int, clear_popped: bool = True) -> DrainResult:
    """Bounded FIFO drain: pop while the head message is available.

    ``max_pops`` sequential pop attempts are unrolled; a pop chain stops at
    the first slot that is empty or not yet available (head-blocking, as in
    the event engine's ``Duct.latest``).

    ``clear_popped=False`` skips resetting popped availability slots to inf
    — a hot-loop optimization: slots outside ``[head, head+size)`` are
    never read, so only callers comparing raw ring state (parity tests)
    need the reset.
    """
    E, C = q_avail.shape
    rows = jnp.arange(E)
    drained = jnp.zeros(E, dtype=jnp.int32)
    alive = recv_active
    for i in range(max_pops):
        avail_i = q_avail[rows, (head + i) % C]
        can = alive & (i < size) & (avail_i <= recv_now)
        drained = drained + can
        alive = can
    delivered = drained > 0
    pop_pos = jnp.where(delivered, (head + drained - 1) % C,
                        head).astype(jnp.int32)
    recv_touch = jnp.where(delivered, q_touch[rows, pop_pos], 0)
    if clear_popped:
        off = (jnp.arange(C)[None, :] - head[:, None]) % C
        q_avail = jnp.where(off < drained[:, None], jnp.inf, q_avail)
    return DrainResult(q_avail, q_touch, (head + drained) % C,
                       size - drained, drained, recv_touch, pop_pos)


def duct_send(q_avail, q_touch, head, size,
              send_now, send_active, send_lat, send_touch,
              *, capacity: int) -> SendResult:
    """Best-effort push: drop iff the buffer is full; stamp latency."""
    E, C = q_avail.shape
    rows = jnp.arange(E)
    accepted = send_active & (size < capacity)
    pos = (head + size) % C
    # drop-mode scatter: rejected rows index out of bounds instead of
    # gathering old values for a where()
    safe_rows = jnp.where(accepted, rows, E)
    q_avail = q_avail.at[safe_rows, pos].set(send_now + send_lat,
                                             mode="drop")
    q_touch = q_touch.at[safe_rows, pos].set(send_touch, mode="drop")
    push_pos = jnp.where(accepted, pos, 0).astype(jnp.int32)
    return SendResult(q_avail, q_touch, size + accepted, accepted, push_pos)


class ExchangeResult(NamedTuple):
    q_avail: jax.Array
    q_touch: jax.Array
    head: jax.Array
    size: jax.Array
    drained: jax.Array
    recv_touch: jax.Array
    pop_pos: jax.Array
    accepted: jax.Array
    push_pos: jax.Array


def duct_exchange_jnp(q_avail, q_touch, head, size,
                      recv_now, recv_active,
                      send_now, send_active, send_lat, send_touch,
                      *, capacity: int, max_pops: int) -> ExchangeResult:
    """Fused drain→send as the composition of the two jnp phases."""
    d = duct_drain(q_avail, q_touch, head, size, recv_now, recv_active,
                   max_pops=max_pops)
    s = duct_send(d.q_avail, d.q_touch, d.head, d.size,
                  send_now, send_active, send_lat, send_touch,
                  capacity=capacity)
    return ExchangeResult(s.q_avail, s.q_touch, d.head, s.size, d.drained,
                          d.recv_touch, d.pop_pos, s.accepted, s.push_pos)


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


# ---------------------------------------------------------------------------
# Fused dense-layout window megakernel (DESIGN.md §10)
# ---------------------------------------------------------------------------
class WindowResult(NamedTuple):
    q_avail: jax.Array     # (n, d, C)
    q_touch: jax.Array     # (n, d, C)
    q_pay: jax.Array       # (n, d, C, L)
    head: jax.Array        # (n, d)
    size: jax.Array        # (n, d)
    drained: jax.Array     # (n, d) i32 messages popped
    recv_touch: jax.Array  # (n, d) i32 touch of freshest popped (0 if none)
    halo_pay: jax.Array    # (n, 4, L) freshest payload per halo slot
    halo_win: jax.Array    # (n, 4) bool: slot refreshed this window


def dense_halo_select(delivered, payload):
    """Per-receiver halo merge for the dense layout: slot ``s`` takes the
    payload of the highest delivering row ``j`` with ``j % 4 == s``.

    Rows are in sorted-source order, which for a fixed receiver is
    canonical-edge-id order, so "highest j wins" reproduces the edge-major
    path's segment_max tie-break as a d-step unrolled select — no scatter.
    ``delivered``: (n, d) bool; ``payload``: (n, d, L).  Returns
    ``(halo_pay (n, 4, L), halo_win (n, 4))``.
    """
    n, d = delivered.shape
    L = payload.shape[-1]
    pay_cols, win_cols = [], []
    for s in range(4):
        pay_s = jnp.zeros((n, L), payload.dtype)
        win_s = jnp.zeros((n,), bool)
        for j in range(s, d, 4):
            pay_s = jnp.where(delivered[:, j, None], payload[:, j], pay_s)
            win_s = win_s | delivered[:, j]
        pay_cols.append(pay_s)
        win_cols.append(win_s)
    return jnp.stack(pay_cols, axis=1), jnp.stack(win_cols, axis=1)


def dense_stage(head, size, active, *, capacity: int):
    """Eager stage decision for the dense layout: drop iff the ring is
    full *now*, against post-drain occupancy — the same judgement
    ``duct_send`` makes on the edge-major path, made one window early so
    the ring writes can ride into the next fused ``duct_window`` pass.
    Returns ``(pos, accepted)``: the slot each accepted push will land in
    and the per-ring accept mask.  The caller owns the occupancy bump
    (``size + accepted``) so its counters stay in this window.
    """
    accepted = active & (size < capacity)
    pos = (head + size) % capacity
    return pos, accepted


def duct_window_jnp(q_avail, q_touch, q_pay, head, size,
                    push_pos, push_acc, push_avail, push_touch, push_pay,
                    recv_now, recv_active,
                    *, max_pops: int) -> WindowResult:
    """jnp twin of the fused window op: push-apply -> drain -> halo-select.

    Same contract as ``ref.duct_window_ref``: the push phase only *applies*
    sends the caller already accepted (drop-iff-full and the slot position
    were decided eagerly at stage time, and ``size`` counts them), then the
    drain pops the longest available FIFO prefix per ring via the lane
    formulation (blocked-offset row-min — gather-free, the same shape of
    work the Pallas kernel does), and the freshest payloads merge into the
    (n, 4, L) halo with ascending-row selects.
    """
    n, d, C = q_avail.shape
    L = q_pay.shape[-1]
    R = n * d
    qa = q_avail.reshape(R, C)
    qt = q_touch.reshape(R, C)
    qp = q_pay.reshape(R, C, L)
    head_f = head.reshape(R)
    size_f = size.reshape(R)
    col = jnp.arange(C, dtype=jnp.int32)[None, :]
    # --- push: masked writes at the staged slots ----------------------
    at = push_acc.reshape(R)[:, None] & (col == push_pos.reshape(R)[:, None])
    qa = jnp.where(at, push_avail.reshape(R)[:, None], qa)
    qt = jnp.where(at, push_touch.reshape(R)[:, None], qt)
    qp = jnp.where(at[:, :, None], push_pay.reshape(R, 1, L), qp)
    # --- drain: longest available FIFO prefix, head-blocking, bounded --
    off = (col - head_f[:, None]) % C
    valid = off < size_f[:, None]
    rnow = jnp.broadcast_to(recv_now[:, None], (n, d)).reshape(R)
    ract = jnp.broadcast_to(recv_active[:, None], (n, d)).reshape(R)
    blocked = valid & (qa > rnow[:, None])
    blocked_off = jnp.min(jnp.where(blocked, off, C), axis=1)
    dr = jnp.minimum(jnp.minimum(blocked_off, size_f), max_pops)
    dr = jnp.where(ract, dr, 0).astype(jnp.int32)
    popped = valid & (off < dr[:, None])
    fresh = popped & (off == dr[:, None] - 1)
    recv_touch = jnp.sum(jnp.where(fresh, qt, 0), axis=1)
    fresh_pay = jnp.sum(jnp.where(fresh[:, :, None], qp,
                                  jnp.zeros((), qp.dtype)), axis=1)
    qa = jnp.where(popped, jnp.inf, qa)
    head2 = (head_f + dr) % C
    size2 = size_f - dr
    halo_pay, halo_win = dense_halo_select(
        (dr > 0).reshape(n, d), fresh_pay.reshape(n, d, L))
    return WindowResult(
        qa.reshape(n, d, C), qt.reshape(n, d, C), qp.reshape(n, d, C, L),
        head2.reshape(n, d), size2.reshape(n, d), dr.reshape(n, d),
        recv_touch.reshape(n, d), halo_pay, halo_win)


def duct_window(q_avail, q_touch, q_pay, head, size,
                push_pos, push_acc, push_avail, push_touch, push_pay,
                recv_now, recv_active,
                *, max_pops: int,
                use_pallas: bool = None,
                interpret=None) -> WindowResult:
    """Backend dispatch for the fused window op: Pallas megakernel on TPU
    (one VMEM-resident sweep per receiver block), jnp twin elsewhere."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return duct_window_jnp(
            q_avail, q_touch, q_pay, head, size,
            push_pos, push_acc, push_avail, push_touch, push_pay,
            recv_now, recv_active, max_pops=max_pops)
    from repro.kernels.duct_exchange.kernel import duct_window_kernel
    return WindowResult(*duct_window_kernel(
        q_avail, q_touch, q_pay, head, size,
        push_pos, push_acc, push_avail, push_touch, push_pay,
        recv_now, recv_active, max_pops=max_pops,
        interpret=_auto_interpret(interpret)))


class CommitResult(NamedTuple):
    q_avail: jax.Array     # (R, C)
    q_touch: jax.Array     # (R, C)
    q_pay: jax.Array       # (R, C, L)


def duct_commit_jnp(q_avail, q_touch, q_pay, head, size0, pb_cnt,
                    pb_avail, pb_touch, pb_pay) -> CommitResult:
    """jnp twin of the superstep commit: fold the compact pushbuf into the
    base rings.  Push ``j`` of ring ``r`` lands at slot
    ``(head[r] + size0[r] + j) % C`` — the live-tail slot the per-window
    path would have written it to, independent of how the superstep's pops
    interleaved with its pushes (FIFO: base drains all precede pushbuf
    drains, so an already-popped pushbuf entry's slot sits behind the
    advanced head and is dead).  Every ring slot recovers which pushbuf
    index lands on it; the fold is a one-hot multiply-accumulate over the
    W pushbuf columns rather than a ``take_along_axis`` (XLA:CPU lowers
    the (R, C) gather to a serial row loop) or a *sequential* chain of W
    masked writes (each link materializes a full (R, C[, L]) intermediate
    — a superstep-dominating copy storm inside a scan).  The sum-of-
    products form is a pure elementwise DAG, so XLA fuses it into a
    single sweep per output array."""
    R, C = q_avail.shape
    W = pb_avail.shape[1]
    col = jnp.arange(C, dtype=jnp.int32)[None, :]
    j = (col - head[:, None] - size0[:, None]) % C
    wr = j < pb_cnt[:, None]
    hot = [(j == w) for w in range(W)]
    acc_a = sum(jnp.where(hot[w], pb_avail[:, w, None], 0.0)
                for w in range(W))
    acc_t = sum(jnp.where(hot[w], pb_touch[:, w, None], 0)
                for w in range(W))
    acc_p = sum(jnp.where(hot[w][:, :, None], pb_pay[:, w, None, :], 0)
                for w in range(W))
    qa = jnp.where(wr, acc_a, q_avail)
    qt = jnp.where(wr, acc_t, q_touch)
    qp = jnp.where(wr[:, :, None], acc_p, q_pay)
    return CommitResult(qa, qt, qp)


def duct_commit(q_avail, q_touch, q_pay, head, size0, pb_cnt,
                pb_avail, pb_touch, pb_pay,
                *, use_pallas: bool = None,
                interpret=None) -> CommitResult:
    """Backend dispatch for the superstep commit: Pallas kernel on TPU
    (one masked-select sweep per ring block, gather-free), jnp twin
    elsewhere.  Slot-exact with ``ref.duct_commit_ref``."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return duct_commit_jnp(q_avail, q_touch, q_pay, head, size0,
                               pb_cnt, pb_avail, pb_touch, pb_pay)
    from repro.kernels.duct_exchange.kernel import duct_commit_kernel
    return CommitResult(*duct_commit_kernel(
        q_avail, q_touch, q_pay, head, size0, pb_cnt,
        pb_avail, pb_touch, pb_pay, interpret=_auto_interpret(interpret)))


def duct_exchange(q_avail, q_touch, head, size,
                  recv_now, recv_active,
                  send_now, send_active, send_lat, send_touch,
                  *, capacity: int, max_pops: int,
                  use_pallas: bool = None,
                  interpret=None) -> ExchangeResult:
    """Backend dispatch: Pallas kernel on TPU, jnp twin elsewhere.

    ``use_pallas=True`` forces the kernel (with ``interpret`` controlling
    the Pallas interpreter, for CPU parity tests).
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return duct_exchange_jnp(
            q_avail, q_touch, head, size, recv_now, recv_active,
            send_now, send_active, send_lat, send_touch,
            capacity=capacity, max_pops=max_pops)
    from repro.kernels.duct_exchange.kernel import duct_exchange_kernel
    return ExchangeResult(*duct_exchange_kernel(
        q_avail, q_touch, head, size, recv_now, recv_active,
        send_now, send_active, send_lat, send_touch,
        capacity=capacity, max_pops=max_pops,
        interpret=_auto_interpret(interpret)))
