"""Selective SSM scan (Mamba-1) — Pallas TPU kernel.

The §Perf cell-B analysis showed the jnp chunked scan is HBM-bound on its
fp32 (B, L, di, N) discretization tensors.  This kernel never materializes
them: the recurrence runs time-sequentially INSIDE the kernel on
VMEM-resident operands (x/dt/B/C chunk blocks + the carried state h), so
HBM traffic collapses to the projected inputs and y out — the state (di
tile × N) lives in VMEM scratch across sequence chunks.

Grid: (batch, di-tiles, seq-chunks) with the chunk dim innermost and
"arbitrary" (sequential — it carries h).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mamba_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, hout_ref,
                  h_ref, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = a_ref[...].astype(jnp.float32)              # (bdi, N)

    def step(t, h):
        x_t = x_ref[0, t].astype(jnp.float32)       # (bdi,)
        dt_t = dt_ref[0, t].astype(jnp.float32)     # (bdi,)
        B_t = b_ref[0, t].astype(jnp.float32)       # (N,)
        C_t = c_ref[0, t].astype(jnp.float32)       # (N,)
        dA = jnp.exp(dt_t[:, None] * A)             # (bdi, N)
        h = dA * h + (dt_t * x_t)[:, None] * B_t[None, :]
        y_ref[0, t] = (h @ C_t).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(ci == nc - 1)
    def _done():
        hout_ref[0] = h_ref[...]


@functools.partial(jax.jit, static_argnames=("bdi", "chunk", "interpret"))
def mamba_scan_kernel(x, dt, B, C, A, *, bdi: int = 256, chunk: int = 128,
                      interpret: bool = False):
    """x, dt: (Bb, S, di); B, C: (Bb, S, N); A: (di, N).
    Returns (y (Bb, S, di), h_final (Bb, di, N))."""
    Bb, S, di = x.shape
    N = A.shape[1]
    bdi = min(bdi, di)
    chunk = min(chunk, S)
    assert di % bdi == 0 and S % chunk == 0, (di, bdi, S, chunk)
    ndi, nc = di // bdi, S // chunk

    y, h_final = pl.pallas_call(
        functools.partial(_mamba_kernel, chunk=chunk),
        grid=(Bb, ndi, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, bdi), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, bdi), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((bdi, N), lambda b, d, c: (d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bdi), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, bdi, N), lambda b, d, c: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, S, di), x.dtype),
            jax.ShapeDtypeStruct((Bb, di, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bdi, N), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, B, C, A)
    return y, h_final
