"""Public wrapper with CPU interpret fallback."""
from __future__ import annotations

import jax

from repro.kernels.mamba_scan.kernel import mamba_scan_kernel


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def mamba_scan(x, dt, B, C, A, *, bdi: int = 256, chunk: int = 128,
               interpret=None):
    """Selective scan: x/dt (Bb,S,di), B/C (Bb,S,N), A (di,N) ->
    (y (Bb,S,di), h_final (Bb,di,N))."""
    return mamba_scan_kernel(x, dt, B, C, A, bdi=bdi, chunk=chunk,
                             interpret=_auto_interpret(interpret))
