"""Pure-jnp oracle for the selective SSM scan (Mamba-1 recurrence)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mamba_scan_ref(x, dt, B, C, A):
    """Sequential selective scan.

    x, dt: (Bb, S, di); B, C: (Bb, S, N); A: (di, N)  [A < 0].
    h_t = exp(dt_t A) * h_{t-1} + (dt_t x_t) B_t;  y_t = h_t · C_t.
    Returns (y (Bb,S,di), h_final (Bb,di,N)).
    """
    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp
        dA = jnp.exp(dt_t[:, :, None] * A[None])          # (Bb, di, N)
        dBx = (dt_t * x_t)[:, :, None] * B_t[:, None, :]  # (Bb, di, N)
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    Bb, S, di = x.shape
    N = A.shape[1]
    h0 = jnp.zeros((Bb, di, N), jnp.float32)
    xs = (x.astype(jnp.float32).transpose(1, 0, 2),
          dt.astype(jnp.float32).transpose(1, 0, 2),
          B.astype(jnp.float32).transpose(1, 0, 2),
          C.astype(jnp.float32).transpose(1, 0, 2))
    h_final, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2).astype(x.dtype), h_final
