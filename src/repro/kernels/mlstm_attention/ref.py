"""Pure-jnp oracle for the fused mLSTM sequence mix (stabilized parallel
form, xLSTM matrix-memory cell)."""
from __future__ import annotations

import jax.numpy as jnp


def mlstm_attention_ref(q, k, v, F, I):
    """q,k,v: (BH, S, hd); F: (BH, S) inclusive cumulative log-forget;
    I: (BH, S) log input gate.  Returns (BH, S, hd).

    h_t = (Σ_{s<=t} exp(D_ts - m_t) (q_t·k_s) v_s)
          / max(|Σ_s exp(D_ts - m_t) (q_t·k_s)|, exp(-m_t)),
    D_ts = F_t - F_s + I_s,  m_t = max_s D_ts.
    """
    BH, S, hd = q.shape
    D = (F[:, :, None] - F[:, None, :] + I[:, None, :]).astype(jnp.float32)
    mask = jnp.tril(jnp.ones((S, S), bool))
    D = jnp.where(mask[None], D, -jnp.inf)
    m = jnp.maximum(D.max(axis=-1, keepdims=True), -1e30)
    W = jnp.exp(D - m)
    scores = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * W
    num = jnp.einsum("bts,bsd->btd", scores, v.astype(jnp.float32))
    den = jnp.maximum(jnp.abs(scores.sum(-1)), jnp.exp(-m[..., 0]))
    return (num / den[..., None]).astype(q.dtype)
