"""Public wrapper: model-layout handling + CPU interpret fallback."""
from __future__ import annotations

import jax

from repro.kernels.mlstm_attention.kernel import mlstm_attention_kernel


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def mlstm_attention(q, k, v, log_f_cum, log_i, *, bq: int = 128,
                    bk: int = 128, interpret=None):
    """Fused mLSTM mix in model layout.

    q,k,v: (B, S, H, hd) (k pre-scaled by hd**-0.5, as in models/ssm.py);
    log_f_cum: (B, S, H) inclusive cumulative log-forget; log_i: (B, S, H).
    Returns (B, S, H, hd).
    """
    B, S, H, hd = q.shape
    to_bh = lambda x: x.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    to_bh2 = lambda x: x.transpose(0, 2, 1).reshape(B * H, S)
    o = mlstm_attention_kernel(
        to_bh(q), to_bh(k), to_bh(v), to_bh2(log_f_cum), to_bh2(log_i),
        bq=bq, bk=bk, interpret=_auto_interpret(interpret))
    return o.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
