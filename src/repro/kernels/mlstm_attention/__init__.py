from repro.kernels.mlstm_attention.ops import mlstm_attention  # noqa: F401
from repro.kernels.mlstm_attention.ref import mlstm_attention_ref  # noqa: F401
