"""Fused mLSTM sequence mix — Pallas TPU kernel (flash-style).

The §Perf cell-A analysis showed the jnp mLSTM is HBM-bound on its fp32
(L, S) decay/score tensors.  This kernel keeps them in VMEM: gate cumulants
F (cumulative log-forget) and I (log input gate) enter as per-position
VECTORS; the (bq, bk) decay matrix D = F_t - F_s + I_s is built, stabilized,
and consumed inside the block, with flash-style online accumulation of the
signed score sum (mLSTM's denominator) and the value accumulator across key
blocks.  HBM traffic collapses to q/k/v/F/I in + h out.

F is passed twice (query-block-indexed and key-block-indexed views of the
same vector) so each gets a clean BlockSpec.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, fq_ref, fk_ref, i_ref, o_ref,
                  acc_ref, m_ref, s_ref, *, bq: int, bk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        s_ref[...] = jnp.zeros_like(s_ref)

    block_live = ki * bk <= qi * bq + bq - 1  # causal block skip

    @pl.when(block_live)
    def _body():
        q = q_ref[0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0].astype(jnp.float32)            # (bk, hd)
        fq = fq_ref[0].astype(jnp.float32)          # (bq,)
        fk = fk_ref[0].astype(jnp.float32)          # (bk,)
        ik = i_ref[0].astype(jnp.float32)           # (bk,)

        # decay matrix within the block, causal-masked
        D = fq[:, None] - fk[None, :] + ik[None, :]
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        D = jnp.where(q_pos >= k_pos, D, NEG_INF)

        m_prev = m_ref[...]                          # (bq, 1)
        m_new = jnp.maximum(m_prev, D.max(axis=-1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        W = jnp.exp(D - m_new)
        scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                     preferred_element_type=jnp.float32) * W
        s_ref[...] = s_ref[...] * corr + scores.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            scores, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _done():
        den = jnp.maximum(jnp.abs(s_ref[...]), jnp.exp(-m_ref[...]))
        o_ref[0] = (acc_ref[...] / den).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "interpret"))
def mlstm_attention_kernel(q, k, v, F, I, *, bq: int = 128, bk: int = 128,
                           interpret: bool = False):
    """q,k,v: (BH, S, hd); F: (BH, S) inclusive cumulative log-forget;
    I: (BH, S) log input gate.  Returns (BH, S, hd)."""
    BH, S, hd = q.shape
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    nq, nk = S // bq, S // bk

    return pl.pallas_call(
        functools.partial(_mlstm_kernel, bq=bq, bk=bk),
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, bq), lambda b, qi, ki: (b, qi)),   # F @ queries
            pl.BlockSpec((1, bk), lambda b, qi, ki: (b, ki)),   # F @ keys
            pl.BlockSpec((1, bk), lambda b, qi, ki: (b, ki)),   # I @ keys
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, F, F, I)
