"""Pure-jnp oracle for single-token (decode) attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k, v, *, scale=None):
    """q: (BK, G, hd); k, v: (BK, S, hd).  Returns (BK, G, hd)."""
    BK, G, hd = q.shape
    scale = hd ** -0.5 if scale is None else scale
    s = jnp.einsum("bgd,bkd->bgk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bgk,bkd->bgd", p, v.astype(jnp.float32)).astype(q.dtype)
