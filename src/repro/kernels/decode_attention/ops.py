"""Flash-decoding wrapper: kernel partials + log-sum-exp combine."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_partials


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def decode_attention(q, k, v, *, bc: int = 512, interpret=None):
    """Single-token attention over a chunked KV cache.

    q: (BK, G, hd); k, v: (BK, S, hd).  Returns (BK, G, hd).
    """
    acc, m, l = decode_attention_partials(
        q, k, v, bc=bc, interpret=_auto_interpret(interpret))
    m_g = m.max(axis=-1, keepdims=True)                      # (BK, G, 1)
    w = jnp.exp(m - m_g)                                     # (BK, G, nc)
    num = (acc * w[..., None]).sum(axis=2)                   # (BK, G, hd)
    den = (l * w).sum(axis=-1, keepdims=True)                # (BK, G, 1)
    return (num / jnp.maximum(den, 1e-30)).astype(q.dtype)
