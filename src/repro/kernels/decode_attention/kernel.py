"""Flash-decoding attention — Pallas TPU kernel (phase 1 of 2).

Decode attends one query token against a long KV cache.  The cache is split
into chunks; each grid step computes a partial softmax (m, l, acc) for one
chunk, fully parallel across chunks (this is what lets a 500k-token cache be
sharded across devices/cores).  Phase 2 (ops.py) merges the per-chunk
partials with the standard log-sum-exp combine — O(nc · hd), negligible.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _decode_kernel(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref, *, scale):
    q = q_ref[0].astype(jnp.float32)          # (G, hd)
    k = k_ref[0].astype(jnp.float32)          # (bc, hd)
    v = v_ref[0].astype(jnp.float32)          # (bc, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (G, bc)
    m = s.max(axis=-1, keepdims=True)          # (G, 1)
    p = jnp.exp(s - m)
    l = p.sum(axis=-1, keepdims=True)
    acc = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (G, hd)
    acc_ref[0, :, 0, :] = acc
    m_ref[0, :, 0] = m[:, 0]
    l_ref[0, :, 0] = l[:, 0]


@functools.partial(jax.jit, static_argnames=("bc", "interpret"))
def decode_attention_partials(q, k, v, *, bc: int = 512, interpret: bool = False):
    """q: (BK, G, hd); k, v: (BK, S, hd).
    Returns partial (acc (BK,G,nc,hd), m (BK,G,nc), l (BK,G,nc))."""
    BK, G, hd = q.shape
    S = k.shape[1]
    bc = min(bc, S)
    assert S % bc == 0, (S, bc)
    nc = S // bc
    scale = hd ** -0.5

    return pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale),
        grid=(BK, nc),
        in_specs=[
            pl.BlockSpec((1, G, hd), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((1, bc, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, bc, hd), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, G, 1, hd), lambda b, c: (b, 0, c, 0)),
            pl.BlockSpec((1, G, 1), lambda b, c: (b, 0, c)),
            pl.BlockSpec((1, G, 1), lambda b, c: (b, 0, c)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BK, G, nc, hd), jnp.float32),
            jax.ShapeDtypeStruct((BK, G, nc), jnp.float32),
            jax.ShapeDtypeStruct((BK, G, nc), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(q, k, v)
