"""Blockwise magnitude top-k — Pallas TPU kernel.

The encode hot path of best-effort gradient compression: each VMEM-resident
block independently selects its k largest-magnitude entries (values +
block-local indices).  Grid is 1-D over blocks; blocks are lane-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _topk_kernel(x_ref, vals_ref, idx_ref, *, k: int):
    x = x_ref[0]                      # (block,)
    _, idx = jax.lax.top_k(jnp.abs(x), k)
    vals_ref[0] = jnp.take(x, idx)
    idx_ref[0] = idx.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def topk_compress_kernel(x, *, k: int, interpret: bool = False):
    """x: (nb, block) -> (values (nb,k), indices (nb,k))."""
    nb, block = x.shape
    assert 0 < k <= block, (k, block)
    return pl.pallas_call(
        functools.partial(_topk_kernel, k=k),
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, k), x.dtype),
            jax.ShapeDtypeStruct((nb, k), jnp.int32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x)
