"""Pure-jnp oracle for blockwise magnitude top-k compression."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def topk_compress_ref(x, k: int):
    """x: (nb, block).  Per block, keep the k largest-magnitude entries.
    Returns (values (nb,k), indices (nb,k) int32) — indices block-local."""
    _, idx = lax.top_k(jnp.abs(x), k)
    vals = jnp.take_along_axis(x, idx, axis=-1)
    return vals, idx.astype(jnp.int32)
