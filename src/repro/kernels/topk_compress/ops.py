"""Public wrapper: flat-tensor padding/blocking + interpret fallback."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.topk_compress.kernel import topk_compress_kernel


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def topk_compress(x, *, ratio: float = 0.01, block: int = 1024, interpret=None):
    """Blockwise top-k of an arbitrary tensor.

    Returns (values (nb,k), global_indices (nb,k) int32, nb) where
    global_indices address the flattened (padded) tensor.
    """
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    padded = jnp.pad(flat, (0, pad))
    nb = padded.size // block
    k = max(1, int(block * ratio))
    vals, idx = topk_compress_kernel(padded.reshape(nb, block), k=k,
                                     interpret=_auto_interpret(interpret))
    gidx = idx + (jnp.arange(nb, dtype=jnp.int32) * block)[:, None]
    return vals, gidx, nb
