from repro.kernels.topk_compress.ops import topk_compress  # noqa: F401
from repro.kernels.topk_compress.ref import topk_compress_ref  # noqa: F401
