"""Outer optimizer for modes 1/2 (periodic cross-pod parameter sync).

Local-SGD / DiLoCo-style: pods run inner AdamW steps independently; every K
steps the pod-mean parameter delta is applied to a shared anchor via Nesterov
outer momentum.  This is the paper's rolling/fixed-barrier mode on the
parameter path: cross-pod traffic drops by ~K× (one fat sync per K steps).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OuterConfig:
    sync_period: int = 16        # K inner steps per outer sync
    outer_lr: float = 0.7
    outer_momentum: float = 0.9
    nesterov: bool = True


def init_outer_state(params):
    return {
        "anchor": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "momentum": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }


def outer_step(params, outer_state, mean_delta, cfg: OuterConfig):
    """Apply one outer update from the pod-mean delta (anchor - params).

    Returns (new_params, new_outer_state): params reset to the new anchor.
    """
    mom = jax.tree.map(
        lambda m, d: cfg.outer_momentum * m + d, outer_state["momentum"], mean_delta)
    if cfg.nesterov:
        upd = jax.tree.map(
            lambda m, d: cfg.outer_momentum * m + d, mom, mean_delta)
    else:
        upd = mom
    anchor = jax.tree.map(
        lambda a, u: a - cfg.outer_lr * u, outer_state["anchor"], upd)
    new_params = jax.tree.map(lambda p, a: a.astype(p.dtype), params, anchor)
    return new_params, {"anchor": anchor, "momentum": mom}


def maybe_outer_step(params, outer_state, do_sync, pod_mean_fn, cfg: OuterConfig):
    """In-graph conditional outer sync.  ``pod_mean_fn`` averages a pytree
    across pods (collectives.pod_mean bound to the pod axis)."""
    delta = jax.tree.map(
        lambda a, p: a - p.astype(jnp.float32), outer_state["anchor"], params)
    mean_delta = pod_mean_fn(delta)
    synced_params, synced_state = outer_step(params, outer_state, mean_delta, cfg)
    sel = lambda a, b: jax.tree.map(
        lambda x, y: jnp.where(do_sync, x, y), a, b)
    return sel(synced_params, params), {
        "anchor": sel(synced_state["anchor"], outer_state["anchor"]),
        "momentum": sel(synced_state["momentum"], outer_state["momentum"]),
    }
