from repro.optim import adamw, compression, outer  # noqa: F401
from repro.optim.adamw import AdamWConfig, apply_updates, init_opt_state  # noqa: F401
from repro.optim.compression import get_compressor  # noqa: F401
from repro.optim.outer import OuterConfig, init_outer_state  # noqa: F401
