"""Lossy gradient compression with error feedback — the best-effort "message
drop" operator on the cross-pod gradient path (DESIGN.md §2).

Coordinates not selected (top-k) or rounded away (int8) are NOT retried; the
residual folds into error-feedback state exactly as dropped best-effort
messages fold into later simulation state.  Payloads are compact, so the
cross-pod collective bytes shrink by the compression ratio (visible in the
dry-run HLO — see benchmarks/roofline.py).

SPMD note (§Perf cell C): encode must be SHAPE-PRESERVING for tensors with
sharded dims — flattening/padding a sharded gradient forces GSPMD to gather
it.  For ndim >= 2 leaves both compressors therefore work row-wise over the
trailing dim (no reshape); 1-D leaves (tiny norm/bias grads) use the
flat/blockwise forms, which also back the Pallas kernels.

Pallas: ``repro.kernels.topk_compress`` / ``repro.kernels.quantize`` are the
TPU kernels for the blockwise encode hot path; these jnp versions are the
oracles and the CPU/dry-run path.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
from jax import lax


@dataclasses.dataclass(frozen=True)
class TopKCompressor:
    """Magnitude top-k selection; payload = (values, indices)."""

    ratio: float = 0.01

    def k_for(self, size: int) -> int:
        return max(1, int(size * self.ratio))

    def encode(self, leaf):
        if leaf.ndim >= 2:
            return self._encode_rows(leaf)
        flat = leaf.reshape(-1).astype(jnp.float32)
        k = self.k_for(flat.size)
        _, idx = lax.top_k(jnp.abs(flat), k)
        vals = flat[idx]
        residual = flat.at[idx].set(0.0).reshape(leaf.shape).astype(leaf.dtype)
        return {"values": vals, "indices": idx.astype(jnp.int32)}, residual

    def _encode_rows(self, leaf):
        rows = leaf.reshape(leaf.shape[0], -1) if leaf.ndim > 2 else leaf
        shape2 = rows.shape
        x = rows.astype(jnp.float32)
        k = self.k_for(shape2[-1])
        _, idx = lax.top_k(jnp.abs(x), k)                 # (R, k)
        vals = jnp.take_along_axis(x, idx, axis=-1)
        residual = jnp.put_along_axis(x, idx, 0.0, axis=-1, inplace=False)
        return ({"values": vals, "indices": idx.astype(jnp.int32)},
                residual.reshape(leaf.shape).astype(leaf.dtype))

    def decode_sum(self, gathered, shape, dtype):
        """gathered: payload with a leading pod dim."""
        vals, idx = gathered["values"], gathered["indices"]
        if vals.ndim >= 3:  # (P, R, k) row-wise
            P_, R, _ = vals.shape
            cols = 1
            for s in shape[1:]:
                cols *= s
            dense = jnp.zeros((R, cols), jnp.float32)
            rows = jnp.arange(R)[:, None]
            for p in range(P_):
                dense = dense.at[rows, idx[p]].add(vals[p])
            return dense.reshape(shape).astype(dtype)
        size = 1
        for s in shape:
            size *= s
        dense = jnp.zeros((size,), jnp.float32)
        dense = dense.at[gathered["indices"].reshape(-1)].add(
            gathered["values"].reshape(-1))
        return dense.reshape(shape).astype(dtype)


@dataclasses.dataclass(frozen=True)
class Int8Compressor:
    """Symmetric int8 quantization: row-wise for ndim>=2 (shape-preserving,
    SPMD-friendly), blockwise for 1-D leaves."""

    block: int = 1024

    def encode(self, leaf):
        if leaf.ndim >= 2:
            xf = leaf.astype(jnp.float32)
            scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 127.0 + 1e-12
            q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
            residual = (xf - q.astype(jnp.float32) * scale).astype(leaf.dtype)
            return {"q": q, "scale": scale}, residual
        flat = leaf.reshape(-1).astype(jnp.float32)
        pad = (-flat.size) % self.block
        padded = jnp.pad(flat, (0, pad)).reshape(-1, self.block)
        scale = jnp.max(jnp.abs(padded), axis=1, keepdims=True) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(padded / scale), -127, 127).astype(jnp.int8)
        deq = (q.astype(jnp.float32) * scale).reshape(-1)[:flat.size]
        residual = (flat - deq).reshape(leaf.shape).astype(leaf.dtype)
        return {"q": q, "scale": scale.astype(jnp.float32)}, residual

    def decode_sum(self, gathered, shape, dtype):
        """gathered: {"q","scale"} with a leading pod dim."""
        deq = gathered["q"].astype(jnp.float32) * gathered["scale"]
        total = deq.sum(axis=0)
        if total.shape == tuple(shape):   # row-wise path
            return total.astype(dtype)
        total = total.reshape(-1)
        size = 1
        for s in shape:
            size *= s
        return total[:size].reshape(shape).astype(dtype)


def get_compressor(name, **kw):
    if name is None or name == "none":
        return None
    if name == "topk":
        return TopKCompressor(**kw)
    if name == "int8":
        return Int8Compressor(**kw)
    raise ValueError(name)
