"""AdamW, functional, pytree-native (no optax dependency)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def init_opt_state(params):
    return {
        "m": jax.tree.map(jnp.zeros_like, params),
        "v": jax.tree.map(jnp.zeros_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g * clip, grads)

    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(m_.dtype),
                     state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(v_.dtype)),
                     state["v"], grads)
    sf = step.astype(jnp.float32)
    mhat_c = 1.0 / (1 - b1 ** sf)
    vhat_c = 1.0 / (1 - b2 ** sf)
    lr = schedule(cfg, step)

    def upd(p, m_, v_):
        u = (m_ * mhat_c) / (jnp.sqrt(v_ * vhat_c) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            u = u + cfg.weight_decay * p.astype(u.dtype)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
