"""Sub-quadratic sequence mixers: Mamba (jamba), mLSTM + sLSTM (xlstm).

All three expose a parallel/chunked training form and an O(1)-state decode
step — this is what makes the ``long_500k`` shape feasible (DESIGN.md §6).
Scan math runs in fp32 for stability; projections in the compute dtype.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.partitioning import constrain


# ===========================================================================
# Mamba (selective SSM)
# ===========================================================================
def _mamba_dims(cfg):
    d = cfg.d_model
    di = cfg.mamba_expand * d
    dt_rank = max(1, math.ceil(d / 16))
    return d, di, cfg.mamba_d_state, cfg.mamba_d_conv, dt_rank


def init_mamba(key, cfg, dtype):
    d, di, N, dconv, dt_rank = _mamba_dims(cfg)
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None], (di, 1))
    return {
        "in_proj": layers.dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (dconv, di)) * (dconv ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": layers.dense_init(ks[2], di, dt_rank + 2 * N, dtype),
        "dt_proj": layers.dense_init(ks[3], dt_rank, di, dtype, scale=dt_rank ** -0.5),
        "dt_bias": (jnp.log(jnp.expm1(jnp.exp(
            jax.random.uniform(ks[4], (di,), minval=math.log(1e-3), maxval=math.log(1e-1))
        )))).astype(jnp.float32),
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": layers.dense_init(ks[5], di, d, dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B,S,di); w: (taps,di)."""
    taps = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (taps - 1, 0), (0, 0)))
    y = sum(pad[:, i:i + x.shape[1], :] * w[i] for i in range(taps))
    return y + b


def _ssm_params(params, x, cfg, compute_dtype):
    """Common projections. x: (B,S,di) conv-ed. Returns dt,B,C fp32."""
    _, di, N, _, dt_rank = _mamba_dims(cfg)
    proj = (x @ params["x_proj"].astype(compute_dtype)).astype(jnp.float32)
    dt, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(dt @ params["dt_proj"].astype(jnp.float32) + params["dt_bias"])
    return dt, Bm, Cm  # (B,S,di), (B,S,N), (B,S,N)


def mamba_forward(params, x, cfg, chunk: int = 256, return_state: bool = False):
    """x: (B,S,d) -> (B,S,d).  Chunked parallel selective scan."""
    B, S, d = x.shape
    _, di, N, dconv, _ = _mamba_dims(cfg)
    cd = x.dtype
    xz = x @ params["in_proj"].astype(cd)
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = constrain(xin, "dp", None, "tp")
    xc = jax.nn.silu(_causal_conv(xin, params["conv_w"].astype(cd), params["conv_b"].astype(cd)))
    dt, Bm, Cm = _ssm_params(params, xc, cfg, cd)
    A = -jnp.exp(params["A_log"])  # (di,N)
    xf = xc.astype(jnp.float32)

    # per-step scan elements
    dtA = dt[..., None] * A  # (B,S,di,N)  log of dA (negative)
    dBx = (dt * xf)[..., None] * Bm[:, :, None, :]  # (B,S,di,N)

    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nc = S // L

    def chunk_body(h0, inp):
        dtA_c, dBx_c, C_c = inp  # (B,L,di,N), (B,L,di,N), (B,L,N)
        # keep di sharded over tp through the scan (§Perf cell B: without
        # these constraints GSPMD all-gathers the chunk tensors per step)
        dtA_c = constrain(dtA_c, "dp", None, "tp", None)
        dBx_c = constrain(dBx_c, "dp", None, "tp", None)

        def comb(a, b):
            return (a[0] + b[0], jnp.exp(b[0]) * a[1] + b[1])
        logA_cum, h_within = jax.lax.associative_scan(comb, (dtA_c, dBx_c), axis=1)
        h = h_within + jnp.exp(logA_cum) * h0[:, None]
        h = constrain(h, "dp", None, "tp", None)
        y = jnp.einsum("bldn,bln->bld", h, C_c)
        return h[:, -1], y

    dtA_c = dtA.reshape(B, nc, L, di, N).transpose(1, 0, 2, 3, 4)
    dBx_c = dBx.reshape(B, nc, L, di, N).transpose(1, 0, 2, 3, 4)
    C_c = Cm.reshape(B, nc, L, N).transpose(1, 0, 2, 3)
    h0 = jnp.zeros((B, di, N), jnp.float32)
    h_final, ys = jax.lax.scan(chunk_body, h0, (dtA_c, dBx_c, C_c))
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, di)

    y = y + params["D"] * xf
    y = (y.astype(cd)) * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(cd)
    if return_state:
        state = {"h": h_final, "conv": xin[:, S - (dconv - 1):, :]}
        return out, state
    return out


def mamba_decode(params, x, state, cfg):
    """x: (B,1,d); state: {"h": (B,di,N) fp32, "conv": (B,dconv-1,di)}."""
    B = x.shape[0]
    _, di, N, dconv, _ = _mamba_dims(cfg)
    cd = x.dtype
    xz = x @ params["in_proj"].astype(cd)
    xin, z = jnp.split(xz, 2, axis=-1)
    window = jnp.concatenate([state["conv"].astype(cd), xin], axis=1)  # (B,dconv,di)
    xc = jax.nn.silu(jnp.einsum("btd,td->bd", window, params["conv_w"].astype(cd))
                     + params["conv_b"].astype(cd))[:, None]
    new_conv = window[:, 1:].astype(state["conv"].dtype)
    dt, Bm, Cm = _ssm_params(params, xc, cfg, cd)
    A = -jnp.exp(params["A_log"])
    xf = xc.astype(jnp.float32)
    dA = jnp.exp(dt[..., None] * A)[:, 0]  # (B,di,N)
    dBx = ((dt * xf)[..., None] * Bm[:, :, None, :])[:, 0]
    h = dA * state["h"] + dBx
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0])[:, None] + params["D"] * xf
    y = y.astype(cd) * jax.nn.silu(z)
    return y @ params["out_proj"].astype(cd), {"h": h, "conv": new_conv}


def init_mamba_state(cfg, batch: int, dtype=jnp.bfloat16):
    _, di, N, dconv, _ = _mamba_dims(cfg)
    return {"h": jnp.zeros((batch, di, N), jnp.float32),
            "conv": jnp.zeros((batch, dconv - 1, di), dtype)}


# ===========================================================================
# mLSTM (xLSTM matrix-memory block, parallel chunked form)
# ===========================================================================
def _mlstm_dims(cfg):
    d = cfg.d_model
    di = int(cfg.xlstm_proj_factor * d)
    di -= di % cfg.num_heads
    return d, di, cfg.num_heads, di // cfg.num_heads


def init_mlstm(key, cfg, dtype):
    d, di, H, hd = _mlstm_dims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "up_proj": layers.dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (4, di)) * 0.5).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "wq": layers.dense_init(ks[2], di, di, dtype),
        "wk": layers.dense_init(ks[3], di, di, dtype),
        "wv": layers.dense_init(ks[4], di, di, dtype),
        "w_if": layers.dense_init(ks[5], di, 2 * H, jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]),
        "out_norm": jnp.zeros((hd,), dtype),
        "down_proj": layers.dense_init(ks[6], di, d, dtype),
    }


def _mlstm_qkv_gates(params, x, cfg):
    """x: (B,S,d) -> q,k,v (B,S,H,hd); log_i, log_f (B,S,H); z (B,S,di)."""
    d, di, H, hd = _mlstm_dims(cfg)
    cd = x.dtype
    B, S, _ = x.shape
    up = x @ params["up_proj"].astype(cd)
    xm, z = jnp.split(up, 2, axis=-1)
    xm = constrain(xm, "dp", None, "tp")
    xc = jax.nn.silu(_causal_conv(xm, params["conv_w"].astype(cd), params["conv_b"].astype(cd)))
    q = (xc @ params["wq"].astype(cd)).reshape(B, S, H, hd)
    k = (xc @ params["wk"].astype(cd)).reshape(B, S, H, hd) * (hd ** -0.5)
    v = (xm @ params["wv"].astype(cd)).reshape(B, S, H, hd)
    gates = xc.astype(jnp.float32) @ params["w_if"] + params["b_if"]
    log_i, f_pre = jnp.split(gates, 2, axis=-1)  # (B,S,H)
    log_f = jax.nn.log_sigmoid(f_pre)
    return q, k, v, log_i, log_f, z


def _mlstm_chunk(qc, Fc, k, v, log_i, F, t_pos, s_pos):
    """One query chunk of the stabilized parallel mLSTM.

    qc: (B,L,H,hd); Fc: (B,L,H) cumulative log-forget at query pos;
    k,v: (B,S,H,hd); log_i,F: (B,S,H); positions for causal masking.

    Stabilizer math runs in fp32.  (§Perf note: storing the big (L,S)
    tensors in bf16 was tried and REFUTED on the HLO-bytes metric — the
    conversion ops offset the savings; the real fix is a fused Pallas
    mLSTM kernel that never materializes them.)
    """
    D = (Fc.transpose(0, 2, 1)[..., None]        # (B,H,L,1)
         - F.transpose(0, 2, 1)[:, :, None, :]   # (B,H,1,S)
         + log_i.transpose(0, 2, 1)[:, :, None, :])
    mask = t_pos[:, None] >= s_pos[None, :]
    D = jnp.where(mask[None, None], D, -jnp.inf)
    m = jnp.max(D, axis=-1, keepdims=True)  # (B,H,L,1)
    m = jnp.maximum(m, -1e30)  # guard all-masked rows
    W = jnp.exp(D - m)
    scores = jnp.einsum("blhd,bshd->bhls", qc.astype(jnp.float32),
                        k.astype(jnp.float32))
    scores = scores * W
    num = jnp.einsum("bhls,bshd->blhd", scores, v.astype(jnp.float32))
    den = jnp.maximum(jnp.abs(scores.sum(-1)), jnp.exp(-m[..., 0])).transpose(0, 2, 1)
    return num / den[..., None]


def mlstm_forward(params, x, cfg, q_chunk: int = 1024, return_state: bool = False):
    d, di, H, hd = _mlstm_dims(cfg)
    B, S, _ = x.shape
    cd = x.dtype
    q, k, v, log_i, log_f, z = _mlstm_qkv_gates(params, x, cfg)
    # D_ts = F_t - F_s + log_i_s (inclusive cumulative log-forget): the
    # contribution of step s at time t is (prod_{j=s+1..t} f_j) * i_s, and at
    # t == s the own forget gate cancels, leaving log_i_s.
    F = jnp.cumsum(log_f, axis=1)

    pos = jnp.arange(S)
    if S <= q_chunk:
        h = _mlstm_chunk(q, F, k, v, log_i, F, pos, pos)
    else:
        assert S % q_chunk == 0
        n = S // q_chunk
        qs = q.reshape(B, n, q_chunk, H, hd).transpose(1, 0, 2, 3, 4)
        Fs = F.reshape(B, n, q_chunk, H).transpose(1, 0, 2, 3)
        ps = pos.reshape(n, q_chunk)

        def body(_, inp):
            qc, Fc, pc = inp
            return None, _mlstm_chunk(qc, Fc, k, v, log_i, F, pc, pos)

        _, hs = jax.lax.scan(body, None, (qs, Fs, ps))
        h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)

    h = layers.head_rms_norm(h.astype(cd), params["out_norm"], cfg.norm_eps)
    h = h.reshape(B, S, di) * jax.nn.silu(z)
    out = h @ params["down_proj"].astype(cd)
    if return_state:
        # Recurrent state equivalent to having consumed the full sequence,
        # stored with the running stabilizer m = max_s D_Ss.
        D_end = F[:, -1:, :] - F + log_i  # (B,S,H)
        m_end = jnp.max(D_end, axis=1)  # (B,H)
        w = jnp.exp(D_end - m_end[:, None, :]).astype(jnp.float32)
        kf = k.astype(jnp.float32) * w[..., None]
        C = jnp.einsum("bshd,bshe->bhde", kf, v.astype(jnp.float32))
        n = kf.sum(axis=1)
        # conv window tail (pre-conv activations of the mixer branch)
        up = x @ params["up_proj"].astype(cd)
        xm = jnp.split(up, 2, axis=-1)[0]
        state = {"C": C, "n": n, "m": m_end, "conv": xm[:, S - 3:, :]}
        return out, state
    return out


def mlstm_decode(params, x, state, cfg):
    """state: {"C": (B,H,hd,hd), "n": (B,H,hd), "m": (B,H)} fp32."""
    d, di, H, hd = _mlstm_dims(cfg)
    B = x.shape[0]
    cd = x.dtype
    up = x @ params["up_proj"].astype(cd)
    xm, z = jnp.split(up, 2, axis=-1)
    window = jnp.concatenate([state["conv"].astype(cd), xm], axis=1)
    xc = jax.nn.silu(jnp.einsum("btd,td->bd", window, params["conv_w"].astype(cd))
                     + params["conv_b"].astype(cd))
    new_conv = window[:, 1:].astype(state["conv"].dtype)
    q = (xc @ params["wq"].astype(cd)).reshape(B, H, hd).astype(jnp.float32)
    k = ((xc @ params["wk"].astype(cd)).reshape(B, H, hd) * (hd ** -0.5)).astype(jnp.float32)
    v = (xm[:, 0] @ params["wv"].astype(cd)).reshape(B, H, hd).astype(jnp.float32)
    gates = xc.astype(jnp.float32) @ params["w_if"] + params["b_if"]
    log_i, f_pre = jnp.split(gates, 2, axis=-1)  # (B,H)
    log_f = jax.nn.log_sigmoid(f_pre)

    m_new = jnp.maximum(log_f + state["m"], log_i)
    f_sc = jnp.exp(log_f + state["m"] - m_new)[..., None]
    i_sc = jnp.exp(log_i - m_new)[..., None]
    C = f_sc[..., None] * state["C"] + i_sc[..., None] * (k[..., None] * v[..., None, :])
    n = f_sc * state["n"] + i_sc * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)), jnp.exp(-m_new))
    h = (num / den[..., None]).astype(cd)
    h = layers.head_rms_norm(h, params["out_norm"], cfg.norm_eps)
    h = h.reshape(B, 1, di) * jax.nn.silu(z)
    return h @ params["down_proj"].astype(cd), {
        "C": C, "n": n, "m": m_new, "conv": new_conv}


def init_mlstm_state(cfg, batch: int, dtype=jnp.bfloat16):
    _, di, H, hd = _mlstm_dims(cfg)
    return {"C": jnp.zeros((batch, H, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, H, hd), jnp.float32),
            "m": jnp.full((batch, H), -1e30, jnp.float32),
            "conv": jnp.zeros((batch, 3, di), dtype)}


# ===========================================================================
# sLSTM (scalar-memory recurrent block)
# ===========================================================================
def init_slstm(key, cfg, dtype):
    d, H = cfg.d_model, cfg.num_heads
    hd = d // H
    ks = jax.random.split(key, 3)
    return {
        "w": layers.dense_init(ks[0], d, 4 * d, dtype),      # i,f,z,o input weights
        "r": (jax.random.normal(ks[1], (4, H, hd, hd)) * (hd ** -0.5)).astype(dtype),
        "b": jnp.concatenate([jnp.zeros((d,)), 3.0 * jnp.ones((d,)),
                              jnp.zeros((2 * d,))]).astype(jnp.float32),
        "out_norm": jnp.zeros((hd,), dtype),
    }


def _slstm_step(params, xw, state, H, hd):
    """xw: (B, 4d) precomputed x@w + b; state dict of (B,H,hd) fp32."""
    B = xw.shape[0]
    h_prev = state["h"]  # (B,H,hd) fp32
    rec = jnp.einsum("bhd,ghde->gbhe", h_prev, params["r"].astype(jnp.float32))
    pre = xw.astype(jnp.float32).reshape(B, 4, H, hd).transpose(1, 0, 2, 3) + rec
    i_pre, f_pre, z_pre, o_pre = pre
    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + state["m"], i_pre)
    i_sc = jnp.exp(i_pre - m_new)
    f_sc = jnp.exp(log_f + state["m"] - m_new)
    c = f_sc * state["c"] + i_sc * jnp.tanh(z_pre)
    n = f_sc * state["n"] + i_sc
    h = jax.nn.sigmoid(o_pre) * c / jnp.maximum(n, 1.0)
    return {"c": c, "n": n, "h": h, "m": m_new}


def slstm_forward(params, x, cfg, return_state: bool = False):
    B, S, d = x.shape
    H = cfg.num_heads
    hd = d // H
    cd = x.dtype
    xw = x @ params["w"].astype(cd) + params["b"].astype(cd)

    def body(state, xw_t):
        new = _slstm_step(params, xw_t, state, H, hd)
        return new, new["h"]

    state0 = init_slstm_state(cfg, B)
    final, hs = jax.lax.scan(body, state0, xw.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2, 3)  # (B,S,H,hd)
    h = layers.head_rms_norm(h.astype(cd), params["out_norm"], cfg.norm_eps)
    out = h.reshape(B, S, d)
    if return_state:
        return out, final
    return out


def slstm_decode(params, x, state, cfg):
    B = x.shape[0]
    d, H = cfg.d_model, cfg.num_heads
    hd = d // H
    cd = x.dtype
    xw = (x[:, 0] @ params["w"].astype(cd) + params["b"].astype(cd))
    new = _slstm_step(params, xw, state, H, hd)
    h = layers.head_rms_norm(new["h"].astype(cd), params["out_norm"], cfg.norm_eps)
    return h.reshape(B, 1, d), new


def init_slstm_state(cfg, batch: int, dtype=jnp.float32):
    H = cfg.num_heads
    hd = cfg.d_model // H
    z = lambda: jnp.zeros((batch, H, hd), jnp.float32)
    return {"c": z(), "n": z(), "h": z(), "m": jnp.full((batch, H, hd), -1e30, jnp.float32)}
