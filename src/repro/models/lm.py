"""Top-level decoder-only LM: init, loss, prefill, decode.

These are the functions the launcher jits: ``loss_fn`` (inside train_step),
``prefill_step`` and ``decode_step`` (serve path).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers, modality, partitioning, transformer
from repro.models.partitioning import constrain


def init_params(key, cfg):
    k_emb, k_stack, k_out = jax.random.split(key, 3)
    dtype = jnp.dtype(cfg.param_dtype)
    params = {
        "embed": layers.init_embedding(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "stack": transformer.init_stack(k_stack, cfg),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = layers.init_embedding(k_out, cfg.vocab_size, cfg.d_model, dtype)
    return params


def abstract_params(cfg, key=None):
    """Shape/dtype pytree of params without allocating (for dry-run/sharding)."""
    key = jax.random.PRNGKey(0) if key is None else key
    return jax.eval_shape(lambda k: init_params(k, cfg), key)


def cast_params_for_compute(params, cfg, specs=None):
    """One bulk fp32->compute-dtype cast at step entry (§Perf cell B).

    Without this, GSPMD all-gathers the fp32 MASTER weights and converts
    after — 2x the FSDP gather bytes.  The cast output must be PINNED to
    the param's own sharding (``specs``): otherwise backward sharding
    propagation marks the convert replicated and the gather moves back in
    front of it.  Differentiable (grads flow to the fp32 masters); router
    weights and 1-D params (norm scales, biases) stay fp32.
    """
    cd = jnp.dtype(cfg.dtype)
    if cd == jnp.float32:
        return params
    spec_of = {}
    if specs is not None:
        from jax.sharding import PartitionSpec as _P
        flat_s = jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, _P))[0]
        spec_of = {jax.tree_util.keystr(p): s for p, s in flat_s}

    def visit(path, p):
        names = [str(getattr(q, "key", "")) for q in path]
        if (hasattr(p, "dtype") and p.dtype == jnp.float32 and p.ndim >= 2
                and "router" not in names):
            out = p.astype(cd)
            return partitioning.constrain_spec(
                out, spec_of.get(jax.tree_util.keystr(path)))
        return p

    return jax.tree_util.tree_map_with_path(visit, params)


def _embed_inputs(params, tokens, cfg, frontend_embeds, compute_dtype):
    x = layers.embed(params["embed"], tokens, compute_dtype)
    if cfg.frontend is not None and frontend_embeds is not None:
        x = modality.splice_frontend(x, frontend_embeds)
    return x


def forward(params, tokens, cfg, frontend_embeds=None, param_specs=None):
    """tokens: (B, S) -> logits (B, S, V) fp32, aux loss."""
    cd = jnp.dtype(cfg.dtype)
    params = cast_params_for_compute(params, cfg, param_specs)
    B, S = tokens.shape
    x = _embed_inputs(params, tokens, cfg, frontend_embeds, cd)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x, aux = transformer.stack_forward(params["stack"], x, cfg, positions)
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = layers.unembed(table, x)
    logits = constrain(logits, "dp", None, "tp")
    return logits, aux


def loss_fn(params, batch, cfg, param_specs=None):
    """Next-token cross entropy. batch: {"tokens", "labels", ["frame_embeds"...]}"""
    logits, aux = forward(params, batch["tokens"], cfg,
                          batch.get(modality.frontend_input_name(cfg))
                          if cfg.frontend else None,
                          param_specs=param_specs)
    labels = batch["labels"]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    token_loss = logz - gold
    mask = (labels >= 0).astype(jnp.float32)
    ce = jnp.sum(token_loss * mask) / jnp.maximum(mask.sum(), 1.0)
    return ce + aux, {"ce": ce, "aux": aux}


def prefill_step(params, tokens, cfg, frontend_embeds=None, param_specs=None):
    """Prefill: logits for the last position + decode caches."""
    cd = jnp.dtype(cfg.dtype)
    params = cast_params_for_compute(params, cfg, param_specs)
    B, S = tokens.shape
    x = _embed_inputs(params, tokens, cfg, frontend_embeds, cd)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x, _, caches = transformer.stack_prefill(params["stack"], x, cfg, positions)
    x = layers.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = layers.unembed(table, x)
    return logits, caches


def decode_step(params, tokens, caches, cfg, write_idx: int,
                param_specs=None):
    """One decode step. tokens: (B, 1) current token; returns
    (next_token (B,1), logits, new_caches)."""
    cd = jnp.dtype(cfg.dtype)
    params = cast_params_for_compute(params, cfg, param_specs)
    x = layers.embed(params["embed"], tokens, cd)
    x, new_caches = transformer.stack_decode(params["stack"], x, caches, cfg, write_idx)
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = layers.unembed(table, x)
    next_token = jnp.argmax(logits, axis=-1).astype(tokens.dtype)
    return next_token, logits, new_caches


def param_count(cfg) -> int:
    shapes = abstract_params(cfg)
    return sum(math.prod(l.shape) for l in jax.tree.leaves(shapes))


def active_param_count(cfg) -> int:
    """Active params per token (MoE: top-k of routed experts)."""
    total = param_count(cfg)
    if cfg.num_experts == 0:
        return total
    # subtract inactive routed-expert weights
    shapes = abstract_params(cfg)
    inactive = 0
    moe_frac = 1.0 - cfg.experts_per_tok / cfg.num_experts

    def visit(path, leaf):
        nonlocal inactive
        names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
        if "ffn" in names and any(n in ("gate", "up", "down") for n in names):
            # routed expert weights are (E, ...) or, scanned, (P, E, ...)
            if leaf.ndim >= 3 and cfg.num_experts in leaf.shape[:2]:
                inactive += math.prod(leaf.shape) * moe_frac

    jax.tree_util.tree_map_with_path(visit, shapes)
    return int(total - inactive)
