"""Sharding roles and constraint helpers.

The model code annotates activations with *logical* axis roles ("dp", "tp",
"sp") rather than mesh axis names.  The launcher activates a
``MeshRules`` context mapping roles to physical mesh axes; outside such a
context (unit tests, single-device runs) all constraints are no-ops, so the
same model code runs everywhere.

Roles:
  dp  — data-parallel axes (batch dim); ("pod", "data") on the production mesh
  tp  — tensor-parallel axis (heads / ffn / experts / vocab); "model"
  sp  — sequence-parallel axis for the residual stream; aliases "model"
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


class MeshRules:
    def __init__(self, mesh: Mesh, dp: Sequence[str], tp: Optional[str],
                 sp: Optional[str] = None):
        self.mesh = mesh
        self.roles = {
            "dp": tuple(dp),
            "tp": tp,
            "sp": sp if sp is not None else tp,
        }

    def resolve(self, dim) -> Union[None, str, tuple]:
        if dim is None:
            return None
        if isinstance(dim, tuple):  # compound role, e.g. ("dp", "sp")
            out = []
            for d in dim:
                r = self.resolve(d)
                if r is None:
                    continue
                out.extend(r if isinstance(r, tuple) else (r,))
            return tuple(out) if out else None
        return self.roles.get(dim, dim)

    def spec(self, *dims) -> P:
        return P(*[self.resolve(d) for d in dims])

    def sharding(self, *dims) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*dims))


def active() -> Optional[MeshRules]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[MeshRules]):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev


def constrain(x, *dims):
    """Apply a sharding constraint by logical roles; no-op without rules."""
    rules = active()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, rules.sharding(*dims))


def constrain_spec(x, spec):
    """Constrain to an explicit PartitionSpec on the active mesh; no-op
    without rules."""
    rules = active()
    if rules is None or spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))
