"""Mixture-of-experts FFN: top-k routing with capacity, shared experts.

Routing uses the sort-based dispatch (memory-light, GSPMD-friendly): token-
expert pairs are ranked per expert via an argsort over expert ids; tokens
beyond expert capacity are dropped (their residual path still carries them —
the MoE analogue of best-effort message drop).  Expert weights are stacked
(E, ...) so the expert dim shards over the "tp" mesh axis (expert
parallelism); the dispatch scatter/gather induces the all-to-all.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.partitioning import constrain

ROUTER_AUX_WEIGHT = 0.01


def init_moe(key, cfg, dtype):
    d, E, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    scale = d ** -0.5
    p = {
        "router": layers.dense_init(ks[0], d, E, jnp.float32, scale),
        "gate": (jax.random.truncated_normal(ks[1], -2, 2, (E, d, ff)) * scale).astype(dtype),
        "up": (jax.random.truncated_normal(ks[2], -2, 2, (E, d, ff)) * scale).astype(dtype),
        "down": (jax.random.truncated_normal(ks[3], -2, 2, (E, ff, d))
                 * (ff ** -0.5)).astype(dtype),
    }
    if cfg.num_shared_experts > 0:
        p["shared"] = layers.init_mlp(ks[4], d, cfg.moe_d_ff * cfg.num_shared_experts, dtype)
    return p


def _positions_in_expert(expert_idx, num_experts: int):
    """Rank of each (token, choice) pair within its expert, via a stable
    sort over expert ids (cheap: O(Tk log Tk) on int32)."""
    T, k = expert_idx.shape
    flat_e = expert_idx.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)  # pairs grouped by expert
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=num_experts)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos_sorted = jnp.arange(T * k) - starts[sorted_e]
    pos = jnp.zeros((T * k,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
    return pos.reshape(T, k)


def _moe_group(params, x, cfg, capacity: int, compute_dtype):
    """x: (T, d) one routing group. Returns (y, aux_loss_terms).

    GShard-style one-hot einsum dispatch: the (T, E, C) dispatch/combine
    tensors keep the expert dim EXPLICIT, so GSPMD shards it over the model
    axis end-to-end (expert parallelism) instead of re-gathering expert
    weights at every use (§Perf cell B: this was >80% of jamba/dbrx train
    collective bytes with the earlier scatter-based dispatch).
    """
    T, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_tok
    logits = (x.astype(jnp.float32) @ params["router"])  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weight, expert_idx = jax.lax.top_k(probs, k)  # (T, k)
    weight = weight / jnp.maximum(weight.sum(-1, keepdims=True), 1e-9)

    pos = _positions_in_expert(expert_idx, E)      # (T, k)
    keep = (pos < capacity).astype(compute_dtype)  # capacity drop, no retry
    # one-hots in the compute dtype: exactly representable, halves the
    # dispatch-tensor bytes vs fp32 (§Perf cell B)
    onehot_e = jax.nn.one_hot(expert_idx, E, dtype=compute_dtype)  # (T,k,E)
    onehot_c = jax.nn.one_hot(pos, capacity, dtype=compute_dtype)  # (T,k,C)
    dispatch = jnp.einsum("tke,tkc->tec", onehot_e * keep[..., None], onehot_c)
    combine = jnp.einsum(
        "tke,tkc->tec",
        onehot_e * (weight.astype(compute_dtype) * keep)[..., None], onehot_c)

    xe = jnp.einsum("tec,td->ecd", dispatch, x)    # (E, C, d), e sharded
    xe = constrain(xe, "tp", None, None)
    gate = jnp.einsum("ecd,edf->ecf", xe, params["gate"].astype(compute_dtype))
    up = jnp.einsum("ecd,edf->ecf", xe, params["up"].astype(compute_dtype))
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(gate) * up,
                    params["down"].astype(compute_dtype))
    ye = constrain(ye, "tp", None, None)
    y = jnp.einsum("tec,ecd->td", combine, ye)

    # load-balance aux loss (Switch-style)
    me = probs.mean(axis=0)  # (E,)
    ce = onehot_e.sum(axis=(0, 1)) / (T * k)
    aux = E * jnp.sum(me * ce)
    return y, aux


def _moe_dense_decode(params, x, cfg):
    """Single-token path: compute every expert densely and mix by router
    weight.  At S==1 all expert weights are read regardless (batch routing
    covers most experts), so dispatch machinery is pure overhead — the
    dense form has no scatter/one-hot resharding (§Perf follow-up: the
    einsum dispatch regressed MoE decode 5x before this path)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_tok
    cd = x.dtype
    logits = x.astype(jnp.float32) @ params["router"]          # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    wfull = jnp.zeros_like(probs).at[
        jnp.arange(B)[:, None, None], jnp.arange(S)[None, :, None], idx
    ].set(w).astype(cd)                                        # (B,S,E)
    # Constrain x's d-dim over the FSDP axis and the outputs E-sharded so
    # the contractions become local partial-sums + small activation
    # all-reduces; without this GSPMD all-gathers the FSDP-sharded expert
    # weights (~3 GB vs ~0.3 GB — for one token, moving activations beats
    # moving weights).
    x = constrain(x, None, None, "dp")
    gate = jnp.einsum("bsd,edf->bsef", x, params["gate"].astype(cd))
    gate = constrain(gate, None, None, "tp", None)
    up = jnp.einsum("bsd,edf->bsef", x, params["up"].astype(cd))
    up = constrain(up, None, None, "tp", None)
    ye = jnp.einsum("bsef,efd->bsed", jax.nn.silu(gate) * up,
                    params["down"].astype(cd))
    ye = constrain(ye, None, None, "tp", None)
    y = jnp.einsum("bse,bsed->bsd", wfull, ye)
    return y, jnp.zeros((), jnp.float32)


def apply_moe(params, x, cfg, capacity_factor: float = 1.25):
    """x: (B, S, d) -> (y, aux_loss).  Routing groups = batch rows."""
    B, S, d = x.shape
    if S == 1:
        y, aux = _moe_dense_decode(params, x, cfg)
    else:
        capacity = int(max(1, round(
            S * cfg.experts_per_tok / cfg.num_experts * capacity_factor)))
        y, aux = jax.vmap(
            lambda g: _moe_group(params, g, cfg, capacity, x.dtype))(x)
        aux = aux.mean()
    y = constrain(y, "dp", None, None)
    if cfg.num_shared_experts > 0:
        y = y + layers.apply_mlp(params["shared"], x, x.dtype)
    return y, aux * ROUTER_AUX_WEIGHT
