"""Block assembly: (mixer, ffn) blocks tiled into a scanned layer stack.

Layer stacks are organized as ``num_periods`` repetitions of an *effective
period* — the lcm of the block pattern and MoE period — so every position in
the period has a static structure and ``lax.scan`` runs over stacked period
parameters (small HLO, fast compile at 512 partitions).
"""
from __future__ import annotations

import math
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, layers, moe, ssm
from repro.models.partitioning import constrain


def block_specs(cfg) -> List[Tuple[str, str]]:
    """Per-position (mixer, ffn) specs for one effective period."""
    period = cfg.pattern_period
    if cfg.num_experts > 0:
        period = math.lcm(period, cfg.moe_period)
    assert cfg.num_layers % period == 0, (cfg.name, cfg.num_layers, period)
    specs = []
    for p in range(period):
        mixer = cfg.kind_at(p)
        if mixer in ("mlstm",):
            ffn = "none"            # mLSTM block embeds its own projections
        elif mixer == "slstm":
            ffn = "ffn43"           # xLSTM post-up-projection FFN (4/3)
        elif cfg.moe_at(p):
            ffn = "moe"
        else:
            ffn = "mlp"
        specs.append((mixer, ffn))
    return specs


def num_periods(cfg) -> int:
    return cfg.num_layers // len(block_specs(cfg))


# ---------------------------------------------------------------------------
# Single block
# ---------------------------------------------------------------------------
_MIXER_INIT = {
    "attn": attention.init_attention,
    "mamba": ssm.init_mamba,
    "mlstm": ssm.init_mlstm,
    "slstm": ssm.init_slstm,
}


def init_block(key, cfg, spec):
    mixer, ffn = spec
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.param_dtype)
    p = {
        "mixer_norm": jnp.zeros((cfg.d_model,), dtype),
        "mixer": _MIXER_INIT[mixer](k1, cfg, dtype),
    }
    if ffn == "mlp":
        p["ffn_norm"] = jnp.zeros((cfg.d_model,), dtype)
        p["ffn"] = layers.init_mlp(k2, cfg.d_model, cfg.d_ff, dtype)
    elif ffn == "ffn43":
        p["ffn_norm"] = jnp.zeros((cfg.d_model,), dtype)
        p["ffn"] = layers.init_mlp(k2, cfg.d_model, int(cfg.d_model * 4 / 3), dtype)
    elif ffn == "moe":
        p["ffn_norm"] = jnp.zeros((cfg.d_model,), dtype)
        p["ffn"] = moe.init_moe(k3, cfg, dtype)
    return p


def _residual_constrain(x, cfg):
    if cfg.seq_sharded_residual:
        return constrain(x, "dp", ("sp",), None)
    return constrain(x, "dp", None, None)


def block_forward(params, x, cfg, spec, positions):
    """Full-sequence forward. Returns (x, aux_loss, cache_seed)."""
    mixer, ffn = spec
    x = _residual_constrain(x, cfg)
    h = layers.rms_norm(x, params["mixer_norm"], cfg.norm_eps)
    cache_seed = None
    if mixer == "attn":
        y, (k, v) = attention.attention_forward(params["mixer"], h, cfg, positions)
        cache_seed = {"k": k, "v": v}
    elif mixer == "mamba":
        y = ssm.mamba_forward(params["mixer"], h, cfg)
    elif mixer == "mlstm":
        y = ssm.mlstm_forward(params["mixer"], h, cfg)
    elif mixer == "slstm":
        y = ssm.slstm_forward(params["mixer"], h, cfg)
    else:
        raise ValueError(mixer)
    x = x + y
    aux = jnp.zeros((), jnp.float32)
    if ffn in ("mlp", "ffn43"):
        h = layers.rms_norm(x, params["ffn_norm"], cfg.norm_eps)
        x = x + layers.apply_mlp(params["ffn"], h, x.dtype)
    elif ffn == "moe":
        h = layers.rms_norm(x, params["ffn_norm"], cfg.norm_eps)
        y, aux = moe.apply_moe(params["ffn"], h, cfg)
        x = x + y
    x = _residual_constrain(x, cfg)
    return x, aux, cache_seed


def block_prefill(params, x, cfg, spec, positions):
    """Full-sequence forward that also returns the decode cache."""
    mixer, ffn = spec
    x = _residual_constrain(x, cfg)
    h = layers.rms_norm(x, params["mixer_norm"], cfg.norm_eps)
    if mixer == "attn":
        y, (k, v) = attention.attention_forward(params["mixer"], h, cfg, positions)
        cache = {"k": k, "v": v}
    elif mixer == "mamba":
        y, cache = ssm.mamba_forward(params["mixer"], h, cfg, return_state=True)
    elif mixer == "mlstm":
        y, cache = ssm.mlstm_forward(params["mixer"], h, cfg, return_state=True)
    elif mixer == "slstm":
        y, cache = ssm.slstm_forward(params["mixer"], h, cfg, return_state=True)
    else:
        raise ValueError(mixer)
    x = x + y
    aux = jnp.zeros((), jnp.float32)
    if ffn in ("mlp", "ffn43"):
        h = layers.rms_norm(x, params["ffn_norm"], cfg.norm_eps)
        x = x + layers.apply_mlp(params["ffn"], h, x.dtype)
    elif ffn == "moe":
        h = layers.rms_norm(x, params["ffn_norm"], cfg.norm_eps)
        y, aux = moe.apply_moe(params["ffn"], h, cfg)
        x = x + y
    x = _residual_constrain(x, cfg)
    return x, aux, cache


def block_decode(params, x, cache, cfg, spec, write_idx):
    """Single-token decode. Returns (x, new_cache)."""
    mixer, ffn = spec
    h = layers.rms_norm(x, params["mixer_norm"], cfg.norm_eps)
    if mixer == "attn":
        y, new_cache = attention.attention_decode(params["mixer"], h, cache, cfg, write_idx)
    elif mixer == "mamba":
        y, new_cache = ssm.mamba_decode(params["mixer"], h, cache, cfg)
    elif mixer == "mlstm":
        y, new_cache = ssm.mlstm_decode(params["mixer"], h, cache, cfg)
    elif mixer == "slstm":
        y, new_cache = ssm.slstm_decode(params["mixer"], h, cache, cfg)
    else:
        raise ValueError(mixer)
    x = x + y
    if ffn in ("mlp", "ffn43"):
        h = layers.rms_norm(x, params["ffn_norm"], cfg.norm_eps)
        x = x + layers.apply_mlp(params["ffn"], h, x.dtype)
    elif ffn == "moe":
        h = layers.rms_norm(x, params["ffn_norm"], cfg.norm_eps)
        y, _ = moe.apply_moe(params["ffn"], h, cfg)
        x = x + y
    return x, new_cache


def init_block_cache(cfg, spec, batch: int, seq: int, dtype=jnp.bfloat16):
    mixer, _ = spec
    if mixer == "attn":
        return attention.init_kv_cache(cfg, batch, seq, dtype)
    if mixer == "mamba":
        return ssm.init_mamba_state(cfg, batch, dtype)
    if mixer == "mlstm":
        return ssm.init_mlstm_state(cfg, batch, dtype)
    if mixer == "slstm":
        return ssm.init_slstm_state(cfg, batch)
    raise ValueError(mixer)


# ---------------------------------------------------------------------------
# Layer stack (scan over periods)
# ---------------------------------------------------------------------------
def init_stack(key, cfg):
    """Params: tuple over period positions of pytrees stacked over periods."""
    specs = block_specs(cfg)
    P = num_periods(cfg)
    out = []
    for p, spec in enumerate(specs):
        keys = jax.random.split(jax.random.fold_in(key, p), P)
        stacked = jax.vmap(lambda k: init_block(k, cfg, spec))(keys)
        out.append(stacked)
    return tuple(out)


def stack_forward(params, x, cfg, positions):
    specs = block_specs(cfg)

    def body(carry, period_params):
        x, aux = carry
        for p, spec in enumerate(specs):
            x, a, _ = block_forward(period_params[p], x, cfg, spec, positions)
            aux = aux + a
        return (x, aux), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params)
    else:
        aux = jnp.zeros((), jnp.float32)
        P = num_periods(cfg)
        for i in range(P):
            (x, aux), _ = body((x, aux), jax.tree.map(lambda a: a[i], params))
    return x, aux


def stack_prefill(params, x, cfg, positions):
    """Forward that also returns per-layer decode caches (stacked like
    ``init_caches``)."""
    specs = block_specs(cfg)

    def body(carry, period_params):
        x, aux = carry
        caches = []
        for p, spec in enumerate(specs):
            x, a, c = block_prefill(period_params[p], x, cfg, spec, positions)
            aux = aux + a
            caches.append(c)
        return (x, aux), tuple(caches)

    if cfg.scan_layers:
        (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params)
    else:
        aux = jnp.zeros((), jnp.float32)
        outs = []
        for i in range(num_periods(cfg)):
            (x, aux), c = body((x, aux), jax.tree.map(lambda a: a[i], params))
            outs.append(c)
        caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    return x, aux, caches


def stack_decode(params, x, caches, cfg, write_idx):
    specs = block_specs(cfg)

    def body(x, inp):
        period_params, period_cache = inp
        new_caches = []
        for p, spec in enumerate(specs):
            x, nc = block_decode(period_params[p], x, period_cache[p], cfg, spec, write_idx)
            new_caches.append(nc)
        return x, tuple(new_caches)

    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(body, x, (params, caches))
    else:
        P = num_periods(cfg)
        outs = []
        for i in range(P):
            x, nc = body(x, jax.tree.map(lambda a: a[i], (params, caches)))
            outs.append(nc)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    return x, new_caches


def init_caches(cfg, batch: int, seq: int, dtype=jnp.bfloat16):
    """Stacked caches matching stack_decode's scan structure."""
    specs = block_specs(cfg)
    P = num_periods(cfg)
    out = []
    for spec in specs:
        one = init_block_cache(cfg, spec, batch, seq, dtype)
        out.append(jax.tree.map(lambda a: jnp.broadcast_to(a, (P,) + a.shape), one))
    return tuple(out)
