"""Modality frontend stubs (DESIGN.md §6).

Per the assignment, [audio]/[vlm] entries specify the transformer BACKBONE
only; the frontend is a stub — ``input_specs()`` provides precomputed
frame/patch embeddings which occupy the first ``cfg.frontend_len`` positions
of the sequence (conditioning prefix / image patches).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def frontend_input_name(cfg) -> str:
    return {"audio": "frame_embeds", "vision": "patch_embeds"}[cfg.frontend]


def splice_frontend(x_embed, frontend_embeds):
    """Replace the first P positions of the token embedding with frontend
    embeddings. x_embed: (B, S, d); frontend_embeds: (B, P, d)."""
    P = frontend_embeds.shape[1]
    return jax.lax.dynamic_update_slice_in_dim(
        x_embed, frontend_embeds.astype(x_embed.dtype), 0, axis=1)


def frontend_spec(cfg, batch: int, dtype=jnp.bfloat16):
    """ShapeDtypeStruct for the stub frontend input."""
    return jax.ShapeDtypeStruct((batch, cfg.frontend_len, cfg.d_model), dtype)
