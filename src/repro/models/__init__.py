from repro.models import (  # noqa: F401
    attention,
    layers,
    lm,
    modality,
    moe,
    partitioning,
    ssm,
    transformer,
)
