"""Basic neural-net layers: RMSNorm, linear init, RoPE, SwiGLU MLP.

Functional style: ``init_*`` returns a params dict; ``apply`` functions are
pure.  Params are kept in ``cfg.param_dtype`` (fp32 master) and cast to
``cfg.dtype`` (bf16) for compute by the caller.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, in_dim: int, out_dim: int, dtype, scale: float | None = None):
    """Truncated-normal fan-in init."""
    if scale is None:
        scale = in_dim ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, (in_dim, out_dim)) * scale).astype(dtype)


def rms_norm(x, scale, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def head_rms_norm(x, scale, eps: float = 1e-6):
    """RMSNorm over the last (head) dim of a (..., heads, head_dim) tensor."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    sin = jnp.sin(angles)[..., None, :]  # broadcast over heads
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d_model, d_ff, dtype),
        "up": dense_init(k2, d_model, d_ff, dtype),
        "down": dense_init(k3, d_ff, d_model, dtype),
    }


def apply_mlp(params, x, compute_dtype):
    gate = x @ params["gate"].astype(compute_dtype)
    up = x @ params["up"].astype(compute_dtype)
    return (jax.nn.silu(gate) * up) @ params["down"].astype(compute_dtype)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------
def init_embedding(key, vocab: int, d_model: int, dtype):
    return (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)


def embed(table, tokens, compute_dtype):
    return jnp.take(table, tokens, axis=0).astype(compute_dtype)


def unembed(table, x):
    """Logits in fp32 for a numerically stable loss."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32), table.astype(jnp.float32))
