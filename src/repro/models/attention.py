"""Grouped-query attention: training/prefill (chunked causal) and decode.

The jnp implementation here is the GSPMD-lowerable reference path (used by the
dry-run and CPU tests).  On TPU the Pallas flash kernels in
``repro.kernels`` plug in via ``use_pallas``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.partitioning import constrain


def init_attention(key, cfg, dtype):
    d, H, KH, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers.dense_init(ks[0], d, H * hd, dtype),
        "wk": layers.dense_init(ks[1], d, KH * hd, dtype),
        "wv": layers.dense_init(ks[2], d, KH * hd, dtype),
        "wo": layers.dense_init(ks[3], H * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KH * hd,), dtype)
        p["bv"] = jnp.zeros((KH * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _project_qkv(params, x, cfg, positions):
    """Returns q: (B,S,KH,G,hd), k/v: (B,S,KH,hd)."""
    B, S, _ = x.shape
    H, KH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    G = H // KH
    dt = x.dtype
    q = x @ params["wq"].astype(dt)
    k = x @ params["wk"].astype(dt)
    v = x @ params["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KH, hd)
    v = v.reshape(B, S, KH, hd)
    if cfg.qk_norm:
        q = layers.head_rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = layers.head_rms_norm(k, params["k_norm"], cfg.norm_eps)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    q = q.reshape(B, S, KH, G, hd)
    return q, k, v


def _attend_chunk(q, k, v, q_pos, k_pos, scale):
    """q: (B,Qc,KH,G,hd); k,v: (B,Sk,KH,hd); causal mask via positions."""
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32) * scale
    mask = q_pos[:, None] >= k_pos[None, :]  # (Qc, Sk)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgqs,bskd->bqkgd", probs, v)


def attention_forward(params, x, cfg, positions, q_chunk: int = 1024):
    """Causal self-attention over the full sequence (train / prefill).

    Memory-bounded: scans over query chunks so the live score tensor is
    (B, KH, G, q_chunk, S) rather than (..., S, S).
    Sharding: the query SEQUENCE dim is sharded over the model axis and k/v
    replicated across it — with small kv-head counts (GQA kv=2..8 < 16-way
    TP) heads cannot shard, and without this the fp32 probs tensor gets
    all-gathered (§Perf: 8.6 GB/layer on qwen2.5-3b).  k/v per chip is only
    B·S·KH·hd bf16, so replication is cheap; every chip computes 1/TP of
    the query rows — sequence-parallel attention.
    Returns (y, (k, v)) — k/v reused as the prefill KV cache.
    """
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, x, cfg, positions)
    if cfg.num_kv_heads <= 4:
        # measured win for kv<=2 (qwen2/2.5: collective 504->220 ms);
        # at kv=8 the replicated k/v outweighs the saved prob gathers
        # (qwen3 regressed 129->589 ms) — gate on kv-head count
        q = constrain(q, "dp", "tp", None, None, None)
        k = constrain(k, "dp", None, None, None)
        v = constrain(v, "dp", None, None, None)
    scale = cfg.hd ** -0.5
    k_pos = positions[0] if positions.ndim > 1 else positions

    if S <= q_chunk:
        out = _attend_chunk(q, k, v, k_pos, k_pos, scale)
    else:
        assert S % q_chunk == 0, (S, q_chunk)
        n = S // q_chunk
        qs = q.reshape(B, n, q_chunk, *q.shape[2:]).transpose(1, 0, 2, 3, 4, 5)
        pos_chunks = k_pos.reshape(n, q_chunk)

        def body(_, inp):
            qc, pc = inp
            return None, _attend_chunk(qc, k, v, pc, k_pos, scale)

        _, outs = jax.lax.scan(body, None, (qs, pos_chunks))
        out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, *q.shape[2:])

    out = out.reshape(B, S, cfg.num_heads * cfg.hd)
    out = constrain(out, "dp", None, "tp")
    y = out @ params["wo"].astype(x.dtype)
    return y, (k, v)


def attention_decode(params, x, cache, cfg, write_idx):
    """Single-token decode against a (pre-allocated) KV cache.

    x: (B, 1, d).  cache: {"k","v"}: (B, S, KH, hd); the new token's k/v is
    written at ``write_idx`` and attention runs over positions <= write_idx.
    The cache sequence dim may be sharded (long-context flash-decoding: XLA
    turns the softmax reductions into tiny all-reduces).
    """
    B = x.shape[0]
    S = cache["k"].shape[1]
    positions = jnp.full((B, 1), write_idx, dtype=jnp.int32)
    q, k_new, v_new = _project_qkv(params, x, cfg, positions)
    k = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), write_idx, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), write_idx, axis=1)
    k = constrain(k, "dp", "sp", None, None)
    v = constrain(v, "dp", "sp", None, None)

    scale = cfg.hd ** -0.5
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k.astype(q.dtype)).astype(jnp.float32) * scale
    valid = (jnp.arange(S) <= write_idx)[None, None, None, None, :]
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(q.dtype))
    out = out.reshape(B, 1, cfg.num_heads * cfg.hd)
    y = out @ params["wo"].astype(x.dtype)
    return y, {"k": k, "v": v}


def init_kv_cache(cfg, batch: int, seq: int, dtype=jnp.bfloat16):
    shape = (batch, seq, cfg.num_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
