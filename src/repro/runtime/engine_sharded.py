"""Mesh-sharded vectorized engine (DESIGN.md §8).

The windowed-time engine (``runtime/engine_jax.py``) advances the whole
population per lockstep window on ONE device.  This subclass partitions the
flat population arrays into contiguous per-shard process blocks over a 1-D
device mesh (``launch/mesh.py::make_shard_mesh``) and runs each window's
drain -> batched compute -> send under ``shard_map``, so only the thin set
of cross-shard boundary edges ever crosses the device link — Conduit's
partitioning discipline (arXiv:2105.10486) applied to the simulator itself.

The window phases themselves live in ``runtime/window_core.py``
(DESIGN.md §11) and are shared with the unsharded engine verbatim: this
file keeps only what is genuinely distributed — the static shard layout
and boundary tables, the packed-ppermute boundary exchange, and the
barrier-release strategy (:class:`~repro.runtime.window_core.MeshRelease`
pmin/pmax reductions over the shard axis).

Layout.  ``topologies.contiguous_partition`` reorders pids so each shard's
processes are contiguous; every duct ring lives on its *receiver's* shard,
so drains, halo scatters, and receiver-side QoS counters are shard-local.
The duct layout itself follows ``layout=`` (DESIGN.md §10): edge-major
local rows in ascending canonical order, or — for degree-regular
topologies — dense receiver-major rows (``m * d`` per shard, no padding)
whose halo merges and receiver counters are plain per-receiver reshape
reductions; the boundary machinery below is layout-agnostic and simply
indexes whichever rows the plan laid out.
Per window, boundary traffic moves in exactly two collective hops per
distinct shard offset:

  1. payload hop: for each boundary edge the source shard packs
     (edge payload, availability stamp ``t_src + latency``, touch counter,
     active bit) into one int32 buffer and ``ppermute``s it to the
     receiver's shard, which scatters the entries into its local send rows;
  2. accept hop: after the local ``duct_send`` (drop iff the ring is full)
     the receiver ``ppermute``s the accept bits back so the source shard
     can maintain its processes' attempted/ok/dropped send counters.

Parity.  All stochastic draws stay keyed by *original* pid and *canonical*
edge id (the unsharded enumeration order), and halo-scatter ties resolve
by canonical edge id, so a run is a pure function of ``(config, seed)``
regardless of shard count: ``--shards 8`` reproduces ``--shards 1``
trajectories exactly (``tests/test_engine_conformance.py``).  The
replicate axis vmaps *inside* each shard, composing ``--replicates`` with
``--shards``.

Self-paced supersteps (DESIGN.md §9).  The per-window exchange above is a
hidden barrier: every window, every shard stops at the same ppermute.
With ``superstep_windows=W`` each shard instead advances W lockstep
windows *entirely shard-locally* per superstep — fault-injected or
jittered shards drift behind in virtual time exactly as the paper's
lac-417 node does — while boundary sends are staged sender-side.  The
superstep-end window then moves all W windows' boundary traffic in ONE
packed ppermute per shard offset (and one packed reverse hop for the
accept bits), cutting the collective count per simulated window by ~W×.
Staged messages carry their sender-window availability stamps and touch
counters, so latency/clumpiness QoS is computed from exact virtual-time
metadata; what W>1 changes is only *when* boundary messages enter the
receiver's ring (superstep boundaries instead of every window), which
perturbs drop patterns and per-message handling costs within a documented
tolerance.  Barrier modes release on superstep-granular pmin/pmax: since
waiting processes' clocks do not advance, release *times* are unchanged —
releases just land on superstep boundaries.  ``W=1`` reproduces the
per-window engine bitwise (same staged values, same operation order).
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.modes import AsyncMode
from repro.launch.mesh import SHARD_AXIS, make_shard_mesh, shard_map
from repro.runtime.engine_jax import JaxEngine
from repro.runtime.simulator import SimResult
from repro.runtime.topologies import contiguous_partition
from repro.runtime.window_core import (
    BARRIER_MODES,
    STREAM_LAT,
    MeshRelease,
    lognormal_factor,
)

#: carry keys indexed by the process axis (permuted into shard layout)
_PROC_KEYS = ("t", "steps", "done", "waiting", "barrier_seq", "last_release",
              "pending", "c_touch", "c_att", "c_ok", "c_drop", "c_laden",
              "c_msgs", "snap", "snap_idx", "halo")
#: carry keys indexed by the edge axis (re-laid-out per shard, padded)
_EDGE_KEYS = ("ptouch", "q_avail", "q_touch", "q_pay", "q_head", "q_size")
#: per-replicate scalars (replicated across shards)
_SCALAR_KEYS = ("seed", "k")


def _bits_i32(x: jax.Array) -> jax.Array:
    """Reinterpret f32 as i32 so one ppermute buffer carries mixed fields."""
    if x.dtype == jnp.int32:
        return x
    return jax.lax.bitcast_convert_type(x, jnp.int32)


def _from_bits(x: jax.Array, dtype) -> jax.Array:
    if np.dtype(dtype) == np.dtype(np.int32):
        return x
    return jax.lax.bitcast_convert_type(x, dtype)


class ShardedJaxEngine(JaxEngine):
    """Windowed-time engine sharded over a 1-D device mesh.

    Same ``Engine`` contract and same trajectories as :class:`JaxEngine`
    (canonical RNG/tie keying — see module docstring); built by the
    registry when ``--shards S`` > 1.
    """

    def __init__(self, app, cfg, faults=None, *, shards: int,
                 superstep_windows: int = 1, max_pops: int = 16,
                 chunk: int = 256, layout: str = "auto"):
        super().__init__(app, cfg, faults, max_pops=max_pops, chunk=chunk,
                         layout=layout)
        if np.dtype(self.bapp.payload_dtype) not in (np.dtype(np.int32),
                                                     np.dtype(np.float32)):
            raise ValueError(
                "sharded engine payloads must be int32/float32 (32-bit "
                f"ppermute packing), got {self.bapp.payload_dtype}")
        self.superstep = int(superstep_windows)
        if self.superstep < 1:
            raise ValueError(
                f"superstep_windows must be >= 1, got {superstep_windows}")
        if self.superstep > 1 and cfg.mode in BARRIER_MODES:
            # releases land only on superstep boundaries, so up to W-1 idle
            # windows precede each one — same virtual-time trajectory, more
            # lockstep windows consumed
            self._max_windows *= self.superstep
        self._supersteps_per_dispatch = max(1, chunk // self.superstep)
        self._windows_per_dispatch = (self._supersteps_per_dispatch *
                                      self.superstep)
        self.shards = int(shards)
        self.plan = contiguous_partition(self.topo, self.shards)
        self.mesh = make_shard_mesh(self.shards)
        self._m = self.n // self.shards
        self._release = MeshRelease(SHARD_AXIS)
        self._build_statics()
        self._statics_sharded = None
        self._cspecs = None

    # ------------------------------------------------------------------
    # Static shard layout: local rows (rings on the receiver's shard) and
    # per-offset boundary exchange tables.  All numpy, hoisted out of jit.
    # ------------------------------------------------------------------
    def _build_statics(self) -> None:
        S, m, E = self.shards, self._m, self.E
        esrc = np.asarray(self._esrc)
        edst = np.asarray(self._edst)
        slot = np.asarray(self._slot)
        out_slot = np.asarray(self._out_slot)
        rev = np.asarray(self._rev)
        lat_base = np.asarray(self._lat_base)
        perm = np.asarray(self.plan.perm, np.int64)
        inv = np.asarray(self.plan.inv, np.int64)

        lsrc, ldst = inv[esrc], inv[edst]     # edge endpoints as positions
        src_sh, dst_sh = lsrc // m, ldst // m
        rows_by_shard = [np.where(dst_sh == s)[0] for s in range(S)]
        if self.lplan.kind == "dense":
            # dense receiver-major local rows (DESIGN.md §10): edge e lives
            # at (local receiver index) * d + j on its receiver's shard,
            # where j is its sorted-source position there — no padding, and
            # each receiver's rows stay in canonical-edge-id order, so the
            # dense halo select ties break like the unsharded engine
            dd = self.lplan.degree
            ein = m * dd
            jof = np.empty(E, np.int64)
            jof[self.lplan.eid.reshape(-1)] = np.tile(np.arange(dd), self.n)
            row_of = (ldst % m) * dd + jof
        else:
            # canonical edge id -> its ring's local row index (ascending
            # canonical order per shard, so local row order == canonical
            # order and segment_max tie-breaks match the unsharded engine)
            ein = max(1, max(len(r) for r in rows_by_shard))
            row_of = np.full(E, -1, np.int64)
            for rows in rows_by_shard:
                row_of[rows] = np.arange(len(rows))
        self._ein = ein

        i32, f32 = np.int32, np.float32
        row_canon = np.zeros((S, ein), i32)
        row_valid = np.zeros((S, ein), bool)
        row_dst = np.full((S, ein), m, i32)
        row_src = np.full((S, ein), m, i32)       # sentinel m: not interior
        row_interior = np.zeros((S, ein), bool)
        row_out_slot = np.zeros((S, ein), i32)
        row_rev = np.full((S, ein), ein, i32)     # sentinel ein: not local
        row_halo_key = np.full((S, ein), 4 * m, i32)
        row_lat = np.zeros((S, ein), f32)
        for s in range(S):
            e = rows_by_shard[s]
            r = row_of[e]   # packed ascending (edge) or receiver-major
            interior = src_sh[e] == s
            row_canon[s, r] = e
            row_valid[s, r] = True
            row_dst[s, r] = ldst[e] - s * m
            row_src[s, r] = np.where(interior, lsrc[e] - s * m, m)
            row_interior[s, r] = interior
            row_out_slot[s, r] = out_slot[e]
            # rev edge (dst, src) drains at src — local iff this edge is
            # interior; boundary rows get their touch stamp via exchange
            row_rev[s, r] = np.where(interior, row_of[rev[e]], ein)
            row_halo_key[s, r] = (ldst[e] - s * m) * 4 + slot[e]
            row_lat[s, r] = lat_base[e]

        # boundary edges grouped by shard offset: one ppermute per offset
        bnd = np.where(src_sh != dst_sh)[0]
        offs = ((dst_sh[bnd] - src_sh[bnd]) % S).astype(np.int64)
        self._offsets = sorted(int(d) for d in set(offs.tolist()))
        bnd_tables: Dict[str, Dict[str, np.ndarray]] = {}
        for d in self._offsets:
            sel = bnd[offs == d]
            per_s = [sel[src_sh[sel] == s] for s in range(S)]  # canon order
            bd = max(1, max(len(p) for p in per_s))
            snd_src = np.full((S, bd), m, i32)
            snd_oslot = np.zeros((S, bd), i32)
            snd_rev = np.full((S, bd), ein, i32)
            snd_canon = np.zeros((S, bd), i32)
            snd_lat = np.zeros((S, bd), f32)
            rcv_row = np.full((S, bd), ein, i32)
            for s in range(S):
                e = per_s[s]
                k = len(e)
                snd_src[s, :k] = lsrc[e] - s * m
                snd_oslot[s, :k] = out_slot[e]
                snd_rev[s, :k] = row_of[rev[e]]
                snd_canon[s, :k] = e
                snd_lat[s, :k] = lat_base[e]
                # sender s's entry j lands at receiver (s+d)%S, entry j
                rcv_row[(s + d) % S, :k] = row_of[e]
            bnd_tables[str(d)] = dict(
                snd_src=snd_src, snd_oslot=snd_oslot, snd_rev=snd_rev,
                snd_canon=snd_canon, snd_lat=snd_lat, rcv_row=rcv_row)

        self._statics = jax.tree.map(jnp.asarray, dict(
            pids=perm.reshape(S, m).astype(i32),
            cfactor=np.asarray(self._cfactor)[perm].reshape(S, m),
            deg=np.asarray(self._deg)[perm].reshape(S, m).astype(i32),
            row_canon=row_canon, row_valid=row_valid, row_dst=row_dst,
            row_src=row_src, row_interior=row_interior,
            row_out_slot=row_out_slot, row_rev=row_rev,
            row_halo_key=row_halo_key, row_lat=row_lat, bnd=bnd_tables))
        self._perm_np = perm
        self._inv_np = inv

    # ------------------------------------------------------------------
    # Layout transforms around the sharded dispatch
    # ------------------------------------------------------------------
    def _edge_state(self) -> Dict[str, jax.Array]:
        """Empty rings in padded per-shard layout: ``S * ein`` rows, row
        ``s * ein + j`` = shard s's local row j.  All-constant, so no
        canonical-order gather is needed (and the full-population edge
        arrays are never allocated)."""
        return self.core.edge_rings(self.shards * self._ein)

    def _to_sharded_layout(self, carry):
        """Permute process-axis leaves into shard order (edge leaves are
        already built in padded per-shard layout by ``_edge_state``)."""
        perm = self._perm_np
        out = dict(carry)
        for key in _PROC_KEYS:
            out[key] = carry[key][:, perm]
        out["app"] = jax.tree.map(lambda x: x[:, perm], carry["app"])
        return out

    def _to_canonical_layout(self, carry):
        """Undo the process permutation on everything ``_assemble`` reads."""
        inv = self._inv_np
        out = dict(carry)
        for key in _PROC_KEYS:
            out[key] = carry[key][:, inv]
        out["app"] = jax.tree.map(lambda x: x[:, inv], carry["app"])
        return out

    def _carry_specs(self, carry):
        specs = jax.tree.map(lambda _: P(None, SHARD_AXIS), carry)
        for key in _SCALAR_KEYS:
            specs[key] = P(None)
        return specs

    # ------------------------------------------------------------------
    # Shard-local window phases: thin wrappers over the shared core with
    # this shard's sentinel-padded tables
    # ------------------------------------------------------------------
    def _drain_phase(self, st, carry, t_pad, act_pad):
        """Drain every local ring (they live on their receiver's shard)
        through the shared core, with this shard's row tables."""
        return self.core.drain(
            carry, t_pad[st["row_dst"]], act_pad[st["row_dst"]],
            halo_key=st["row_halo_key"], n_halo=4 * self._m,
            dst=st["row_dst"], n_dst=self._m,
            dense_degree=(self.lplan.degree
                          if self.lplan.kind == "dense" else None))

    def _stage_offsets(self, st, t_pad, act_pad, eo_pad, ptouch_pad,
                       seed, k):
        """Sender-side staging of this window's boundary sends: one packed
        ``(bd, L+3)`` i32 buffer per shard offset — payload bits, then the
        availability stamp ``t_src + latency``, the reverse-edge touch
        counter, and the sender-active bit.  Stamps are drawn NOW, at the
        sender's window, so a batched exchange at the superstep boundary
        still delivers exact virtual-time metadata (latency/clumpiness QoS
        is computed from these stamps, not from arrival windows)."""
        cfg = self.cfg
        staged = {}
        for off in self._offsets:
            b = st["bnd"][str(off)]
            # latency draws keyed by canonical edge id: identical to the
            # unsharded engine's per-edge stream
            lat_b = b["snd_lat"] * lognormal_factor(
                cfg.latency_sigma, seed, STREAM_LAT, b["snd_canon"], k)
            pay_b = eo_pad[b["snd_src"], b["snd_oslot"]]
            avail_b = t_pad[b["snd_src"]] + lat_b
            att_b = act_pad[b["snd_src"]]
            tch_b = ptouch_pad[b["snd_rev"]]
            staged[str(off)] = jnp.concatenate([
                _bits_i32(pay_b),
                _bits_i32(avail_b)[:, None],
                tch_b[:, None],
                att_b[:, None].astype(jnp.int32)], axis=1)
        return staged

    def _close_window(self, st, u, active, drained_r, *, release: bool):
        """Shared window tail with mesh release reductions; mid-superstep
        windows (``release=False``) skip the cross-shard pmin/pmax check —
        waiting processes stay waiting until the superstep boundary."""
        return self.core.close_window(
            u, active, drained_r, pids=st["pids"], deg=st["deg"],
            cfactor=st["cfactor"],
            release=self._release if release else None)

    # ------------------------------------------------------------------
    # Window bodies
    # ------------------------------------------------------------------
    def _local_window(self, st, carry):
        """One mid-superstep lockstep window: entirely shard-local.

        Interior edges exchange through their (local) rings as usual;
        boundary sends are packed into per-offset staging buffers and
        returned for the superstep scan to stack.  No collectives run, so
        each shard advances at its own jittered pace — fault-injected
        shards simply fall behind in virtual time.
        """
        cfg, m = self.cfg, self._m
        comm = cfg.mode != AsyncMode.NO_COMM
        seed, k, t = carry["seed"], carry["k"], carry["t"]
        active = ~carry["done"] & ~carry["waiting"]
        # sentinel-padded per-process vectors: index m = inactive dummy
        t_pad = jnp.concatenate([t, jnp.zeros(1, t.dtype)])
        act_pad = jnp.concatenate([active, jnp.zeros(1, bool)])
        u = dict(carry)
        drained_r = jnp.zeros(m, jnp.int32)
        staged = {}
        if comm:
            dr, drained_r = self._drain_phase(st, carry, t_pad, act_pad)
            u.update(dr)
        app_state, edges_out, steps = self.core.compute(
            carry, active, u["halo"], st["pids"])
        u.update(app=app_state, steps=steps)
        if comm:
            eo_pad = jnp.concatenate(
                [edges_out, jnp.zeros((1,) + edges_out.shape[1:],
                                      edges_out.dtype)])
            ptouch_pad = jnp.concatenate([u["ptouch"],
                                          jnp.zeros(1, jnp.int32)])
            staged = self._stage_offsets(st, t_pad, act_pad, eo_pad,
                                         ptouch_pad, seed, k)
            # interior-only send attempt (drop iff full)
            lat_row = st["row_lat"] * lognormal_factor(
                cfg.latency_sigma, seed, STREAM_LAT, st["row_canon"], k)
            x_act = act_pad[st["row_src"]] & st["row_interior"]
            sp = self.core.send_edge(
                u, t_pad[st["row_src"]] + lat_row, x_act, jnp.float32(0.0),
                ptouch_pad[st["row_rev"]],
                eo_pad[st["row_src"], st["row_out_slot"]],
                st["row_src"], m)
            u.update(sp.rings)
            u.update(c_att=carry["c_att"] + sp.sums[:, 0],
                     c_ok=carry["c_ok"] + sp.sums[:, 1],
                     c_drop=carry["c_drop"] + sp.sums[:, 2])
        return self._close_window(st, u, active, drained_r,
                                  release=False), staged

    def _final_window(self, st, carry, stage_mid):
        """The superstep-end window: the only one that talks to peers.

        All staged boundary windows (plus this window's own) move in ONE
        packed ppermute per shard offset; the receiver pushes them into its
        rings in sender-window order (drop iff full per push, FIFO
        preserved), and the accept bits return in one packed reverse
        ppermute per offset so sender-side attempted/ok/dropped counters
        stay exact.  With ``superstep_windows=1`` this is operation-for-
        operation the per-window exchange engine.
        """
        cfg, m, ein, S = self.cfg, self._m, self._ein, self.shards
        W = self.superstep
        comm = cfg.mode != AsyncMode.NO_COMM
        seed, k, t = carry["seed"], carry["k"], carry["t"]
        active = ~carry["done"] & ~carry["waiting"]
        t_pad = jnp.concatenate([t, jnp.zeros(1, t.dtype)])
        act_pad = jnp.concatenate([active, jnp.zeros(1, bool)])
        u = dict(carry)
        drained_r = jnp.zeros(m, jnp.int32)
        if comm:
            dr, drained_r = self._drain_phase(st, carry, t_pad, act_pad)
            u.update(dr)
        app_state, edges_out, steps = self.core.compute(
            carry, active, u["halo"], st["pids"])
        u.update(app=app_state, steps=steps)
        if comm:
            pay_dtype = edges_out.dtype
            Lp = self.bapp.payload_len
            eo_pad = jnp.concatenate(
                [edges_out, jnp.zeros((1,) + edges_out.shape[1:],
                                      edges_out.dtype)])
            ptouch_pad = jnp.concatenate([u["ptouch"],
                                          jnp.zeros(1, jnp.int32)])
            own = self._stage_offsets(st, t_pad, act_pad, eo_pad,
                                      ptouch_pad, seed, k)
            # --- payload hop: ONE packed ppermute per offset for all W ----
            staged_l, staged_r = {}, {}
            for off in self._offsets:
                key = str(off)
                full = (own[key][None] if stage_mid is None else
                        jnp.concatenate([stage_mid[key], own[key][None]],
                                        axis=0))
                staged_l[key] = full     # sender-local copy: the att bits
                staged_r[key] = jax.lax.ppermute(
                    full, SHARD_AXIS,
                    [(i, (i + off) % S) for i in range(S)])

            # interior send inputs for THIS window
            lat_row = st["row_lat"] * lognormal_factor(
                cfg.latency_sigma, seed, STREAM_LAT, st["row_canon"], k)
            int_pay = eo_pad[st["row_src"], st["row_out_slot"]]
            int_avail = t_pad[st["row_src"]] + lat_row
            int_act = act_pad[st["row_src"]] & st["row_interior"]
            int_tch = ptouch_pad[st["row_rev"]]

            # --- W push passes in sender-window order (FIFO per ring).
            # Boundary rows push staged window j in pass j; interior rows
            # push their current message in the last pass (their own
            # window).  Rings are single-writer, so the row sets are
            # disjoint and pass composition is exact.
            rings = {key: u[key] for key in
                     ("q_avail", "q_touch", "q_head", "q_size", "q_pay")}
            acc = {str(off): [] for off in self._offsets}
            send_sums = jnp.zeros((m, 3), jnp.int32)
            for j in range(W):
                last = j == W - 1
                x_pay = int_pay if last else jnp.zeros_like(int_pay)
                x_avail = int_avail if last else jnp.zeros_like(int_avail)
                x_act = int_act if last else jnp.zeros(ein, bool)
                x_tch = int_tch if last else jnp.zeros(ein, jnp.int32)
                for off in self._offsets:
                    b = st["bnd"][str(off)]
                    buf = staged_r[str(off)][j]
                    rr = b["rcv_row"]  # pad entries carry the ein sentinel
                    x_pay = x_pay.at[rr].set(
                        _from_bits(buf[:, :Lp], pay_dtype), mode="drop")
                    x_avail = x_avail.at[rr].set(
                        _from_bits(buf[:, Lp], jnp.float32), mode="drop")
                    x_tch = x_tch.at[rr].set(buf[:, Lp + 1], mode="drop")
                    x_act = x_act.at[rr].set(buf[:, Lp + 2].astype(bool),
                                             mode="drop")
                sp = self.core.send_edge(
                    rings, x_avail, x_act, jnp.float32(0.0), x_tch, x_pay,
                    st["row_src"], m, want_sums=last)
                rings.update(sp.rings)
                acc_pad = jnp.concatenate([sp.accepted,
                                           jnp.zeros(1, bool)])
                for off in self._offsets:
                    acc[str(off)].append(
                        acc_pad[st["bnd"][str(off)]["rcv_row"]])
                if last:
                    # interior counters (boundary rows carry the m sentinel
                    # in row_src: their contributions drop into the spare
                    # segment)
                    send_sums = sp.sums
            u.update(rings)

            # --- accept hop: ONE packed reverse ppermute per offset -------
            for off in self._offsets:
                b = st["bnd"][str(off)]
                acc_back = jax.lax.ppermute(
                    jnp.stack(acc[str(off)]).astype(jnp.int32), SHARD_AXIS,
                    [(i, (i - off) % S) for i in range(S)])
                att = staged_l[str(off)][:, :, Lp + 2].astype(bool)
                ok = acc_back.astype(bool)
                cols_b = jnp.stack([
                    att.astype(jnp.int32).sum(0),
                    (att & ok).astype(jnp.int32).sum(0),
                    (att & ~ok).astype(jnp.int32).sum(0)], axis=1)
                send_sums = send_sums + jax.ops.segment_sum(
                    cols_b, b["snd_src"], num_segments=m + 1)[:m]
            u.update(c_att=carry["c_att"] + send_sums[:, 0],
                     c_ok=carry["c_ok"] + send_sums[:, 1],
                     c_drop=carry["c_drop"] + send_sums[:, 2])
        return self._close_window(st, u, active, drained_r, release=True)

    # ------------------------------------------------------------------
    def _get_runner(self):
        if self._runner is None:
            W = self.superstep

            def chunk_fn(st, carry):
                st = jax.tree.map(lambda a: a[0], st)  # (1, ...) -> local

                def superstep(c, _):
                    if W > 1:
                        c, stage_mid = jax.lax.scan(
                            lambda cc, __: self._local_window(st, cc),
                            c, None, length=W - 1)
                    else:
                        stage_mid = None
                    return self._final_window(st, c, stage_mid), None

                def one(c):
                    c, _ = jax.lax.scan(
                        superstep, c, None,
                        length=self._supersteps_per_dispatch)
                    return c
                # replicate (seed) axis vmaps INSIDE each shard
                return jax.vmap(one)(carry)

            sspecs = jax.tree.map(lambda _: P(SHARD_AXIS), self._statics)
            f = shard_map(chunk_fn, self.mesh, in_specs=(sspecs, self._cspecs),
                          out_specs=self._cspecs)
            self._runner = jax.jit(f, donate_argnums=1)
        return self._runner

    # ------------------------------------------------------------------
    def run_replicates(self, seeds: Sequence[int]) -> List[SimResult]:
        """One replicate per seed: a single sharded, vmapped dispatch."""
        carries = [self._init_carry(int(s)) for s in seeds]
        carry = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *carries)
        carry = self._to_sharded_layout(carry)
        if self._cspecs is None:
            self._cspecs = self._carry_specs(carry)
        carry = jax.device_put(carry, jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), self._cspecs,
            is_leaf=lambda x: isinstance(x, P)))
        if self._statics_sharded is None:
            self._statics_sharded = jax.device_put(
                self._statics, jax.tree.map(
                    lambda _: NamedSharding(self.mesh, P(SHARD_AXIS)),
                    self._statics))
        runner = self._get_runner()
        windows = 0
        prev_done = None
        while windows < self._max_windows:
            carry = runner(self._statics_sharded, carry)
            windows += self._windows_per_dispatch
            # pipelined early-exit probe (same pattern as JaxEngine): only
            # the *previous* dispatch's done reduction is read, so the host
            # never stalls the mesh on a fresh round-trip — at the cost of
            # one state-invariant extra dispatch after the run completes
            all_done = jnp.all(carry["done"])
            if prev_done is not None and bool(prev_done):
                break
            prev_done = all_done
        carry = jax.device_get(carry)
        carry = self._to_canonical_layout(carry)
        return [self._assemble(carry, r) for r in range(len(seeds))]
