"""Mesh-sharded vectorized engine (DESIGN.md §8).

The windowed-time engine (``runtime/engine_jax.py``) advances the whole
population per lockstep window on ONE device.  This subclass partitions the
flat population arrays into contiguous per-shard process blocks over a 1-D
device mesh (``launch/mesh.py::make_shard_mesh``) and runs each window's
drain -> batched compute -> send under ``shard_map``, so only the thin set
of cross-shard boundary edges ever crosses the device link — Conduit's
partitioning discipline (arXiv:2105.10486) applied to the simulator itself.

The window phases themselves live in ``runtime/window_core.py``
(DESIGN.md §11) and are shared with the unsharded engine verbatim: this
file keeps only what is genuinely distributed — the static shard layout
and boundary tables, the packed-ppermute boundary exchange, and the
barrier-release strategy (:class:`~repro.runtime.window_core.MeshRelease`
pmin/pmax reductions over the shard axis).

Layout.  ``topologies.contiguous_partition`` reorders pids so each shard's
processes are contiguous; every duct ring lives on its *receiver's* shard,
so drains, halo scatters, and receiver-side QoS counters are shard-local.
The duct layout itself follows ``layout=`` (DESIGN.md §10): edge-major
local rows in ascending canonical order, or — for degree-regular
topologies — dense receiver-major rows (``m * d`` per shard, no padding)
whose halo merges and receiver counters are plain per-receiver reshape
reductions; the boundary machinery below is layout-agnostic and simply
indexes whichever rows the plan laid out.
Per window, boundary traffic moves in exactly two collective hops per
distinct shard offset:

  1. payload hop: for each boundary edge the source shard packs
     (edge payload, availability stamp ``t_src + latency``, touch counter,
     active bit) into one int32 buffer and ``ppermute``s it to the
     receiver's shard, which scatters the entries into its local send rows;
  2. accept hop: after the local ``duct_send`` (drop iff the ring is full)
     the receiver ``ppermute``s the accept bits back so the source shard
     can maintain its processes' attempted/ok/dropped send counters.

Parity.  All stochastic draws stay keyed by *original* pid and *canonical*
edge id (the unsharded enumeration order), and halo-scatter ties resolve
by canonical edge id, so a run is a pure function of ``(config, seed)``
regardless of shard count: ``--shards 8`` reproduces ``--shards 1``
trajectories exactly (``tests/test_engine_conformance.py``).  The
replicate axis vmaps *inside* each shard, composing ``--replicates`` with
``--shards``.

Self-paced supersteps (DESIGN.md §9).  The per-window exchange above is a
hidden barrier: every window, every shard stops at the same ppermute.
With ``superstep_windows=W`` each shard instead advances W lockstep
windows *entirely shard-locally* per superstep — fault-injected or
jittered shards drift behind in virtual time exactly as the paper's
lac-417 node does — while boundary sends are staged sender-side.  The
superstep-end window then moves all W windows' boundary traffic in ONE
packed ppermute per shard offset (and one packed reverse hop for the
accept bits), cutting the collective count per simulated window by ~W×.
Staged messages carry their sender-window availability stamps and touch
counters, so latency/clumpiness QoS is computed from exact virtual-time
metadata; what W>1 changes is only *when* boundary messages enter the
receiver's ring (superstep boundaries instead of every window), which
perturbs drop patterns and per-message handling costs within a documented
tolerance.  Barrier modes release on superstep-granular pmin/pmax: since
waiting processes' clocks do not advance, release *times* are unchanged —
releases just land on superstep boundaries.  ``W=1`` reproduces the
per-window engine bitwise (same staged values, same operation order).

Pipelined overlap (DESIGN.md §12).  ``scheduler="pipelined"`` double-
buffers the superstep exchange: at boundary k the packed payload is
*staged* into shadow carry buffers (``fly_fwd_<off>``/``fly_acc_<off>``)
and the ppermute + receiver-side ``duct_send`` run at boundary k+1,
overlapping with superstep k+1's interior windows; the accept bits ride
the k+2 hop back to the sender's counters.  Boundary messages therefore
arrive one superstep later than under ``superstep`` — an honest,
QoS-visible latency (docs/QOS.md), not a reordering: stamps and touch
counters still carry exact sender-side virtual-time metadata, and drops
still happen at the receiver against real ring occupancy.  An epilogue
flush (att-bit gated, idempotent) empties the shadow buffers after the
last superstep so conservation closes exactly
(``tests/test_engine_sharded.py::test_pipelined_conservation_across_flush``).
Release decisions under barrier modes are consumed one boundary late
(:class:`~repro.runtime.window_core.PipelinedRelease`), which is sound
because a release cohort is frozen — all-stopped shards admit no new
sends — and the window budget doubles to ``2*W`` per superstep to cover
the drained tail.  Push passes before the superstep's last window have
no interior senders, so they gather only the static union of boundary
receiver rows into a compact sub-ring block (``rows_bnd``), run the send
phase there, and scatter back — the overlap's fixed cost scales with the
boundary cut, not the shard's full edge set.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.modes import AsyncMode
from repro.launch.mesh import SHARD_AXIS, make_shard_mesh, shard_map
from repro.runtime.engine_jax import JaxEngine
from repro.runtime.simulator import SimResult
from repro.runtime.topologies import contiguous_partition
from repro.runtime.window_core import (
    BARRIER_MODES,
    STREAM_LAT,
    BucketSlab,
    DenseSpec,
    MeshRelease,
    PipelinedRelease,
    lognormal_factor,
)

#: window schedulers this engine implements (registry vocabulary)
_SCHEDULERS = ("window", "superstep", "pipelined")

#: carry keys indexed by the process axis (permuted into shard layout);
#: the service keys ("arr_cum", "served"), the fault-attribution counters
#: ("c_loss", "c_dead"), and the quarantine flags ("quar") are present only
#: when the config enables them, so layout transforms guard on membership
_PROC_KEYS = ("t", "steps", "done", "waiting", "barrier_seq", "last_release",
              "pending", "c_touch", "c_att", "c_ok", "c_drop", "c_laden",
              "c_msgs", "c_loss", "c_dead", "quar", "snap", "snap_idx",
              "halo", "arr_cum", "served")
#: carry keys indexed by the edge axis (re-laid-out per shard, padded)
_EDGE_KEYS = ("ptouch", "q_avail", "q_touch", "q_pay", "q_head", "q_size")
#: per-replicate scalars (replicated across shards)
_SCALAR_KEYS = ("seed", "k")


def _bits_i32(x: jax.Array) -> jax.Array:
    """Reinterpret f32 as i32 so one ppermute buffer carries mixed fields."""
    if x.dtype == jnp.int32:
        return x
    return jax.lax.bitcast_convert_type(x, jnp.int32)


def _from_bits(x: jax.Array, dtype) -> jax.Array:
    if np.dtype(dtype) == np.dtype(np.int32):
        return x
    return jax.lax.bitcast_convert_type(x, dtype)


class ShardedJaxEngine(JaxEngine):
    """Windowed-time engine sharded over a 1-D device mesh.

    Same ``Engine`` contract and same trajectories as :class:`JaxEngine`
    (canonical RNG/tie keying — see module docstring); built by the
    registry when ``--shards S`` > 1.
    """

    def __init__(self, app, cfg, faults=None, *, shards: int,
                 superstep_windows: int = 1, scheduler: str = "auto",
                 max_pops: int = 16, chunk: int = 256, layout: str = "auto"):
        super().__init__(app, cfg, faults, max_pops=max_pops, chunk=chunk,
                         layout=layout)
        if np.dtype(self.bapp.payload_dtype) not in (np.dtype(np.int32),
                                                     np.dtype(np.float32)):
            raise ValueError(
                "sharded engine payloads must be int32/float32 (32-bit "
                f"ppermute packing), got {self.bapp.payload_dtype}")
        self.superstep = int(superstep_windows)
        if self.superstep < 1:
            raise ValueError(
                f"superstep_windows must be >= 1, got {superstep_windows}")
        if scheduler == "auto":
            scheduler = "superstep" if self.superstep > 1 else "window"
        if scheduler not in _SCHEDULERS:
            raise ValueError(
                f"unknown scheduler {scheduler!r}; choose from "
                f"{('auto',) + _SCHEDULERS}")
        if scheduler == "pipelined" and self.superstep < 2:
            raise ValueError(
                "scheduler='pipelined' overlaps boundary exchange with the "
                "next superstep's interior windows; pass "
                "superstep_windows > 1 (--superstep-windows W) to choose W")
        self.scheduler = scheduler
        if cfg.mode in BARRIER_MODES:
            # releases land only on superstep boundaries, so up to W-1 idle
            # windows precede each one — same virtual-time trajectory, more
            # lockstep windows consumed.  The pipelined scheduler defers
            # both the release reductions and the boundary delivery by one
            # more superstep, so budget 2W windows per release.
            if scheduler == "pipelined":
                self._max_windows *= 2 * self.superstep
            elif self.superstep > 1:
                self._max_windows *= self.superstep
        self._supersteps_per_dispatch = max(1, chunk // self.superstep)
        self._windows_per_dispatch = (self._supersteps_per_dispatch *
                                      self.superstep)
        self.shards = int(shards)
        self.plan = contiguous_partition(self.topo, self.shards)
        self.mesh = make_shard_mesh(self.shards)
        self._m = self.n // self.shards
        self._release = (PipelinedRelease(SHARD_AXIS)
                         if scheduler == "pipelined"
                         else MeshRelease(SHARD_AXIS))
        self._build_statics()
        self._statics_sharded = None
        self._cspecs = None
        self._flusher = None

    # ------------------------------------------------------------------
    # Static shard layout: local rows (rings on the receiver's shard) and
    # per-offset boundary exchange tables.  All numpy, hoisted out of jit.
    # ------------------------------------------------------------------
    def _build_statics(self) -> None:
        S, m, E = self.shards, self._m, self.E
        esrc = np.asarray(self._esrc)
        edst = np.asarray(self._edst)
        slot = np.asarray(self._slot)
        out_slot = np.asarray(self._out_slot)
        rev = np.asarray(self._rev)
        lat_base = np.asarray(self._lat_base)
        perm = np.asarray(self.plan.perm, np.int64)
        inv = np.asarray(self.plan.inv, np.int64)

        lsrc, ldst = inv[esrc], inv[edst]     # edge endpoints as positions
        src_sh, dst_sh = lsrc // m, ldst // m
        rows_by_shard = [np.where(dst_sh == s)[0] for s in range(S)]
        bucket_members: Dict[str, np.ndarray] = {}
        if self.lplan.kind == "dense":
            # bucketed dense receiver-major local rows (DESIGN.md §13):
            # bucket degrees are global, and every shard hosts its local
            # members of bucket b in a slab at the SAME static offset —
            # member block i (ascending local position) owns rows
            # off_b + i*deg_b .. off_b + (i+1)*deg_b - 1, with j the
            # edge's sorted-source position there, so each receiver's live
            # rows stay in canonical-edge-id order and the dense halo
            # select ties break like the unsharded engine.  Slabs pad to
            # the max member count over shards with sentinel blocks
            # (member value m: gathers clamp, scatters drop).
            lp = self.lplan
            rows_live = np.where(lp.live)[0]
            jof = np.empty(E, np.int64)
            jof[lp.eid[rows_live]] = (rows_live -
                                      lp.row_start[lp.dst[rows_live]])
            bdeg_pos = np.asarray(lp.bdeg, np.int64)[perm]  # by position
            self._bucket_geom: List[tuple] = []
            row0_pos = np.zeros(self.n, np.int64)  # first local row of the
            start = 0                              # position's member block
            for bi, b in enumerate(lp.buckets):
                counts = [int(np.sum(bdeg_pos[s * m:(s + 1) * m] == b.deg))
                          for s in range(S)]
                nb_max = max(1, max(counts))
                mem = np.full((S, nb_max), m, np.int32)
                for s in range(S):
                    loc = np.where(bdeg_pos[s * m:(s + 1) * m] == b.deg)[0]
                    mem[s, :len(loc)] = loc
                    row0_pos[s * m + loc] = start + np.arange(len(loc)) * b.deg
                identity = (len(lp.buckets) == 1 and nb_max == m and
                            min(counts) == m)
                self._bucket_geom.append((start, nb_max, b.deg, identity))
                if not identity:
                    bucket_members[str(bi)] = mem
                start += nb_max * b.deg
            ein = start
            row_of = row0_pos[ldst] + jof
        else:
            # canonical edge id -> its ring's local row index (ascending
            # canonical order per shard, so local row order == canonical
            # order and segment_max tie-breaks match the unsharded engine)
            ein = max(1, max(len(r) for r in rows_by_shard))
            row_of = np.full(E, -1, np.int64)
            for rows in rows_by_shard:
                row_of[rows] = np.arange(len(rows))
        self._ein = ein

        i32, f32 = np.int32, np.float32
        has_f = self._has_faults
        if has_f:
            # per-canonical-edge fault parameters, re-laid-out onto this
            # shard's local rows (and, below, its boundary send tables) so
            # every kill draw stays keyed by canonical edge id
            loss_e = np.asarray(self._loss, f32)
            flap_e = np.asarray(self._flap, f32)
            dead_e = np.asarray(self._dead, bool)
            row_loss = np.zeros((S, ein), f32)
            row_flap = np.zeros((S, ein), f32)
            row_dead = np.zeros((S, ein), bool)
        row_canon = np.zeros((S, ein), i32)
        row_valid = np.zeros((S, ein), bool)
        row_dst = np.full((S, ein), m, i32)
        row_src = np.full((S, ein), m, i32)       # sentinel m: not interior
        row_interior = np.zeros((S, ein), bool)
        row_out_slot = np.zeros((S, ein), i32)
        row_rev = np.full((S, ein), ein, i32)     # sentinel ein: not local
        row_halo_key = np.full((S, ein), 4 * m, i32)
        row_lat = np.zeros((S, ein), f32)
        for s in range(S):
            e = rows_by_shard[s]
            r = row_of[e]   # packed ascending (edge) or receiver-major
            interior = src_sh[e] == s
            row_canon[s, r] = e
            row_valid[s, r] = True
            row_dst[s, r] = ldst[e] - s * m
            row_src[s, r] = np.where(interior, lsrc[e] - s * m, m)
            row_interior[s, r] = interior
            row_out_slot[s, r] = out_slot[e]
            # rev edge (dst, src) drains at src — local iff this edge is
            # interior; boundary rows get their touch stamp via exchange
            row_rev[s, r] = np.where(interior, row_of[rev[e]], ein)
            row_halo_key[s, r] = (ldst[e] - s * m) * 4 + slot[e]
            row_lat[s, r] = lat_base[e]
            if has_f:
                row_loss[s, r] = loss_e[e]
                row_flap[s, r] = flap_e[e]
                row_dead[s, r] = dead_e[e]

        # boundary edges grouped by shard offset: one ppermute per offset
        bnd = np.where(src_sh != dst_sh)[0]
        offs = ((dst_sh[bnd] - src_sh[bnd]) % S).astype(np.int64)
        self._offsets = sorted(int(d) for d in set(offs.tolist()))
        self._bnd_bd: Dict[int, int] = {}
        bnd_tables: Dict[str, Dict[str, np.ndarray]] = {}
        for d in self._offsets:
            sel = bnd[offs == d]
            per_s = [sel[src_sh[sel] == s] for s in range(S)]  # canon order
            bd = max(1, max(len(p) for p in per_s))
            self._bnd_bd[d] = bd
            snd_src = np.full((S, bd), m, i32)
            snd_oslot = np.zeros((S, bd), i32)
            snd_rev = np.full((S, bd), ein, i32)
            snd_canon = np.zeros((S, bd), i32)
            snd_lat = np.zeros((S, bd), f32)
            rcv_row = np.full((S, bd), ein, i32)
            if has_f:
                snd_loss = np.zeros((S, bd), f32)
                snd_flap = np.zeros((S, bd), f32)
                snd_dead = np.zeros((S, bd), bool)
            for s in range(S):
                e = per_s[s]
                k = len(e)
                snd_src[s, :k] = lsrc[e] - s * m
                snd_oslot[s, :k] = out_slot[e]
                snd_rev[s, :k] = row_of[rev[e]]
                snd_canon[s, :k] = e
                snd_lat[s, :k] = lat_base[e]
                if has_f:
                    snd_loss[s, :k] = loss_e[e]
                    snd_flap[s, :k] = flap_e[e]
                    snd_dead[s, :k] = dead_e[e]
                # sender s's entry j lands at receiver (s+d)%S, entry j
                rcv_row[(s + d) % S, :k] = row_of[e]
            bnd_tables[str(d)] = dict(
                snd_src=snd_src, snd_oslot=snd_oslot, snd_rev=snd_rev,
                snd_canon=snd_canon, snd_lat=snd_lat, rcv_row=rcv_row)
            if has_f:
                bnd_tables[str(d)].update(
                    snd_loss=snd_loss, snd_flap=snd_flap, snd_dead=snd_dead)

        # compact boundary-row set: the union of every offset's receiver
        # rows, per shard.  Mid push passes (superstep/pipelined boundary
        # windows) touch ONLY these rows — gather the sub-rings, push, and
        # scatter back — instead of sweeping all ein rows W times.
        bnd_rows = [set() for _ in range(S)]
        for d in self._offsets:
            rr = bnd_tables[str(d)]["rcv_row"]
            for s in range(S):
                bnd_rows[s].update(int(r) for r in rr[s] if r < ein)
        eb = max(1, max((len(x) for x in bnd_rows), default=1))
        self._eb = eb
        rows_bnd = np.full((S, eb), ein, i32)  # sentinel ein: scatter-drop
        pos_of: List[Dict[int, int]] = []
        for s in range(S):
            rs = sorted(bnd_rows[s])
            rows_bnd[s, :len(rs)] = rs
            pos_of.append({r: i for i, r in enumerate(rs)})
        for d in self._offsets:
            tb = bnd_tables[str(d)]
            rcv_pos = np.full(tb["rcv_row"].shape, eb, i32)
            for s in range(S):
                for j, r in enumerate(tb["rcv_row"][s].tolist()):
                    if r < ein:
                        rcv_pos[s, j] = pos_of[s][r]
            tb["rcv_pos"] = rcv_pos

        extra = {}
        if has_f:
            extra.update(row_loss=row_loss, row_flap=row_flap,
                         row_dead=row_dead)
        if self._any_crashed:
            extra["crashed"] = (
                np.asarray(self._crashed)[perm].reshape(S, m))
        self._statics = jax.tree.map(jnp.asarray, dict(
            pids=perm.reshape(S, m).astype(i32),
            cfactor=np.asarray(self._cfactor)[perm].reshape(S, m),
            deg=np.asarray(self._deg)[perm].reshape(S, m).astype(i32),
            row_canon=row_canon, row_valid=row_valid, row_dst=row_dst,
            row_src=row_src, row_interior=row_interior,
            row_out_slot=row_out_slot, row_rev=row_rev,
            row_halo_key=row_halo_key, row_lat=row_lat,
            rows_bnd=rows_bnd, bnd=bnd_tables, bmem=bucket_members,
            **extra))
        self._crashed_pos = jnp.asarray(np.asarray(self._crashed)[perm])
        self._perm_np = perm
        self._inv_np = inv

    # ------------------------------------------------------------------
    # Layout transforms around the sharded dispatch
    # ------------------------------------------------------------------
    def _edge_state(self) -> Dict[str, jax.Array]:
        """Empty rings in padded per-shard layout: ``S * ein`` rows, row
        ``s * ein + j`` = shard s's local row j.  All-constant, so no
        canonical-order gather is needed (and the full-population edge
        arrays are never allocated)."""
        return self.core.edge_rings(self.shards * self._ein)

    def _init_carry(self, seed):
        carry = super()._init_carry(seed)
        if (self.scheduler == "pipelined" and
                self.cfg.mode != AsyncMode.NO_COMM):
            # double-buffer carry entries, already in per-shard layout
            # (axis 0 partitioned like the edge keys):
            #   fly_fwd_<off>  shadow buffers staged at the previous
            #                  boundary, in flight toward their receiver —
            #                  pushed into rings at the NEXT boundary
            #   fly_acc_<off>  packed (att << 1) | accept bits returning to
            #                  the sender — folded into counters at the
            #                  next boundary
            # all-zero init: att = 0 entries are no-ops at the first
            # boundary, so the pipeline fills naturally.
            W, S, Lp = self.superstep, self.shards, self.bapp.payload_len
            for off in self._offsets:
                bd = self._bnd_bd[off]
                carry[f"fly_fwd_{off}"] = jnp.zeros((S * W, bd, Lp + 3),
                                                    jnp.int32)
                carry[f"fly_acc_{off}"] = jnp.zeros((S * W, bd), jnp.int32)
            if self.cfg.mode in BARRIER_MODES:
                # per-shard staged release decision (PipelinedRelease):
                # reductions issued at boundary i, consumed at i+1
                carry["rel_ready"] = jnp.zeros(S, bool)
                carry["rel_t"] = jnp.full(S, -np.inf, jnp.float32)
                if self.cfg.barrier_timeout > 0:
                    # quarantine gate's cohort front rides the same
                    # one-boundary stage as the release decision
                    carry["rel_ref"] = jnp.full(S, -np.inf, jnp.float32)
        return carry

    def _to_sharded_layout(self, carry):
        """Permute process-axis leaves into shard order (edge leaves are
        already built in padded per-shard layout by ``_edge_state``)."""
        perm = self._perm_np
        out = dict(carry)
        for key in _PROC_KEYS:
            if key in carry:
                out[key] = carry[key][:, perm]
        out["app"] = jax.tree.map(lambda x: x[:, perm], carry["app"])
        return out

    def _to_canonical_layout(self, carry):
        """Undo the process permutation on everything ``_assemble`` reads."""
        inv = self._inv_np
        out = dict(carry)
        for key in _PROC_KEYS:
            if key in carry:
                out[key] = carry[key][:, inv]
        out["app"] = jax.tree.map(lambda x: x[:, inv], carry["app"])
        return out

    def _carry_specs(self, carry):
        specs = jax.tree.map(lambda _: P(None, SHARD_AXIS), carry)
        for key in _SCALAR_KEYS:
            specs[key] = P(None)
        return specs

    # ------------------------------------------------------------------
    # Shard-local window phases: thin wrappers over the shared core with
    # this shard's sentinel-padded tables
    # ------------------------------------------------------------------
    def _dense_spec_local(self, st) -> DenseSpec:
        """This shard's bucket-slab geometry: static offsets shared by all
        shards, member tables from the sharded statics (identity buckets
        skip theirs and take the zero-gather fast path)."""
        slabs = tuple(
            BucketSlab(start=start, nb=nb, deg=deg,
                       members=None if ident else st["bmem"][str(bi)])
            for bi, (start, nb, deg, ident)
            in enumerate(self._bucket_geom))
        return DenseSpec(n_dst=self._m, n_rows=self._ein, buckets=slabs)

    def _drain_phase(self, st, carry, t_pad, act_pad):
        """Drain every local ring (they live on their receiver's shard)
        through the shared core, with this shard's row tables."""
        return self.core.drain(
            carry, t_pad[st["row_dst"]], act_pad[st["row_dst"]],
            halo_key=st["row_halo_key"], n_halo=4 * self._m,
            dst=st["row_dst"], n_dst=self._m,
            dense_spec=(self._dense_spec_local(st)
                        if self.lplan.kind == "dense" else None))

    def _stage_offsets(self, st, t_pad, act_pad, eo_pad, ptouch_pad,
                       seed, steps_pad):
        """Sender-side staging of this window's boundary sends: one packed
        ``(bd, L+3)`` i32 buffer per shard offset — payload bits, then the
        availability stamp ``t_src + latency``, the reverse-edge touch
        counter, and the sender-active bit.  Stamps are drawn NOW, at the
        sender's window, so a batched exchange at the superstep boundary
        still delivers exact virtual-time metadata (latency/clumpiness QoS
        is computed from these stamps, not from arrival windows).

        Typed fault kills (lossy / flapping / dead-destination links,
        DESIGN.md §14) are decided HERE, sender-side: a killed boundary
        send is staged with a zero att bit — it never crosses the mesh as
        an attempt — and its attempted/dropped/cause counts come back as
        the second return value ``(m, 2)`` [loss, dead] for the caller to
        fold in this very window, exactly when the unsharded engine counts
        it.  Draws are keyed by canonical edge id and sender step count,
        so kill decisions are shard-count invariant."""
        cfg, m = self.cfg, self._m
        staged = {}
        bks = (jnp.zeros((m, 2), jnp.int32) if self._has_faults else None)
        for off in self._offsets:
            b = st["bnd"][str(off)]
            # latency draws keyed by (canonical edge id, sender step
            # count): identical to the unsharded engine's per-edge stream,
            # and invariant to which lockstep window the send runs under
            lat_b = b["snd_lat"] * lognormal_factor(
                cfg.latency_sigma, seed, STREAM_LAT, b["snd_canon"],
                steps_pad[b["snd_src"]])
            pay_b = eo_pad[b["snd_src"], b["snd_oslot"]]
            avail_b = t_pad[b["snd_src"]] + lat_b
            att_b = act_pad[b["snd_src"]]
            tch_b = ptouch_pad[b["snd_rev"]]
            if self._has_faults:
                l_k, d_k = self.core.fault_masks(
                    seed, t_pad[b["snd_src"]], steps_pad[b["snd_src"]],
                    b["snd_canon"], b["snd_loss"], b["snd_flap"],
                    self.faults.flap_period, b["snd_dead"])
                cols = jnp.stack([(att_b & l_k).astype(jnp.int32),
                                  (att_b & d_k).astype(jnp.int32)], axis=1)
                bks = bks + jax.ops.segment_sum(
                    cols, b["snd_src"], num_segments=m + 1)[:m]
                att_b = att_b & ~(l_k | d_k)
            staged[str(off)] = jnp.concatenate([
                _bits_i32(pay_b),
                _bits_i32(avail_b)[:, None],
                tch_b[:, None],
                att_b[:, None].astype(jnp.int32)], axis=1)
        return staged, bks

    def _interior_kills(self, st, seed, t_pad, steps_pad, x_act):
        """Kill mask + per-process ``(m, 2)`` [loss, dead] counts for this
        window's interior sends, from the same canonical-eid draws as the
        unsharded engine.  Boundary and padding rows carry the m sentinel
        in ``row_src``, so their counts fall into the spare segment (and
        their garbage draws are masked by ``x_act``)."""
        loss_kill, dead_kill = self.core.fault_masks(
            seed, t_pad[st["row_src"]], steps_pad[st["row_src"]],
            st["row_canon"], st["row_loss"], st["row_flap"],
            self.faults.flap_period, st["row_dead"])
        cols = jnp.stack([(x_act & loss_kill).astype(jnp.int32),
                          (x_act & dead_kill).astype(jnp.int32)], axis=1)
        ks = jax.ops.segment_sum(cols, st["row_src"],
                                 num_segments=self._m + 1)[:self._m]
        return loss_kill | dead_kill, ks

    def _close_window(self, st, u, active, drained_r, *, release: bool):
        """Shared window tail with mesh release reductions; mid-superstep
        windows (``release=False``) skip the cross-shard pmin/pmax check —
        waiting processes stay waiting until the superstep boundary."""
        return self.core.close_window(
            u, active, drained_r, pids=st["pids"], deg=st["deg"],
            cfactor=st["cfactor"],
            release=self._release if release else None)

    # ------------------------------------------------------------------
    # Window bodies
    # ------------------------------------------------------------------
    def _local_window(self, st, carry):
        """One mid-superstep lockstep window: entirely shard-local.

        Interior edges exchange through their (local) rings as usual;
        boundary sends are packed into per-offset staging buffers and
        returned for the superstep scan to stack.  No collectives run, so
        each shard advances at its own jittered pace — fault-injected
        shards simply fall behind in virtual time.
        """
        cfg, m = self.cfg, self._m
        comm = cfg.mode != AsyncMode.NO_COMM
        seed, t = carry["seed"], carry["t"]
        active = ~carry["done"] & ~carry["waiting"]
        if self._any_crashed:
            active = active & ~st["crashed"]
        # sentinel-padded per-process vectors: index m = inactive dummy
        t_pad = jnp.concatenate([t, jnp.zeros(1, t.dtype)])
        act_pad = jnp.concatenate([active, jnp.zeros(1, bool)])
        u = dict(carry)
        drained_r = jnp.zeros(m, jnp.int32)
        staged = {}
        if comm:
            dr, drained_r = self._drain_phase(st, carry, t_pad, act_pad)
            u.update(dr)
        app_state, edges_out, steps = self.core.compute(
            carry, active, u["halo"], st["pids"])
        u.update(app=app_state, steps=steps)
        if comm:
            eo_pad = jnp.concatenate(
                [edges_out, jnp.zeros((1,) + edges_out.shape[1:],
                                      edges_out.dtype)])
            ptouch_pad = jnp.concatenate([u["ptouch"],
                                          jnp.zeros(1, jnp.int32)])
            steps_pad = jnp.concatenate([steps, jnp.zeros(1, jnp.int32)])
            staged, bks = self._stage_offsets(st, t_pad, act_pad, eo_pad,
                                              ptouch_pad, seed, steps_pad)
            # interior-only send attempt (drop iff full)
            lat_row = st["row_lat"] * lognormal_factor(
                cfg.latency_sigma, seed, STREAM_LAT, st["row_canon"],
                steps_pad[st["row_src"]])
            x_act = act_pad[st["row_src"]] & st["row_interior"]
            send_act = x_act
            if self._has_faults:
                kill, iks = self._interior_kills(st, seed, t_pad,
                                                 steps_pad, x_act)
                send_act = x_act & ~kill
            sp = self.core.send_edge(
                u, t_pad[st["row_src"]] + lat_row, send_act,
                jnp.float32(0.0), ptouch_pad[st["row_rev"]],
                eo_pad[st["row_src"], st["row_out_slot"]],
                st["row_src"], m)
            u.update(sp.rings)
            if self._has_faults:
                ks = bks + iks
                killed = ks[:, 0] + ks[:, 1]
                u.update(c_att=carry["c_att"] + sp.sums[:, 0] + killed,
                         c_ok=carry["c_ok"] + sp.sums[:, 1],
                         c_drop=carry["c_drop"] + sp.sums[:, 2] + killed,
                         c_loss=carry["c_loss"] + ks[:, 0],
                         c_dead=carry["c_dead"] + ks[:, 1])
            else:
                u.update(c_att=carry["c_att"] + sp.sums[:, 0],
                         c_ok=carry["c_ok"] + sp.sums[:, 1],
                         c_drop=carry["c_drop"] + sp.sums[:, 2])
        return self._close_window(st, u, active, drained_r,
                                  release=False), staged

    def _final_window(self, st, carry, stage_mid):
        """The superstep-end window: the only one that talks to peers.

        All staged boundary windows (plus this window's own) move in ONE
        packed ppermute per shard offset; the receiver pushes them into its
        rings in sender-window order (drop iff full per push, FIFO
        preserved), and the accept bits return in one packed reverse
        ppermute per offset so sender-side attempted/ok/dropped counters
        stay exact.  With ``superstep_windows=1`` this is operation-for-
        operation the per-window exchange engine.
        """
        cfg, m, S = self.cfg, self._m, self.shards
        comm = cfg.mode != AsyncMode.NO_COMM
        seed, t = carry["seed"], carry["t"]
        active = ~carry["done"] & ~carry["waiting"]
        if self._any_crashed:
            active = active & ~st["crashed"]
        t_pad = jnp.concatenate([t, jnp.zeros(1, t.dtype)])
        act_pad = jnp.concatenate([active, jnp.zeros(1, bool)])
        u = dict(carry)
        drained_r = jnp.zeros(m, jnp.int32)
        if comm:
            dr, drained_r = self._drain_phase(st, carry, t_pad, act_pad)
            u.update(dr)
        app_state, edges_out, steps = self.core.compute(
            carry, active, u["halo"], st["pids"])
        u.update(app=app_state, steps=steps)
        if comm:
            Lp = self.bapp.payload_len
            eo_pad = jnp.concatenate(
                [edges_out, jnp.zeros((1,) + edges_out.shape[1:],
                                      edges_out.dtype)])
            ptouch_pad = jnp.concatenate([u["ptouch"],
                                          jnp.zeros(1, jnp.int32)])
            steps_pad = jnp.concatenate([steps, jnp.zeros(1, jnp.int32)])
            own, bks = self._stage_offsets(st, t_pad, act_pad, eo_pad,
                                           ptouch_pad, seed, steps_pad)
            # --- payload hop: ONE packed ppermute per offset for all W ----
            staged_l, staged_r = {}, {}
            for off in self._offsets:
                key = str(off)
                full = (own[key][None] if stage_mid is None else
                        jnp.concatenate([stage_mid[key], own[key][None]],
                                        axis=0))
                staged_l[key] = full     # sender-local copy: the att bits
                staged_r[key] = jax.lax.ppermute(
                    full, SHARD_AXIS,
                    [(i, (i + off) % S) for i in range(S)])

            # interior send inputs for THIS window
            lat_row = st["row_lat"] * lognormal_factor(
                cfg.latency_sigma, seed, STREAM_LAT, st["row_canon"],
                steps_pad[st["row_src"]])
            int_pay = eo_pad[st["row_src"], st["row_out_slot"]]
            int_avail = t_pad[st["row_src"]] + lat_row
            int_act = act_pad[st["row_src"]] & st["row_interior"]
            int_tch = ptouch_pad[st["row_rev"]]
            if self._has_faults:
                kill, iks = self._interior_kills(st, seed, t_pad,
                                                 steps_pad, int_act)
                int_act = int_act & ~kill

            rings = {key: u[key] for key in
                     ("q_avail", "q_touch", "q_head", "q_size", "q_pay")}
            rings, acc, send_sums = self._push_passes(
                st, rings, staged_r, int_pay, int_avail, int_act, int_tch)
            u.update(rings)

            # --- accept hop: ONE packed reverse ppermute per offset -------
            for off in self._offsets:
                b = st["bnd"][str(off)]
                acc_back = jax.lax.ppermute(
                    acc[str(off)], SHARD_AXIS,
                    [(i, (i - off) % S) for i in range(S)])
                att = staged_l[str(off)][:, :, Lp + 2].astype(bool)
                ok = acc_back.astype(bool)
                cols_b = jnp.stack([
                    att.astype(jnp.int32).sum(0),
                    (att & ok).astype(jnp.int32).sum(0),
                    (att & ~ok).astype(jnp.int32).sum(0)], axis=1)
                send_sums = send_sums + jax.ops.segment_sum(
                    cols_b, b["snd_src"], num_segments=m + 1)[:m]
            if self._has_faults:
                # killed sends (att bit zeroed at staging) fold here: they
                # count attempted + dropped + cause, never ok
                ks = bks + iks
                killed = ks[:, 0] + ks[:, 1]
                u.update(c_att=carry["c_att"] + send_sums[:, 0] + killed,
                         c_ok=carry["c_ok"] + send_sums[:, 1],
                         c_drop=carry["c_drop"] + send_sums[:, 2] + killed,
                         c_loss=carry["c_loss"] + ks[:, 0],
                         c_dead=carry["c_dead"] + ks[:, 1])
            else:
                u.update(c_att=carry["c_att"] + send_sums[:, 0],
                         c_ok=carry["c_ok"] + send_sums[:, 1],
                         c_drop=carry["c_drop"] + send_sums[:, 2])
        return self._close_window(st, u, active, drained_r, release=True)

    def _push_passes(self, st, rings, bufs, int_pay, int_avail, int_act,
                     int_tch, *, want_sums: bool = True):
        """W ordered push passes over this shard's rings (FIFO per ring).

        ``bufs`` holds one receiver-side packed ``(W, bd, L+3)`` buffer per
        shard offset.  Boundary rows push buffer window j in pass j;
        interior rows push their current message in the last pass (their
        own window).  Rings are single-writer, so the row sets are disjoint
        and pass composition is exact.

        Passes 0..W-2 have no interior senders, so they run COMPACT: the
        static union of boundary receiver rows (``rows_bnd``, eb rows —
        a small fraction of ein on low-surface shardings) is gathered into
        a sub-ring block, pushed through the shared core, and scattered
        back.  Only the final pass sweeps all ein rows, so boundary-window
        send cost is ~one full sweep + (W-1) boundary-sized sweeps instead
        of W full sweeps.  Returns ``(rings, acc, sums)``: the updated
        ring dict, per-offset ``(W, bd)`` i32 accept bits, and the final
        pass's interior counter sums (``None`` unless ``want_sums`` —
        boundary rows carry the m sentinel in ``row_src``, so their
        contributions drop into the spare segment).
        """
        m, ein, W = self._m, self._ein, self.superstep
        eb = self._eb
        Lp = self.bapp.payload_len
        pay_dtype = int_pay.dtype
        rings = dict(rings)
        ring_keys = ("q_avail", "q_touch", "q_head", "q_size", "q_pay")
        rows_bnd = st["rows_bnd"]  # pad entries carry the ein sentinel
        acc = {str(off): [] for off in self._offsets}
        sums = None
        for j in range(W):
            last = j == W - 1
            if not last and not self._offsets:
                continue
            if last:
                # full-width pass: interior rows send their own message,
                # boundary rows push buffer window W-1
                x_pay = int_pay
                x_avail = int_avail
                x_act = int_act
                x_tch = int_tch
            else:
                # compact pass: only boundary rows are live, so gather the
                # union-of-offsets row subset, push into the sub-rings, and
                # scatter the touched rows back (rows_bnd pads carry the
                # ein sentinel: the gather clamps, the scatter drops)
                x_pay = jnp.zeros((eb,) + int_pay.shape[1:], pay_dtype)
                x_avail = jnp.zeros(eb, jnp.float32)
                x_act = jnp.zeros(eb, bool)
                x_tch = jnp.zeros(eb, jnp.int32)
            for off in self._offsets:
                b = st["bnd"][str(off)]
                buf = bufs[str(off)][j]
                rr = b["rcv_row"] if last else b["rcv_pos"]
                x_pay = x_pay.at[rr].set(
                    _from_bits(buf[:, :Lp], pay_dtype), mode="drop")
                x_avail = x_avail.at[rr].set(
                    _from_bits(buf[:, Lp], jnp.float32), mode="drop")
                x_tch = x_tch.at[rr].set(buf[:, Lp + 1], mode="drop")
                x_act = x_act.at[rr].set(buf[:, Lp + 2].astype(bool),
                                         mode="drop")
            if last:
                sp = self.core.send_edge(
                    rings, x_avail, x_act, jnp.float32(0.0), x_tch, x_pay,
                    st["row_src"], m, want_sums=want_sums)
                rings.update(sp.rings)
                acc_pad = jnp.concatenate([sp.accepted,
                                           jnp.zeros(1, bool)])
                for off in self._offsets:
                    acc[str(off)].append(
                        acc_pad[st["bnd"][str(off)]["rcv_row"]])
                if want_sums:
                    sums = sp.sums
            else:
                sub = {key: rings[key][rows_bnd] for key in ring_keys}
                sp = self.core.send_edge(
                    sub, x_avail, x_act, jnp.float32(0.0), x_tch, x_pay,
                    jnp.zeros(eb, jnp.int32), 1, want_sums=False)
                for key in ring_keys:
                    if key in sp.rings:
                        rings[key] = rings[key].at[rows_bnd].set(
                            sp.rings[key], mode="drop")
                acc_pad = jnp.concatenate([sp.accepted,
                                           jnp.zeros(1, bool)])
                for off in self._offsets:
                    acc[str(off)].append(
                        acc_pad[st["bnd"][str(off)]["rcv_pos"]])
        acc = {key: jnp.stack(v).astype(jnp.int32)
               for key, v in acc.items()}
        return rings, acc, sums

    def _final_window_pipelined(self, st, carry, stage_mid):
        """Superstep-boundary window of the ``pipelined`` scheduler.

        Double-buffered exchange (DESIGN.md §12): this boundary PUSHES the
        shadow buffers that arrived during the superstep (staged at the
        previous boundary), FOLDS the accept/attempt bits that returned
        for the previous boundary's pushes, then DISPATCHES this
        superstep's own staged buffers forward and this boundary's accept
        bits backward — both consumed only at the NEXT boundary, so
        neither collective's result blocks the next superstep's interior
        windows.  Boundary messages arrive exactly one superstep later
        than under ``scheduler='superstep'``; their availability stamps
        are unchanged (drawn at the sender's window), so the shift is
        honest added latency that the QoS stream observes.
        """
        cfg, m, S = self.cfg, self._m, self.shards
        comm = cfg.mode != AsyncMode.NO_COMM
        seed, t = carry["seed"], carry["t"]
        active = ~carry["done"] & ~carry["waiting"]
        if self._any_crashed:
            active = active & ~st["crashed"]
        t_pad = jnp.concatenate([t, jnp.zeros(1, t.dtype)])
        act_pad = jnp.concatenate([active, jnp.zeros(1, bool)])
        u = dict(carry)
        drained_r = jnp.zeros(m, jnp.int32)
        if comm:
            dr, drained_r = self._drain_phase(st, carry, t_pad, act_pad)
            u.update(dr)
        app_state, edges_out, steps = self.core.compute(
            carry, active, u["halo"], st["pids"])
        u.update(app=app_state, steps=steps)
        if comm:
            Lp = self.bapp.payload_len
            eo_pad = jnp.concatenate(
                [edges_out, jnp.zeros((1,) + edges_out.shape[1:],
                                      edges_out.dtype)])
            ptouch_pad = jnp.concatenate([u["ptouch"],
                                          jnp.zeros(1, jnp.int32)])
            steps_pad = jnp.concatenate([steps, jnp.zeros(1, jnp.int32)])
            own, bks = self._stage_offsets(st, t_pad, act_pad, eo_pad,
                                           ptouch_pad, seed, steps_pad)

            # interior send inputs for THIS window
            lat_row = st["row_lat"] * lognormal_factor(
                cfg.latency_sigma, seed, STREAM_LAT, st["row_canon"],
                steps_pad[st["row_src"]])
            int_pay = eo_pad[st["row_src"], st["row_out_slot"]]
            int_avail = t_pad[st["row_src"]] + lat_row
            int_act = act_pad[st["row_src"]] & st["row_interior"]
            int_tch = ptouch_pad[st["row_rev"]]
            if self._has_faults:
                kill, iks = self._interior_kills(st, seed, t_pad,
                                                 steps_pad, int_act)
                int_act = int_act & ~kill

            # --- push the shadow buffers staged at the PREVIOUS boundary --
            bufs = {str(off): u[f"fly_fwd_{off}"] for off in self._offsets}
            rings = {key: u[key] for key in
                     ("q_avail", "q_touch", "q_head", "q_size", "q_pay")}
            rings, acc, send_sums = self._push_passes(
                st, rings, bufs, int_pay, int_avail, int_act, int_tch)
            u.update(rings)

            # --- fold the bits that returned for the previous boundary's
            # pushes: packed (att << 1) | accept, already on their sender
            for off in self._offsets:
                b = st["bnd"][str(off)]
                bits = u[f"fly_acc_{off}"]
                att = (bits >> 1) & 1
                okb = bits & 1
                cols_b = jnp.stack([
                    att.sum(0),
                    (att & okb).sum(0),
                    (att & (1 - okb)).sum(0)], axis=1)
                send_sums = send_sums + jax.ops.segment_sum(
                    cols_b, b["snd_src"], num_segments=m + 1)[:m]
            if self._has_faults:
                # kills are counted at stage time (this window), while the
                # killed sends' att bits are zero for the rest of the
                # pipeline — the deferred folds never see them
                ks = bks + iks
                killed = ks[:, 0] + ks[:, 1]
                u.update(c_att=carry["c_att"] + send_sums[:, 0] + killed,
                         c_ok=carry["c_ok"] + send_sums[:, 1],
                         c_drop=carry["c_drop"] + send_sums[:, 2] + killed,
                         c_loss=carry["c_loss"] + ks[:, 0],
                         c_dead=carry["c_dead"] + ks[:, 1])
            else:
                u.update(c_att=carry["c_att"] + send_sums[:, 0],
                         c_ok=carry["c_ok"] + send_sums[:, 1],
                         c_drop=carry["c_drop"] + send_sums[:, 2])

            # --- dispatch the next hops, consumed at the NEXT boundary ----
            for off in self._offsets:
                key = str(off)
                full = (own[key][None] if stage_mid is None else
                        jnp.concatenate([stage_mid[key], own[key][None]],
                                        axis=0))
                u[f"fly_fwd_{off}"] = jax.lax.ppermute(
                    full, SHARD_AXIS,
                    [(i, (i + off) % S) for i in range(S)])
                att_r = bufs[key][:, :, Lp + 2]
                u[f"fly_acc_{off}"] = jax.lax.ppermute(
                    (att_r << 1) | acc[key], SHARD_AXIS,
                    [(i, (i - off) % S) for i in range(S)])
        return self._close_window(st, u, active, drained_r, release=True)

    def _flush_body(self, st, u):
        """Epilogue flush of the pipeline's in-flight state (one shard,
        one replicate): fold the carried accept bits, deliver the carried
        shadow buffers, and fold the bits those pushes produce.  Every
        step is gated on att bits, so anything the natural post-done
        supersteps already processed is a no-op — the flush only
        guarantees conservation when the run ends with a live superstep
        still in flight."""
        m, S = self._m, self.shards
        ein, Lp = self._ein, self.bapp.payload_len
        u = dict(u)
        send_sums = jnp.zeros((m, 3), jnp.int32)

        def fold(bits, b, sums):
            att = (bits >> 1) & 1
            okb = bits & 1
            cols_b = jnp.stack([
                att.sum(0), (att & okb).sum(0),
                (att & (1 - okb)).sum(0)], axis=1)
            return sums + jax.ops.segment_sum(
                cols_b, b["snd_src"], num_segments=m + 1)[:m]

        for off in self._offsets:
            send_sums = fold(u[f"fly_acc_{off}"], st["bnd"][str(off)],
                             send_sums)
        bufs = {str(off): u[f"fly_fwd_{off}"] for off in self._offsets}
        rings = {key: u[key] for key in
                 ("q_avail", "q_touch", "q_head", "q_size", "q_pay")}
        rings, acc, _ = self._push_passes(
            st, rings,
            bufs,
            jnp.zeros((ein, Lp), self.bapp.payload_dtype),
            jnp.zeros(ein, jnp.float32), jnp.zeros(ein, bool),
            jnp.zeros(ein, jnp.int32), want_sums=False)
        u.update(rings)
        for off in self._offsets:
            att_r = bufs[str(off)][:, :, Lp + 2]
            bits_back = jax.lax.ppermute(
                (att_r << 1) | acc[str(off)], SHARD_AXIS,
                [(i, (i - off) % S) for i in range(S)])
            send_sums = fold(bits_back, st["bnd"][str(off)], send_sums)
            u[f"fly_fwd_{off}"] = jnp.zeros_like(u[f"fly_fwd_{off}"])
            u[f"fly_acc_{off}"] = jnp.zeros_like(u[f"fly_acc_{off}"])
        u.update(c_att=u["c_att"] + send_sums[:, 0],
                 c_ok=u["c_ok"] + send_sums[:, 1],
                 c_drop=u["c_drop"] + send_sums[:, 2])
        return u

    def _get_flusher(self):
        if self._flusher is None:
            def flush_fn(st, carry):
                st = jax.tree.map(lambda a: a[0], st)
                return jax.vmap(lambda c: self._flush_body(st, c))(carry)
            sspecs = jax.tree.map(lambda _: P(SHARD_AXIS), self._statics)
            f = shard_map(flush_fn, self.mesh,
                          in_specs=(sspecs, self._cspecs),
                          out_specs=self._cspecs)
            self._flusher = jax.jit(f, donate_argnums=1)
        return self._flusher

    # ------------------------------------------------------------------
    def _get_runner(self):
        if self._runner is None:
            W = self.superstep
            final = (self._final_window_pipelined
                     if self.scheduler == "pipelined"
                     else self._final_window)

            def chunk_fn(st, carry):
                st = jax.tree.map(lambda a: a[0], st)  # (1, ...) -> local

                def superstep(c, _):
                    if W > 1:
                        c, stage_mid = jax.lax.scan(
                            lambda cc, __: self._local_window(st, cc),
                            c, None, length=W - 1)
                    else:
                        stage_mid = None
                    return final(st, c, stage_mid), None

                def one(c):
                    c, _ = jax.lax.scan(
                        superstep, c, None,
                        length=self._supersteps_per_dispatch)
                    return c
                # replicate (seed) axis vmaps INSIDE each shard
                return jax.vmap(one)(carry)

            sspecs = jax.tree.map(lambda _: P(SHARD_AXIS), self._statics)
            f = shard_map(chunk_fn, self.mesh, in_specs=(sspecs, self._cspecs),
                          out_specs=self._cspecs)
            self._runner = jax.jit(f, donate_argnums=1)
        return self._runner

    # ------------------------------------------------------------------
    def run_replicates(self, seeds: Sequence[int]) -> List[SimResult]:
        """One replicate per seed: a single sharded, vmapped dispatch."""
        carries = [self._init_carry(int(s)) for s in seeds]
        carry = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *carries)
        carry = self._to_sharded_layout(carry)
        if self._cspecs is None:
            self._cspecs = self._carry_specs(carry)
        carry = jax.device_put(carry, jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), self._cspecs,
            is_leaf=lambda x: isinstance(x, P)))
        if self._statics_sharded is None:
            self._statics_sharded = jax.device_put(
                self._statics, jax.tree.map(
                    lambda _: NamedSharding(self.mesh, P(SHARD_AXIS)),
                    self._statics))
        runner = self._get_runner()
        windows = 0
        prev_done = None
        while windows < self._max_windows:
            carry = runner(self._statics_sharded, carry)
            windows += self._windows_per_dispatch
            # pipelined early-exit probe (same pattern as JaxEngine): only
            # the *previous* dispatch's done reduction is read, so the host
            # never stalls the mesh on a fresh round-trip — at the cost of
            # one state-invariant extra dispatch after the run completes.
            # crashed processes never reach the horizon; the probe treats
            # them as terminally stopped (position order, like the carry)
            all_done = (jnp.all(carry["done"] | self._crashed_pos)
                        if self._any_crashed else jnp.all(carry["done"]))
            if prev_done is not None and bool(prev_done):
                break
            prev_done = all_done
        if (self.scheduler == "pipelined" and
                self.cfg.mode != AsyncMode.NO_COMM):
            # epilogue flush: deliver/fold whatever is still in flight so
            # message conservation holds even when the loop exits with a
            # live superstep's exchange un-consumed
            carry = self._get_flusher()(self._statics_sharded, carry)
        carry = jax.device_get(carry)
        carry = self._to_canonical_layout(carry)
        if getattr(self, "debug_keep_carry", False):
            self._final_carry = carry
        return [self._assemble(carry, r) for r in range(len(seeds))]
