from repro.runtime import channels, faults, simulator  # noqa: F401
from repro.runtime.simulator import SimConfig, Simulator, SimResult  # noqa: F401
