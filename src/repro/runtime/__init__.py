from repro.runtime import channels, faults, simulator, topologies  # noqa: F401
from repro.runtime.engine import ENGINES, Engine, make_engine  # noqa: F401
from repro.runtime.simulator import SimConfig, Simulator, SimResult  # noqa: F401
from repro.runtime.topologies import Topology, make_topology  # noqa: F401
