"""Discrete-event best-effort runtime: virtual processes, real compute.

Executes an application's *actual* compute fragments (JAX/numpy) under a
virtual-time model of per-step jitter, link latency, bounded send buffers,
barrier costs, and fault injection — reproducing the paper's cluster
experiments (C1–C4, DESIGN.md §1) deterministically on a single host.

Event ordering: step completions are processed in global virtual-time order
(heap), so message availability is causally consistent.  Each simstep is
compute-phase → communication-phase, with received messages incorporated at
the *next* compute phase, matching the paper's model.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Tuple

from repro.core.modes import AsyncMode
from repro.core.qos import Counters, QosReport, report
from repro.runtime.channels import Duct
from repro.runtime.faults import FaultModel, Jitter


@dataclasses.dataclass(frozen=True)
class SimConfig:
    mode: AsyncMode = AsyncMode.BEST_EFFORT
    duration: float = 1.0              # virtual seconds
    base_compute: float = 15e-6        # mean compute seconds per update
    work_units: int = 0                # added compute work (paper §III-C)
    work_unit_cost: float = 35e-9
    per_message_cost: float = 0.1e-6   # receiver-side handling per message
    per_pull_cost: float = 0.3e-6      # per pull attempt (bulk drain)
    jitter_sigma: float = 0.15
    stall_prob: float = 0.01           # occasional OS/cache stall
    stall_factor: float = 8.0
    base_latency: float = 500e-6       # internode one-way latency
    latency_sigma: float = 0.5
    buffer_capacity: int = 64
    barrier_base: float = 2e-5
    barrier_per_log2: float = 1.5e-5   # sync cost grows with CPU count
    rolling_quantum: float = 0.01      # mode 1 work chunk (10 ms, paper)
    fixed_interval: float = 0.25       # mode 2 sync timepoints
    snapshot_interval: float = 0.2     # QoS snapshot spacing
    snapshot_warmup: float = 0.2
    seed: int = 0


@dataclasses.dataclass
class SimResult:
    updates: List[int]
    horizon: float
    quality: float
    qos: List[QosReport]               # one per (process, window)
    qos_by_process: Dict[int, List[QosReport]]
    dropped: int
    sent: int

    @property
    def update_rate_per_cpu(self) -> float:
        return sum(self.updates) / len(self.updates) / self.horizon

    @property
    def delivery_failure_rate(self) -> float:
        return self.dropped / max(self.sent, 1)


class _Proc:
    __slots__ = ("pid", "clock", "steps", "pending_handling", "waiting",
                 "last_release", "barrier_seq", "done", "touch")

    def __init__(self, pid: int):
        self.pid = pid
        self.clock = 0.0
        self.steps = 0
        self.pending_handling = 0.0
        self.waiting = False
        self.last_release = 0.0
        self.barrier_seq = 0
        self.done = False
        self.touch: Dict[int, int] = {}


class Simulator:
    """Generic engine; the application provides fragments + topology."""

    def __init__(self, app, cfg: SimConfig, faults: Optional[FaultModel] = None):
        self.app = app
        self.cfg = cfg
        self.faults = faults or FaultModel()
        self.n = app.n_processes
        self.topology: Dict[int, List[int]] = app.topology()
        self.fragments = app.make_fragments()
        self.jitter = Jitter(cfg.jitter_sigma, cfg.seed,
                             cfg.stall_prob, cfg.stall_factor)
        self.procs = [_Proc(i) for i in range(self.n)]
        for p in self.procs:
            p.touch = {nb: 0 for nb in self.topology[p.pid]}
        self.ducts: Dict[Tuple[int, int], Duct] = {}
        for src, nbs in self.topology.items():
            for dst in nbs:
                self.ducts[(src, dst)] = Duct(
                    cfg.buffer_capacity, self._latency_fn(src, dst),
                    name=f"{src}->{dst}")
        self._lat_count = 0
        self._snapshots: Dict[int, List[Tuple[float, Counters]]] = {
            i: [] for i in range(self.n)}
        self._barrier_arrivals: Dict[int, List[Tuple[int, float]]] = {}

    # ------------------------------------------------------------------
    def _latency_fn(self, src, dst):
        def fn(now):
            self._lat_count += 1
            f = self.jitter.latency_factor(src, self._lat_count)
            return self.cfg.base_latency * f * self.faults.link_factor(src, dst)
        return fn

    def _step_duration(self, pid: int, step: int) -> float:
        cfg = self.cfg
        base = cfg.base_compute + cfg.work_units * cfg.work_unit_cost
        f = self.jitter.factor(pid, step)
        return base * f * self.faults.compute_factor(pid)

    def _barrier_cost(self) -> float:
        if self.n <= 1:
            return 0.0  # a lone process has nothing to synchronize with
        return self.cfg.barrier_base + self.cfg.barrier_per_log2 * math.log2(self.n)

    # ------------------------------------------------------------------
    def _proc_counters(self, pid: int) -> Counters:
        """Aggregate a process's channel counters + its own update/touch."""
        c = Counters()
        p = self.procs[pid]
        c.update_count = p.steps
        c.touch_count = sum(p.touch.values())
        c.wall_time = p.clock
        for nb in self.topology[pid]:
            out_d = self.ducts[(pid, nb)]
            in_d = self.ducts[(nb, pid)]
            c.attempted_send_count += out_d.inlet.attempted_send_count
            c.successful_send_count += out_d.inlet.successful_send_count
            c.laden_pull_count += in_d.outlet.laden_pull_count
            c.message_count += in_d.outlet.message_count
            c.pull_attempt_count += in_d.outlet.pull_attempt_count
        return c

    def _maybe_snapshot(self, pid: int, t: float):
        snaps = self._snapshots[pid]
        due = self.cfg.snapshot_warmup + len(snaps) * self.cfg.snapshot_interval
        if t >= due:
            c = self._proc_counters(pid)
            c.wall_time = t
            snaps.append((t, c))

    # ------------------------------------------------------------------
    def _barrier_due(self, p: _Proc, t: float) -> bool:
        mode = self.cfg.mode
        if mode == AsyncMode.BARRIER_EVERY_STEP:
            return True
        if mode == AsyncMode.ROLLING_BARRIER:
            return (t - p.last_release) >= self.cfg.rolling_quantum
        if mode == AsyncMode.FIXED_BARRIER:
            return t >= (p.barrier_seq + 1) * self.cfg.fixed_interval
        return False

    # ------------------------------------------------------------------
    def run(self) -> SimResult:
        cfg = self.cfg
        heap: List[Tuple[float, int, int]] = []
        seq = 0
        for p in self.procs:
            d = self._step_duration(p.pid, 0)
            heapq.heappush(heap, (d, seq, p.pid))
            seq += 1

        active = self.n
        comm = cfg.mode != AsyncMode.NO_COMM

        while heap:
            t, _, pid = heapq.heappop(heap)
            p = self.procs[pid]
            if p.done:
                continue
            p.clock = t

            # --- communication phase: bulk-drain inboxes -------------------
            inbox = {}
            n_msgs = 0
            if comm:
                for nb in self.topology[pid]:
                    msg, drained = self.ducts[(nb, pid)].latest(t)
                    n_msgs += drained
                    if msg is not None:
                        p.touch[nb] = 1 + msg.touch
                        inbox[nb] = msg.payload
                    else:
                        inbox[nb] = None
            else:
                inbox = {nb: None for nb in self.topology[pid]}

            # --- compute phase (the real application fragment) -------------
            outputs = self.fragments[pid].update(inbox)
            p.steps += 1

            if comm:
                for nb, payload in outputs.items():
                    self.ducts[(pid, nb)].try_send(payload, t, p.touch[nb])

            p.pending_handling = (n_msgs * cfg.per_message_cost
                                  + len(self.topology[pid]) * cfg.per_pull_cost)
            self._maybe_snapshot(pid, t)

            # --- termination ------------------------------------------------
            if t >= cfg.duration:
                p.done = True
                active -= 1
                # release any barrier this process would have joined
                seq = self._try_release_barriers(heap, seq)
                continue

            # --- scheduling / barriers --------------------------------------
            if self._barrier_due(p, t):
                b = p.barrier_seq
                self._barrier_arrivals.setdefault(b, []).append((pid, t))
                p.waiting = True
                seq = self._try_release_barriers(heap, seq)
            else:
                d = self._step_duration(pid, p.steps) + p.pending_handling
                heapq.heappush(heap, (t + d, seq, pid))
                seq += 1

        updates = [p.steps for p in self.procs]
        qos_by_proc: Dict[int, List[QosReport]] = {}
        all_qos: List[QosReport] = []
        for pid, snaps in self._snapshots.items():
            reps = []
            for (t0, c0), (t1, c1) in zip(snaps, snaps[1:]):
                reps.append(report(c0, c1))
            qos_by_proc[pid] = reps
            all_qos.extend(reps)

        sent = sum(d.inlet.attempted_send_count for d in self.ducts.values())
        ok = sum(d.inlet.successful_send_count for d in self.ducts.values())
        return SimResult(
            updates=updates,
            horizon=cfg.duration,
            quality=self.app.quality(self.fragments),
            qos=all_qos,
            qos_by_process=qos_by_proc,
            dropped=sent - ok,
            sent=sent,
        )

    # ------------------------------------------------------------------
    def _try_release_barriers(self, heap, seq) -> int:
        """Release every barrier whose full active cohort has arrived."""
        for b in sorted(self._barrier_arrivals):
            arrivals = self._barrier_arrivals[b]
            waiting_active = [a for a in arrivals if not self.procs[a[0]].done]
            needed = sum(1 for p in self.procs
                         if not p.done and p.barrier_seq == b)
            if needed > 0 and len(waiting_active) >= needed:
                release = max(a[1] for a in arrivals) + self._barrier_cost()
                for pid, _ in waiting_active:
                    p = self.procs[pid]
                    p.waiting = False
                    p.barrier_seq = b + 1
                    p.last_release = release
                    d = self._step_duration(pid, p.steps) + p.pending_handling
                    heapq.heappush(heap, (release + d, seq, pid))
                    seq += 1
                del self._barrier_arrivals[b]
        return seq
