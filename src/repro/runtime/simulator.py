"""Discrete-event best-effort runtime: virtual processes, real compute.

Executes an application's *actual* compute fragments (JAX/numpy) under a
virtual-time model of per-step jitter, link latency, bounded send buffers,
barrier costs, and fault injection — reproducing the paper's cluster
experiments (C1–C4, DESIGN.md §1) deterministically on a single host.

Event ordering: step completions are processed in global virtual-time order
(heap), so message availability is causally consistent.  Each simstep is
compute-phase → communication-phase, with received messages incorporated at
the *next* compute phase, matching the paper's model.

Scale: process state lives in flat numpy arrays and QoS counters are
accumulated incrementally inside the event loop (never recomputed by
scanning ducts), so the engine sustains 1024+ virtual processes.  The link
model is hierarchical (DESIGN.md §3): when the application's topology
carries a host assignment, intra-node hops use ``intra_node_latency`` while
inter-node hops pay ``base_latency``.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.modes import AsyncMode
from repro.core.qos import Counters, QosReport, report
from repro.runtime.channels import Duct
from repro.runtime.faults import (
    STREAM_FLAP,
    STREAM_LOSS,
    FaultModel,
    Jitter,
    np_hash_uniform,
)

_BARRIER_MODES = (AsyncMode.BARRIER_EVERY_STEP, AsyncMode.ROLLING_BARRIER,
                  AsyncMode.FIXED_BARRIER)


@dataclasses.dataclass(frozen=True)
class SimConfig:
    mode: AsyncMode = AsyncMode.BEST_EFFORT
    duration: float = 1.0              # virtual seconds
    base_compute: float = 15e-6        # mean compute seconds per update
    work_units: int = 0                # added compute work (paper §III-C)
    work_unit_cost: float = 35e-9
    per_message_cost: float = 0.1e-6   # receiver-side handling per message
    per_pull_cost: float = 0.3e-6      # per pull attempt (bulk drain)
    jitter_sigma: float = 0.15
    stall_prob: float = 0.01           # occasional OS/cache stall
    stall_factor: float = 8.0
    base_latency: float = 500e-6       # internode one-way latency
    intra_node_latency: Optional[float] = None  # same-host hops (None: flat)
    latency_sigma: float = 0.5
    buffer_capacity: int = 64
    barrier_base: float = 2e-5
    barrier_per_log2: float = 1.5e-5   # sync cost grows with CPU count
    # barrier quarantine (DESIGN.md §14): > 0 releases a barrier without
    # processes whose next arrival lags the cohort front by more than this
    # many virtual seconds (a crashed process's next arrival is +inf, so any
    # finite timeout excludes it); quarantined processes rejoin after
    # catching up to within timeout/2 (hysteresis).  0 = plain barrier.
    barrier_timeout: float = 0.0
    rolling_quantum: float = 0.01      # mode 1 work chunk (10 ms, paper)
    fixed_interval: float = 0.25       # mode 2 sync timepoints
    snapshot_interval: float = 0.2     # QoS snapshot spacing
    snapshot_warmup: float = 0.2
    seed: int = 0
    # --- open-loop service arrivals (runtime/service.py) ----------------
    # rate > 0 switches the run into the live-service posture: a
    # deterministic splitmix-hashed arrival stream feeds each process's
    # work queue, and every update serves up to service_chunk queued items
    # at per_item_cost compute seconds each.  The stream is precomputed
    # per (seed, pid, time bin) so every engine injects identical load.
    arrival_rate: float = 0.0          # mean arrivals /process /vsecond
    arrival_shape: str = "poisson"     # poisson | bursty | diurnal
    arrival_bin: float = 1e-3          # arrival-draw bin width (vseconds)
    arrival_burst_prob: float = 0.05   # bursty: per-bin global surge odds
    arrival_burst_factor: float = 8.0  # bursty: surge rate multiplier
    arrival_period: float = 0.02       # diurnal: sinusoid period
    service_chunk: int = 4             # max queue items served per update
    per_item_cost: float = 2e-6        # compute seconds per served item
    # export final app state into SimResult.app_state (when the app
    # implements export_state).  Off by default: the snapshot copies the
    # whole population's state per replicate, which batch sweeps never
    # read; runtime/service.py turns it on to carry survivors' state
    # across epoch boundaries.
    carry_app_state: bool = False


@dataclasses.dataclass
class SimResult:
    updates: List[int]
    horizon: float
    quality: float
    qos: List[QosReport]               # one per (process, window)
    qos_by_process: Dict[int, List[QosReport]]
    dropped: int
    sent: int
    #: drop attribution (DESIGN.md §14): ``dropped`` is the total across all
    #: causes; these two split out lossy/flapping-link drops and sends toward
    #: a crashed destination.  Capacity drops (full duct) are the remainder.
    dropped_loss: int = 0
    dropped_dead: int = 0
    #: live-service queue accounting (``cfg.arrival_rate > 0`` only):
    #: {"arrivals": [...], "served": [...], "backlog": [...]} per process
    service: Optional[dict] = None
    #: final app state, {pid: state} — populated when the app exposes
    #: ``export_state``; lets runtime/service.py carry survivors' state
    #: across epoch boundaries instead of re-initializing every epoch
    app_state: Optional[dict] = None

    @property
    def update_rate_per_cpu(self) -> float:
        return sum(self.updates) / len(self.updates) / self.horizon

    @property
    def delivery_failure_rate(self) -> float:
        return self.dropped / max(self.sent, 1)


class Simulator:
    """Generic engine; the application provides fragments + topology.

    ``app.topology()`` may return either a plain ``{pid: [neighbors]}`` dict
    or a :class:`repro.runtime.topologies.Topology`; the latter enables the
    hierarchical link model and host-level fault injection.

    Implements the :class:`repro.runtime.engine.Engine` protocol (the
    reference event-ordered backend; ``runtime/engine_jax.py`` is the
    vectorized one).
    """

    name = "event"

    def __init__(self, app, cfg: SimConfig, faults: Optional[FaultModel] = None):
        self.app = app
        self.cfg = cfg
        self.faults = faults or FaultModel()
        self.n = n = app.n_processes
        topo = app.topology()
        if hasattr(topo, "as_dict"):          # Topology object
            self.topo = topo
            self.topology: Dict[int, List[int]] = topo.as_dict()
        else:
            self.topo = None
            self.topology = topo
        self.fragments = app.make_fragments()
        self.jitter = Jitter(cfg.jitter_sigma, cfg.seed,
                             cfg.stall_prob, cfg.stall_factor)
        self.lat_jitter = Jitter(cfg.latency_sigma, cfg.seed)

        # --- array-backed process state: flat per-pid arrays, no objects ---
        # (plain lists: python-int increments beat numpy scalar boxing on the
        # hot path; bulk math converts to numpy at aggregation time)
        self._clock = [0.0] * n
        self._steps = [0] * n
        self._done = [False] * n
        self._last_release = [0.0] * n
        self._barrier_seq = [0] * n
        self._pending = [0.0] * n      # message-handling cost of last step
        self._deg = [len(self.topology[pid]) for pid in range(n)]
        self._cfactor = [self.faults.compute_factor(pid) for pid in range(n)]
        # incremental per-process QoS counters (DESIGN.md §5): maintained in
        # the event loop so snapshots are O(1), never an O(degree) duct scan.
        # pull_attempt_count is exactly steps*degree (one bulk drain of every
        # in-duct per update), so it is derived, not stored.
        self._c_touch = [0] * n
        self._c_att = [0] * n
        self._c_ok = [0] * n
        self._c_drop = [0] * n
        self._c_laden = [0] * n
        self._c_msgs = [0] * n
        # drop-attribution counters (DESIGN.md §14): c_drop stays the TOTAL
        # (capacity + loss + dead), these split out the non-capacity causes
        self._c_loss = [0] * n
        self._c_dead = [0] * n
        self._crashed = [self.faults.is_crashed(pid) for pid in range(n)]

        self._touch: List[Dict[int, int]] = [
            {nb: 0 for nb in self.topology[pid]} for pid in range(n)]
        self.ducts: Dict[Tuple[int, int], Duct] = {}
        # per-out-edge fault info, hoisted so the send loop sees one tuple:
        # (duct, canonical eid, loss prob f32, flap frac f32, dst crashed)
        self._out_info: List[Dict[int, tuple]] = [{} for _ in range(n)]
        self._fault_sends = False
        duct_id = 0
        for src in range(n):
            for dst in self.topology[src]:
                duct = Duct(
                    cfg.buffer_capacity, self._latency_fn(src, dst, duct_id),
                    name=f"{src}->{dst}")
                self.ducts[(src, dst)] = duct
                loss = np.float32(self.faults.loss_prob(src, dst))
                flap = np.float32(self.faults.flap_frac(src, dst))
                dead = self._crashed[dst]
                self._out_info[src][dst] = (duct, duct_id, loss, flap, dead)
                if loss > 0 or flap > 0 or dead:
                    self._fault_sends = True
                duct_id += 1
        # pid -> [(neighbor, incoming duct)] in neighbor order, hoisted out
        # of the hot loop so events never hash (src, dst) tuples
        self._in_ducts = [[(nb, self.ducts[(nb, pid)])
                           for nb in self.topology[pid]] for pid in range(n)]
        self._snapshots: Dict[int, List[Tuple[float, Counters]]] = {
            i: [] for i in range(n)}
        self._barrier_arrivals: Dict[int, List[Tuple[int, float]]] = {}
        self._seq_active: Dict[int, int] = {0: n}  # barrier_seq -> live procs
        # barrier-quarantine state (cfg.barrier_timeout > 0, DESIGN.md §14):
        # next scheduled arrival per process (+inf for crashed — they never
        # arrive), the global waiting set, and the sticky quarantine flags
        self._arr_t = [math.inf if self._crashed[pid]
                       else self._step_duration(pid, 0) for pid in range(n)]
        self._waiting: Dict[int, float] = {}
        self._quar = [False] * n

    # ------------------------------------------------------------------
    def _link_base(self, src: int, dst: int) -> float:
        """Hierarchical link model: same-host hops are cheap (DESIGN.md §3)."""
        cfg = self.cfg
        if (cfg.intra_node_latency is not None and self.topo is not None
                and self.topo.same_node(src, dst)):
            return cfg.intra_node_latency
        return cfg.base_latency

    def _latency_fn(self, src, dst, duct_id: int):
        # fault and hierarchy factors are constant per link: hoist them so a
        # send costs one cached jitter lookup, not two dict probes
        base = self._link_base(src, dst) * self.faults.link_factor(src, dst)
        jitter = self.lat_jitter
        count = [0]

        def fn(now):
            c = count[0]
            count[0] = c + 1
            return base * jitter.latency_factor(duct_id, c)
        return fn

    def _step_duration(self, pid: int, step: int) -> float:
        cfg = self.cfg
        base = cfg.base_compute + cfg.work_units * cfg.work_unit_cost
        return base * self.jitter.factor(pid, step) * self._cfactor[pid]

    def _barrier_cost(self) -> float:
        if self.n <= 1:
            return 0.0  # a lone process has nothing to synchronize with
        return self.cfg.barrier_base + self.cfg.barrier_per_log2 * math.log2(self.n)

    # ------------------------------------------------------------------
    def _proc_counters(self, pid: int, t: Optional[float] = None) -> Counters:
        """Snapshot of a process's accumulated counters (O(1))."""
        return Counters(
            update_count=self._steps[pid],
            touch_count=self._c_touch[pid],
            attempted_send_count=self._c_att[pid],
            successful_send_count=self._c_ok[pid],
            dropped_send_count=self._c_drop[pid],
            loss_dropped_send_count=self._c_loss[pid],
            dead_dropped_send_count=self._c_dead[pid],
            laden_pull_count=self._c_laden[pid],
            message_count=self._c_msgs[pid],
            pull_attempt_count=(self._steps[pid] * self._deg[pid]
                                if self.cfg.mode != AsyncMode.NO_COMM else 0),
            wall_time=self._clock[pid] if t is None else t,
        )

    # ------------------------------------------------------------------
    def _barrier_due(self, pid: int, t: float) -> bool:
        mode = self.cfg.mode
        if mode == AsyncMode.BARRIER_EVERY_STEP:
            return True
        if mode == AsyncMode.ROLLING_BARRIER:
            return (t - self._last_release[pid]) >= self.cfg.rolling_quantum
        if mode == AsyncMode.FIXED_BARRIER:
            return t >= (self._barrier_seq[pid] + 1) * self.cfg.fixed_interval
        return False

    # ------------------------------------------------------------------
    def run(self) -> SimResult:
        cfg = self.cfg
        n = self.n
        comm = cfg.mode != AsyncMode.NO_COMM
        barriered = cfg.mode in _BARRIER_MODES
        # mode 1 meters its quantum on the WORK clock (compute + halo
        # pulls): per-message handling rides in the barrier slack, so the
        # update schedule is a function of (seed, release times) alone —
        # see window_core.close_window for the invariance argument
        rolling = cfg.mode == AsyncMode.ROLLING_BARRIER
        duration = cfg.duration
        per_msg_cost = cfg.per_message_cost
        per_pull_cost = cfg.per_pull_cost
        warmup = cfg.snapshot_warmup
        interval = cfg.snapshot_interval

        clock = self._clock
        steps = self._steps
        done = self._done
        c_touch, c_att, c_ok = self._c_touch, self._c_att, self._c_ok
        c_drop, c_laden, c_msgs = self._c_drop, self._c_laden, self._c_msgs
        touch = self._touch
        in_ducts = self._in_ducts
        ducts = self.ducts
        fragments = self.fragments
        snapshots = self._snapshots
        next_snap = [warmup] * n
        base_compute = cfg.base_compute + cfg.work_units * cfg.work_unit_cost
        cfactor = self._cfactor
        jitter_factor = self.jitter.factor
        pull_costs = [d * per_pull_cost for d in self._deg]
        heappush, heappop = heapq.heappush, heapq.heappop

        # --- open-loop service arrivals (runtime/service.py) --------------
        # the cumulative arrival table is a pure function of (cfg, seed,
        # pid, bin), precomputed host-side; the vectorized engines carry
        # the identical table, so every backend injects the same load
        arr_rows = None
        if cfg.arrival_rate > 0:
            from repro.runtime.service import cum_arrivals
            arr_np = cum_arrivals(cfg, cfg.seed, n)
            arr_rows = arr_np.tolist()
            arr_bins = arr_np.shape[1] - 1
            arr_bin = cfg.arrival_bin
            serve_chunk = cfg.service_chunk
            item_cost = cfg.per_item_cost
            served = [0] * n

        # crashed processes are never scheduled: they do no compute and take
        # no snapshots, but the topology keeps their in-ducts alive so
        # neighbors' sends surface as dead-destination delivery failures
        heap: List[Tuple[float, int, int]] = [
            (self._step_duration(pid, 0), pid, pid) for pid in range(n)
            if not self._crashed[pid]]
        heapq.heapify(heap)
        seq = n

        fault_sends = self._fault_sends
        out_info = self._out_info
        c_loss, c_dead = self._c_loss, self._c_dead
        quarantined = barriered and cfg.barrier_timeout > 0
        seed = cfg.seed
        flap_period = np.float32(self.faults.flap_period)

        while heap:
            t, _, pid = heappop(heap)
            if done[pid]:
                continue
            clock[pid] = t
            ptouch = touch[pid]

            # --- communication phase: bulk-drain inboxes -------------------
            # inbox holds fresh payloads only; fragments treat missing
            # neighbors as "no news" (stale halo)
            inbox = {}
            n_msgs = 0
            if comm:
                n_laden = 0
                for nb, duct in in_ducts[pid]:
                    msg, drained = duct.latest(t)
                    if drained:
                        n_msgs += drained
                        n_laden += 1
                        new_touch = 1 + msg.touch
                        c_touch[pid] += new_touch - ptouch[nb]
                        ptouch[nb] = new_touch
                        inbox[nb] = msg.payload
                if n_msgs:
                    c_msgs[pid] += n_msgs
                    c_laden[pid] += n_laden

            # --- compute phase (the real application fragment) -------------
            outputs = fragments[pid].update(inbox)
            step = steps[pid] + 1
            steps[pid] = step

            if comm and outputs:
                n_ok = 0
                n_drop = 0
                if fault_sends:
                    # typed-fault send path: the decision order (dead, then
                    # flap, then loss, then capacity) and the draw keys
                    # mirror window_core's vectorized masks bit-for-bit
                    n_loss = 0
                    n_dead = 0
                    info = out_info[pid]
                    for nb, payload in outputs.items():
                        duct, eid, loss_p, flap_f, is_dead = info[nb]
                        if is_dead:
                            n_dead += 1
                            continue
                        if flap_f > 0:
                            bucket = int(np.float32(t) / flap_period)
                            if np_hash_uniform(seed, STREAM_FLAP, eid,
                                               bucket) < flap_f:
                                n_loss += 1
                                continue
                        if loss_p > 0 and np_hash_uniform(
                                seed, STREAM_LOSS, eid, step) < loss_p:
                            n_loss += 1
                            continue
                        if duct.try_send(payload, t, ptouch[nb]):
                            n_ok += 1
                        else:
                            n_drop += 1
                    c_loss[pid] += n_loss
                    c_dead[pid] += n_dead
                    n_drop += n_loss + n_dead
                else:
                    for nb, payload in outputs.items():
                        if ducts[(pid, nb)].try_send(payload, t, ptouch[nb]):
                            n_ok += 1
                        else:
                            n_drop += 1  # counted at the drop site, not derived
                c_att[pid] += len(outputs)
                c_ok[pid] += n_ok
                c_drop[pid] += n_drop

            pending = (pull_costs[pid] if rolling
                       else n_msgs * per_msg_cost + pull_costs[pid])

            if t >= next_snap[pid]:
                snaps = snapshots[pid]
                snaps.append((t, self._proc_counters(pid, t)))
                next_snap[pid] = warmup + len(snaps) * interval

            # --- termination ------------------------------------------------
            if t >= duration:
                done[pid] = True
                if not quarantined:
                    # cohort ledger only feeds _try_release_barriers; the
                    # quarantine gate scans arrival times directly and its
                    # releases never book processes into new sequences here
                    self._seq_active[self._barrier_seq[pid]] -= 1
                # release any barrier this process would have joined (a
                # finishing process can also unblock a quarantine gate it
                # was holding open mid-step)
                seq = (self._try_release_quarantine(heap, seq) if quarantined
                       else self._try_release_barriers(heap, seq))
                continue

            # --- serve queued arrivals (continuing processes only) ----------
            # arrivals of bin b are queued once b has fully elapsed on the
            # process's own clock; each update serves up to service_chunk
            # items, whose cost rides on the work clock with the compute —
            # same recurrence as window_core.close_window, so the update
            # schedule stays engine-, layout-, and W-invariant
            if arr_rows is not None:
                b = int(t / arr_bin)
                if b > arr_bins:
                    b = arr_bins
                backlog = arr_rows[pid][b] - served[pid]
                if backlog > 0:
                    k = backlog if backlog < serve_chunk else serve_chunk
                    served[pid] += k
                    pending += k * item_cost

            # --- scheduling / barriers --------------------------------------
            if barriered and self._barrier_due(pid, t):
                self._pending[pid] = pending
                if quarantined:
                    self._waiting[pid] = t
                    seq = self._try_release_quarantine(heap, seq)
                else:
                    b = self._barrier_seq[pid]
                    self._barrier_arrivals.setdefault(b, []).append((pid, t))
                    seq = self._try_release_barriers(heap, seq)
            else:
                d = base_compute * jitter_factor(pid, step) * cfactor[pid]
                nt = t + d + pending
                self._arr_t[pid] = nt
                heappush(heap, (nt, seq, pid))
                seq += 1
                # a reschedule can push this process's next arrival past the
                # quarantine limit — re-evaluate the gate it was holding open
                if quarantined and self._waiting:
                    seq = self._try_release_quarantine(heap, seq)

        updates = list(steps)
        qos_by_proc: Dict[int, List[QosReport]] = {}
        all_qos: List[QosReport] = []
        for pid, snaps in self._snapshots.items():
            reps = [report(c0, c1)
                    for (t0, c0), (t1, c1) in zip(snaps, snaps[1:])]
            qos_by_proc[pid] = reps
            all_qos.extend(reps)

        service = None
        if arr_rows is not None:
            totals = [int(row[-1]) for row in arr_rows]
            service = {
                "arrivals": totals,
                "served": [int(s) for s in served],
                "backlog": [int(a - s) for a, s in zip(totals, served)],
            }

        sent = sum(self._c_att)
        return SimResult(
            updates=updates,
            horizon=cfg.duration,
            quality=self.app.quality(self.fragments),
            qos=all_qos,
            qos_by_process=qos_by_proc,
            dropped=sum(self._c_drop),
            sent=sent,
            dropped_loss=sum(self._c_loss),
            dropped_dead=sum(self._c_dead),
            service=service,
            app_state=(self.app.export_state(self.fragments)
                       if cfg.carry_app_state
                       and hasattr(self.app, "export_state") else None),
        )

    # ------------------------------------------------------------------
    def _try_release_quarantine(self, heap, seq) -> int:
        """Barrier release under ``cfg.barrier_timeout`` (DESIGN.md §14).

        The cohort front is the latest arrival among non-quarantined
        waiting processes.  The barrier releases once every process is
        done, waiting, quarantined, or *unreachable*: its next scheduled
        arrival lags the front by more than the timeout.  A crashed
        process's next arrival is +inf, so any finite timeout excludes it
        — turning a crashed clique member from a full-swarm stall into a
        QoS-visible degradation.  Quarantined processes still release
        with the cohort when they do arrive (they ride along), but are
        excluded from the front until they catch up to within timeout/2
        (hysteresis, so a marginal straggler doesn't flap in and out).

        ``window_core.close_window`` implements the same rule with the
        same arithmetic for the vectorized engines.
        """
        tau = self.cfg.barrier_timeout
        done = self._done
        waiting = self._waiting
        if not waiting:
            return seq
        quar = self._quar
        arr_t = self._arr_t
        core = [t for p, t in waiting.items() if not quar[p]]
        ref = max(core) if core else max(waiting.values())
        limit = ref + tau
        for p in range(self.n):
            if done[p] or quar[p] or p in waiting:
                continue
            if arr_t[p] <= limit:
                return seq          # someone within reach: hold the barrier
        # quarantine bookkeeping (before the release moves anyone): skipped
        # processes enter quarantine; waiting quarantined processes that
        # caught up to within tau/2 of the front are re-admitted
        readmit = ref - tau / 2
        for p in range(self.n):
            if done[p]:
                continue
            if p in waiting:
                if quar[p] and waiting[p] >= readmit:
                    quar[p] = False
            elif arr_t[p] > limit:
                quar[p] = True
        release = ref + self._barrier_cost()
        members = sorted(waiting)
        if release >= self.cfg.duration:
            for p in members:
                self._barrier_seq[p] += 1
                self._last_release[p] = release
                self._clock[p] = self.cfg.duration
                self._done[p] = True
        else:
            for p in members:
                self._barrier_seq[p] += 1
                self._last_release[p] = release
                d = (self._step_duration(p, self._steps[p])
                     + self._pending[p])
                nt = release + d
                arr_t[p] = nt
                heapq.heappush(heap, (nt, seq, p))
                seq += 1
        waiting.clear()
        return seq

    # ------------------------------------------------------------------
    def _try_release_barriers(self, heap, seq) -> int:
        """Release every barrier whose full active cohort has arrived.

        ``_seq_active`` tracks how many live processes sit at each barrier
        sequence number, so cohort checks are O(1) instead of an O(n) scan.
        """
        done = self._done
        for b in sorted(self._barrier_arrivals):
            arrivals = self._barrier_arrivals[b]
            waiting_active = [a for a in arrivals if not done[a[0]]]
            needed = self._seq_active.get(b, 0)
            if needed > 0 and len(waiting_active) >= needed:
                release = max(a[1] for a in arrivals) + self._barrier_cost()
                self._seq_active[b] -= len(waiting_active)
                if self._seq_active[b] <= 0:
                    del self._seq_active[b]
                if release >= self.cfg.duration:
                    # horizon snap (matches window_core.close_window): a
                    # cohort released at or past the horizon is done at the
                    # horizon clock — no post-horizon update is scheduled
                    for pid, t_arr in waiting_active:
                        self._barrier_seq[pid] = b + 1
                        self._last_release[pid] = release
                        self._clock[pid] = self.cfg.duration
                        self._done[pid] = True
                    del self._barrier_arrivals[b]
                    continue
                self._seq_active[b + 1] = (self._seq_active.get(b + 1, 0)
                                           + len(waiting_active))
                for pid, t_arr in waiting_active:
                    self._barrier_seq[pid] = b + 1
                    self._last_release[pid] = release
                    d = (self._step_duration(pid, self._steps[pid])
                         + self._pending[pid])
                    heapq.heappush(heap, (release + d, seq, pid))
                    seq += 1
                del self._barrier_arrivals[b]
        return seq
