"""Shared window-phase core for the vectorized engines (DESIGN.md §11).

Both JAX engines — the single-device windowed-time engine
(``runtime/engine_jax.py``) and the mesh-sharded engine
(``runtime/engine_sharded.py``) — advance the population through the same
lockstep-window phases:

  drain      pop every duct ring's available FIFO prefix, merge the
             freshest payloads into the (n, 4, L) halos, bump the
             receiver-side QoS counters
  compute    the application's actual batched step, masked by activity
  send       best-effort push attempt per out-edge (drop iff the ring is
             full, latency stamp), sender-side QoS counters
  stage      the dense layout's eager send decision (ring writes ride
             into the next window's fused ``duct_window`` pass)
  close      QoS snapshot scatter, termination, barrier bookkeeping and
             the virtual-time advance

Before this module existed each engine reimplemented all of them; now the
engines are thin compositions.  What stays engine-specific is exactly the
distribution machinery: the sharded engine's mesh/shard_map plumbing,
packed-ppermute boundary exchange, and *where* its barrier-release
reductions run (a :class:`MeshRelease` over the shard axis instead of
:data:`LOCAL_RELEASE`).  The phases themselves are row-count agnostic:
the unsharded engine passes full-population tables, the sharded engine
passes its shard's sentinel-padded slices, and both trace to the same
operation sequence — which is why ``tests/test_engine_conformance.py``
can pin every registry engine to the event-engine oracle bitwise.

All stochastic draws are counter-based splitmix-style hashes (the
in-graph twin of ``runtime/faults.py``'s splitmix64 streams — same
distributions, different bit streams), keyed by *original* pid and
*canonical* edge id so trajectories are a pure function of
``(config, seed)`` regardless of layout, scheduler, or shard count.
"""
from __future__ import annotations

import math
from typing import Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.modes import AsyncMode
from repro.core.qos import QosReport
from repro.kernels.duct_exchange.ops import (
    dense_halo_select,
    dense_stage,
    duct_commit,
    duct_drain,
    duct_send,
    duct_window,
)
from repro.runtime.faults import STREAM_FLAP, STREAM_LOSS  # noqa: F401
from repro.runtime.simulator import SimResult

#: modes whose processes stop at a barrier and wait for a global release
BARRIER_MODES = (AsyncMode.BARRIER_EVERY_STEP, AsyncMode.ROLLING_BARRIER,
                 AsyncMode.FIXED_BARRIER)

# ---------------------------------------------------------------------------
# Counter-based RNG: splitmix-style 32-bit finalizer chains, pure functions
# of their integer keys.
# ---------------------------------------------------------------------------
_GOLDEN = np.uint32(0x9E3779B9)

# stream tags keep independent draws independent
STREAM_STEP, STREAM_STALL, STREAM_LAT, STREAM_APP, STREAM_MUT = 1, 2, 3, 4, 5


def _mix32(x: jax.Array) -> jax.Array:
    """32-bit splitmix-style finalizer (lowbias32 constants)."""
    x = (x ^ (x >> np.uint32(16))) * np.uint32(0x7FEB352D)
    x = (x ^ (x >> np.uint32(15))) * np.uint32(0x846CA68B)
    return x ^ (x >> np.uint32(16))


def hash_u32(*keys) -> jax.Array:
    """Combine integer keys (arrays broadcast) into one hashed uint32."""
    h = _GOLDEN
    for k in keys:
        k = jnp.asarray(k).astype(jnp.uint32)
        h = _mix32(h ^ (k + _GOLDEN + (h << np.uint32(6)) +
                        (h >> np.uint32(2))))
    return h


def hash_uniform(*keys) -> jax.Array:
    """Deterministic uniform in (0, 1) from integer keys."""
    h = hash_u32(*keys)
    return ((h >> np.uint32(8)).astype(jnp.float32) + 0.5) * np.float32(
        1.0 / (1 << 24))


def hash_normal(*keys) -> jax.Array:
    u1 = hash_uniform(*keys, 101)
    u2 = hash_uniform(*keys, 202)
    return jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(2.0 * np.pi * u2)


def lognormal_factor(sigma: float, *keys) -> jax.Array:
    """Mean-one lognormal, matching faults.Jitter's parameterization."""
    if sigma <= 0:
        return jnp.ones(jnp.broadcast_shapes(
            *(jnp.shape(k) for k in keys)), jnp.float32)
    z = hash_normal(*keys)
    return jnp.exp(np.float32(-0.5 * sigma * sigma) + np.float32(sigma) * z)


# ---------------------------------------------------------------------------
# Barrier-release strategies: where the close phase's global reductions run
# ---------------------------------------------------------------------------
class LocalRelease:
    """Single-device release reductions: plain jnp reductions."""

    #: staged strategies consume reductions issued one superstep boundary
    #: earlier (see :class:`PipelinedRelease`)
    staged = False

    def all_stopped(self, x: jax.Array) -> jax.Array:
        return jnp.all(x)

    def any_waiting(self, x: jax.Array) -> jax.Array:
        return jnp.any(x)

    def max_time(self, x: jax.Array) -> jax.Array:
        return jnp.max(x)


#: the default strategy (one device holds the whole population)
LOCAL_RELEASE = LocalRelease()


class MeshRelease:
    """Cross-shard release reductions: exact psum-style pmin/pmax scalars
    over the named mesh axis, once per (super)step."""

    staged = False

    def __init__(self, axis: str):
        self.axis = axis

    def all_stopped(self, x: jax.Array) -> jax.Array:
        return jax.lax.pmin(jnp.all(x).astype(jnp.int32), self.axis) > 0

    def any_waiting(self, x: jax.Array) -> jax.Array:
        return jax.lax.pmax(jnp.any(x).astype(jnp.int32), self.axis) > 0

    def max_time(self, x: jax.Array) -> jax.Array:
        return jax.lax.pmax(jnp.max(x), self.axis)


class PipelinedRelease(MeshRelease):
    """Release strategy for the ``pipelined`` scheduler: the cross-shard
    release reductions issued at superstep boundary i are *consumed* at
    boundary i+1, so the pmin/pmax collectives never serialize against the
    boundary's own compute.

    Correctness rests on the frozen-cohort argument (DESIGN.md §12): once
    ``all_stopped`` is observed true, every live process is waiting, no
    process is active, and therefore nothing can join, leave, or advance
    the cohort before the (stale) decision is applied one boundary later.
    The release *time* — max over the frozen waiting clocks plus the
    barrier cost — is exactly what an un-staged release would compute;
    only the lockstep window it lands on moves one superstep later.
    ``close_window`` reads the carried decision from ``u["rel_ready"]`` /
    ``u["rel_t"]`` and stores fresh post-release reductions for the next
    boundary.
    """

    staged = True


class SendPhase(NamedTuple):
    """Result of one edge-major send attempt over a block of rings."""
    rings: Dict[str, jax.Array]   # q_avail / q_touch / q_size / q_pay
    accepted: jax.Array           # (rows,) bool push accepted
    sums: Optional[jax.Array]     # (n, 3) attempted/ok/dropped per process


class BucketSlab(NamedTuple):
    """Static view of one dense degree bucket's flat row slab.

    ``members is None`` marks the identity bucket: it covers every
    receiver (``nb == n_dst``, member i == receiver i), which is what
    every degree-regular topology collapses to — the per-bucket phases
    then skip all gathers/scatters and trace exactly the pre-bucketed
    receiver-major graph.  Otherwise ``members`` maps slab block index to
    receiver id; sentinel entries (value ``n_dst``) mark dead padding
    blocks whose scatters drop (the sharded engine pads shards to a
    uniform slab shape with them)."""

    start: int                       # first flat row of the slab
    nb: int                          # member blocks in the slab
    deg: int                         # padded rows per member block
    members: Optional[jax.Array]     # (nb,) receiver ids, or None


class DenseSpec(NamedTuple):
    """Static dense-layout geometry the bucketed phases iterate over."""

    n_dst: int                       # receivers covered
    n_rows: int                      # total flat rows R
    buckets: tuple                   # of BucketSlab


def make_dense_spec(plan) -> DenseSpec:
    """Build the phase-iteration spec from a ``topologies.LayoutPlan``,
    collapsing full-coverage single buckets to the identity fast path."""
    slabs = []
    for b in plan.buckets:
        nb = len(b.members)
        identity = (nb == plan.row_start.shape[0] and
                    bool((np.asarray(b.members) == np.arange(nb)).all()))
        slabs.append(BucketSlab(
            start=int(b.start), nb=nb, deg=int(b.deg),
            members=None if identity else jnp.asarray(b.members, jnp.int32)))
    return DenseSpec(n_dst=int(plan.row_start.shape[0]),
                     n_rows=int(plan.n_rows), buckets=tuple(slabs))


# ---------------------------------------------------------------------------
# The core
# ---------------------------------------------------------------------------
class WindowCore:
    """Window-phase kernels shared by every vectorized engine.

    Holds only population-invariant configuration (``cfg``, the batched
    app's payload shape, snapshot slot count, barrier cost).  Topology
    tables — edge endpoints, halo keys, latency bases, per-shard row
    tables — are *arguments* to the phase methods, so one core instance
    serves the full population and any sentinel-padded shard slice of it
    with identical traced semantics.
    """

    def __init__(self, cfg, bapp, n: int, *, max_pops: int = 16):
        self.cfg = cfg
        self.bapp = bapp
        self.n = n
        self.max_pops = max_pops
        warmup, interval = cfg.snapshot_warmup, cfg.snapshot_interval
        #: snapshot slots per process (S in DESIGN.md §7)
        self.S = max(1, int((cfg.duration - warmup) / interval) + 3)
        base_total = cfg.base_compute + cfg.work_units * cfg.work_unit_cost
        self.base_total = np.float32(base_total)
        if n <= 1:
            self.barrier_cost = 0.0
        else:
            self.barrier_cost = (cfg.barrier_base +
                                 cfg.barrier_per_log2 * math.log2(n))
        # generous lockstep-window budget: fastest plausible step is about
        # half the mean, plus slack for barrier-arrival idling
        self.default_max_windows = int(8 * cfg.duration / base_total) + 2048

    # ------------------------------------------------------------------
    # RNG phases
    # ------------------------------------------------------------------
    def step_factor(self, seed, steps, pids, cfactor) -> jax.Array:
        """Per-process compute-time factor; draws are keyed by original
        pid, so any shard slice reproduces the full-population stream."""
        cfg = self.cfg
        f = lognormal_factor(cfg.jitter_sigma, seed, STREAM_STEP,
                             pids, steps)
        if cfg.stall_prob > 0:
            u = hash_uniform(seed, STREAM_STALL, pids, steps)
            f = jnp.where(u < cfg.stall_prob,
                          f * np.float32(cfg.stall_factor), f)
        return f * cfactor

    def fault_masks(self, seed, t_src, steps_src, eids, loss, flap,
                    flap_period, dead):
        """Per-edge typed-fault send masks (DESIGN.md §14).

        ``loss``/``flap`` are per-edge probabilities, ``dead`` marks edges
        whose destination process is crashed.  Returns ``(loss_kill,
        dead_kill)`` — disjoint bool masks (dead wins) to strip from the
        send activity bits and fold into the attribution counters.  The
        draws are keyed by canonical edge id + sender step count (loss) /
        sender-time bucket (flap), so they are layout-, scheduler-, and
        shard-invariant, and ``simulator.run``'s host-side twin makes the
        identical decisions bit-for-bit on every engine.
        """
        lost = (loss > np.float32(0)) & (
            hash_uniform(seed, STREAM_LOSS, eids, steps_src) < loss)
        bucket = jnp.floor(t_src / np.float32(flap_period)).astype(jnp.int32)
        flap_down = (flap > np.float32(0)) & (
            hash_uniform(seed, STREAM_FLAP, eids, bucket) < flap)
        return (lost | flap_down) & ~dead, dead

    # ------------------------------------------------------------------
    # State builders
    # ------------------------------------------------------------------
    def edge_rings(self, rows: int) -> Dict[str, jax.Array]:
        """Fresh (empty) edge-major ring state for ``rows`` rings — the
        unsharded engine's E canonical edges or a sharded engine's padded
        ``shards * ein`` local rows; all-constant either way."""
        cfg = self.cfg
        L = self.bapp.payload_len
        return dict(
            ptouch=jnp.zeros(rows, jnp.int32),
            q_avail=jnp.full((rows, cfg.buffer_capacity), jnp.inf,
                             jnp.float32),
            q_touch=jnp.zeros((rows, cfg.buffer_capacity), jnp.int32),
            q_pay=jnp.zeros((rows, cfg.buffer_capacity, L),
                            self.bapp.payload_dtype),
            q_head=jnp.zeros(rows, jnp.int32),
            q_size=jnp.zeros(rows, jnp.int32),
        )

    def dense_rings(self, rows: int) -> Dict[str, jax.Array]:
        """Fresh dense bucketed ring state: flat ``(R, C)`` rings (the
        bucketed phases slice per-bucket slabs and reshape) plus the
        staged-send buffers — the send *decision* happens eagerly at stage
        time, the ring *writes* ride into the next window's fused
        ``duct_window`` pass (DESIGN.md §10/§13)."""
        L = self.bapp.payload_len
        u = self.edge_rings(rows)
        u.update(
            stage_pos=jnp.zeros(rows, jnp.int32),
            stage_acc=jnp.zeros(rows, bool),
            stage_avail=jnp.zeros(rows, jnp.float32),
            stage_touch=jnp.zeros(rows, jnp.int32),
            stage_pay=jnp.zeros((rows, L), self.bapp.payload_dtype),
        )
        return u

    def superstep_rings(self, rows: int, w: int) -> Dict[str, jax.Array]:
        """Extra carry for the W-fused superstep scheduler (DESIGN.md §13):
        base rings stay frozen across a superstep while per-window pushes
        append to a compact ``(R, W)`` pushbuf and drains walk base-prefix
        then pushbuf; ``duct_commit`` folds the pushbuf into the rings once
        per superstep."""
        L = self.bapp.payload_len
        u = self.dense_rings(rows)
        u.update(
            size0=jnp.zeros(rows, jnp.int32),      # base size at superstep start
            dr_base=jnp.zeros(rows, jnp.int32),    # base pops this superstep
            pb_cnt=jnp.zeros(rows, jnp.int32),     # pushbuf appends
            pb_pop=jnp.zeros(rows, jnp.int32),     # pushbuf pops
            pb_avail=jnp.zeros((rows, w), jnp.float32),
            pb_touch=jnp.zeros((rows, w), jnp.int32),
            pb_pay=jnp.zeros((rows, w, L), self.bapp.payload_dtype),
            # FIFO offset of every ring slot from the frozen superstep
            # head, precomputed once per superstep (head starts at 0);
            # int8 when capacity permits — the drain re-reads this table
            # every window, so its footprint is paid W times per commit
            base_off=jnp.broadcast_to(
                jnp.arange(self.cfg.buffer_capacity,
                           dtype=self._off_dtype()),
                (rows, self.cfg.buffer_capacity)),
        )
        return u

    def _off_dtype(self):
        return jnp.int8 if self.cfg.buffer_capacity <= 127 else jnp.int32

    # ------------------------------------------------------------------
    # Phase 1: drain
    # ------------------------------------------------------------------
    def drain(self, carry, t_rows, act_rows, *, halo_key, n_halo,
              dst, n_dst, dense_spec: Optional[DenseSpec] = None):
        """Edge-major drain over a block of rings living on their
        receiver's device: bounded FIFO pops, halo-winner select, and the
        three receiver-side QoS counter columns.

        ``halo_key`` flattens (receiver, slot); several in-edges may share
        one halo slot, and delivery ties resolve to the highest row index
        (rows are in ascending canonical-edge order on every engine), so
        the scatter is deterministic on every backend.  Sentinel-padded
        tables work unchanged: invalid rows carry key ``n_halo`` /
        segment ``n_dst``, which land in the sliced-off spare segment.
        With ``dense_spec`` the rows are bucketed receiver-major slabs
        (DESIGN.md §13) and both the halo merge and the counter sums
        become per-bucket reshape reductions — gather/scatter only on
        non-identity buckets, never per edge.

        Returns ``(carry updates, drained_r)``.
        """
        rows = jnp.arange(t_rows.shape[0], dtype=jnp.int32)
        d = duct_drain(carry["q_avail"], carry["q_touch"],
                       carry["q_head"], carry["q_size"],
                       t_rows, act_rows, max_pops=self.max_pops,
                       clear_popped=False)
        delivered = d.drained > 0
        payload = carry["q_pay"][rows, d.pop_pos]
        L = carry["halo"].shape[-1]
        new_touch = d.recv_touch + 1
        dtouch = jnp.where(delivered, new_touch - carry["ptouch"], 0)
        ptouch = jnp.where(delivered, new_touch, carry["ptouch"])
        # one multi-column reduction for all receiver-side counters
        recv_cols = jnp.stack([d.drained, delivered.astype(jnp.int32),
                               dtouch], axis=1)
        if dense_spec is not None:
            halo, recv_sums = self._merge_buckets(
                dense_spec, carry["halo"], delivered, payload, recv_cols)
        else:
            winner = jax.ops.segment_max(
                jnp.where(delivered, rows, -1), halo_key,
                num_segments=n_halo + 1)[:n_halo]
            has_win = winner >= 0
            fresh = payload[jnp.where(has_win, winner, 0)]
            halo = jnp.where(has_win[:, None], fresh,
                             carry["halo"].reshape(n_halo, L)).reshape(
                n_dst, 4, L)
            recv_sums = jax.ops.segment_sum(recv_cols, dst,
                                            num_segments=n_dst + 1)[:n_dst]
        return dict(
            halo=halo, ptouch=ptouch,
            c_msgs=carry["c_msgs"] + recv_sums[:, 0],
            c_laden=carry["c_laden"] + recv_sums[:, 1],
            c_touch=carry["c_touch"] + recv_sums[:, 2],
            q_avail=d.q_avail, q_touch=d.q_touch,
            q_head=d.head, q_size=d.size), recv_sums[:, 0]

    def _merge_buckets(self, spec: DenseSpec, halo, delivered, payload,
                       recv_cols):
        """Bucket-sliced halo merge + receiver counter reduction over flat
        dense rows.  Each receiver lives in exactly one bucket, so the
        identity fast path updates whole arrays and non-identity buckets
        scatter disjoint member sets (sentinel members drop)."""
        L = halo.shape[-1]
        cols = recv_cols.shape[-1]
        recv_sums = jnp.zeros((spec.n_dst, cols), recv_cols.dtype)
        for b in spec.buckets:
            sl = slice(b.start, b.start + b.nb * b.deg)
            hp, hw = dense_halo_select(
                delivered[sl].reshape(b.nb, b.deg),
                payload[sl].reshape(b.nb, b.deg, L))
            sums_b = recv_cols[sl].reshape(b.nb, b.deg, cols).sum(axis=1)
            if b.members is None:
                halo = jnp.where(hw[:, :, None], hp, halo)
                recv_sums = recv_sums + sums_b
            else:
                old = halo[jnp.clip(b.members, 0, spec.n_dst - 1)]
                halo = halo.at[b.members].set(
                    jnp.where(hw[:, :, None], hp, old), mode="drop")
                recv_sums = recv_sums.at[b.members].add(sums_b, mode="drop")
        return halo, recv_sums

    def window_dense(self, carry, t, active, *, spec: DenseSpec):
        """Dense-layout drain phase: per degree bucket, one fused
        ``duct_window`` pass applies the previous window's staged sends,
        drains at this window's clocks, and merges halos (DESIGN.md
        §10/§13).  Dead padding rows never get staged into, so their empty
        rings drain as no-ops without any extra masking here.  On the
        identity bucket (every degree-regular topology) this is zero
        gathers/scatters.  Returns ``(carry updates, drained_r)``."""
        C = self.cfg.buffer_capacity
        L = carry["halo"].shape[-1]
        R = spec.n_rows
        halo = carry["halo"]
        new = {key: carry[key] for key in
               ("q_avail", "q_touch", "q_pay", "q_head", "q_size",
                "ptouch")}
        drained_r = jnp.zeros(spec.n_dst, jnp.int32)
        laden_r = jnp.zeros(spec.n_dst, jnp.int32)
        touch_r = jnp.zeros(spec.n_dst, jnp.int32)
        for b in spec.buckets:
            sl = slice(b.start, b.start + b.nb * b.deg)
            shp = (b.nb, b.deg)

            def slab(key, *tail):
                return carry[key][sl].reshape(shp + tail)

            t_b = t if b.members is None else t[
                jnp.clip(b.members, 0, spec.n_dst - 1)]
            act_b = active if b.members is None else (
                active[jnp.clip(b.members, 0, spec.n_dst - 1)] &
                (b.members < spec.n_dst))
            w = duct_window(
                slab("q_avail", C), slab("q_touch", C), slab("q_pay", C, L),
                slab("q_head"), slab("q_size"),
                slab("stage_pos"), slab("stage_acc"),
                slab("stage_avail"), slab("stage_touch"),
                slab("stage_pay", L), t_b, act_b, max_pops=self.max_pops)
            delivered = w.drained > 0
            new_touch = w.recv_touch + 1
            pt_b = slab("ptouch")
            dtouch = jnp.where(delivered, new_touch - pt_b, 0)
            pt_b = jnp.where(delivered, new_touch, pt_b)
            dr_b = w.drained.sum(axis=1)
            laden_b = delivered.astype(jnp.int32).sum(axis=1)
            tch_b = dtouch.sum(axis=1)
            if b.members is None:
                halo = jnp.where(w.halo_win[:, :, None], w.halo_pay, halo)
                drained_r = drained_r + dr_b
                laden_r = laden_r + laden_b
                touch_r = touch_r + tch_b
            else:
                old = halo[jnp.clip(b.members, 0, spec.n_dst - 1)]
                halo = halo.at[b.members].set(
                    jnp.where(w.halo_win[:, :, None], w.halo_pay, old),
                    mode="drop")
                drained_r = drained_r.at[b.members].add(dr_b, mode="drop")
                laden_r = laden_r.at[b.members].add(laden_b, mode="drop")
                touch_r = touch_r.at[b.members].add(tch_b, mode="drop")

            def put(cur, val):
                flat = val.reshape((sl.stop - sl.start,) + val.shape[2:])
                if sl.start == 0 and sl.stop == R:
                    return flat
                return cur.at[sl].set(flat)

            new["q_avail"] = put(new["q_avail"], w.q_avail)
            new["q_touch"] = put(new["q_touch"], w.q_touch)
            new["q_pay"] = put(new["q_pay"], w.q_pay)
            new["q_head"] = put(new["q_head"], w.head)
            new["q_size"] = put(new["q_size"], w.size)
            new["ptouch"] = put(new["ptouch"], pt_b)
        new.update(
            halo=halo,
            c_msgs=carry["c_msgs"] + drained_r,
            c_laden=carry["c_laden"] + laden_r,
            c_touch=carry["c_touch"] + touch_r)
        return new, drained_r

    def window_dense_fused(self, carry, t, active, *, spec: DenseSpec,
                           dst_row):
        """One window of the W-fused superstep scheduler (DESIGN.md §13).

        The base rings are FROZEN for the whole superstep: this window's
        accepted push appends to the compact ``(R, W)`` pushbuf instead of
        writing the ring, and the drain walks the base FIFO prefix with an
        ``O(max_pops)`` strided gather, then — only once every remaining
        base message is popped (FIFO: everything in the base ring is older
        than any push of this superstep) — the pushbuf prefix.  The pop
        sequence, accept decisions, and counters are therefore *bitwise
        identical* to running ``window_dense`` every window; only the
        ``O(R*C)`` ring sweep is deferred to one ``duct_commit`` per
        superstep.  Returns ``(carry updates, drained_r)``."""
        C = self.cfg.buffer_capacity
        R = spec.n_rows
        P = self.max_pops
        W = carry["pb_avail"].shape[-1]
        # --- append the previous window's staged send to the pushbuf ------
        # masked dense writes over the narrow (R, W) buffers: XLA:CPU
        # lowers row scatters to serial loops, and this append runs every
        # window — the where-form vectorizes and is the difference between
        # the fused path winning and losing to the per-window O(R*C) sweep
        wcol_a = jnp.arange(W, dtype=jnp.int32)[None, :]
        at = carry["stage_acc"][:, None] & (wcol_a == carry["pb_cnt"][:, None])
        pb_avail = jnp.where(at, carry["stage_avail"][:, None],
                             carry["pb_avail"])
        pb_touch = jnp.where(at, carry["stage_touch"][:, None],
                             carry["pb_touch"])
        pb_pay = jnp.where(at[:, :, None], carry["stage_pay"][:, None, :],
                           carry["pb_pay"])
        pb_cnt = carry["pb_cnt"] + carry["stage_acc"]
        # --- drain: base-prefix walk, head-blocking, bounded --------------
        # dense formulation over the (R, C) ring (no take_along_axis: XLA
        # CPU lowers gathers to row loops): FIFO offsets from the FROZEN
        # superstep head are precomputed once per superstep
        # (``base_off``), so the pop count is one compare + min — the
        # offset of the first blocked not-yet-popped slot, clamped by the
        # remaining base prefix and the pop budget
        t_r = t[dst_row]
        act_r = active[dst_row]
        base_rem = carry["size0"] - carry["dr_base"]
        off = carry["base_off"]
        odt = off.dtype
        blocked = ((off >= carry["dr_base"].astype(odt)[:, None]) &
                   (off < carry["size0"].astype(odt)[:, None]) &
                   (carry["q_avail"] > t_r[:, None]))
        first_block = jnp.where(blocked, off,
                                jnp.asarray(C, odt)).min(axis=1)
        n1 = jnp.minimum(first_block.astype(jnp.int32) - carry["dr_base"],
                         jnp.minimum(base_rem, P))
        n1 = jnp.where(act_r, n1, 0)
        # --- then the pushbuf prefix, within the same max_pops budget -----
        wcol = jnp.arange(W, dtype=jnp.int32)[None, :]
        pb_ok = ((wcol < pb_cnt[:, None]) & (pb_avail <= t_r[:, None])) | (
            wcol < carry["pb_pop"][:, None])
        run = (jnp.cumprod(pb_ok.astype(jnp.int32), axis=1).sum(axis=1) -
               carry["pb_pop"])
        n2 = jnp.clip(run, 0, P - n1)
        n2 = jnp.where(act_r & (n1 == base_rem), n2, 0).astype(jnp.int32)
        drained = (n1 + n2).astype(jnp.int32)
        delivered = drained > 0
        # --- freshest popped message (touch stamp + payload) --------------
        # ONE element per row: XLA:CPU's serial gather lowering is O(R)
        # here — unlike the O(R*C) full-ring gathers banished elsewhere —
        # and avoids pulling two more full (R, C[, L]) passes through the
        # cache for a one-hot reduction
        L = carry["q_pay"].shape[-1]
        last_b = ((carry["q_head"] + carry["dr_base"] + n1 - 1) % C)[:, None]
        tch_b = jnp.take_along_axis(carry["q_touch"], last_b, axis=1)[:, 0]
        pay_b = jnp.take_along_axis(
            carry["q_pay"], jnp.broadcast_to(last_b[:, :, None], (R, 1, L)),
            axis=1)[:, 0]
        last_p = jnp.clip(carry["pb_pop"] + n2 - 1, 0, W - 1)[:, None]
        tch_p = jnp.take_along_axis(pb_touch, last_p, axis=1)[:, 0]
        pay_p = jnp.take_along_axis(
            pb_pay, jnp.broadcast_to(last_p[:, :, None], (R, 1, L)),
            axis=1)[:, 0]
        has2 = n2 > 0
        recv_touch = jnp.where(has2, tch_p, jnp.where(n1 > 0, tch_b, 0))
        fresh_pay = jnp.where(has2[:, None], pay_p, pay_b)
        # --- halo merge + receiver counters (shared bucket machinery) -----
        new_touch = recv_touch + 1
        dtouch = jnp.where(delivered, new_touch - carry["ptouch"], 0)
        ptouch = jnp.where(delivered, new_touch, carry["ptouch"])
        recv_cols = jnp.stack([drained, delivered.astype(jnp.int32),
                               dtouch], axis=1)
        halo, recv_sums = self._merge_buckets(
            spec, carry["halo"], delivered, fresh_pay, recv_cols)
        return dict(
            halo=halo, ptouch=ptouch,
            c_msgs=carry["c_msgs"] + recv_sums[:, 0],
            c_laden=carry["c_laden"] + recv_sums[:, 1],
            c_touch=carry["c_touch"] + recv_sums[:, 2],
            q_size=carry["q_size"] - drained,
            dr_base=carry["dr_base"] + n1.astype(jnp.int32),
            pb_pop=carry["pb_pop"] + n2,
            pb_cnt=pb_cnt, pb_avail=pb_avail, pb_touch=pb_touch,
            pb_pay=pb_pay), recv_sums[:, 0]

    def commit_superstep(self, carry):
        """Superstep epilogue for the fused scheduler: ONE ``duct_commit``
        launch folds the whole superstep's accepted pushes into the base
        rings (push j of ring r lands at slot ``(head0 + size0 + j) % C``,
        independent of how pops interleaved — already-popped pushbuf
        entries land behind the advanced head, on provably dead slots) and
        re-bases the head/size counters for the next superstep."""
        C = self.cfg.buffer_capacity
        qa, qt, qp = duct_commit(
            carry["q_avail"], carry["q_touch"], carry["q_pay"],
            carry["q_head"], carry["size0"], carry["pb_cnt"],
            carry["pb_avail"], carry["pb_touch"], carry["pb_pay"])
        z = jnp.zeros_like(carry["pb_cnt"])
        # new base size counts only committed messages: the last window's
        # staged accept (already in q_size) rides into the NEXT superstep's
        # pushbuf at its first window, not into the base ring
        size0 = (carry["size0"] - carry["dr_base"] +
                 carry["pb_cnt"] - carry["pb_pop"])
        head = (carry["q_head"] + carry["dr_base"] + carry["pb_pop"]) % C
        col = jnp.arange(C, dtype=jnp.int32)[None, :]
        return dict(
            q_avail=qa, q_touch=qt, q_pay=qp, q_head=head,
            size0=size0, dr_base=z, pb_cnt=z, pb_pop=z,
            base_off=((col - head[:, None]) % C).astype(self._off_dtype()))

    # ------------------------------------------------------------------
    # Phase 2: compute
    # ------------------------------------------------------------------
    def compute(self, carry, active, halo, pids):
        """The application's actual batched compute, masked by activity.
        Returns ``(app_state, edges_out, steps)``."""
        n = active.shape[0]
        new_state, edges_out = self.bapp.step(carry["app"], halo,
                                              carry["steps"], carry["seed"],
                                              pids=pids)
        app_state = jax.tree_util.tree_map(
            lambda new, old: jnp.where(
                active.reshape((n,) + (1,) * (new.ndim - 1)), new, old),
            new_state, carry["app"])
        return app_state, edges_out, carry["steps"] + active

    # ------------------------------------------------------------------
    # Phase 3: send (edge-major)
    # ------------------------------------------------------------------
    def send_edge(self, rings, now, act, lat, touch, payload,
                  src, n_src, *, sorted_src: bool = False,
                  want_sums: bool = True) -> SendPhase:
        """Best-effort push attempt over a block of edge-major rings (drop
        iff the post-drain ring is full) plus the sender-side counter
        columns, summed per source process.  Sentinel-padded ``src``
        tables (value ``n_src``) drop their contributions into the sliced
        spare segment; ``want_sums=False`` skips the reduction (the
        sharded superstep push passes only need the final pass's sums)."""
        rows_n = rings["q_avail"].shape[0]
        rows = jnp.arange(rows_n, dtype=jnp.int32)
        s = duct_send(rings["q_avail"], rings["q_touch"],
                      rings["q_head"], rings["q_size"],
                      now, act, lat, touch,
                      capacity=self.cfg.buffer_capacity)
        q_pay = rings["q_pay"].at[
            jnp.where(s.accepted, rows, rows_n), s.push_pos].set(
            payload, mode="drop")
        sums = None
        if want_sums:
            send_cols = jnp.stack([
                act.astype(jnp.int32),
                (act & s.accepted).astype(jnp.int32),
                (act & ~s.accepted).astype(jnp.int32)], axis=1)
            sums = jax.ops.segment_sum(send_cols, src,
                                       num_segments=n_src + 1,
                                       indices_are_sorted=sorted_src)[:n_src]
        return SendPhase(
            rings=dict(q_avail=s.q_avail, q_touch=s.q_touch,
                       q_size=s.size, q_pay=q_pay),
            accepted=s.accepted, sums=sums)

    # ------------------------------------------------------------------
    # Phase 3': stage (dense layout)
    # ------------------------------------------------------------------
    def stage_dense(self, carry, u, t, active, edges_out, lat,
                    *, src, rev, out_slot, live, deg, spec: DenseSpec,
                    kill_masks=None):
        """Stage this window's sends on the dense layout: decide
        drop-iff-full NOW against the post-drain rings (exactly what the
        edge-major send attempt sees, so counters land in this window)
        and defer only the ring writes to the next fused pass.  Sender
        counters come through the out-edge table as gathers — flat row
        ``r``'s sender is its receiver by construction, so no scatters on
        the identity bucket.  ``live`` masks the dead padding rows: they
        never accept a push, so their rings stay empty forever."""
        n = t.shape[0]
        src_c = jnp.clip(src, 0, n - 1)     # sentinel n on dead rows
        s_avail = t[src_c] + lat
        s_act = live & active[src_c]
        if kill_masks is not None:
            # typed faults (DESIGN.md §14): a lost / flapped / dead-bound
            # send still counts as attempted (att_r covers every out-edge
            # of an active sender) but never reaches the ring, so it folds
            # into c_drop via att - ok exactly like a capacity drop — the
            # loss_r/dead_r sums below attribute it
            loss_kill, dead_kill = kill_masks
            s_act = s_act & ~(loss_kill | dead_kill)
        s_touch = u["ptouch"][rev]
        s_pay = edges_out[src_c, out_slot]
        s_pos, s_acc = dense_stage(u["q_head"], u["q_size"], s_act,
                                   capacity=self.cfg.buffer_capacity)
        # acceptance of receiver p's own sends lives at its out-edge rows
        # rev[rows of p]; dead rows rev to themselves and contribute 0
        acc_out = s_acc[rev].astype(jnp.int32)
        cols = [acc_out]
        if kill_masks is not None:
            sender_act = (live & active[src_c]).astype(jnp.int32)
            cols.append((loss_kill.astype(jnp.int32) * sender_act)[rev])
            cols.append((dead_kill.astype(jnp.int32) * sender_act)[rev])
        out_cols = jnp.stack(cols, axis=1)
        sums_r = jnp.zeros((spec.n_dst, out_cols.shape[1]), jnp.int32)
        for b in spec.buckets:
            sl = slice(b.start, b.start + b.nb * b.deg)
            sums_b = out_cols[sl].reshape(b.nb, b.deg, -1).sum(axis=1)
            if b.members is None:
                sums_r = sums_r + sums_b
            else:
                sums_r = sums_r.at[b.members].add(sums_b, mode="drop")
        ok_r = sums_r[:, 0]
        att_r = jnp.where(active, deg, 0)
        out = dict(q_size=u["q_size"] + s_acc,
                   c_att=carry["c_att"] + att_r,
                   c_ok=carry["c_ok"] + ok_r,
                   c_drop=carry["c_drop"] + att_r - ok_r,
                   stage_pos=s_pos, stage_acc=s_acc, stage_avail=s_avail,
                   stage_touch=s_touch, stage_pay=s_pay)
        if kill_masks is not None:
            out["c_loss"] = carry["c_loss"] + sums_r[:, 1]
            out["c_dead"] = carry["c_dead"] + sums_r[:, 2]
        return out

    # ------------------------------------------------------------------
    # Phase 4: close window
    # ------------------------------------------------------------------
    def close_window(self, u, active, drained_r, *, pids, deg, cfactor,
                     release):
        """Shared window tail: QoS snapshot scatter, termination, barrier
        bookkeeping, and the virtual-time advance.

        ``release`` picks where the barrier-release reductions run:
        :data:`LOCAL_RELEASE` on one device, a :class:`MeshRelease` over
        the shard axis, or ``None`` to skip the release check entirely
        (mid-superstep windows: waiting clocks do not advance, so the
        release *time* computed at the superstep boundary is identical —
        only the lockstep window it lands on moves)."""
        cfg = self.cfg
        mode = cfg.mode
        barriered = mode in BARRIER_MODES
        t, steps = u["t"], u["steps"]
        n = t.shape[0]
        done, waiting = u["done"], u["waiting"]
        # rolling barriers meter their quantum on the WORK clock: compute
        # plus the (degree-fixed) halo pull cost, with per-message handling
        # absorbed into barrier slack.  That makes the number of updates a
        # quantum holds — and hence every release and the horizon straddle —
        # independent of drain timing, so the superstep scheduler's boundary
        # staging (which perturbs drop/drain patterns) cannot drift the
        # update schedule: rolling-barrier runs are exactly W-invariant.
        # The free-running modes keep the drain-coupled clock.
        pull_cost = deg.astype(jnp.float32) * np.float32(cfg.per_pull_cost)
        if mode == AsyncMode.ROLLING_BARRIER:
            pending = pull_cost
        else:
            pending = (drained_r.astype(jnp.float32) * np.float32(
                cfg.per_message_cost) + pull_cost)
        snap_idx = u["snap_idx"]
        thr = (np.float32(cfg.snapshot_warmup) +
               snap_idx.astype(jnp.float32) * np.float32(
                   cfg.snapshot_interval))
        snap_due = active & (t >= thr) & (snap_idx < self.S)
        row = jnp.stack([
            steps.astype(jnp.float32), u["c_touch"].astype(jnp.float32),
            u["c_att"].astype(jnp.float32), u["c_ok"].astype(jnp.float32),
            u["c_drop"].astype(jnp.float32),
            u["c_laden"].astype(jnp.float32),
            u["c_msgs"].astype(jnp.float32), t], axis=1)
        snap = u["snap"].at[
            jnp.where(snap_due, jnp.arange(n, dtype=jnp.int32), n),
            snap_idx].set(row, mode="drop")
        snap_idx = snap_idx + snap_due

        # --- termination / barriers / time advance ------------------------
        newly_done = active & (t >= np.float32(cfg.duration))
        done = done | newly_done

        # --- open-loop service arrivals (runtime/service.py) --------------
        # arrivals of time bin b queue up once b has fully elapsed on the
        # process's own clock (the cumulative table travels in the carry,
        # rows keyed by original pid); each update serves up to
        # service_chunk items whose cost rides on the work clock with the
        # compute.  The recurrence reads only (t, served), never drain
        # state, so the update schedule stays engine-, layout-, shard- and
        # W-invariant — and bit-identical to simulator.run's serve block.
        served = u.get("served")
        if served is not None:
            cont = active & ~newly_done
            arr_cum = u["arr_cum"]
            nbins = arr_cum.shape[-1] - 1
            b = jnp.minimum(
                (t / np.float32(cfg.arrival_bin)).astype(jnp.int32), nbins)
            avail = jnp.take_along_axis(arr_cum, b[:, None], axis=1)[:, 0]
            serve = jnp.clip(avail - served, 0, cfg.service_chunk)
            serve = jnp.where(cont, serve, 0)
            pending = pending + serve.astype(jnp.float32) * np.float32(
                cfg.per_item_cost)
            served = served + serve

        d_next = self.base_total * self.step_factor(u["seed"], steps,
                                                    pids, cfactor)
        barrier_seq = u["barrier_seq"]
        last_release = u["last_release"]
        pending_saved = u["pending"]

        if barriered:
            if mode == AsyncMode.BARRIER_EVERY_STEP:
                due = active & ~newly_done
            elif mode == AsyncMode.ROLLING_BARRIER:
                due = active & ~newly_done & (
                    (t - last_release) >= np.float32(cfg.rolling_quantum))
            else:
                due = active & ~newly_done & (
                    t >= (barrier_seq + 1).astype(jnp.float32) *
                    np.float32(cfg.fixed_interval))
            waiting = waiting | due
            pending_saved = jnp.where(due, pending, pending_saved)
            t = jnp.where(active & ~newly_done & ~due,
                          t + d_next + pending, t)
            quarantined = "quar" in u
            tau = np.float32(cfg.barrier_timeout)
            if release is not None:
                if release.staged:
                    # pipelined: apply the decision issued one boundary
                    # earlier (frozen cohort — see PipelinedRelease)
                    release_ready = u["rel_ready"]
                    release_t = u["rel_t"]
                    if quarantined:
                        ref = u["rel_ref"]
                elif quarantined:
                    # quarantine release (DESIGN.md §14): a non-waiting,
                    # non-done process's clock is its next barrier arrival,
                    # so "unreachable" == next arrival lags the cohort
                    # front (ref) by more than the timeout; crashed clocks
                    # sit at +inf and any finite tau excludes them
                    quar0 = u["quar"]
                    ref = self._quarantine_ref(release, t, waiting, quar0)
                    stopped = waiting | done
                    unreachable = ~stopped & (t > ref + tau)
                    release_ready = (
                        release.any_waiting(waiting) &
                        release.all_stopped(stopped | quar0 | unreachable))
                    release_t = ref + np.float32(self.barrier_cost)
                else:
                    release_ready = (release.all_stopped(waiting | done) &
                                     release.any_waiting(waiting))
                    release_t = (release.max_time(
                        jnp.where(waiting, t, -jnp.inf)) +
                        np.float32(self.barrier_cost))
                rel = release_ready & waiting
                if quarantined:
                    # hysteresis, evaluated on the pre-release state: a
                    # quarantined member that made it to the barrier within
                    # tau/2 of the front is readmitted; a straggler whose
                    # next arrival exceeds ref + tau is newly quarantined
                    quar = u["quar"]
                    readmit = waiting & quar & (
                        t >= ref - tau * np.float32(0.5))
                    newq = ~done & ~waiting & (t > ref + tau)
                    quar = jnp.where(release_ready,
                                     (quar & ~readmit) | newq, quar)
                # horizon snap: a cohort released at or past the horizon is
                # done at the horizon clock — no engine schedules (and the
                # event oracle no longer executes) a post-horizon update,
                # so straddle-sensitive float drift cannot flip the final
                # update count
                at_horizon = release_t >= np.float32(cfg.duration)
                t = jnp.where(
                    rel, jnp.where(at_horizon, np.float32(cfg.duration),
                                   release_t + d_next + pending_saved), t)
                done = done | (rel & at_horizon)
                last_release = jnp.where(rel, release_t, last_release)
                barrier_seq = barrier_seq + rel
                waiting = waiting & ~release_ready
        else:
            t = jnp.where(active & ~newly_done, t + d_next + pending, t)

        out = dict(u)
        out.update(k=u["k"] + 1, t=t, done=done, waiting=waiting,
                   barrier_seq=barrier_seq, last_release=last_release,
                   pending=pending_saved, snap=snap, snap_idx=snap_idx)
        if served is not None:
            out["served"] = served
        if barriered and release is not None and quarantined:
            out["quar"] = quar
        if release is not None and release.staged and barriered:
            # store fresh post-release reductions for the next boundary
            if quarantined:
                fref = self._quarantine_ref(release, t, waiting, quar)
                fstopped = waiting | done
                funreach = ~fstopped & (t > fref + tau)
                fresh_ready = (
                    release.any_waiting(waiting) &
                    release.all_stopped(fstopped | quar | funreach))
                fresh_t = fref + np.float32(self.barrier_cost)
                out["rel_ref"] = fref.reshape(u["rel_ref"].shape)
            else:
                fresh_ready = (release.all_stopped(waiting | done) &
                               release.any_waiting(waiting))
                fresh_t = (release.max_time(
                    jnp.where(waiting, t, -jnp.inf)) +
                    np.float32(self.barrier_cost))
            out.update(rel_ready=fresh_ready.reshape(u["rel_ready"].shape),
                       rel_t=fresh_t.reshape(u["rel_t"].shape))
        return out

    def _quarantine_ref(self, release, t, waiting, quar):
        """Cohort front for the quarantine gate: max waiting clock over the
        non-quarantined core, falling back to the full waiting set when
        every waiting member is quarantined (so an all-quarantined cohort
        still releases rather than stalling)."""
        core = release.max_time(jnp.where(waiting & ~quar, t, -jnp.inf))
        full = release.max_time(jnp.where(waiting, t, -jnp.inf))
        return jnp.where(core == -jnp.inf, full, core)

    # ------------------------------------------------------------------
    # QoS assembly
    # ------------------------------------------------------------------
    def assemble(self, carry, r: int, deg: np.ndarray,
                 quality: float, app_state=None) -> SimResult:
        """Numpy-vectorized QoS assembly: all report fields for all
        (process, window) samples come from whole-array ops over the
        snapshot deltas — the python loop only constructs the result
        objects.  The math mirrors ``core.qos.report`` exactly (same
        guards, same operation order), so values are bit-identical to the
        per-pair path it replaces."""
        cfg = self.cfg
        n = deg.shape[0]
        comm = cfg.mode != AsyncMode.NO_COMM
        snap = np.asarray(carry["snap"][r], np.float64)      # (n, S, 8)
        snap_idx = np.asarray(carry["snap_idx"][r])
        steps = np.asarray(carry["steps"][r])

        nwin = np.maximum(snap_idx - 1, 0)                   # reports/proc
        d = snap[:, 1:, :] - snap[:, :-1, :]                 # (n, S-1, 8)
        dup, dtch, datt = d[..., 0], d[..., 1], d[..., 2]
        ddrop, dladen, dmsg, dwall = (d[..., 4], d[..., 5], d[..., 6],
                                      d[..., 7])
        # zero-update windows stamp the explicit inf sentinel, mirroring
        # qos.simstep_period / qos.walltime_latency (idle != fast)
        idle = dup <= 0
        fin_period = dwall / np.maximum(dup, 1)
        period = np.where(idle, np.inf, fin_period)
        lat = dup / np.maximum(dtch, 1)
        # product over the finite period only: 0 * inf would leak nan
        # through np.where's eagerly evaluated branch
        wall_lat = np.where(idle, np.inf, lat * fin_period)
        fail = np.where(datt > 0, ddrop / np.maximum(datt, 1), 0.0)
        dpull = dup * deg[:, None] if comm else np.zeros_like(dup)
        opp = np.minimum(dmsg, dpull)
        clump = np.where(
            opp > 0, 1.0 - np.minimum(dladen / np.maximum(opp, 1), 1.0),
            0.0)
        t0, t1 = snap[:, :-1, 7], snap[:, 1:, 7]

        qos_by_proc: Dict[int, List[QosReport]] = {}
        all_qos: List[QosReport] = []
        for p in range(n):
            reps = [QosReport(
                simstep_period=float(period[p, i]),
                simstep_latency=float(lat[p, i]),
                walltime_latency=float(wall_lat[p, i]),
                delivery_failure_rate=float(fail[p, i]),
                delivery_clumpiness=float(clump[p, i]),
                t_start=float(t0[p, i]), t_end=float(t1[p, i]))
                for i in range(int(nwin[p]))]
            qos_by_proc[p] = reps
            all_qos.extend(reps)

        service = None
        if "served" in carry:
            srv = np.asarray(carry["served"][r])
            tot = np.asarray(carry["arr_cum"][r])[:, -1]
            service = {
                "arrivals": [int(x) for x in tot],
                "served": [int(x) for x in srv],
                "backlog": [int(a - s) for a, s in zip(tot, srv)],
            }

        return SimResult(
            updates=[int(x) for x in steps],
            horizon=cfg.duration,
            quality=quality,
            qos=all_qos,
            qos_by_process=qos_by_proc,
            dropped=int(np.sum(carry["c_drop"][r])),
            dropped_loss=(int(np.sum(carry["c_loss"][r]))
                          if "c_loss" in carry else 0),
            dropped_dead=(int(np.sum(carry["c_dead"][r]))
                          if "c_dead" in carry else 0),
            sent=int(np.sum(carry["c_att"][r])),
            service=service,
            app_state=app_state,
        )
