"""Live best-effort service harness: open-loop traffic + elastic churn.

Turns a batch simulation run into a serving scenario (ROADMAP "live
service"; Conduit frames best-effort exchange as a long-running service
rather than a batch job):

  * **Open-loop arrivals** — a deterministic splitmix-hashed arrival
    stream models external users feeding each process's work queue at a
    rate that does not care how fast the system drains it.  The stream is
    precomputed as a cumulative per-(process, time-bin) table — a pure
    function of ``(cfg, seed)`` — and carried into every engine, so the
    event-ordered reference and the vectorized/sharded engines all inject
    bit-identical load (``simulator.run``'s serve block and
    ``window_core.close_window``'s serve hook implement the same
    recurrence).  Three traffic shapes: ``poisson`` (constant rate),
    ``bursty`` (hash-gated global surges, rate-normalized so the mean
    matches), ``diurnal`` (sinusoidal rate swing).
  * **Elastic churn** — a :class:`~repro.runtime.faults.FaultTimeline`
    schedules hosts faulting/healing and processes leaving/rejoining.
    The run is split into epochs at event boundaries; each epoch patches
    the pristine topology (``topologies.patch_topology`` splices the duct
    rings of departed processes closed) and composes the active host
    faults, then runs on the selected engine.  Processes present on both
    sides of a membership change carry their application state across the
    boundary (``SimResult.app_state`` round-trips through the builder's
    ``initial_state`` argument); departed processes re-initialize fresh
    on rejoin.
  * **SLO verdicts** — per-epoch QoS timeseries rows are shifted onto the
    global clock, concatenated, and scored by
    :func:`repro.core.slo.evaluate_timeseries`.

Arrival draws use dedicated splitmix streams disjoint from the jitter and
app streams; per bin the count is Knuth/inversion Poisson (exact, capped
exponential draws) for small means and a rounded normal approximation for
large means — both pure counter hashes, so any engine, layout, shard
count, or superstep width sees the identical table.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.qos import aggregate_reports, aggregate_timeseries
from repro.core.slo import SloPolicy, evaluate_timeseries
from repro.runtime.config import RunConfig
from repro.runtime.faults import (FaultTimeline, TimelineEvent, _chain_prefix,
                                  _np_splitmix64, _np_uniform)
from repro.runtime.simulator import SimConfig
from repro.runtime.topologies import Topology, patch_topology

#: splitmix stream tags for the arrival draws (disjoint from the jitter
#: streams in faults.py and the app/window streams in window_core.py)
STREAM_ARRIVE = 0x41525256   # per-(pid, bin) count draws
STREAM_SHAPE = 0x53485045    # per-bin global shape gates (bursty)

#: capped exponential draws per (pid, bin) for the exact small-mean branch
_CAP = 32
#: per-bin mean at or above which the normal approximation takes over
#: (P[Poisson(10) > 32] ~ 1e-9, so the cap never truncates below it)
_NORMAL_CUTOFF = 10.0


# ---------------------------------------------------------------------------
# Arrival streams
# ---------------------------------------------------------------------------
def n_bins(cfg: SimConfig) -> int:
    return max(1, int(math.ceil(cfg.duration / cfg.arrival_bin - 1e-9)))


def rate_profile(cfg: SimConfig, seed: int, nbins: int) -> np.ndarray:
    """Per-bin arrival rate (arrivals /process /vsecond), shape ``(nbins,)``.

    ``poisson`` is flat; ``bursty`` gates each bin globally (one hash per
    bin) into a ``arrival_burst_factor``x surge with probability
    ``arrival_burst_prob``, normalized so the expected rate still equals
    ``arrival_rate``; ``diurnal`` swings sinusoidally (+-60%) with period
    ``arrival_period``.  All shapes conserve the configured mean rate.
    """
    rate = float(cfg.arrival_rate)
    shape = cfg.arrival_shape
    if shape == "poisson":
        return np.full(nbins, rate)
    if shape == "bursty":
        prefix = _chain_prefix(seed, STREAM_SHAPE)
        u = _np_uniform(_np_splitmix64(
            np.uint64(prefix) ^ np.arange(nbins, dtype=np.uint64)))
        p = cfg.arrival_burst_prob
        f = cfg.arrival_burst_factor
        norm = 1.0 - p + p * f
        return np.where(u < p, rate * f / norm, rate / norm)
    if shape == "diurnal":
        centers = (np.arange(nbins) + 0.5) * cfg.arrival_bin
        swing = np.sin(2.0 * np.pi * centers / cfg.arrival_period)
        return rate * (1.0 + 0.6 * swing)
    raise ValueError(
        f"unknown arrival_shape {shape!r} (poisson|bursty|diurnal)")


def arrival_table(cfg: SimConfig, seed: int, n: int) -> np.ndarray:
    """Per-(process, bin) arrival counts, shape ``(n, nbins)`` int64.

    Pure function of ``(cfg, seed)``: every count is a counter-based hash
    draw keyed by ``(seed, STREAM_ARRIVE, pid, bin)``.  Bins with mean
    below :data:`_NORMAL_CUTOFF` draw exact Poisson counts by inversion
    (count = #{k : sum of k exponentials < mean}, exponentials from the
    hash chain, cap :data:`_CAP`); heavier bins use a rounded
    mean + sqrt(mean) * z normal approximation (one Box-Muller draw per
    (pid, bin)) — unbiased to first order, so rate conservation holds per
    shape.
    """
    nbins = n_bins(cfg)
    means = rate_profile(cfg, seed, nbins) * cfg.arrival_bin
    prefixes = np.array(
        [_chain_prefix(seed, STREAM_ARRIVE, pid) for pid in range(n)],
        dtype=np.uint64)
    counts = np.zeros((n, nbins), dtype=np.int64)

    small = np.nonzero(means < _NORMAL_CUTOFF)[0]
    if small.size:
        ctr = (small.astype(np.uint64) * np.uint64(_CAP))[None, :, None] \
            + np.arange(_CAP, dtype=np.uint64)[None, None, :]
        u = _np_uniform(_np_splitmix64(prefixes[:, None, None] ^ ctr))
        s = np.cumsum(-np.log(u), axis=-1)
        counts[:, small] = (s < means[small][None, :, None]).sum(axis=-1)

    large = np.nonzero(means >= _NORMAL_CUTOFF)[0]
    if large.size:
        ctr = (large.astype(np.uint64) * np.uint64(_CAP))[None, :]
        h = _np_splitmix64(prefixes[:, None] ^ ctr)
        u1 = _np_uniform(_np_splitmix64(h ^ np.uint64(1)))
        u2 = _np_uniform(_np_splitmix64(h ^ np.uint64(2)))
        z = np.sqrt(-2.0 * np.log(u1)) * np.cos(2.0 * np.pi * u2)
        m = means[large][None, :]
        counts[:, large] = np.maximum(
            0.0, np.rint(m + np.sqrt(m) * z)).astype(np.int64)
    return counts


def cum_arrivals(cfg: SimConfig, seed: int, n: int) -> np.ndarray:
    """Zero-prefixed cumulative arrival table, shape ``(n, nbins + 1)``.

    ``cum[pid][b]`` = arrivals queued to ``pid`` in bins strictly before
    ``b`` — i.e. everything available once bin ``b - 1`` has fully
    elapsed on the process's own clock; column ``-1`` is the run total.
    This is the exact array both ``simulator.run`` and the jax engines
    carry (int32; the total is asserted to fit).
    """
    counts = arrival_table(cfg, seed, n)
    cum = np.zeros((n, counts.shape[1] + 1), dtype=np.int64)
    np.cumsum(counts, axis=1, out=cum[:, 1:])
    if cum.max(initial=0) >= 2 ** 31:
        raise ValueError(
            "arrival totals overflow int32: lower arrival_rate or "
            "duration (max cumulative count "
            f"{int(cum.max(initial=0))})")
    return cum.astype(np.int32)


# ---------------------------------------------------------------------------
# Churn schedules
# ---------------------------------------------------------------------------
def default_timeline(topo: Topology, churn: int, duration: float,
                     compute_factor: float = 30.0,
                     link_factor: float = 50.0) -> FaultTimeline:
    """An evenly spaced churn schedule with ``churn`` incidents.

    Incident ``i`` occupies the open slot ``(2i+1 .. 2i+2) / (2*churn+1)``
    of the run, so incidents never overlap and the run starts and ends
    calm.  Even incidents degrade-then-heal a host (round-robin over the
    topology's hosts); odd incidents make a process leave then rejoin
    (spread across the pid range).  Deterministic in ``(topo, churn,
    duration)``.
    """
    if churn <= 0:
        return FaultTimeline((), compute_factor, link_factor)
    hosts = sorted(set(topo.node_of))
    events: List[TimelineEvent] = []
    slots = 2 * churn + 1
    for i in range(churn):
        on = duration * (2 * i + 1) / slots
        off = duration * (2 * i + 2) / slots
        if i % 2 == 0:
            host = hosts[(i // 2) % len(hosts)]
            events.append(TimelineEvent(t=on, kind="fault", host=host))
            events.append(TimelineEvent(t=off, kind="heal", host=host))
        else:
            pid = (topo.n // 2 + (i // 2) * 7919) % topo.n
            events.append(TimelineEvent(t=on, kind="leave", pid=pid))
            events.append(TimelineEvent(t=off, kind="join", pid=pid))
    return FaultTimeline(tuple(events), compute_factor, link_factor)


# ---------------------------------------------------------------------------
# Epoch orchestration
# ---------------------------------------------------------------------------
def _shift_reports(reps, offset: float):
    return [dataclasses.replace(r, t_start=r.t_start + offset,
                                t_end=r.t_end + offset) for r in reps]


def run_service(run: RunConfig,
                app_builder: Callable[[Topology, int], object],
                cfg: SimConfig, topo: Topology,
                timeline: Optional[FaultTimeline] = None,
                policy: Optional[SloPolicy] = None,
                percentiles: Sequence[int] = (50, 95, 99)) -> dict:
    """Run one live-service scenario end to end.

    Splits ``[0, cfg.duration)`` into epochs at the timeline's event
    boundaries.  Each epoch patches the pristine ``topo`` by the pids
    absent at its start, composes the active host faults, and runs
    ``run.replicates`` seeds of ``app_builder(patched_topology, seed)``
    through the registry engine via
    :func:`~repro.runtime.engine.run_replicates`.  Per-epoch QoS windows
    are shifted onto the global clock and concatenated into one
    timeseries, which the SLO policy scores per interval.

    Returns a JSON-ready dict::

        {"epochs": [...], "qos": {...}, "qos_timeseries": [...],
         "slo": {"verdicts": [...], "summary": {...}},
         "service": {"arrivals": A, "served": S, "backlog": A - S}}

    ``epochs`` logs each membership/fault regime (bounds, live process
    count, absent original pids, faulty hosts).  When the app exports
    carriable state (``SimResult.app_state``) and ``app_builder`` accepts
    a third ``initial_state`` argument, processes present on both sides
    of an epoch boundary resume from their previous epoch's final state;
    departed-then-rejoined processes re-initialize fresh.  Builders with
    the legacy two-argument signature keep the old restart-every-epoch
    behavior.
    """
    # deferred: repro.runtime.engine imports this module's consumers
    import inspect

    from repro.runtime.engine import run_replicates

    timeline = timeline or FaultTimeline()
    policy = policy or SloPolicy()
    timeline.validate(topo)
    bounds = timeline.boundaries(cfg.duration)
    edges = [0.0, *bounds, cfg.duration]
    try:
        carries = len(inspect.signature(app_builder).parameters) >= 3
    except (TypeError, ValueError):
        carries = False

    epochs: List[dict] = []
    all_rows: List[dict] = []
    pooled_qos: List = []
    totals = {"arrivals": 0, "served": 0, "backlog": 0}
    interval = 0
    #: per replicate position: {original pid: app state} from the previous
    #: epoch (None before the first epoch or when the app exports nothing)
    carried: Optional[List[dict]] = None
    for ei in range(len(edges) - 1):
        t0, t1 = edges[ei], edges[ei + 1]
        absent = timeline.absent_pids(t0)
        patched, pid_map = patch_topology(topo, absent)
        faults = timeline.fault_model(patched, t0, pid_map=pid_map)
        ep_len = t1 - t0
        ep_cfg = dataclasses.replace(
            cfg, duration=ep_len,
            snapshot_warmup=min(cfg.snapshot_warmup, ep_len / 6),
            seed=cfg.seed + 7919 * ei,
            carry_app_state=carries)
        seeds = run.seeds(ep_cfg.seed)
        init_state = None
        if carries and carried is not None:
            # survivors resume: re-key each replicate's carried state from
            # original to this epoch's patched pids (departed pids fall out
            # of pid_map and so re-initialize fresh on rejoin), indexed by
            # the replicate's seed so one app serves a whole batch
            init_state = {
                seeds[i]: {pid_map[p]: st for p, st in carried[i].items()
                           if p in pid_map}
                for i in range(len(seeds))}
        build = ((lambda s: app_builder(patched, s, init_state)) if carries
                 else (lambda s: app_builder(patched, s)))
        results = run_replicates(
            run, build, ep_cfg, seeds=seeds, faults=faults)
        inv_map = {v: k for k, v in pid_map.items()}
        if all(res.app_state is not None for res in results):
            # back to original pid numbering for the next epoch's re-key
            carried = [{inv_map[p]: st for p, st in res.app_state.items()}
                       for res in results]
        else:
            carried = None

        reps_lists = [_shift_reports(reps, t0)
                      for res in results
                      for reps in res.qos_by_process.values()]
        rows = aggregate_timeseries(reps_lists, percentiles=percentiles)
        for row in rows:
            row["interval"] = interval
            row["epoch"] = ei
            interval += 1
        all_rows.extend(rows)
        pooled_qos.extend(q for res in results for q in res.qos)
        for res in results:
            if res.service:
                for key in totals:
                    totals[key] += sum(res.service[key])
        epochs.append({
            "epoch": ei,
            "t_start": t0,
            "t_end": t1,
            "n_procs": patched.n,
            "absent_pids": sorted(absent),
            "faulty_hosts": sorted(timeline.faulty_hosts(t0)),
            "intervals": len(rows),
        })

    slo = evaluate_timeseries(all_rows, policy)
    return {
        "epochs": epochs,
        "qos": aggregate_reports(pooled_qos, percentiles=percentiles),
        "qos_timeseries": all_rows,
        "slo": slo,
        "service": totals,
    }
