"""Engine protocol + registry: one contract, two simulation backends.

Every backend consumes the same inputs (an application exposing
``n_processes`` / ``topology()`` / fragments or a batched step, a
:class:`~repro.runtime.simulator.SimConfig`, an optional
:class:`~repro.runtime.faults.FaultModel`) and produces the same
:class:`~repro.runtime.simulator.SimResult`, so experiment families,
benchmarks, and tests are backend-agnostic.

Registered backends:

  event   ``runtime/simulator.py`` — discrete-event heap loop; exact event
          ordering, the reference semantics (DESIGN.md §1)
  jax     ``runtime/engine_jax.py`` — vectorized windowed-time engine; the
          whole population advances per lockstep window as flat JAX arrays,
          with ``jax.vmap`` over seeds for multi-replicate sweeps
          (DESIGN.md §7)

The jax backend additionally offers ``run_replicates(seeds)``; engines that
lack a native batched form fall back to sequential runs via
:func:`run_replicates`.
"""
from __future__ import annotations

from typing import List, Optional, Protocol, Sequence, runtime_checkable

from repro.runtime.faults import FaultModel
from repro.runtime.simulator import SimConfig, SimResult, Simulator


@runtime_checkable
class Engine(Protocol):
    """What every simulation backend must provide."""

    name: str

    def run(self) -> SimResult:
        """Execute the configured run and return the QoS result."""
        ...


def _make_event(app, cfg: SimConfig, faults: Optional[FaultModel]) -> Engine:
    return Simulator(app, cfg, faults)


def _make_jax(app, cfg: SimConfig, faults: Optional[FaultModel]) -> Engine:
    from repro.runtime.engine_jax import JaxEngine  # deferred: heavy import
    return JaxEngine(app, cfg, faults)


ENGINES = {
    "event": _make_event,
    "jax": _make_jax,
}


def make_engine(name: str, app, cfg: SimConfig,
                faults: Optional[FaultModel] = None) -> Engine:
    """Build a registered engine by name."""
    try:
        factory = ENGINES[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; choose from {sorted(ENGINES)}")
    return factory(app, cfg, faults)


def run_replicates(engine_name: str, make_app, cfg: SimConfig,
                   seeds: Sequence[int],
                   faults: Optional[FaultModel] = None) -> List[SimResult]:
    """Run one replicate per seed, batched where the backend supports it.

    ``make_app(seed)`` builds a fresh application per replicate.  Backends
    exposing a native ``run_replicates`` (the jax engine: one vmapped scan)
    get all seeds at once; others loop.  ``cfg.seed`` is overridden by
    each replicate's seed.
    """
    import dataclasses
    eng = make_engine(engine_name, make_app(int(seeds[0])),
                      dataclasses.replace(cfg, seed=int(seeds[0])), faults)
    if hasattr(eng, "run_replicates"):
        return eng.run_replicates([int(s) for s in seeds])
    out = [eng.run()]
    for s in seeds[1:]:
        eng = make_engine(engine_name, make_app(int(s)),
                          dataclasses.replace(cfg, seed=int(s)), faults)
        out.append(eng.run())
    return out
