"""Engine protocol + registry: one contract, two simulation backends.

Every backend consumes the same inputs (an application exposing
``n_processes`` / ``topology()`` / fragments or a batched step, a
:class:`~repro.runtime.simulator.SimConfig`, an optional
:class:`~repro.runtime.faults.FaultModel`) and produces the same
:class:`~repro.runtime.simulator.SimResult`, so experiment families,
benchmarks, and tests are backend-agnostic.

Registered backends:

  event   ``runtime/simulator.py`` — discrete-event heap loop; exact event
          ordering, the reference semantics (DESIGN.md §1)
  jax     ``runtime/engine_jax.py`` — vectorized windowed-time engine; the
          whole population advances per lockstep window as flat JAX arrays,
          with ``jax.vmap`` over seeds for multi-replicate sweeps
          (DESIGN.md §7).  With ``shards`` > 1 the population is
          partitioned into contiguous blocks over a 1-D device mesh
          (``runtime/engine_sharded.py``, DESIGN.md §8); only boundary-edge
          duct traffic crosses shards

The jax backend additionally offers ``run_replicates(seeds)``; engines that
lack a native batched form fall back to sequential runs via
:func:`run_replicates`.
"""
from __future__ import annotations

from typing import List, Optional, Protocol, Sequence, runtime_checkable

from repro.runtime.faults import FaultModel
from repro.runtime.simulator import SimConfig, SimResult, Simulator


@runtime_checkable
class Engine(Protocol):
    """What every simulation backend must provide."""

    name: str

    def run(self) -> SimResult:
        """Execute the configured run and return the QoS result."""
        ...


def _make_event(app, cfg: SimConfig, faults: Optional[FaultModel],
                **kwargs) -> Engine:
    shards = kwargs.pop("shards", 1)
    superstep = kwargs.pop("superstep_windows", 1)
    layout = kwargs.pop("layout", "auto")
    if shards and shards > 1:
        raise ValueError("the event engine is single-device; "
                         "--shards requires --engine jax")
    if superstep and superstep > 1:
        raise ValueError("the event engine has no superstep scheduler; "
                         "--superstep-windows requires --engine jax")
    if layout != "auto":
        raise ValueError("--layout selects the vectorized engines' duct "
                         "layout (DESIGN.md §10); the event engine has "
                         "none — use --engine jax")
    if kwargs:
        raise TypeError(f"unknown engine options {sorted(kwargs)}")
    return Simulator(app, cfg, faults)


def _make_jax(app, cfg: SimConfig, faults: Optional[FaultModel],
              **kwargs) -> Engine:
    # deferred imports: heavy jax machinery
    shards = kwargs.pop("shards", 1)
    superstep = kwargs.pop("superstep_windows", 1)
    if shards and shards > 1:
        from repro.runtime.engine_sharded import ShardedJaxEngine
        return ShardedJaxEngine(app, cfg, faults, shards=shards,
                                superstep_windows=superstep, **kwargs)
    if superstep and superstep > 1:
        raise ValueError(
            "superstep_windows > 1 amortizes cross-shard exchanges and "
            "needs the sharded engine; pass shards > 1 (--shards)")
    from repro.runtime.engine_jax import JaxEngine
    return JaxEngine(app, cfg, faults, **kwargs)


ENGINES = {
    "event": _make_event,
    "jax": _make_jax,
}


def make_engine(name: str, app, cfg: SimConfig,
                faults: Optional[FaultModel] = None, **kwargs) -> Engine:
    """Build a registered engine by name.

    ``kwargs`` are backend options: the jax engine accepts ``shards`` (> 1
    builds the mesh-sharded engine, DESIGN.md §8), ``superstep_windows``
    (> 1 enables the self-paced superstep scheduler, DESIGN.md §9; needs
    ``shards`` > 1), ``layout`` (``auto``/``dense``/``edge`` duct layout,
    DESIGN.md §10 — ``auto`` picks the dense receiver-major fast path for
    degree-regular topologies) plus ``max_pops`` / ``chunk``; the event
    engine accepts none.
    """
    try:
        factory = ENGINES[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; choose from {sorted(ENGINES)}")
    return factory(app, cfg, faults, **kwargs)


def run_replicates(engine_name: str, make_app, cfg: SimConfig,
                   seeds: Sequence[int],
                   faults: Optional[FaultModel] = None,
                   **engine_kwargs) -> List[SimResult]:
    """Run one replicate per seed, batched where the backend supports it.

    ``make_app(seed)`` builds a fresh application per replicate.  Backends
    exposing a native ``run_replicates`` (the jax engine: one vmapped scan,
    sharded over the device mesh when ``shards`` > 1) get all seeds at
    once; others loop.  ``cfg.seed`` is overridden by each replicate's
    seed.
    """
    import dataclasses
    eng = make_engine(engine_name, make_app(int(seeds[0])),
                      dataclasses.replace(cfg, seed=int(seeds[0])), faults,
                      **engine_kwargs)
    if hasattr(eng, "run_replicates"):
        return eng.run_replicates([int(s) for s in seeds])
    out = [eng.run()]
    for s in seeds[1:]:
        eng = make_engine(engine_name, make_app(int(s)),
                          dataclasses.replace(cfg, seed=int(s)), faults,
                          **engine_kwargs)
        out.append(eng.run())
    return out
