"""Engine protocol + registry: one contract, N simulation backends.

Every backend consumes the same inputs (an application exposing
``n_processes`` / ``topology()`` / fragments or a batched step, a
:class:`~repro.runtime.simulator.SimConfig`, an optional
:class:`~repro.runtime.faults.FaultModel`) and produces the same
:class:`~repro.runtime.simulator.SimResult`, so experiment families,
benchmarks, and tests are backend-agnostic.

Each backend registers an :class:`EngineSpec` declaring its capability
surface — which duct layouts it understands, which window schedulers it
offers, whether it shards over a device mesh — so callers (the CLI, the
conformance suite in ``tests/test_engine_conformance.py``) can enumerate
and validate options *before* any JAX tracing starts: a bad combination
fails with one actionable ``ValueError``, never a shape error from inside
a ``shard_map``.

Registered backends:

  event   ``runtime/simulator.py`` — discrete-event heap loop; exact event
          ordering, the reference semantics (DESIGN.md §1)
  jax     ``runtime/engine_jax.py`` — vectorized windowed-time engine; the
          whole population advances per lockstep window as flat JAX arrays,
          with ``jax.vmap`` over seeds for multi-replicate sweeps
          (DESIGN.md §7).  With ``shards`` > 1 the population is
          partitioned into contiguous blocks over a 1-D device mesh
          (``runtime/engine_sharded.py``, DESIGN.md §8); only boundary-edge
          duct traffic crosses shards.  Both variants compose the shared
          window-phase core (``runtime/window_core.py``, DESIGN.md §11)

Orthogonal strategy axes (DESIGN.md §11):

  layout     ``auto`` / ``edge`` / ``dense`` — how duct rings are laid out
             in memory (resolved per topology by ``plan_layout``)
  scheduler  ``auto`` / ``window`` / ``superstep`` / ``pipelined`` — when
             cross-shard boundary exchanges run: every lockstep window,
             batched every ``superstep_windows`` windows (self-paced
             supersteps, DESIGN.md §9), or batched *and* overlapped with
             the next superstep's interior windows via double-buffered
             shadow staging (DESIGN.md §12; sharded engine only)

The jax backend additionally offers ``run_replicates(seeds)``; engines that
lack a native batched form fall back to sequential runs via
:func:`run_replicates`.

Callers select strategies with one frozen
:class:`~repro.runtime.config.RunConfig` value
(``make_engine(RunConfig(engine="jax", layout="dense", shards=8), app,
cfg)``); the legacy loose-kwargs spelling survives behind a deprecation
shim (:func:`_resolve_run`).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import (Callable, Dict, List, Optional, Protocol, Sequence,
                    Tuple, Union, runtime_checkable)

from repro.runtime.config import STRATEGY_KEYS, RunConfig
from repro.runtime.faults import FaultModel
from repro.runtime.simulator import SimConfig, SimResult, Simulator

#: window schedulers an engine may declare (EngineSpec.schedulers)
SCHEDULERS: Tuple[str, ...] = ("window", "superstep", "pipelined")
#: duct layouts an engine may declare (EngineSpec.layouts); resolution
#: against a concrete topology lives in ``topologies.plan_layout``
LAYOUTS: Tuple[str, ...] = ("edge", "dense")


@runtime_checkable
class Engine(Protocol):
    """What every simulation backend must provide."""

    name: str

    def run(self) -> SimResult:
        """Execute the configured run and return the QoS result."""
        ...


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """A registered backend plus its declared capability surface.

    The registry — not the factory — rejects unsupported combinations, so
    every mis-configuration surfaces as one actionable ``ValueError`` with
    the registered vocabulary in the message.  The conformance suite
    iterates :func:`engine_specs` to build its parity matrix, so a newly
    registered engine is conformance-tested by construction.
    """

    name: str
    factory: Callable[..., Engine]
    description: str
    #: duct layouts the backend accepts (beyond the implicit "auto")
    layouts: Tuple[str, ...] = ()
    #: window schedulers the backend offers; "window" = per-window
    schedulers: Tuple[str, ...] = ("window",)
    #: accepts shards > 1 (mesh-sharded dispatch)
    shardable: bool = False
    #: vectorized windowed-time semantics (vs exact event ordering)
    vectorized: bool = False

    def __post_init__(self):
        bad = set(self.layouts) - set(LAYOUTS)
        if bad:
            raise ValueError(
                f"engine {self.name!r} declares unknown layouts {sorted(bad)}; "
                f"known: {LAYOUTS}")
        bad = set(self.schedulers) - set(SCHEDULERS)
        if bad:
            raise ValueError(
                f"engine {self.name!r} declares unknown schedulers "
                f"{sorted(bad)}; known: {SCHEDULERS}")


def _make_event(app, cfg: SimConfig, faults: Optional[FaultModel],
                **kwargs) -> Engine:
    if kwargs:
        raise TypeError(f"unknown engine options {sorted(kwargs)}")
    return Simulator(app, cfg, faults)


def _make_jax(app, cfg: SimConfig, faults: Optional[FaultModel],
              **kwargs) -> Engine:
    # deferred imports: heavy jax machinery
    shards = kwargs.pop("shards", 1)
    if shards and shards > 1:
        from repro.runtime.engine_sharded import ShardedJaxEngine
        return ShardedJaxEngine(app, cfg, faults, shards=shards, **kwargs)
    # the unsharded engine understands window + superstep (the W-fused
    # dense megakernel); _validate already rejected pipelined here
    from repro.runtime.engine_jax import JaxEngine
    return JaxEngine(app, cfg, faults, **kwargs)


_REGISTRY: Dict[str, EngineSpec] = {}


def register_engine(spec: EngineSpec) -> EngineSpec:
    """Register (or replace) a backend under ``spec.name``."""
    _REGISTRY[spec.name] = spec
    return spec


def engine_specs() -> Tuple[EngineSpec, ...]:
    """All registered backends, in registration order."""
    return tuple(_REGISTRY.values())


def get_engine_spec(name: str) -> EngineSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; choose from {sorted(_REGISTRY)}")


register_engine(EngineSpec(
    name="event",
    factory=_make_event,
    description="discrete-event heap loop; exact event ordering "
                "(the reference semantics, DESIGN.md §1)",
))
register_engine(EngineSpec(
    name="jax",
    factory=_make_jax,
    description="vectorized windowed-time engine over the shared "
                "window-phase core; shards > 1 partitions the population "
                "over a device mesh (DESIGN.md §7/§8/§11)",
    layouts=LAYOUTS,
    schedulers=SCHEDULERS,
    shardable=True,
    vectorized=True,
))

#: backward-compat view: engine name -> factory (tests and callers that
#: only need the names should prefer :func:`engine_specs`)
ENGINES = {name: spec.factory for name, spec in _REGISTRY.items()}


def _validate(spec: EngineSpec, kwargs: dict) -> dict:
    """Resolve strategy kwargs against ``spec``; mutates a copy of kwargs.

    Understands the three orthogonal axes — ``shards`` (partitioning),
    ``layout`` (duct memory layout), ``scheduler`` + ``superstep_windows``
    (exchange cadence) — and raises one actionable error per bad
    combination.  Remaining kwargs pass through to the factory untouched.
    """
    kwargs = dict(kwargs)
    shards = kwargs.get("shards", 1) or 1
    superstep = kwargs.get("superstep_windows", 1) or 1
    layout = kwargs.get("layout", "auto")
    scheduler = kwargs.pop("scheduler", "auto")

    if shards > 1 and not spec.shardable:
        raise ValueError(
            f"the {spec.name} engine is single-device; --shards requires a "
            "shardable engine (--engine jax)")
    if layout != "auto" and layout not in spec.layouts:
        if not spec.layouts:
            raise ValueError(
                f"--layout selects the vectorized engines' duct layout "
                f"(DESIGN.md §10); the {spec.name} engine has none — use "
                "--engine jax")
        raise ValueError(
            f"unknown layout {layout!r} for engine {spec.name!r}; choose "
            f"from {('auto',) + spec.layouts}")

    if scheduler == "auto":
        scheduler = "superstep" if superstep > 1 else "window"
    if scheduler not in SCHEDULERS:
        raise ValueError(
            f"unknown scheduler {scheduler!r}; choose from "
            f"{('auto',) + SCHEDULERS}")
    if scheduler not in spec.schedulers:
        raise ValueError(
            f"the {spec.name} engine has no {scheduler!r} scheduler "
            f"(offers: {spec.schedulers}); --superstep-windows requires "
            "--engine jax" if scheduler == "superstep" else
            f"the {spec.name} engine has no {scheduler!r} scheduler "
            f"(offers: {spec.schedulers})")
    if scheduler == "superstep":
        if superstep <= 1:
            raise ValueError(
                "scheduler='superstep' fuses W windows per exchange "
                "(sharded: one collective per superstep; unsharded: one "
                "ring commit per superstep); pass superstep_windows > 1 "
                "(--superstep-windows W) to choose W")
        if shards <= 1 and layout == "edge":
            raise ValueError(
                "the unsharded superstep scheduler is the W-fused dense "
                "megakernel (DESIGN.md §13) and needs the dense layout; "
                "drop --layout edge or pass shards > 1 (--shards)")
    elif scheduler == "pipelined":
        if superstep <= 1:
            raise ValueError(
                "scheduler='pipelined' overlaps superstep k's boundary "
                "exchange with superstep k+1's interior windows; pass "
                "superstep_windows > 1 (--superstep-windows W) to choose W")
        if shards <= 1:
            raise ValueError(
                "scheduler='pipelined' double-buffers the cross-shard "
                "boundary exchange and needs the sharded engine; pass "
                "shards > 1 (--shards)")
    elif superstep > 1:
        raise ValueError(
            "scheduler='window' exchanges every lockstep window, but "
            f"superstep_windows={superstep} was given; drop it or pass "
            "scheduler='superstep'")

    # the event factory takes no strategy kwargs at all; strip the
    # defaults we resolved so TypeError stays reserved for true unknowns
    if not spec.vectorized:
        for key in ("shards", "superstep_windows", "layout"):
            kwargs.pop(key, None)
    else:
        # the resolved scheduler travels to the factory (the sharded
        # engine dispatches its boundary-window strategy on it)
        kwargs["scheduler"] = scheduler
    return kwargs


def _resolve_run(run: Union[RunConfig, str], kwargs: dict) -> Tuple[str, dict]:
    """Normalize the two calling conventions to (engine name, kwargs).

    The preferred form passes a :class:`~repro.runtime.config.RunConfig`
    first — one frozen value carrying every strategy axis.  The legacy
    form (an engine-name string plus loose ``layout=`` / ``scheduler=`` /
    ``shards=`` / ``superstep_windows=`` kwargs) still works through this
    shim, with a :class:`DeprecationWarning` pointing at RunConfig.
    Backend extras (``max_pops``, ``chunk``, ...) pass through either way.
    """
    if isinstance(run, RunConfig):
        clash = sorted(set(kwargs) & set(STRATEGY_KEYS))
        if clash:
            raise TypeError(
                f"strategy kwargs {clash} conflict with the RunConfig; "
                "set them on the RunConfig instead")
        return run.engine, {**run.engine_kwargs(), **kwargs}
    legacy = sorted(set(kwargs) & set(STRATEGY_KEYS))
    if legacy:
        warnings.warn(
            f"passing {legacy} as loose kwargs is deprecated; build a "
            "repro.runtime.config.RunConfig and pass it as the first "
            "argument (make_engine(RunConfig(engine=..., ...), app, cfg))",
            DeprecationWarning, stacklevel=3)
    return run, kwargs


def make_engine(run: Union[RunConfig, str], app, cfg: SimConfig,
                faults: Optional[FaultModel] = None, **kwargs) -> Engine:
    """Build a registered engine from a RunConfig (or a name, legacy).

    The preferred call passes a :class:`~repro.runtime.config.RunConfig`
    carrying the strategy axes — ``engine``, ``layout``
    (``auto``/``dense``/``edge`` duct layout, DESIGN.md §10/§13 — ``auto``
    resolves to the bucketed dense layout on every built-in topology),
    ``scheduler`` (``auto``/``window``/``superstep``/``pipelined`` exchange
    cadence, DESIGN.md §9/§12/§13 — ``auto`` follows
    ``superstep_windows``), ``shards`` (> 1 builds the mesh-sharded
    engine, DESIGN.md §8), and ``superstep_windows`` — validated against
    the engine's :class:`EngineSpec` before the factory runs.  ``kwargs``
    are backend extras such as ``max_pops`` / ``chunk``.  The event engine
    accepts none.

    The legacy form ``make_engine("jax", app, cfg, layout=...)`` routes
    through a deprecation shim; see :func:`_resolve_run`.
    """
    name, kwargs = _resolve_run(run, kwargs)
    spec = get_engine_spec(name)
    kwargs = _validate(spec, kwargs)
    return spec.factory(app, cfg, faults, **kwargs)


def validate_run_config(run: RunConfig) -> None:
    """Eagerly check a RunConfig against its engine's registered spec.

    Entry points (the experiments CLI) call this before any app or JAX
    machinery is built, so a bad combination fails in microseconds with
    the registry's message.
    """
    spec = get_engine_spec(run.engine)
    _validate(spec, run.engine_kwargs())


def run_replicates(run: Union[RunConfig, str], make_app, cfg: SimConfig,
                   seeds: Optional[Sequence[int]] = None,
                   faults: Optional[FaultModel] = None,
                   **engine_kwargs) -> List[SimResult]:
    """Run one replicate per seed, batched where the backend supports it.

    ``make_app(seed)`` builds a fresh application per replicate.  Backends
    exposing a native ``run_replicates`` (the jax engine: one vmapped scan,
    sharded over the device mesh when ``shards`` > 1) get all seeds at
    once; others loop.  ``cfg.seed`` is overridden by each replicate's
    seed.  With a :class:`RunConfig` first argument, ``seeds`` may be
    omitted: the sweep is ``run.seeds(cfg.seed)`` (``replicates`` seeds
    rooted at the SimConfig seed).
    """
    if seeds is None:
        if not isinstance(run, RunConfig):
            raise TypeError("seeds may only be omitted when a RunConfig "
                            "is passed (its replicates field sizes the "
                            "sweep)")
        seeds = run.seeds(cfg.seed)
    eng = make_engine(run, make_app(int(seeds[0])),
                      dataclasses.replace(cfg, seed=int(seeds[0])), faults,
                      **engine_kwargs)
    if hasattr(eng, "run_replicates"):
        return eng.run_replicates([int(s) for s in seeds])
    out = [eng.run()]
    for s in seeds[1:]:
        eng = make_engine(run, make_app(int(s)),
                          dataclasses.replace(cfg, seed=int(s)), faults,
                          **engine_kwargs)
        out.append(eng.run())
    return out
