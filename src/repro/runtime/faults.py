"""Fault and heterogeneity injection for the discrete-event runtime.

Models the paper's observed conditions: per-step lognormal jitter with
occasional stalls, a faulty node (lac-417 analogue: extreme slowdown +
degraded links for the node and its clique), and transient stragglers.

Randomness is a counter-based splitmix64 hash — deterministic, O(ns) per
sample, no generator objects on the hot path.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

_MASK = (1 << 64) - 1


def _splitmix64(x: int) -> int:
    x = (x + 0x9E3779B97F4A7C15) & _MASK
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK
    return z ^ (z >> 31)


def _hash_uniform(*ints: int) -> float:
    """Deterministic uniform in (0, 1) from integer keys."""
    h = 0
    for v in ints:
        h = _splitmix64(h ^ (v & _MASK))
    return (h >> 11) / float(1 << 53) + 1e-16


def _hash_normal(*ints: int) -> float:
    u1 = _hash_uniform(*ints, 1)
    u2 = _hash_uniform(*ints, 2)
    return math.sqrt(-2.0 * math.log(u1)) * math.cos(2 * math.pi * u2)


@dataclasses.dataclass(frozen=True)
class FaultModel:
    compute_slowdown: Dict[int, float] = dataclasses.field(default_factory=dict)
    link_slowdown: Dict[Tuple[int, int], float] = dataclasses.field(default_factory=dict)

    def compute_factor(self, pid: int) -> float:
        return self.compute_slowdown.get(pid, 1.0)

    def link_factor(self, src: int, dst: int) -> float:
        return self.link_slowdown.get((src, dst), 1.0)


def faulty_node(pid: int, neighbors, compute_factor: float = 30.0,
                link_factor: float = 50.0) -> FaultModel:
    """A single apparently-faulty node: slow compute and slow links to/from
    its clique (the paper's lac-417 scenario)."""
    links = {}
    for nb in neighbors:
        links[(pid, nb)] = link_factor
        links[(nb, pid)] = link_factor
    return FaultModel({pid: compute_factor}, links)


class Jitter:
    """Deterministic per-(process, step) multiplicative jitter."""

    def __init__(self, sigma: float, seed: int,
                 stall_prob: float = 0.0, stall_factor: float = 1.0):
        self.sigma = sigma
        self.seed = seed
        self.stall_prob = stall_prob
        self.stall_factor = stall_factor

    def factor(self, pid: int, step: int) -> float:
        if self.sigma <= 0 and self.stall_prob <= 0:
            return 1.0
        f = 1.0
        if self.sigma > 0:
            z = _hash_normal(self.seed, pid, step)
            f = math.exp(-0.5 * self.sigma ** 2 + self.sigma * z)
        if self.stall_prob > 0 and _hash_uniform(self.seed, 13, pid, step) < self.stall_prob:
            f *= self.stall_factor
        return f

    def latency_factor(self, pid: int, count: int) -> float:
        if self.sigma <= 0:
            return 1.0
        z = _hash_normal(self.seed, 7919, pid, count)
        return math.exp(-0.5 * self.sigma ** 2 + self.sigma * z)
