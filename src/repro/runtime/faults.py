"""Fault and heterogeneity injection for the discrete-event runtime.

Models the paper's observed conditions: per-step lognormal jitter with
occasional stalls, a faulty node (lac-417 analogue: extreme slowdown +
degraded links for the node and its clique), and transient stragglers.

Randomness is a counter-based splitmix64 hash — deterministic and
generator-free.  The hot path samples it through :class:`Jitter`, which
evaluates the hash chain vectorized (numpy uint64, wrapping arithmetic)
in blocks of 512 counters per process, so the amortized per-sample cost
is O(ns) even with millions of events.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import numpy as np

_MASK = (1 << 64) - 1
_BLOCK = 512          # vectorized sample block (power of two)
_BMASK = _BLOCK - 1
_BSHIFT = _BLOCK.bit_length() - 1


def _splitmix64(x: int) -> int:
    x = (x + 0x9E3779B97F4A7C15) & _MASK
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK
    return z ^ (z >> 31)


def _hash_uniform(*ints: int) -> float:
    """Deterministic uniform in (0, 1) from integer keys."""
    h = 0
    for v in ints:
        h = _splitmix64(h ^ (v & _MASK))
    return (h >> 11) / float(1 << 53) + 1e-16


def _hash_normal(*ints: int) -> float:
    u1 = _hash_uniform(*ints, 1)
    u2 = _hash_uniform(*ints, 2)
    return math.sqrt(-2.0 * math.log(u1)) * math.cos(2 * math.pi * u2)


# -- vectorized twins (numpy uint64: multiplication/addition wrap mod 2^64,
#    reproducing the scalar chain bit-for-bit) -------------------------------
def _np_splitmix64(x: np.ndarray) -> np.ndarray:
    x = x + np.uint64(0x9E3779B97F4A7C15)
    z = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


def _np_chain(prefix: int, tail: np.ndarray) -> np.ndarray:
    """Continue a scalar splitmix chain ``prefix`` over a counter array."""
    return _np_splitmix64(np.uint64(prefix) ^ tail)


def _np_uniform(h: np.ndarray) -> np.ndarray:
    return (h >> np.uint64(11)).astype(np.float64) / float(1 << 53) + 1e-16


def _chain_prefix(*ints: int) -> int:
    h = 0
    for v in ints:
        h = _splitmix64(h ^ (v & _MASK))
    return h


@dataclasses.dataclass(frozen=True)
class FaultModel:
    compute_slowdown: Dict[int, float] = dataclasses.field(default_factory=dict)
    link_slowdown: Dict[Tuple[int, int], float] = dataclasses.field(default_factory=dict)

    def compute_factor(self, pid: int) -> float:
        return self.compute_slowdown.get(pid, 1.0)

    def link_factor(self, src: int, dst: int) -> float:
        return self.link_slowdown.get((src, dst), 1.0)


def faulty_node(pid: int, neighbors, compute_factor: float = 30.0,
                link_factor: float = 50.0) -> FaultModel:
    """A single apparently-faulty node: slow compute and slow links to/from
    its clique (the paper's lac-417 scenario)."""
    links = {}
    for nb in neighbors:
        links[(pid, nb)] = link_factor
        links[(nb, pid)] = link_factor
    return FaultModel({pid: compute_factor}, links)


def faulty_host(topology, host: int, compute_factor: float = 30.0,
                link_factor: float = 50.0) -> FaultModel:
    """Degrade a whole physical host: every process placed on ``host``
    (per ``topology.node_of``) runs slow, and every link touching one of
    those processes is slow in both directions — the paper's faulty node
    dragging its entire communication clique (§III-G)."""
    pids = topology.host_pids(host)
    assert pids, f"host {host} has no processes"
    links = {}
    for p in pids:
        for nb in topology.neighbors[p]:
            links[(p, nb)] = link_factor
            links[(nb, p)] = link_factor
    return FaultModel({p: compute_factor for p in pids}, links)


@dataclasses.dataclass(frozen=True)
class TimelineEvent:
    """One scheduled churn event on the service timeline.

    ``kind`` is one of:

      fault   host ``host`` degrades (compute + clique links slow down)
      heal    host ``host`` recovers
      leave   process ``pid`` (original numbering) departs; its duct ring
              is spliced closed by ``topologies.patch_topology``
      join    process ``pid`` returns; the pristine ring segment reappears
    """

    t: float
    kind: str
    host: int = -1
    pid: int = -1

    def __post_init__(self):
        assert self.kind in ("fault", "heal", "leave", "join"), self.kind
        assert self.t > 0, "timeline events must be strictly inside the run"


@dataclasses.dataclass(frozen=True)
class FaultTimeline:
    """A schedule of churn events extending the static :class:`FaultModel`.

    The static model answers "which processes/links are slow"; the
    timeline answers "when does that change".  ``runtime/service.py``
    splits the run into epochs at :meth:`boundaries` and rebuilds the
    epoch's topology (from :meth:`absent_pids`) and fault model (from
    :meth:`fault_model`) at each boundary — churn state is piecewise
    constant, never mid-epoch.
    """

    events: Tuple[TimelineEvent, ...] = ()
    compute_factor: float = 30.0
    link_factor: float = 50.0

    def boundaries(self, duration: float) -> List[float]:
        """Distinct event times strictly inside ``(0, duration)``."""
        return sorted({e.t for e in self.events if 0 < e.t < duration})

    def absent_pids(self, t: float) -> frozenset:
        """Original pids that have left (and not rejoined) by time ``t``.

        An event at exactly ``t`` has taken effect (epochs are closed on
        the left: the epoch starting at a boundary sees its events).
        """
        absent = set()
        for e in sorted(self.events, key=lambda e: e.t):
            if e.t > t:
                break
            if e.kind == "leave":
                absent.add(e.pid)
            elif e.kind == "join":
                absent.discard(e.pid)
        return frozenset(absent)

    def faulty_hosts(self, t: float) -> frozenset:
        """Hosts degraded (faulted, not yet healed) at time ``t``."""
        hosts = set()
        for e in sorted(self.events, key=lambda e: e.t):
            if e.t > t:
                break
            if e.kind == "fault":
                hosts.add(e.host)
            elif e.kind == "heal":
                hosts.discard(e.host)
        return frozenset(hosts)

    def fault_model(self, topology, t: float):
        """Compose the active host faults at ``t`` into one FaultModel.

        ``topology`` is the *patched* epoch topology (post-churn pid
        numbering), so the composed slowdown dicts speak the numbering
        the engine actually runs with.  A faulted host whose processes
        have all left contributes nothing.
        """
        compute: Dict[int, float] = {}
        links: Dict[Tuple[int, int], float] = {}
        for host in sorted(self.faulty_hosts(t)):
            pids = topology.host_pids(host)
            if not pids:
                continue
            fm = faulty_host(topology, host, self.compute_factor,
                             self.link_factor)
            compute.update(fm.compute_slowdown)
            links.update(fm.link_slowdown)
        if not compute and not links:
            return None
        return FaultModel(compute, links)


class Jitter:
    """Deterministic per-(process, step) multiplicative jitter.

    Samples are pure functions of (seed, key, counter).  Because consumers
    walk counters sequentially, samples are produced vectorized in blocks of
    ``_BLOCK`` and cached (latest block per key), making the common-case
    lookup an array index instead of ~10 python big-int hash rounds.
    """

    def __init__(self, sigma: float, seed: int,
                 stall_prob: float = 0.0, stall_factor: float = 1.0):
        self.sigma = sigma
        self.seed = seed
        self.stall_prob = stall_prob
        self.stall_factor = stall_factor
        self._arange = np.arange(_BLOCK, dtype=np.uint64)
        self._fcache: Dict[int, Tuple[int, list]] = {}
        self._lcache: Dict[int, Tuple[int, list]] = {}

    # -- block generation ----------------------------------------------------
    def _normal_block(self, prefix: int, start: int) -> np.ndarray:
        h = _np_chain(prefix, np.uint64(start) + self._arange)
        u1 = _np_uniform(_np_splitmix64(h ^ np.uint64(1)))
        u2 = _np_uniform(_np_splitmix64(h ^ np.uint64(2)))
        return np.sqrt(-2.0 * np.log(u1)) * np.cos(2 * np.pi * u2)

    def _lognormal_block(self, prefix: int, start: int) -> np.ndarray:
        z = self._normal_block(prefix, start)
        return np.exp(-0.5 * self.sigma ** 2 + self.sigma * z)

    def _factor_block(self, pid: int, start: int) -> np.ndarray:
        if self.sigma > 0:
            f = self._lognormal_block(_chain_prefix(self.seed, pid), start)
        else:
            f = np.ones(_BLOCK)
        if self.stall_prob > 0:
            u = _np_uniform(_np_chain(_chain_prefix(self.seed, 13, pid),
                                      np.uint64(start) + self._arange))
            f = np.where(u < self.stall_prob, f * self.stall_factor, f)
        return f

    # -- sample access -------------------------------------------------------
    def factor(self, pid: int, step: int) -> float:
        if self.sigma <= 0 and self.stall_prob <= 0:
            return 1.0
        block = step >> _BSHIFT
        cached = self._fcache.get(pid)
        if cached is None or cached[0] != block:
            # .tolist() so lookups hand back python floats (fast arithmetic)
            cached = (block, self._factor_block(pid, block << _BSHIFT).tolist())
            self._fcache[pid] = cached
        return cached[1][step & _BMASK]

    def latency_factor(self, key: int, count: int) -> float:
        """Link-latency jitter for duct ``key`` at its ``count``-th send."""
        if self.sigma <= 0:
            return 1.0
        block = count >> _BSHIFT
        cached = self._lcache.get(key)
        if cached is None or cached[0] != block:
            cached = (block, self._lognormal_block(
                _chain_prefix(self.seed, 7919, key), block << _BSHIFT).tolist())
            self._lcache[key] = cached
        return cached[1][count & _BMASK]
