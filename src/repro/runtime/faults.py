"""Fault and heterogeneity injection for the discrete-event runtime.

Models the paper's observed conditions: per-step lognormal jitter with
occasional stalls, a faulty node (lac-417 analogue: extreme slowdown +
degraded links for the node and its clique), and transient stragglers.

Randomness is a counter-based splitmix64 hash — deterministic and
generator-free.  The hot path samples it through :class:`Jitter`, which
evaluates the hash chain vectorized (numpy uint64, wrapping arithmetic)
in blocks of 512 counters per process, so the amortized per-sample cost
is O(ns) even with millions of events.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import numpy as np

_MASK = (1 << 64) - 1
_BLOCK = 512          # vectorized sample block (power of two)
_BMASK = _BLOCK - 1
_BSHIFT = _BLOCK.bit_length() - 1

#: stream tags for the 32-bit lowbias chain shared with ``window_core``
#: (values continue window_core's STREAM_* numbering, which ends at 5)
STREAM_LOSS, STREAM_FLAP = 6, 7


def _splitmix64(x: int) -> int:
    x = (x + 0x9E3779B97F4A7C15) & _MASK
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK
    return z ^ (z >> 31)


def _hash_uniform(*ints: int) -> float:
    """Deterministic uniform in (0, 1) from integer keys."""
    h = 0
    for v in ints:
        h = _splitmix64(h ^ (v & _MASK))
    return (h >> 11) / float(1 << 53) + 1e-16


def _hash_normal(*ints: int) -> float:
    u1 = _hash_uniform(*ints, 1)
    u2 = _hash_uniform(*ints, 2)
    return math.sqrt(-2.0 * math.log(u1)) * math.cos(2 * math.pi * u2)


# -- vectorized twins (numpy uint64: multiplication/addition wrap mod 2^64,
#    reproducing the scalar chain bit-for-bit) -------------------------------
def _np_splitmix64(x: np.ndarray) -> np.ndarray:
    x = x + np.uint64(0x9E3779B97F4A7C15)
    z = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


def _np_chain(prefix: int, tail: np.ndarray) -> np.ndarray:
    """Continue a scalar splitmix chain ``prefix`` over a counter array."""
    return _np_splitmix64(np.uint64(prefix) ^ tail)


def _np_uniform(h: np.ndarray) -> np.ndarray:
    return (h >> np.uint64(11)).astype(np.float64) / float(1 << 53) + 1e-16


def _chain_prefix(*ints: int) -> int:
    h = 0
    for v in ints:
        h = _splitmix64(h ^ (v & _MASK))
    return h


# -- host-side twins of window_core's in-graph 32-bit lowbias chain ----------
# The vectorized engines draw per-message loss/flap decisions in-graph with
# ``window_core.hash_uniform`` (a lowbias32 finalizer chain producing an
# exact float32 in (0, 1)).  The event engine must make the *same* decisions
# bit-for-bit, so these numpy twins reproduce that chain exactly: uint32
# wrapping arithmetic, identical constants, identical float32 construction.
_GOLDEN32 = np.uint32(0x9E3779B9)


def _np_mix32(x: np.ndarray) -> np.ndarray:
    x = (x ^ (x >> np.uint32(16))) * np.uint32(0x7FEB352D)
    x = (x ^ (x >> np.uint32(15))) * np.uint32(0x846CA68B)
    return x ^ (x >> np.uint32(16))


def np_hash_u32(*keys) -> np.ndarray:
    """Host-side twin of ``window_core.hash_u32`` (bitwise identical)."""
    with np.errstate(over="ignore"):
        h = _GOLDEN32
        for k in keys:
            k = np.asarray(k).astype(np.uint32)
            h = _np_mix32(h ^ (k + _GOLDEN32 + (h << np.uint32(6)) +
                               (h >> np.uint32(2))))
    return h


def np_hash_uniform(*keys) -> np.ndarray:
    """Host-side twin of ``window_core.hash_uniform`` — same float32 bits."""
    h = np_hash_u32(*keys)
    return ((h >> np.uint32(8)).astype(np.float32) +
            np.float32(0.5)) * np.float32(1.0 / (1 << 24))


#: default period (seconds of virtual time) of one flap schedule bucket.
#: A power of two, so the bucket index ``floor(t / period)`` is exact in
#: float32 on dyadic configs — the conformance suite relies on that.
FLAP_PERIOD = 2.0 ** -12


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Typed fault taxonomy for one run (piecewise-constant per epoch).

    ``compute_slowdown``/``link_slowdown`` are the paper's apparently-faulty
    node (everything still works, just slowly).  The remaining fields model
    degraded hardware that best-effort communication must *absorb*:

      crashed     processes that are dead for the whole run: they never
                  compute, send, or snapshot, but — unlike churn ``leave`` —
                  the topology is untouched, so neighbors keep sending into
                  the dead duct and those sends surface as dead-destination
                  delivery failures.
      link_loss   per-directed-link message loss probability: each send is
                  dropped by a deterministic lowbias32 draw keyed by
                  (seed, STREAM_LOSS, canonical edge id, sender step count).
      link_flap   per-directed-link down-fraction: the link is deterministically
                  down for a hash-chosen subset of ``flap_period`` time
                  buckets — (seed, STREAM_FLAP, edge id, bucket) < fraction.
    """

    compute_slowdown: Dict[int, float] = dataclasses.field(default_factory=dict)
    link_slowdown: Dict[Tuple[int, int], float] = dataclasses.field(default_factory=dict)
    crashed: frozenset = frozenset()
    link_loss: Dict[Tuple[int, int], float] = dataclasses.field(default_factory=dict)
    link_flap: Dict[Tuple[int, int], float] = dataclasses.field(default_factory=dict)
    flap_period: float = FLAP_PERIOD

    def compute_factor(self, pid: int) -> float:
        return self.compute_slowdown.get(pid, 1.0)

    def link_factor(self, src: int, dst: int) -> float:
        return self.link_slowdown.get((src, dst), 1.0)

    def loss_prob(self, src: int, dst: int) -> float:
        return self.link_loss.get((src, dst), 0.0)

    def flap_frac(self, src: int, dst: int) -> float:
        return self.link_flap.get((src, dst), 0.0)

    def is_crashed(self, pid: int) -> bool:
        return pid in self.crashed


def merge_fault_models(*models: FaultModel) -> FaultModel:
    """Compose several fault models; later models win on conflicting keys."""
    compute: Dict[int, float] = {}
    links: Dict[Tuple[int, int], float] = {}
    loss: Dict[Tuple[int, int], float] = {}
    flap: Dict[Tuple[int, int], float] = {}
    crashed: set = set()
    period = FLAP_PERIOD
    for m in models:
        if m is None:
            continue
        compute.update(m.compute_slowdown)
        links.update(m.link_slowdown)
        loss.update(m.link_loss)
        flap.update(m.link_flap)
        crashed |= set(m.crashed)
        period = m.flap_period
    return FaultModel(compute, links, frozenset(crashed), loss, flap, period)


def _clique_links(topology, host: int, value: float) -> Dict[Tuple[int, int], float]:
    links: Dict[Tuple[int, int], float] = {}
    for p in topology.host_pids(host):
        for nb in topology.neighbors[p]:
            links[(p, nb)] = value
            links[(nb, p)] = value
    return links


def faulty_node(pid: int, neighbors, compute_factor: float = 30.0,
                link_factor: float = 50.0) -> FaultModel:
    """A single apparently-faulty node: slow compute and slow links to/from
    its clique (the paper's lac-417 scenario)."""
    links = {}
    for nb in neighbors:
        links[(pid, nb)] = link_factor
        links[(nb, pid)] = link_factor
    return FaultModel({pid: compute_factor}, links)


def _host_pids(topology, host: int, caller: str):
    pids = topology.host_pids(host)
    if not pids:
        raise ValueError(
            f"{caller}: host {host} has no processes "
            f"(topology {topology.name!r} has hosts 0..{topology.n_nodes - 1})")
    return pids


def faulty_host(topology, host: int, compute_factor: float = 30.0,
                link_factor: float = 50.0) -> FaultModel:
    """Degrade a whole physical host: every process placed on ``host``
    (per ``topology.node_of``) runs slow, and every link touching one of
    those processes is slow in both directions — the paper's faulty node
    dragging its entire communication clique (§III-G)."""
    pids = _host_pids(topology, host, "faulty_host")
    links = {}
    for p in pids:
        for nb in topology.neighbors[p]:
            links[(p, nb)] = link_factor
            links[(nb, p)] = link_factor
    return FaultModel({p: compute_factor for p in pids}, links)


def crashed_host(topology, host: int) -> FaultModel:
    """Every process on ``host`` is dead: no compute, no sends, no
    snapshots — but the topology is untouched, so the clique's neighbors
    keep attempting delivery into the dead ducts."""
    pids = _host_pids(topology, host, "crashed_host")
    return FaultModel(crashed=frozenset(pids))


def lossy_host(topology, host: int, loss_prob: float = 0.05) -> FaultModel:
    """Every link touching a process on ``host`` silently drops each
    message with probability ``loss_prob`` (deterministic per-send draw)."""
    _host_pids(topology, host, "lossy_host")
    return FaultModel(link_loss=_clique_links(topology, host, loss_prob))


def flapping_host(topology, host: int, down_frac: float = 0.5,
                  flap_period: float = FLAP_PERIOD) -> FaultModel:
    """Every link touching a process on ``host`` flaps: down for a
    hash-chosen ``down_frac`` of ``flap_period`` time buckets."""
    _host_pids(topology, host, "flapping_host")
    return FaultModel(link_flap=_clique_links(topology, host, down_frac),
                      flap_period=flap_period)


#: kinds keyed by host (heal clears fault, lossy, and flap on that host)
_HOST_KINDS = ("fault", "heal", "lossy", "flap")
#: kinds keyed by original pid
_PID_KINDS = ("leave", "join", "crash")
TIMELINE_KINDS = _HOST_KINDS + _PID_KINDS


@dataclasses.dataclass(frozen=True)
class TimelineEvent:
    """One scheduled churn event on the service timeline.

    ``kind`` is one of:

      fault   host ``host`` degrades (compute + clique links slow down)
      heal    host ``host`` recovers (clears fault, lossy, and flap)
      lossy   host ``host``'s clique links start dropping messages
      flap    host ``host``'s clique links start flapping down/up
      leave   process ``pid`` (original numbering) departs; its duct ring
              is spliced closed by ``topologies.patch_topology``
      join    process ``pid`` returns; the pristine ring segment reappears
      crash   process ``pid`` dies without churn splicing: the topology is
              untouched, neighbors keep sending into the dead duct, and a
              crash is permanent (no heal/join re-admits the process)
    """

    t: float
    kind: str
    host: int = -1
    pid: int = -1

    def __post_init__(self):
        if self.kind not in TIMELINE_KINDS:
            raise ValueError(
                f"unknown timeline event kind {self.kind!r}; "
                f"expected one of {TIMELINE_KINDS}")
        if not self.t > 0:
            raise ValueError(
                f"timeline events must be strictly inside the run, got t={self.t}")
        if self.kind in _HOST_KINDS and self.host < 0:
            raise ValueError(f"{self.kind!r} event needs host >= 0, got {self.host}")
        if self.kind in _PID_KINDS and self.pid < 0:
            raise ValueError(f"{self.kind!r} event needs pid >= 0, got {self.pid}")


@dataclasses.dataclass(frozen=True)
class FaultTimeline:
    """A schedule of churn events extending the static :class:`FaultModel`.

    The static model answers "which processes/links are slow"; the
    timeline answers "when does that change".  ``runtime/service.py``
    splits the run into epochs at :meth:`boundaries` and rebuilds the
    epoch's topology (from :meth:`absent_pids`) and fault model (from
    :meth:`fault_model`) at each boundary — churn state is piecewise
    constant, never mid-epoch.
    """

    events: Tuple[TimelineEvent, ...] = ()
    compute_factor: float = 30.0
    link_factor: float = 50.0
    loss_prob: float = 0.05
    flap_down: float = 0.5
    flap_period: float = FLAP_PERIOD

    def boundaries(self, duration: float) -> List[float]:
        """Distinct event times strictly inside ``(0, duration)``."""
        return sorted({e.t for e in self.events if 0 < e.t < duration})

    def validate(self, topology) -> None:
        """Raise an actionable ``ValueError`` for events that can never take
        effect on ``topology`` (unknown host or pid) instead of letting them
        silently contribute nothing to any epoch's fault model."""
        for e in self.events:
            if e.kind in _HOST_KINDS and not (0 <= e.host < topology.n_nodes):
                raise ValueError(
                    f"timeline event {e.kind!r} at t={e.t} names host "
                    f"{e.host}, but topology {topology.name!r} only has "
                    f"hosts 0..{topology.n_nodes - 1}")
            if e.kind in _PID_KINDS and not (0 <= e.pid < topology.n):
                raise ValueError(
                    f"timeline event {e.kind!r} at t={e.t} names pid "
                    f"{e.pid}, but topology {topology.name!r} only has "
                    f"pids 0..{topology.n - 1}")

    def absent_pids(self, t: float) -> frozenset:
        """Original pids that have left (and not rejoined) by time ``t``.

        An event at exactly ``t`` has taken effect (epochs are closed on
        the left: the epoch starting at a boundary sees its events).
        """
        absent = set()
        for e in sorted(self.events, key=lambda e: e.t):
            if e.t > t:
                break
            if e.kind == "leave":
                absent.add(e.pid)
            elif e.kind == "join":
                absent.discard(e.pid)
        return frozenset(absent)

    def _active_hosts(self, t: float, on_kind: str) -> frozenset:
        """Hosts where ``on_kind`` is active (not yet healed) at time ``t``."""
        hosts = set()
        for e in sorted(self.events, key=lambda e: e.t):
            if e.t > t:
                break
            if e.kind == on_kind:
                hosts.add(e.host)
            elif e.kind == "heal":
                hosts.discard(e.host)
        return frozenset(hosts)

    def faulty_hosts(self, t: float) -> frozenset:
        """Hosts degraded (faulted, not yet healed) at time ``t``."""
        return self._active_hosts(t, "fault")

    def lossy_hosts(self, t: float) -> frozenset:
        """Hosts whose clique links are lossy at time ``t``."""
        return self._active_hosts(t, "lossy")

    def flapping_hosts(self, t: float) -> frozenset:
        """Hosts whose clique links are flapping at time ``t``."""
        return self._active_hosts(t, "flap")

    def crashed_pids(self, t: float) -> frozenset:
        """Original pids crashed by time ``t`` (crashes are permanent)."""
        return frozenset(e.pid for e in self.events
                         if e.kind == "crash" and e.t <= t)

    def fault_model(self, topology, t: float, pid_map=None):
        """Compose the active faults at ``t`` into one FaultModel.

        ``topology`` is the *patched* epoch topology (post-churn pid
        numbering), so the composed dicts speak the numbering the engine
        actually runs with; ``pid_map`` (original pid → patched pid, from
        ``topologies.patch_topology``) translates pid-keyed crash events.
        A faulted host whose processes have all left, or a crashed pid
        that has also left, contributes nothing.
        """
        compute: Dict[int, float] = {}
        links: Dict[Tuple[int, int], float] = {}
        loss: Dict[Tuple[int, int], float] = {}
        flap: Dict[Tuple[int, int], float] = {}
        for host in sorted(self.faulty_hosts(t)):
            if not topology.host_pids(host):
                continue
            fm = faulty_host(topology, host, self.compute_factor,
                             self.link_factor)
            compute.update(fm.compute_slowdown)
            links.update(fm.link_slowdown)
        for host in sorted(self.lossy_hosts(t)):
            if topology.host_pids(host):
                loss.update(_clique_links(topology, host, self.loss_prob))
        for host in sorted(self.flapping_hosts(t)):
            if topology.host_pids(host):
                flap.update(_clique_links(topology, host, self.flap_down))
        crashed = set()
        for pid in sorted(self.crashed_pids(t)):
            mapped = pid_map.get(pid) if pid_map is not None else pid
            if mapped is not None and 0 <= mapped < topology.n:
                crashed.add(mapped)
        if not compute and not links and not loss and not flap and not crashed:
            return None
        return FaultModel(compute, links, frozenset(crashed), loss, flap,
                          self.flap_period)


class Jitter:
    """Deterministic per-(process, step) multiplicative jitter.

    Samples are pure functions of (seed, key, counter).  Because consumers
    walk counters sequentially, samples are produced vectorized in blocks of
    ``_BLOCK`` and cached (latest block per key), making the common-case
    lookup an array index instead of ~10 python big-int hash rounds.
    """

    def __init__(self, sigma: float, seed: int,
                 stall_prob: float = 0.0, stall_factor: float = 1.0):
        self.sigma = sigma
        self.seed = seed
        self.stall_prob = stall_prob
        self.stall_factor = stall_factor
        self._arange = np.arange(_BLOCK, dtype=np.uint64)
        self._fcache: Dict[int, Tuple[int, list]] = {}
        self._lcache: Dict[int, Tuple[int, list]] = {}

    # -- block generation ----------------------------------------------------
    def _normal_block(self, prefix: int, start: int) -> np.ndarray:
        h = _np_chain(prefix, np.uint64(start) + self._arange)
        u1 = _np_uniform(_np_splitmix64(h ^ np.uint64(1)))
        u2 = _np_uniform(_np_splitmix64(h ^ np.uint64(2)))
        return np.sqrt(-2.0 * np.log(u1)) * np.cos(2 * np.pi * u2)

    def _lognormal_block(self, prefix: int, start: int) -> np.ndarray:
        z = self._normal_block(prefix, start)
        return np.exp(-0.5 * self.sigma ** 2 + self.sigma * z)

    def _factor_block(self, pid: int, start: int) -> np.ndarray:
        if self.sigma > 0:
            f = self._lognormal_block(_chain_prefix(self.seed, pid), start)
        else:
            f = np.ones(_BLOCK)
        if self.stall_prob > 0:
            u = _np_uniform(_np_chain(_chain_prefix(self.seed, 13, pid),
                                      np.uint64(start) + self._arange))
            f = np.where(u < self.stall_prob, f * self.stall_factor, f)
        return f

    # -- sample access -------------------------------------------------------
    def factor(self, pid: int, step: int) -> float:
        if self.sigma <= 0 and self.stall_prob <= 0:
            return 1.0
        block = step >> _BSHIFT
        cached = self._fcache.get(pid)
        if cached is None or cached[0] != block:
            # .tolist() so lookups hand back python floats (fast arithmetic)
            cached = (block, self._factor_block(pid, block << _BSHIFT).tolist())
            self._fcache[pid] = cached
        return cached[1][step & _BMASK]

    def latency_factor(self, key: int, count: int) -> float:
        """Link-latency jitter for duct ``key`` at its ``count``-th send."""
        if self.sigma <= 0:
            return 1.0
        block = count >> _BSHIFT
        cached = self._lcache.get(key)
        if cached is None or cached[0] != block:
            cached = (block, self._lognormal_block(
                _chain_prefix(self.seed, 7919, key), block << _BSHIFT).tolist())
            self._lcache[key] = cached
        return cached[1][count & _BMASK]
