"""RunConfig: one frozen carrier for a run's strategy axes (DESIGN.md §11).

Every entry point that launches an engine — the experiments CLI, the
benchmarks, library callers — used to thread the same loose kwargs
(``shards=``, ``layout=``, ``scheduler=``, ``superstep_windows=``, ...)
through its own plumbing, each with its own defaulting and validation.
:class:`RunConfig` replaces that with a single immutable value:

    rc = RunConfig(engine="jax", layout="dense", scheduler="superstep",
                   shards=8, superstep_windows=4)
    eng = make_engine(rc, app, sim_cfg)

The axes are *orthogonal strategies*, not backend internals:

  engine             registered backend name (``event`` / ``jax``)
  layout             duct ring memory layout (``auto``/``dense``/``edge``,
                     DESIGN.md §10/§13; ``auto`` resolves to the bucketed
                     dense layout on every built-in topology)
  scheduler          exchange cadence (``auto``/``window``/``superstep``/
                     ``pipelined``, DESIGN.md §9/§12/§13; ``auto`` follows
                     ``superstep_windows``)
  shards             device-mesh partitions (1 = single device)
  superstep_windows  windows fused per exchange for the superstep /
                     pipelined schedulers (and per ring commit for the
                     unsharded W-fused megakernel)
  replicates         seeds per sweep point (one vmapped dispatch on jax)
  qos_interval       QoS snapshot spacing in virtual seconds (None = the
                     caller's default, usually duration / 12)

Only *domain* checks live here (is the word known, is the count
positive).  Cross-axis rules — which combinations a given engine accepts —
stay in ``engine._validate`` against the registered
:class:`~repro.runtime.engine.EngineSpec`, so they are enforced once, for
every entry point, with the registry's vocabulary in the message.

``SimConfig`` describes the simulated world (latencies, horizon, buffer
capacity); ``RunConfig`` describes how this process executes it.  The two
never overlap.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

#: RunConfig's layout vocabulary ("auto" + engine.LAYOUTS)
LAYOUT_CHOICES = ("auto", "dense", "edge")
#: RunConfig's scheduler vocabulary ("auto" + engine.SCHEDULERS)
SCHEDULER_CHOICES = ("auto", "window", "superstep", "pipelined")

#: make_engine kwargs that RunConfig subsumes (the legacy loose-kwargs
#: spelling routes through these names; see engine.make_engine's shim)
STRATEGY_KEYS = ("layout", "scheduler", "shards", "superstep_windows")


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Immutable strategy selection for one run (or one sweep point)."""

    engine: str = "event"
    layout: str = "auto"
    scheduler: str = "auto"
    shards: int = 1
    superstep_windows: int = 1
    replicates: int = 1
    qos_interval: Optional[float] = None

    def __post_init__(self):
        if not self.engine or not isinstance(self.engine, str):
            raise ValueError(f"engine must be a backend name, got "
                             f"{self.engine!r}")
        if self.layout not in LAYOUT_CHOICES:
            raise ValueError(f"unknown layout {self.layout!r}; choose from "
                             f"{LAYOUT_CHOICES}")
        if self.scheduler not in SCHEDULER_CHOICES:
            raise ValueError(f"unknown scheduler {self.scheduler!r}; choose "
                             f"from {SCHEDULER_CHOICES}")
        for field in ("shards", "superstep_windows", "replicates"):
            v = getattr(self, field)
            if not isinstance(v, int) or v < 1:
                raise ValueError(f"{field} must be a positive int, got {v!r}")
        if self.qos_interval is not None and not self.qos_interval > 0:
            raise ValueError(f"qos_interval must be positive, got "
                             f"{self.qos_interval!r}")

    # ------------------------------------------------------------------
    @classmethod
    def from_args(cls, args) -> "RunConfig":
        """Build from an argparse namespace (missing attrs keep defaults).

        The experiments CLI and the benchmark runners share flag names
        (``--engine --layout --scheduler --shards --superstep-windows
        --replicates --qos-interval``), so one constructor covers them all.
        """
        defaults = cls()
        return cls(**{f.name: getattr(args, f.name, getattr(defaults, f.name))
                      for f in dataclasses.fields(cls)})

    def to_dict(self) -> dict:
        """JSON-ready mapping of every axis (result-row provenance)."""
        return dataclasses.asdict(self)

    def engine_kwargs(self) -> dict:
        """The strategy kwargs ``make_engine`` forwards to the registry.

        ``replicates`` and ``qos_interval`` are run-level concerns (seed
        sweep size, SimConfig snapshot spacing) — not engine options — so
        they are deliberately absent.
        """
        return dict(layout=self.layout, scheduler=self.scheduler,
                    shards=self.shards,
                    superstep_windows=self.superstep_windows)

    def seeds(self, base_seed: int) -> list:
        """The replicate seed sweep rooted at ``base_seed``."""
        return [int(base_seed) + r for r in range(self.replicates)]
