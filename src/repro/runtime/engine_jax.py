"""Vectorized windowed-time best-effort engine (DESIGN.md §7).

The discrete-event engine (``runtime/simulator.py``) processes one event at
a time from a heap — exact, but serial.  This engine advances the *entire
process population per lockstep window* as flat JAX arrays: window k is
every process's k-th simstep, executed at per-process virtual times that
drift apart exactly as the paper describes (jitter, stalls, faults,
barriers).  Per window it composes the shared window-phase core
(``runtime/window_core.py``, DESIGN.md §11):

  1. drain      edge-parallel duct drain (bounded FIFO rings,
                latency-delayed availability) + halo-winner select
  2. compute    halo scatter + the application's *actual* batched compute
  3. send       edge-parallel send attempt (capacity drop, latency stamp)
  4. close      incremental QoS counters + O(1) snapshot scatter,
                termination, barriers, virtual-time advance

All stochastic draws are counter-based splitmix-style hashes evaluated
in-graph, so a run is a pure function of ``(config, seed)`` and
``jax.vmap`` over the seed axis dispatches a whole replicate sweep in one
scan (``run_replicates``).

Two duct layouts share these semantics (``layout=`` / ``--layout``,
DESIGN.md §10): the general *edge-major* path above, and the *dense
receiver-major* fast path for degree-regular topologies (ring, torus),
where each process owns its ``d`` in-edge rings contiguously as
``(n, d, C)`` arrays and the whole window's ring traffic runs through one
fused ``duct_window`` pass — zero segment/scatter ops, bitwise-identical
trajectories.

Where it diverges from the event engine — and why that is acceptable for
median/p95 QoS — is documented in DESIGN.md §7.  Parity is enforced by the
registry-driven conformance suite (``tests/test_engine_conformance.py``).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.modes import AsyncMode
from repro.runtime.faults import FaultModel
from repro.runtime.simulator import SimConfig, SimResult
from repro.runtime.topologies import (
    OPP_IDX,
    Topology,
    canonical_edges,
    halo_slot_map,
    plan_layout,
)
from repro.runtime.window_core import (  # noqa: F401  (re-exports: the RNG
    # helpers and stream tags predate window_core and are imported from
    # here by apps and older callers)
    BARRIER_MODES as _BARRIER_MODES,
    LOCAL_RELEASE,
    make_dense_spec,
    STREAM_APP,
    STREAM_LAT,
    STREAM_MUT,
    STREAM_STALL,
    STREAM_STEP,
    WindowCore,
    hash_normal,
    hash_u32,
    hash_uniform,
    lognormal_factor,
)


class JaxEngine:
    """Windowed-time engine over flat arrays; ``Engine`` protocol member.

    Requires an application with an injected
    :class:`~repro.runtime.topologies.Topology` and a ``batched()`` entry
    point (``apps/graphcolor.py`` / ``apps/evo.py``) whose step runs the
    real fragment compute vectorized over the whole population.
    """

    name = "jax"

    def __init__(self, app, cfg: SimConfig,
                 faults: Optional[FaultModel] = None,
                 *, max_pops: int = 16, chunk: int = 256,
                 layout: str = "auto", scheduler: str = "window",
                 superstep_windows: int = 1):
        self.app = app
        self.cfg = cfg
        self.faults = faults or FaultModel()
        self.max_pops = max_pops
        self.chunk = chunk
        self.scheduler = scheduler
        self.superstep_windows = int(superstep_windows)
        topo = getattr(app, "injected", None)
        if not isinstance(topo, Topology):
            raise ValueError(
                "JaxEngine needs an app built with an injected "
                "runtime.topologies.Topology (experiments always inject one)")
        self.topo = topo
        self.n = n = app.n_processes
        self.bapp = app.batched()
        self.core = WindowCore(cfg, self.bapp, n, max_pops=max_pops)

        # --- static edge plumbing (numpy, hoisted out of the scan) --------
        esrc, edst, index = canonical_edges(topo)
        slot_maps = [halo_slot_map(topo.neighbors[p]) for p in range(n)]
        slot = [slot_maps[d][s] for s, d in zip(esrc, edst)]
        rev = [index[(d, s)] for s, d in zip(esrc, edst)]
        self.E = E = len(esrc)
        self._esrc = jnp.asarray(esrc, jnp.int32)
        self._edst = jnp.asarray(edst, jnp.int32)
        self._slot = jnp.asarray(slot, jnp.int32)
        # flattened (dst, slot) key: several in-edges may share one halo
        # slot; delivery ties are broken by highest edge index (segment_max)
        # so the scatter is deterministic on every backend
        self._halo_key = jnp.asarray(
            [d * 4 + s for d, s in zip(edst, slot)], jnp.int32)
        self._out_slot = jnp.asarray([OPP_IDX[s] for s in slot], jnp.int32)
        self._rev = jnp.asarray(rev, jnp.int32)
        self._eids = jnp.arange(E, dtype=jnp.int32)
        self._pids = jnp.arange(n, dtype=jnp.int32)

        lat = np.empty(E, np.float32)
        loss = np.empty(E, np.float32)
        flap = np.empty(E, np.float32)
        dead = np.empty(E, bool)
        for e, (s, d) in enumerate(zip(esrc, edst)):
            base = cfg.base_latency
            if cfg.intra_node_latency is not None and topo.same_node(s, d):
                base = cfg.intra_node_latency
            lat[e] = base * self.faults.link_factor(s, d)
            loss[e] = self.faults.loss_prob(s, d)
            flap[e] = self.faults.flap_frac(s, d)
            dead[e] = self.faults.is_crashed(d)
        self._lat_base = jnp.asarray(lat)
        # typed faults (DESIGN.md §14): per-edge loss/flap probabilities and
        # dead-destination flags, plus the crashed-process mask.  All static
        # per run — TimelineEvent faults re-instantiate the engine per epoch
        crashed_np = np.asarray(
            [self.faults.is_crashed(p) for p in range(n)], bool)
        self._has_faults = bool(loss.any() or flap.any() or dead.any())
        self._any_crashed = bool(crashed_np.any())
        self._crashed = jnp.asarray(crashed_np)
        if self._has_faults:
            self._loss = jnp.asarray(loss)
            self._flap = jnp.asarray(flap)
            self._dead = jnp.asarray(dead)
        self._deg = jnp.asarray([topo.degree(p) for p in range(n)], jnp.int32)
        self._cfactor = jnp.asarray(
            [self.faults.compute_factor(p) for p in range(n)], jnp.float32)

        # --- duct layout (DESIGN.md §10/§13): bucketed dense receiver-major
        # fast path (every topology), or the general edge-major path
        self.lplan = plan_layout(topo, layout)
        self.layout = self.lplan.kind
        if self.layout == "dense":
            lp = self.lplan
            self._spec = make_dense_spec(lp)
            self.R = R = int(lp.n_rows)
            # flat (R,) row tables; dead padding rows carry sentinel
            # src == n / eid == E and live == False
            j = np.arange(R) - lp.row_start[lp.dst]
            self._d_src = jnp.asarray(lp.src)
            self._d_dst = jnp.asarray(lp.dst)
            self._d_rev = jnp.asarray(lp.rev)
            self._d_eid = jnp.asarray(lp.eid)
            self._d_live = jnp.asarray(lp.live)
            # row j of a receiver block feeds halo slot j % 4, so the
            # sender writes the opposite slot — same OPP_IDX formula as
            # the edge-major path, computed per flat row
            self._d_out_slot = jnp.asarray(
                np.asarray(OPP_IDX, np.int32)[j % 4])
            self._d_lat = jnp.asarray(np.concatenate(
                [lat, np.zeros(1, np.float32)])[lp.eid])
            if self._has_faults:
                self._d_loss = jnp.asarray(np.concatenate(
                    [loss, np.zeros(1, np.float32)])[lp.eid])
                self._d_flap = jnp.asarray(np.concatenate(
                    [flap, np.zeros(1, np.float32)])[lp.eid])
                self._d_dead = jnp.asarray(np.concatenate(
                    [dead, np.zeros(1, bool)])[lp.eid])
        if scheduler == "superstep" and self.layout != "edge":
            w = self.superstep_windows
            if w < 2:
                raise ValueError(
                    "scheduler='superstep' fuses superstep_windows >= 2 "
                    f"windows per launch (got {w})")
            if w > cfg.buffer_capacity:
                raise ValueError(
                    f"superstep_windows={w} must not exceed "
                    f"buffer_capacity={cfg.buffer_capacity}: the compact "
                    "pushbuf commits at most one slot per window into the "
                    "ring tail")
        elif scheduler == "superstep":
            raise ValueError("scheduler='superstep' needs the dense layout "
                             "(pass layout='auto' or 'dense')")

        self.S = self.core.S
        self._max_windows = self.core.default_max_windows
        self._runner = None
        self._windows_per_call = self.chunk

    # ------------------------------------------------------------------
    def _barrier_cost(self) -> float:
        return self.core.barrier_cost

    def _step_factor(self, seed, steps, pids=None, cfactor=None):
        """Per-process compute-time factor; ``pids``/``cfactor`` default to
        the full-population arrays (the sharded engine passes its shard's
        slices — draws are keyed by original pid, so identical)."""
        return self.core.step_factor(
            seed, steps,
            self._pids if pids is None else pids,
            self._cfactor if cfactor is None else cfactor)

    # ------------------------------------------------------------------
    def _edge_state(self) -> Dict[str, jax.Array]:
        """Fresh (empty-ring) duct state in this engine's layout.  Every
        array is constant, so the sharded subclass overrides only the row
        count (padded per-shard layout) without re-deriving anything."""
        if self.layout == "dense":
            if self.scheduler == "superstep":
                return self.core.superstep_rings(self.R,
                                                 self.superstep_windows)
            return self.core.dense_rings(self.R)
        return self.core.edge_rings(self.E)

    def _init_carry(self, seed: int) -> Dict[str, jax.Array]:
        n = self.n
        bapp = self.bapp
        seed_arr = jnp.asarray(seed, jnp.int32)
        t0 = self.core.base_total * self._step_factor(
            seed_arr, jnp.zeros(n, jnp.int32))
        state, halo = bapp.init(seed)
        extra: Dict[str, jax.Array] = {}
        if self._any_crashed:
            # a crashed process's clock IS its next barrier arrival: +inf
            # keeps it out of every snapshot/release and lets the
            # quarantine gate see it as unreachable under any finite tau
            t0 = jnp.where(self._crashed, jnp.inf, t0)
        if self._has_faults:
            extra["c_loss"] = jnp.zeros(n, jnp.int32)
            extra["c_dead"] = jnp.zeros(n, jnp.int32)
        if self.cfg.barrier_timeout > 0 and self.cfg.mode in _BARRIER_MODES:
            extra["quar"] = jnp.zeros(n, bool)
        if self.cfg.arrival_rate > 0:
            # open-loop service arrivals: the cumulative per-(pid, bin)
            # arrival table is precomputed host-side (pure function of
            # (cfg, seed)) and carried so close_window's serve hook reads
            # the same stream every engine injects
            from repro.runtime.service import cum_arrivals
            extra["arr_cum"] = jnp.asarray(
                cum_arrivals(self.cfg, seed, n), jnp.int32)
            extra["served"] = jnp.zeros(n, jnp.int32)
        return dict(
            **extra,
            seed=seed_arr,
            k=jnp.asarray(0, jnp.int32),
            t=t0,
            steps=jnp.zeros(n, jnp.int32),
            done=jnp.zeros(n, bool),
            waiting=jnp.zeros(n, bool),
            barrier_seq=jnp.zeros(n, jnp.int32),
            last_release=jnp.zeros(n, jnp.float32),
            pending=jnp.zeros(n, jnp.float32),
            c_touch=jnp.zeros(n, jnp.int32),
            c_att=jnp.zeros(n, jnp.int32),
            c_ok=jnp.zeros(n, jnp.int32),
            c_drop=jnp.zeros(n, jnp.int32),
            c_laden=jnp.zeros(n, jnp.int32),
            c_msgs=jnp.zeros(n, jnp.int32),
            **self._edge_state(),
            halo=halo,
            app=state,
            snap=jnp.zeros((n, self.S, 8), jnp.float32),
            snap_idx=jnp.zeros(n, jnp.int32),
        )

    # ------------------------------------------------------------------
    def _window_body(self, carry, _):
        """One lockstep window on the edge-major layout: a straight
        composition of the core's drain -> compute -> send phases over the
        full-population edge tables."""
        cfg, n = self.cfg, self.n
        core = self.core
        comm = cfg.mode != AsyncMode.NO_COMM
        esrc, edst = self._esrc, self._edst
        seed, t = carry["seed"], carry["t"]
        active = ~carry["done"] & ~carry["waiting"]
        if self._any_crashed:
            active = active & ~self._crashed
        drained_r = jnp.zeros(n, jnp.int32)
        u = dict(carry)

        if comm:
            upd, drained_r = core.drain(
                carry, t[edst], active[edst],
                halo_key=self._halo_key, n_halo=n * 4, dst=edst, n_dst=n)
            u.update(upd)

        app_state, edges_out, steps = core.compute(
            carry, active, u["halo"], self._pids)
        u.update(app=app_state, steps=steps)

        if comm:
            # latency draws are keyed by (canonical edge, sender step
            # count), NOT the lockstep window counter: a process's c-th
            # send draws the same jitter no matter which window — or
            # scheduler — it executes under, so W-invariance is exact
            lat = self._lat_base * lognormal_factor(
                cfg.latency_sigma, seed, STREAM_LAT, self._eids, steps[esrc])
            act_e = active[esrc]
            send_act = act_e
            if self._has_faults:
                # a lost / flapped / dead-bound send is killed before the
                # ring: it still counts attempted + dropped (total), and
                # the per-cause segment sums attribute it
                loss_kill, dead_kill = core.fault_masks(
                    seed, t[esrc], steps[esrc], self._eids,
                    self._loss, self._flap, self.faults.flap_period,
                    self._dead)
                send_act = act_e & ~(loss_kill | dead_kill)
            sp = core.send_edge(
                u, t[esrc], send_act, lat, u["ptouch"][self._rev],
                edges_out[esrc, self._out_slot], esrc, n, sorted_src=True)
            u.update(sp.rings)
            if self._has_faults:
                kill_cols = jnp.stack(
                    [(act_e & loss_kill).astype(jnp.int32),
                     (act_e & dead_kill).astype(jnp.int32)], axis=1)
                ks = jax.ops.segment_sum(kill_cols, esrc,
                                         num_segments=n + 1,
                                         indices_are_sorted=True)[:n]
                killed = ks[:, 0] + ks[:, 1]
                u.update(c_att=carry["c_att"] + sp.sums[:, 0] + killed,
                         c_ok=carry["c_ok"] + sp.sums[:, 1],
                         c_drop=carry["c_drop"] + sp.sums[:, 2] + killed,
                         c_loss=carry["c_loss"] + ks[:, 0],
                         c_dead=carry["c_dead"] + ks[:, 1])
            else:
                u.update(c_att=carry["c_att"] + sp.sums[:, 0],
                         c_ok=carry["c_ok"] + sp.sums[:, 1],
                         c_drop=carry["c_drop"] + sp.sums[:, 2])
        return self._finish_window(u, active, drained_r), None

    # ------------------------------------------------------------------
    def _window_body_dense(self, carry, _, fused: bool = False):
        """One lockstep window on the dense bucketed receiver-major layout.

        Same window semantics, regrouped so one fused ``duct_window`` pass
        per window touches the ring state (core.window_dense) and this
        window's sends are staged eagerly (core.stage_dense).  The global
        drain/send sequence — and with it every trajectory and QoS
        counter — is bitwise identical to the edge-major path.  With
        ``fused`` the drain runs against frozen base rings via the
        superstep pushbuf (core.window_dense_fused) — same pops, same
        accepts, same counters.
        """
        cfg = self.cfg
        core = self.core
        comm = cfg.mode != AsyncMode.NO_COMM
        seed, t = carry["seed"], carry["t"]
        active = ~carry["done"] & ~carry["waiting"]
        if self._any_crashed:
            active = active & ~self._crashed
        drained_r = jnp.zeros(self.n, jnp.int32)
        u = dict(carry)

        if comm:
            if fused:
                upd, drained_r = core.window_dense_fused(
                    carry, t, active, spec=self._spec, dst_row=self._d_dst)
            else:
                upd, drained_r = core.window_dense(carry, t, active,
                                                   spec=self._spec)
            u.update(upd)

        app_state, edges_out, steps = core.compute(
            carry, active, u["halo"], self._pids)
        u.update(app=app_state, steps=steps)

        if comm:
            # same (edge, sender step) latency keying as the edge-major
            # path: flat row r's sender is src[r] (sentinel-clipped on
            # dead rows, whose draws are masked off by `live`)
            src_c = jnp.clip(self._d_src, 0, self.n - 1)
            lat = self._d_lat * lognormal_factor(
                cfg.latency_sigma, seed, STREAM_LAT, self._d_eid,
                steps[src_c])
            km = None
            if self._has_faults:
                km = core.fault_masks(
                    seed, t[src_c], steps[src_c], self._d_eid,
                    self._d_loss, self._d_flap, self.faults.flap_period,
                    self._d_dead)
            u.update(core.stage_dense(
                carry, u, t, active, edges_out, lat,
                src=self._d_src, rev=self._d_rev,
                out_slot=self._d_out_slot, live=self._d_live,
                deg=self._deg, spec=self._spec, kill_masks=km))
        return self._finish_window(u, active, drained_r), None

    # ------------------------------------------------------------------
    def _superstep_body(self, carry, _):
        """One W-fused superstep (DESIGN.md §13): W windows against frozen
        base rings (pushes append to the compact pushbuf, drains walk
        base-prefix then pushbuf), then ONE ``duct_commit`` folds the
        superstep's pushes into the rings.  Trajectories, counters, and
        QoS samples are bitwise identical to the per-window dense path;
        only the O(R*C) ring sweeps are fused away."""

        def win(c, __):
            return self._window_body_dense(c, None, fused=True)

        carry, _ = jax.lax.scan(win, carry, None,
                                length=self.superstep_windows)
        carry = dict(carry)
        carry.update(self.core.commit_superstep(carry))
        return carry, None

    # ------------------------------------------------------------------
    def _finish_window(self, u, active, drained_r):
        """Shared window tail (both layouts), with single-device release
        reductions."""
        return self.core.close_window(
            u, active, drained_r, pids=self._pids, deg=self._deg,
            cfactor=self._cfactor, release=LOCAL_RELEASE)

    # ------------------------------------------------------------------
    def _get_runner(self):
        if self._runner is None:
            if self.layout == "dense" and self.scheduler == "superstep":
                W = self.superstep_windows
                sup = max(1, self.chunk // W)
                self._windows_per_call = sup * W

                def chunk(carry):
                    carry, _ = jax.lax.scan(self._superstep_body, carry,
                                            None, length=sup)
                    return carry
            else:
                body = (self._window_body_dense if self.layout == "dense"
                        else self._window_body)
                self._windows_per_call = self.chunk

                def chunk(carry):
                    carry, _ = jax.lax.scan(body, carry, None,
                                            length=self.chunk)
                    return carry
            # donation lets XLA reuse the ring/state buffers across chunks
            self._runner = jax.jit(jax.vmap(chunk), donate_argnums=0)
        return self._runner

    # ------------------------------------------------------------------
    def run(self) -> SimResult:
        return self.run_replicates([self.cfg.seed])[0]

    def run_replicates(self, seeds: Sequence[int]) -> List[SimResult]:
        """One replicate per seed, dispatched as a single vmapped scan."""
        carries = [self._init_carry(int(s)) for s in seeds]
        carry = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *carries)
        runner = self._get_runner()
        windows = 0
        prev_done = None
        while windows < self._max_windows:
            carry = runner(carry)
            windows += self._windows_per_call
            # pipelined early-exit probe: enqueue this chunk's tiny done
            # reduction, but only *read* the previous chunk's — the host
            # blocks on a result whose chunk already finished while the
            # next chunk keeps the device busy, so the dispatch pipeline
            # never drains.  Costs one extra (state-invariant: every
            # process is inactive) chunk after the run completes.
            # crashed processes never reach the horizon; the probe treats
            # them as terminally stopped
            all_done = (jnp.all(carry["done"] | self._crashed)
                        if self._any_crashed else jnp.all(carry["done"]))
            if prev_done is not None and bool(prev_done):
                break
            prev_done = all_done
        carry = jax.device_get(carry)
        if getattr(self, "debug_keep_carry", False):
            self._final_carry = carry
        return [self._assemble(carry, r) for r in range(len(seeds))]

    # ------------------------------------------------------------------
    def _assemble(self, carry, r: int) -> SimResult:
        app_state = jax.tree_util.tree_map(lambda x: x[r], carry["app"])
        return self.core.assemble(
            carry, r, np.asarray(self._deg, np.int64),
            self.bapp.quality(app_state),
            app_state=(self.bapp.export_state(app_state)
                       if self.cfg.carry_app_state
                       and hasattr(self.bapp, "export_state") else None))
