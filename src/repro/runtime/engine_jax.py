"""Vectorized windowed-time best-effort engine (DESIGN.md §7).

The discrete-event engine (``runtime/simulator.py``) processes one event at
a time from a heap — exact, but serial.  This engine advances the *entire
process population per lockstep window* as flat JAX arrays: window k is
every process's k-th simstep, executed at per-process virtual times that
drift apart exactly as the paper describes (jitter, stalls, faults,
barriers).  Per window it performs

  1. edge-parallel duct drain   (kernels/duct_exchange: bounded FIFO rings,
                                 latency-delayed availability)
  2. halo scatter + the application's *actual* batched compute
  3. edge-parallel send attempt (capacity drop, latency stamp)
  4. incremental QoS counter updates + O(1) snapshot scatter

All stochastic draws are counter-based splitmix-style hashes evaluated
in-graph, so a run is a pure function of ``(config, seed)`` and
``jax.vmap`` over the seed axis dispatches a whole replicate sweep in one
scan (``run_replicates``).

Where it diverges from the event engine — and why that is acceptable for
median/p95 QoS — is documented in DESIGN.md §7.  Parity on small configs is
enforced by ``tests/test_engine_jax.py``.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.modes import AsyncMode
from repro.core.qos import Counters, QosReport, report
from repro.kernels.duct_exchange.ops import duct_drain, duct_send
from repro.runtime.faults import FaultModel
from repro.runtime.simulator import SimConfig, SimResult
from repro.runtime.topologies import OPP_IDX, Topology, halo_slot_map

_BARRIER_MODES = (AsyncMode.BARRIER_EVERY_STEP, AsyncMode.ROLLING_BARRIER,
                  AsyncMode.FIXED_BARRIER)

# ---------------------------------------------------------------------------
# Counter-based RNG: splitmix-style 32-bit finalizer chains, pure functions
# of their integer keys — the in-graph twin of runtime/faults.py's
# splitmix64 streams (same distributions, different bit streams).
# ---------------------------------------------------------------------------
_GOLDEN = np.uint32(0x9E3779B9)

# stream tags keep independent draws independent
STREAM_STEP, STREAM_STALL, STREAM_LAT, STREAM_APP, STREAM_MUT = 1, 2, 3, 4, 5


def _mix32(x: jax.Array) -> jax.Array:
    """32-bit splitmix-style finalizer (lowbias32 constants)."""
    x = (x ^ (x >> np.uint32(16))) * np.uint32(0x7FEB352D)
    x = (x ^ (x >> np.uint32(15))) * np.uint32(0x846CA68B)
    return x ^ (x >> np.uint32(16))


def hash_u32(*keys) -> jax.Array:
    """Combine integer keys (arrays broadcast) into one hashed uint32."""
    h = _GOLDEN
    for k in keys:
        k = jnp.asarray(k).astype(jnp.uint32)
        h = _mix32(h ^ (k + _GOLDEN + (h << np.uint32(6)) +
                        (h >> np.uint32(2))))
    return h


def hash_uniform(*keys) -> jax.Array:
    """Deterministic uniform in (0, 1) from integer keys."""
    h = hash_u32(*keys)
    return ((h >> np.uint32(8)).astype(jnp.float32) + 0.5) * np.float32(
        1.0 / (1 << 24))


def hash_normal(*keys) -> jax.Array:
    u1 = hash_uniform(*keys, 101)
    u2 = hash_uniform(*keys, 202)
    return jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(2.0 * np.pi * u2)


def lognormal_factor(sigma: float, *keys) -> jax.Array:
    """Mean-one lognormal, matching faults.Jitter's parameterization."""
    if sigma <= 0:
        return jnp.ones(jnp.broadcast_shapes(
            *(jnp.shape(k) for k in keys)), jnp.float32)
    z = hash_normal(*keys)
    return jnp.exp(np.float32(-0.5 * sigma * sigma) + np.float32(sigma) * z)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------
class JaxEngine:
    """Windowed-time engine over flat arrays; ``Engine`` protocol member.

    Requires an application with an injected
    :class:`~repro.runtime.topologies.Topology` and a ``batched()`` entry
    point (``apps/graphcolor.py`` / ``apps/evo.py``) whose step runs the
    real fragment compute vectorized over the whole population.
    """

    name = "jax"

    def __init__(self, app, cfg: SimConfig,
                 faults: Optional[FaultModel] = None,
                 *, max_pops: int = 16, chunk: int = 256):
        self.app = app
        self.cfg = cfg
        self.faults = faults or FaultModel()
        self.max_pops = max_pops
        self.chunk = chunk
        topo = getattr(app, "injected", None)
        if not isinstance(topo, Topology):
            raise ValueError(
                "JaxEngine needs an app built with an injected "
                "runtime.topologies.Topology (experiments always inject one)")
        self.topo = topo
        self.n = n = app.n_processes
        self.bapp = app.batched()

        # --- static edge plumbing (numpy, hoisted out of the scan) --------
        esrc, edst, slot = [], [], []
        index = {}
        for src in range(n):
            for dst in topo.neighbors[src]:
                index[(src, dst)] = len(esrc)
                esrc.append(src)
                edst.append(dst)
        slot_maps = [halo_slot_map(topo.neighbors[p]) for p in range(n)]
        slot = [slot_maps[d][s] for s, d in zip(esrc, edst)]
        rev = [index[(d, s)] for s, d in zip(esrc, edst)]
        self.E = E = len(esrc)
        self._esrc = jnp.asarray(esrc, jnp.int32)
        self._edst = jnp.asarray(edst, jnp.int32)
        self._slot = jnp.asarray(slot, jnp.int32)
        # flattened (dst, slot) key: several in-edges may share one halo
        # slot; delivery ties are broken by highest edge index (segment_max)
        # so the scatter is deterministic on every backend
        self._halo_key = jnp.asarray(
            [d * 4 + s for d, s in zip(edst, slot)], jnp.int32)
        self._out_slot = jnp.asarray([OPP_IDX[s] for s in slot], jnp.int32)
        self._rev = jnp.asarray(rev, jnp.int32)
        self._eids = jnp.arange(E, dtype=jnp.int32)
        self._pids = jnp.arange(n, dtype=jnp.int32)

        lat = np.empty(E, np.float32)
        for e, (s, d) in enumerate(zip(esrc, edst)):
            base = cfg.base_latency
            if cfg.intra_node_latency is not None and topo.same_node(s, d):
                base = cfg.intra_node_latency
            lat[e] = base * self.faults.link_factor(s, d)
        self._lat_base = jnp.asarray(lat)
        self._deg = jnp.asarray([topo.degree(p) for p in range(n)], jnp.int32)
        self._cfactor = jnp.asarray(
            [self.faults.compute_factor(p) for p in range(n)], jnp.float32)

        warmup, interval = cfg.snapshot_warmup, cfg.snapshot_interval
        self.S = max(1, int((cfg.duration - warmup) / interval) + 3)
        base_total = cfg.base_compute + cfg.work_units * cfg.work_unit_cost
        # generous lockstep-window budget: fastest plausible step is about
        # half the mean, plus slack for barrier-arrival idling
        self._max_windows = int(8 * cfg.duration / base_total) + 2048
        self._runner = None

    # ------------------------------------------------------------------
    def _barrier_cost(self) -> float:
        if self.n <= 1:
            return 0.0
        return self.cfg.barrier_base + self.cfg.barrier_per_log2 * math.log2(
            self.n)

    def _step_factor(self, seed, steps, pids=None, cfactor=None):
        """Per-process compute-time factor; ``pids``/``cfactor`` default to
        the full-population arrays (the sharded engine passes its shard's
        slices — draws are keyed by original pid, so identical)."""
        cfg = self.cfg
        pids = self._pids if pids is None else pids
        cfactor = self._cfactor if cfactor is None else cfactor
        f = lognormal_factor(cfg.jitter_sigma, seed, STREAM_STEP,
                             pids, steps)
        if cfg.stall_prob > 0:
            u = hash_uniform(seed, STREAM_STALL, pids, steps)
            f = jnp.where(u < cfg.stall_prob,
                          f * np.float32(cfg.stall_factor), f)
        return f * cfactor

    # ------------------------------------------------------------------
    def _edge_state(self) -> Dict[str, jax.Array]:
        """Fresh (empty-ring) edge state.  Every array is constant, so the
        sharded subclass overrides only the row count (padded per-shard
        layout) without re-deriving anything."""
        cfg, E = self.cfg, self.E
        L = self.bapp.payload_len
        return dict(
            ptouch=jnp.zeros(E, jnp.int32),
            q_avail=jnp.full((E, cfg.buffer_capacity), jnp.inf, jnp.float32),
            q_touch=jnp.zeros((E, cfg.buffer_capacity), jnp.int32),
            q_pay=jnp.zeros((E, cfg.buffer_capacity, L),
                            self.bapp.payload_dtype),
            q_head=jnp.zeros(E, jnp.int32),
            q_size=jnp.zeros(E, jnp.int32),
        )

    def _init_carry(self, seed: int) -> Dict[str, jax.Array]:
        cfg, n = self.cfg, self.n
        bapp = self.bapp
        base_total = np.float32(
            cfg.base_compute + cfg.work_units * cfg.work_unit_cost)
        seed_arr = jnp.asarray(seed, jnp.int32)
        t0 = base_total * self._step_factor(
            seed_arr, jnp.zeros(n, jnp.int32))
        state, halo = bapp.init(seed)
        return dict(
            seed=seed_arr,
            k=jnp.asarray(0, jnp.int32),
            t=t0,
            steps=jnp.zeros(n, jnp.int32),
            done=jnp.zeros(n, bool),
            waiting=jnp.zeros(n, bool),
            barrier_seq=jnp.zeros(n, jnp.int32),
            last_release=jnp.zeros(n, jnp.float32),
            pending=jnp.zeros(n, jnp.float32),
            c_touch=jnp.zeros(n, jnp.int32),
            c_att=jnp.zeros(n, jnp.int32),
            c_ok=jnp.zeros(n, jnp.int32),
            c_drop=jnp.zeros(n, jnp.int32),
            c_laden=jnp.zeros(n, jnp.int32),
            c_msgs=jnp.zeros(n, jnp.int32),
            **self._edge_state(),
            halo=halo,
            app=state,
            snap=jnp.zeros((n, self.S, 8), jnp.float32),
            snap_idx=jnp.zeros(n, jnp.int32),
        )

    # ------------------------------------------------------------------
    def _window_body(self, carry, _):
        cfg, n, E = self.cfg, self.n, self.E
        bapp = self.bapp
        mode = cfg.mode
        comm = mode != AsyncMode.NO_COMM
        barriered = mode in _BARRIER_MODES
        rows = self._eids
        esrc, edst = self._esrc, self._edst
        seed = carry["seed"]
        k = carry["k"]
        t = carry["t"]
        done, waiting = carry["done"], carry["waiting"]
        active = ~done & ~waiting
        halo = carry["halo"]
        drained_r = jnp.zeros(n, jnp.int32)

        if comm:
            # --- 1. edge-parallel drain (bounded FIFO, head-blocking) -----
            d = duct_drain(carry["q_avail"], carry["q_touch"],
                           carry["q_head"], carry["q_size"],
                           t[edst], active[edst], max_pops=self.max_pops,
                           clear_popped=False)
            delivered = d.drained > 0
            payload = carry["q_pay"][rows, d.pop_pos]
            # halo update: per (dst, slot) the highest delivering edge index
            # wins — a deterministic stand-in for "last fresh message wins"
            # (plain duplicate-index scatter order is unspecified in JAX)
            winner = jax.ops.segment_max(
                jnp.where(delivered, rows, -1), self._halo_key,
                num_segments=n * 4)
            has_win = winner >= 0
            fresh = payload[jnp.where(has_win, winner, 0)]
            L = halo.shape[-1]
            halo = jnp.where(has_win[:, None], fresh,
                             halo.reshape(n * 4, L)).reshape(n, 4, L)
            new_touch = d.recv_touch + 1
            dtouch = jnp.where(delivered, new_touch - carry["ptouch"], 0)
            ptouch = jnp.where(delivered, new_touch, carry["ptouch"])
            # one multi-column segment sum for all receiver-side counters
            recv_cols = jnp.stack([d.drained, delivered.astype(jnp.int32),
                                   dtouch], axis=1)
            recv_sums = jax.ops.segment_sum(recv_cols, edst, num_segments=n)
            drained_r = recv_sums[:, 0]
            c_msgs = carry["c_msgs"] + drained_r
            c_laden = carry["c_laden"] + recv_sums[:, 1]
            c_touch = carry["c_touch"] + recv_sums[:, 2]
            q_avail, q_touch = d.q_avail, d.q_touch
            q_head, q_size = d.head, d.size
        else:
            ptouch = carry["ptouch"]
            c_touch, c_laden, c_msgs = (carry["c_touch"], carry["c_laden"],
                                        carry["c_msgs"])
            q_avail, q_touch = carry["q_avail"], carry["q_touch"]
            q_head, q_size = carry["q_head"], carry["q_size"]

        # --- 2. the application's actual batched compute ------------------
        new_state, edges_out = bapp.step(carry["app"], halo, carry["steps"],
                                         seed, pids=self._pids)
        app_state = jax.tree_util.tree_map(
            lambda new, old: jnp.where(
                active.reshape((n,) + (1,) * (new.ndim - 1)), new, old),
            new_state, carry["app"])
        steps = carry["steps"] + active

        if comm:
            # --- 3. edge-parallel send attempt (drop iff full) ------------
            out_pay = edges_out[esrc, self._out_slot]
            lat = self._lat_base * lognormal_factor(
                cfg.latency_sigma, seed, STREAM_LAT, rows, k)
            s = duct_send(q_avail, q_touch, q_head, q_size,
                          t[esrc], active[esrc], lat, ptouch[self._rev],
                          capacity=cfg.buffer_capacity)
            q_pay = carry["q_pay"].at[
                jnp.where(s.accepted, rows, E), s.push_pos].set(
                out_pay, mode="drop")
            q_avail, q_touch, q_size = s.q_avail, s.q_touch, s.size
            attempted = active[esrc]
            send_cols = jnp.stack([
                attempted.astype(jnp.int32), s.accepted.astype(jnp.int32),
                (attempted & ~s.accepted).astype(jnp.int32)], axis=1)
            send_sums = jax.ops.segment_sum(send_cols, esrc, num_segments=n,
                                            indices_are_sorted=True)
            c_att = carry["c_att"] + send_sums[:, 0]
            c_ok = carry["c_ok"] + send_sums[:, 1]
            c_drop = carry["c_drop"] + send_sums[:, 2]
        else:
            q_pay = carry["q_pay"]
            c_att, c_ok, c_drop = carry["c_att"], carry["c_ok"], carry["c_drop"]

        # --- 4. incremental QoS counters + snapshot scatter ---------------
        pending = (drained_r.astype(jnp.float32) * np.float32(
            cfg.per_message_cost) +
            self._deg.astype(jnp.float32) * np.float32(cfg.per_pull_cost))
        snap_idx = carry["snap_idx"]
        thr = (np.float32(cfg.snapshot_warmup) +
               snap_idx.astype(jnp.float32) * np.float32(
                   cfg.snapshot_interval))
        snap_due = active & (t >= thr) & (snap_idx < self.S)
        row = jnp.stack([
            steps.astype(jnp.float32), c_touch.astype(jnp.float32),
            c_att.astype(jnp.float32), c_ok.astype(jnp.float32),
            c_drop.astype(jnp.float32), c_laden.astype(jnp.float32),
            c_msgs.astype(jnp.float32), t], axis=1)
        snap = carry["snap"].at[jnp.where(snap_due, self._pids, n),
                                snap_idx].set(row, mode="drop")
        snap_idx = snap_idx + snap_due

        # --- termination / barriers / time advance ------------------------
        newly_done = active & (t >= np.float32(cfg.duration))
        done = done | newly_done
        d_next = (np.float32(cfg.base_compute + cfg.work_units *
                             cfg.work_unit_cost) *
                  self._step_factor(seed, steps))
        barrier_seq = carry["barrier_seq"]
        last_release = carry["last_release"]
        pending_saved = carry["pending"]

        if barriered:
            if mode == AsyncMode.BARRIER_EVERY_STEP:
                due = active & ~newly_done
            elif mode == AsyncMode.ROLLING_BARRIER:
                due = active & ~newly_done & (
                    (t - last_release) >= np.float32(cfg.rolling_quantum))
            else:
                due = active & ~newly_done & (
                    t >= (barrier_seq + 1).astype(jnp.float32) *
                    np.float32(cfg.fixed_interval))
            waiting = waiting | due
            pending_saved = jnp.where(due, pending, pending_saved)
            t = jnp.where(active & ~newly_done & ~due,
                          t + d_next + pending, t)
            release_ready = jnp.all(waiting | done) & jnp.any(waiting)
            release_t = (jnp.max(jnp.where(waiting, t, -jnp.inf)) +
                         np.float32(self._barrier_cost()))
            rel = release_ready & waiting
            t = jnp.where(rel, release_t + d_next + pending_saved, t)
            last_release = jnp.where(rel, release_t, last_release)
            barrier_seq = barrier_seq + rel
            waiting = waiting & ~release_ready
        else:
            t = jnp.where(active & ~newly_done, t + d_next + pending, t)

        carry = dict(
            seed=seed, k=k + 1, t=t, steps=steps, done=done, waiting=waiting,
            barrier_seq=barrier_seq, last_release=last_release,
            pending=pending_saved,
            c_touch=c_touch, c_att=c_att, c_ok=c_ok, c_drop=c_drop,
            c_laden=c_laden, c_msgs=c_msgs, ptouch=ptouch,
            q_avail=q_avail, q_touch=q_touch, q_pay=q_pay,
            q_head=q_head, q_size=q_size,
            halo=halo, app=app_state, snap=snap, snap_idx=snap_idx)
        return carry, None

    # ------------------------------------------------------------------
    def _get_runner(self):
        if self._runner is None:
            def chunk(carry):
                carry, _ = jax.lax.scan(self._window_body, carry, None,
                                        length=self.chunk)
                return carry
            # donation lets XLA reuse the ring/state buffers across chunks
            self._runner = jax.jit(jax.vmap(chunk), donate_argnums=0)
        return self._runner

    # ------------------------------------------------------------------
    def run(self) -> SimResult:
        return self.run_replicates([self.cfg.seed])[0]

    def run_replicates(self, seeds: Sequence[int]) -> List[SimResult]:
        """One replicate per seed, dispatched as a single vmapped scan."""
        carries = [self._init_carry(int(s)) for s in seeds]
        carry = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *carries)
        runner = self._get_runner()
        windows = 0
        while windows < self._max_windows:
            carry = runner(carry)
            windows += self.chunk
            if bool(jnp.all(carry["done"])):
                break
        carry = jax.device_get(carry)
        return [self._assemble(carry, r) for r in range(len(seeds))]

    # ------------------------------------------------------------------
    def _assemble(self, carry, r: int) -> SimResult:
        cfg, n = self.cfg, self.n
        comm = cfg.mode != AsyncMode.NO_COMM
        deg = np.asarray(self._deg)
        snap = np.asarray(carry["snap"][r])
        snap_idx = np.asarray(carry["snap_idx"][r])
        steps = np.asarray(carry["steps"][r])

        def counters(p, row):
            up = int(row[0])
            return Counters(
                update_count=up,
                touch_count=int(row[1]),
                attempted_send_count=int(row[2]),
                successful_send_count=int(row[3]),
                dropped_send_count=int(row[4]),
                laden_pull_count=int(row[5]),
                message_count=int(row[6]),
                pull_attempt_count=up * int(deg[p]) if comm else 0,
                wall_time=float(row[7]),
            )

        qos_by_proc: Dict[int, List[QosReport]] = {}
        all_qos: List[QosReport] = []
        for p in range(n):
            rows = snap[p, :snap_idx[p]]
            cs = [counters(p, row) for row in rows]
            reps = [report(c0, c1) for c0, c1 in zip(cs, cs[1:])]
            qos_by_proc[p] = reps
            all_qos.extend(reps)

        app_state = jax.tree_util.tree_map(lambda x: x[r], carry["app"])
        return SimResult(
            updates=[int(u) for u in steps],
            horizon=cfg.duration,
            quality=self.bapp.quality(app_state),
            qos=all_qos,
            qos_by_process=qos_by_proc,
            dropped=int(np.sum(carry["c_drop"][r])),
            sent=int(np.sum(carry["c_att"][r])),
        )
