"""Vectorized windowed-time best-effort engine (DESIGN.md §7).

The discrete-event engine (``runtime/simulator.py``) processes one event at
a time from a heap — exact, but serial.  This engine advances the *entire
process population per lockstep window* as flat JAX arrays: window k is
every process's k-th simstep, executed at per-process virtual times that
drift apart exactly as the paper describes (jitter, stalls, faults,
barriers).  Per window it performs

  1. edge-parallel duct drain   (kernels/duct_exchange: bounded FIFO rings,
                                 latency-delayed availability)
  2. halo scatter + the application's *actual* batched compute
  3. edge-parallel send attempt (capacity drop, latency stamp)
  4. incremental QoS counter updates + O(1) snapshot scatter

All stochastic draws are counter-based splitmix-style hashes evaluated
in-graph, so a run is a pure function of ``(config, seed)`` and
``jax.vmap`` over the seed axis dispatches a whole replicate sweep in one
scan (``run_replicates``).

Two duct layouts share these semantics (``layout=`` / ``--layout``,
DESIGN.md §10): the general *edge-major* path above, and the *dense
receiver-major* fast path for degree-regular topologies (ring, torus),
where each process owns its ``d`` in-edge rings contiguously as
``(n, d, C)`` arrays and the whole window's ring traffic runs through one
fused ``duct_window`` pass — zero segment/scatter ops, bitwise-identical
trajectories (``tests/test_layout_dense.py``).

Where it diverges from the event engine — and why that is acceptable for
median/p95 QoS — is documented in DESIGN.md §7.  Parity on small configs is
enforced by ``tests/test_engine_jax.py``.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.modes import AsyncMode
from repro.core.qos import QosReport
from repro.kernels.duct_exchange.ops import duct_drain, duct_send, duct_window
from repro.runtime.faults import FaultModel
from repro.runtime.simulator import SimConfig, SimResult
from repro.runtime.topologies import (
    OPP_IDX,
    Topology,
    canonical_edges,
    halo_slot_map,
    plan_layout,
)

_BARRIER_MODES = (AsyncMode.BARRIER_EVERY_STEP, AsyncMode.ROLLING_BARRIER,
                  AsyncMode.FIXED_BARRIER)

# ---------------------------------------------------------------------------
# Counter-based RNG: splitmix-style 32-bit finalizer chains, pure functions
# of their integer keys — the in-graph twin of runtime/faults.py's
# splitmix64 streams (same distributions, different bit streams).
# ---------------------------------------------------------------------------
_GOLDEN = np.uint32(0x9E3779B9)

# stream tags keep independent draws independent
STREAM_STEP, STREAM_STALL, STREAM_LAT, STREAM_APP, STREAM_MUT = 1, 2, 3, 4, 5


def _mix32(x: jax.Array) -> jax.Array:
    """32-bit splitmix-style finalizer (lowbias32 constants)."""
    x = (x ^ (x >> np.uint32(16))) * np.uint32(0x7FEB352D)
    x = (x ^ (x >> np.uint32(15))) * np.uint32(0x846CA68B)
    return x ^ (x >> np.uint32(16))


def hash_u32(*keys) -> jax.Array:
    """Combine integer keys (arrays broadcast) into one hashed uint32."""
    h = _GOLDEN
    for k in keys:
        k = jnp.asarray(k).astype(jnp.uint32)
        h = _mix32(h ^ (k + _GOLDEN + (h << np.uint32(6)) +
                        (h >> np.uint32(2))))
    return h


def hash_uniform(*keys) -> jax.Array:
    """Deterministic uniform in (0, 1) from integer keys."""
    h = hash_u32(*keys)
    return ((h >> np.uint32(8)).astype(jnp.float32) + 0.5) * np.float32(
        1.0 / (1 << 24))


def hash_normal(*keys) -> jax.Array:
    u1 = hash_uniform(*keys, 101)
    u2 = hash_uniform(*keys, 202)
    return jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(2.0 * np.pi * u2)


def lognormal_factor(sigma: float, *keys) -> jax.Array:
    """Mean-one lognormal, matching faults.Jitter's parameterization."""
    if sigma <= 0:
        return jnp.ones(jnp.broadcast_shapes(
            *(jnp.shape(k) for k in keys)), jnp.float32)
    z = hash_normal(*keys)
    return jnp.exp(np.float32(-0.5 * sigma * sigma) + np.float32(sigma) * z)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------
class JaxEngine:
    """Windowed-time engine over flat arrays; ``Engine`` protocol member.

    Requires an application with an injected
    :class:`~repro.runtime.topologies.Topology` and a ``batched()`` entry
    point (``apps/graphcolor.py`` / ``apps/evo.py``) whose step runs the
    real fragment compute vectorized over the whole population.
    """

    name = "jax"

    def __init__(self, app, cfg: SimConfig,
                 faults: Optional[FaultModel] = None,
                 *, max_pops: int = 16, chunk: int = 256,
                 layout: str = "auto"):
        self.app = app
        self.cfg = cfg
        self.faults = faults or FaultModel()
        self.max_pops = max_pops
        self.chunk = chunk
        topo = getattr(app, "injected", None)
        if not isinstance(topo, Topology):
            raise ValueError(
                "JaxEngine needs an app built with an injected "
                "runtime.topologies.Topology (experiments always inject one)")
        self.topo = topo
        self.n = n = app.n_processes
        self.bapp = app.batched()

        # --- static edge plumbing (numpy, hoisted out of the scan) --------
        esrc, edst, index = canonical_edges(topo)
        slot_maps = [halo_slot_map(topo.neighbors[p]) for p in range(n)]
        slot = [slot_maps[d][s] for s, d in zip(esrc, edst)]
        rev = [index[(d, s)] for s, d in zip(esrc, edst)]
        self.E = E = len(esrc)
        self._esrc = jnp.asarray(esrc, jnp.int32)
        self._edst = jnp.asarray(edst, jnp.int32)
        self._slot = jnp.asarray(slot, jnp.int32)
        # flattened (dst, slot) key: several in-edges may share one halo
        # slot; delivery ties are broken by highest edge index (segment_max)
        # so the scatter is deterministic on every backend
        self._halo_key = jnp.asarray(
            [d * 4 + s for d, s in zip(edst, slot)], jnp.int32)
        self._out_slot = jnp.asarray([OPP_IDX[s] for s in slot], jnp.int32)
        self._rev = jnp.asarray(rev, jnp.int32)
        self._eids = jnp.arange(E, dtype=jnp.int32)
        self._pids = jnp.arange(n, dtype=jnp.int32)

        lat = np.empty(E, np.float32)
        for e, (s, d) in enumerate(zip(esrc, edst)):
            base = cfg.base_latency
            if cfg.intra_node_latency is not None and topo.same_node(s, d):
                base = cfg.intra_node_latency
            lat[e] = base * self.faults.link_factor(s, d)
        self._lat_base = jnp.asarray(lat)
        self._deg = jnp.asarray([topo.degree(p) for p in range(n)], jnp.int32)
        self._cfactor = jnp.asarray(
            [self.faults.compute_factor(p) for p in range(n)], jnp.float32)

        # --- duct layout (DESIGN.md §10): dense receiver-major fast path --
        # for degree-regular topologies, or the general edge-major path
        self.lplan = plan_layout(topo, layout)
        self.layout = self.lplan.kind
        if self.layout == "dense":
            lp = self.lplan
            dd = lp.degree
            self._d_src = jnp.asarray(lp.src)   # (n, d) source pid per row
            self._d_rev = jnp.asarray(lp.rev)   # (n, d) flat out-edge rows
            self._d_eid = jnp.asarray(lp.eid)   # (n, d) canonical edge ids
            self._d_out_slot = jnp.asarray(np.broadcast_to(
                np.asarray([OPP_IDX[j % 4] for j in range(dd)], np.int32),
                (n, dd)))
            self._d_lat = jnp.asarray(
                lat[lp.eid.reshape(-1)].reshape(n, dd))

        warmup, interval = cfg.snapshot_warmup, cfg.snapshot_interval
        self.S = max(1, int((cfg.duration - warmup) / interval) + 3)
        base_total = cfg.base_compute + cfg.work_units * cfg.work_unit_cost
        # generous lockstep-window budget: fastest plausible step is about
        # half the mean, plus slack for barrier-arrival idling
        self._max_windows = int(8 * cfg.duration / base_total) + 2048
        self._runner = None

    # ------------------------------------------------------------------
    def _barrier_cost(self) -> float:
        if self.n <= 1:
            return 0.0
        return self.cfg.barrier_base + self.cfg.barrier_per_log2 * math.log2(
            self.n)

    def _step_factor(self, seed, steps, pids=None, cfactor=None):
        """Per-process compute-time factor; ``pids``/``cfactor`` default to
        the full-population arrays (the sharded engine passes its shard's
        slices — draws are keyed by original pid, so identical)."""
        cfg = self.cfg
        pids = self._pids if pids is None else pids
        cfactor = self._cfactor if cfactor is None else cfactor
        f = lognormal_factor(cfg.jitter_sigma, seed, STREAM_STEP,
                             pids, steps)
        if cfg.stall_prob > 0:
            u = hash_uniform(seed, STREAM_STALL, pids, steps)
            f = jnp.where(u < cfg.stall_prob,
                          f * np.float32(cfg.stall_factor), f)
        return f * cfactor

    # ------------------------------------------------------------------
    def _edge_state(self) -> Dict[str, jax.Array]:
        """Fresh (empty-ring) edge state.  Every array is constant, so the
        sharded subclass overrides only the row count (padded per-shard
        layout) without re-deriving anything.

        The dense layout shapes rings receiver-major ``(n, d, C)`` and adds
        the staged-send buffers: the send *decision* happens eagerly at
        stage time, the ring *writes* ride into the next window's fused
        ``duct_window`` pass (DESIGN.md §10)."""
        cfg, E = self.cfg, self.E
        L = self.bapp.payload_len
        if self.layout == "dense":
            n, dd, C = self.n, self.lplan.degree, cfg.buffer_capacity
            return dict(
                ptouch=jnp.zeros((n, dd), jnp.int32),
                q_avail=jnp.full((n, dd, C), jnp.inf, jnp.float32),
                q_touch=jnp.zeros((n, dd, C), jnp.int32),
                q_pay=jnp.zeros((n, dd, C, L), self.bapp.payload_dtype),
                q_head=jnp.zeros((n, dd), jnp.int32),
                q_size=jnp.zeros((n, dd), jnp.int32),
                stage_pos=jnp.zeros((n, dd), jnp.int32),
                stage_acc=jnp.zeros((n, dd), bool),
                stage_avail=jnp.zeros((n, dd), jnp.float32),
                stage_touch=jnp.zeros((n, dd), jnp.int32),
                stage_pay=jnp.zeros((n, dd, L), self.bapp.payload_dtype),
            )
        return dict(
            ptouch=jnp.zeros(E, jnp.int32),
            q_avail=jnp.full((E, cfg.buffer_capacity), jnp.inf, jnp.float32),
            q_touch=jnp.zeros((E, cfg.buffer_capacity), jnp.int32),
            q_pay=jnp.zeros((E, cfg.buffer_capacity, L),
                            self.bapp.payload_dtype),
            q_head=jnp.zeros(E, jnp.int32),
            q_size=jnp.zeros(E, jnp.int32),
        )

    def _init_carry(self, seed: int) -> Dict[str, jax.Array]:
        cfg, n = self.cfg, self.n
        bapp = self.bapp
        base_total = np.float32(
            cfg.base_compute + cfg.work_units * cfg.work_unit_cost)
        seed_arr = jnp.asarray(seed, jnp.int32)
        t0 = base_total * self._step_factor(
            seed_arr, jnp.zeros(n, jnp.int32))
        state, halo = bapp.init(seed)
        return dict(
            seed=seed_arr,
            k=jnp.asarray(0, jnp.int32),
            t=t0,
            steps=jnp.zeros(n, jnp.int32),
            done=jnp.zeros(n, bool),
            waiting=jnp.zeros(n, bool),
            barrier_seq=jnp.zeros(n, jnp.int32),
            last_release=jnp.zeros(n, jnp.float32),
            pending=jnp.zeros(n, jnp.float32),
            c_touch=jnp.zeros(n, jnp.int32),
            c_att=jnp.zeros(n, jnp.int32),
            c_ok=jnp.zeros(n, jnp.int32),
            c_drop=jnp.zeros(n, jnp.int32),
            c_laden=jnp.zeros(n, jnp.int32),
            c_msgs=jnp.zeros(n, jnp.int32),
            **self._edge_state(),
            halo=halo,
            app=state,
            snap=jnp.zeros((n, self.S, 8), jnp.float32),
            snap_idx=jnp.zeros(n, jnp.int32),
        )

    # ------------------------------------------------------------------
    def _window_body(self, carry, _):
        cfg, n, E = self.cfg, self.n, self.E
        bapp = self.bapp
        comm = cfg.mode != AsyncMode.NO_COMM
        rows = self._eids
        esrc, edst = self._esrc, self._edst
        seed = carry["seed"]
        k = carry["k"]
        t = carry["t"]
        done, waiting = carry["done"], carry["waiting"]
        active = ~done & ~waiting
        halo = carry["halo"]
        drained_r = jnp.zeros(n, jnp.int32)

        if comm:
            # --- 1. edge-parallel drain (bounded FIFO, head-blocking) -----
            d = duct_drain(carry["q_avail"], carry["q_touch"],
                           carry["q_head"], carry["q_size"],
                           t[edst], active[edst], max_pops=self.max_pops,
                           clear_popped=False)
            delivered = d.drained > 0
            payload = carry["q_pay"][rows, d.pop_pos]
            # halo update: per (dst, slot) the highest delivering edge index
            # wins — a deterministic stand-in for "last fresh message wins"
            # (plain duplicate-index scatter order is unspecified in JAX)
            winner = jax.ops.segment_max(
                jnp.where(delivered, rows, -1), self._halo_key,
                num_segments=n * 4)
            has_win = winner >= 0
            fresh = payload[jnp.where(has_win, winner, 0)]
            L = halo.shape[-1]
            halo = jnp.where(has_win[:, None], fresh,
                             halo.reshape(n * 4, L)).reshape(n, 4, L)
            new_touch = d.recv_touch + 1
            dtouch = jnp.where(delivered, new_touch - carry["ptouch"], 0)
            ptouch = jnp.where(delivered, new_touch, carry["ptouch"])
            # one multi-column segment sum for all receiver-side counters
            recv_cols = jnp.stack([d.drained, delivered.astype(jnp.int32),
                                   dtouch], axis=1)
            recv_sums = jax.ops.segment_sum(recv_cols, edst, num_segments=n)
            drained_r = recv_sums[:, 0]
            c_msgs = carry["c_msgs"] + drained_r
            c_laden = carry["c_laden"] + recv_sums[:, 1]
            c_touch = carry["c_touch"] + recv_sums[:, 2]
            q_avail, q_touch = d.q_avail, d.q_touch
            q_head, q_size = d.head, d.size
        else:
            ptouch = carry["ptouch"]
            c_touch, c_laden, c_msgs = (carry["c_touch"], carry["c_laden"],
                                        carry["c_msgs"])
            q_avail, q_touch = carry["q_avail"], carry["q_touch"]
            q_head, q_size = carry["q_head"], carry["q_size"]

        # --- 2. the application's actual batched compute ------------------
        new_state, edges_out = bapp.step(carry["app"], halo, carry["steps"],
                                         seed, pids=self._pids)
        app_state = jax.tree_util.tree_map(
            lambda new, old: jnp.where(
                active.reshape((n,) + (1,) * (new.ndim - 1)), new, old),
            new_state, carry["app"])
        steps = carry["steps"] + active

        if comm:
            # --- 3. edge-parallel send attempt (drop iff full) ------------
            out_pay = edges_out[esrc, self._out_slot]
            lat = self._lat_base * lognormal_factor(
                cfg.latency_sigma, seed, STREAM_LAT, rows, k)
            s = duct_send(q_avail, q_touch, q_head, q_size,
                          t[esrc], active[esrc], lat, ptouch[self._rev],
                          capacity=cfg.buffer_capacity)
            q_pay = carry["q_pay"].at[
                jnp.where(s.accepted, rows, E), s.push_pos].set(
                out_pay, mode="drop")
            q_avail, q_touch, q_size = s.q_avail, s.q_touch, s.size
            attempted = active[esrc]
            send_cols = jnp.stack([
                attempted.astype(jnp.int32), s.accepted.astype(jnp.int32),
                (attempted & ~s.accepted).astype(jnp.int32)], axis=1)
            send_sums = jax.ops.segment_sum(send_cols, esrc, num_segments=n,
                                            indices_are_sorted=True)
            c_att = carry["c_att"] + send_sums[:, 0]
            c_ok = carry["c_ok"] + send_sums[:, 1]
            c_drop = carry["c_drop"] + send_sums[:, 2]
        else:
            q_pay = carry["q_pay"]
            c_att, c_ok, c_drop = carry["c_att"], carry["c_ok"], carry["c_drop"]

        u = dict(carry, steps=steps, halo=halo, app=app_state, ptouch=ptouch,
                 c_touch=c_touch, c_att=c_att, c_ok=c_ok, c_drop=c_drop,
                 c_laden=c_laden, c_msgs=c_msgs,
                 q_avail=q_avail, q_touch=q_touch, q_pay=q_pay,
                 q_head=q_head, q_size=q_size)
        return self._finish_window(u, active, drained_r), None

    # ------------------------------------------------------------------
    def _window_body_dense(self, carry, _):
        """One lockstep window on the dense receiver-major layout.

        Same window semantics as ``_window_body``, regrouped so one fused
        ``duct_window`` pass per window touches the ring state
        (DESIGN.md §10): the op applies the *previous* window's staged
        sends, drains at this window's clocks, and merges halos — all per
        receiver row, zero segment/scatter ops.  This window's sends are
        then *decided* eagerly against the post-drain rings (drop iff
        full, slot position, occupancy bump, all sender counters) and only
        their ring writes are staged for the next pass.  The global
        drain/send sequence — and with it every trajectory and QoS
        counter — is bitwise identical to the edge-major path.
        """
        cfg, n = self.cfg, self.n
        dd = self.lplan.degree
        bapp = self.bapp
        comm = cfg.mode != AsyncMode.NO_COMM
        seed = carry["seed"]
        k = carry["k"]
        t = carry["t"]
        active = ~carry["done"] & ~carry["waiting"]
        halo = carry["halo"]
        drained_r = jnp.zeros(n, jnp.int32)
        u = dict(carry)

        if comm:
            # --- 1. fused push-apply -> drain -> halo-select --------------
            w = duct_window(
                carry["q_avail"], carry["q_touch"], carry["q_pay"],
                carry["q_head"], carry["q_size"],
                carry["stage_pos"], carry["stage_acc"],
                carry["stage_avail"], carry["stage_touch"],
                carry["stage_pay"], t, active, max_pops=self.max_pops)
            delivered = w.drained > 0
            halo = jnp.where(w.halo_win[:, :, None], w.halo_pay, halo)
            new_touch = w.recv_touch + 1
            dtouch = jnp.where(delivered, new_touch - carry["ptouch"], 0)
            ptouch = jnp.where(delivered, new_touch, carry["ptouch"])
            # receiver counters: plain row reductions over the d in-edges
            drained_r = w.drained.sum(axis=1)
            u.update(ptouch=ptouch,
                     c_msgs=carry["c_msgs"] + drained_r,
                     c_laden=carry["c_laden"] +
                     delivered.astype(jnp.int32).sum(axis=1),
                     c_touch=carry["c_touch"] + dtouch.sum(axis=1),
                     q_avail=w.q_avail, q_touch=w.q_touch, q_pay=w.q_pay,
                     q_head=w.head, q_size=w.size)

        # --- 2. the application's actual batched compute ------------------
        new_state, edges_out = bapp.step(carry["app"], halo, carry["steps"],
                                         seed, pids=self._pids)
        app_state = jax.tree_util.tree_map(
            lambda new, old: jnp.where(
                active.reshape((n,) + (1,) * (new.ndim - 1)), new, old),
            new_state, carry["app"])
        u.update(halo=halo, app=app_state, steps=carry["steps"] + active)

        if comm:
            # --- 3. stage this window's sends; decide drop-iff-full NOW ---
            # (against the post-drain rings — exactly what the edge-major
            # send attempt sees — so counters land in this window)
            lat = self._d_lat * lognormal_factor(
                cfg.latency_sigma, seed, STREAM_LAT, self._d_eid, k)
            s_avail = t[self._d_src] + lat
            s_act = active[self._d_src]
            s_touch = u["ptouch"].reshape(-1)[self._d_rev]
            s_pay = edges_out[self._d_src, self._d_out_slot]
            q_size = u["q_size"]
            s_acc = s_act & (q_size < cfg.buffer_capacity)
            s_pos = (u["q_head"] + q_size) % cfg.buffer_capacity
            # sender counters through the out-edge table: gathers, no
            # scatters (row (p, j)'s sender is p by construction)
            ok_r = s_acc.reshape(-1)[self._d_rev].astype(
                jnp.int32).sum(axis=1)
            att_r = jnp.where(active, dd, 0)
            u.update(q_size=q_size + s_acc,
                     c_att=carry["c_att"] + att_r,
                     c_ok=carry["c_ok"] + ok_r,
                     c_drop=carry["c_drop"] + att_r - ok_r,
                     stage_pos=s_pos, stage_acc=s_acc, stage_avail=s_avail,
                     stage_touch=s_touch, stage_pay=s_pay)
        return self._finish_window(u, active, drained_r), None

    # ------------------------------------------------------------------
    def _finish_window(self, u, active, drained_r):
        """Shared window tail (both layouts): QoS snapshot scatter,
        termination, barrier release, and virtual-time advance."""
        cfg, n = self.cfg, self.n
        mode = cfg.mode
        barriered = mode in _BARRIER_MODES
        seed, t = u["seed"], u["t"]
        steps = u["steps"]
        done, waiting = u["done"], u["waiting"]
        pending = (drained_r.astype(jnp.float32) * np.float32(
            cfg.per_message_cost) +
            self._deg.astype(jnp.float32) * np.float32(cfg.per_pull_cost))
        snap_idx = u["snap_idx"]
        thr = (np.float32(cfg.snapshot_warmup) +
               snap_idx.astype(jnp.float32) * np.float32(
                   cfg.snapshot_interval))
        snap_due = active & (t >= thr) & (snap_idx < self.S)
        row = jnp.stack([
            steps.astype(jnp.float32), u["c_touch"].astype(jnp.float32),
            u["c_att"].astype(jnp.float32), u["c_ok"].astype(jnp.float32),
            u["c_drop"].astype(jnp.float32),
            u["c_laden"].astype(jnp.float32),
            u["c_msgs"].astype(jnp.float32), t], axis=1)
        snap = u["snap"].at[jnp.where(snap_due, self._pids, n),
                            snap_idx].set(row, mode="drop")
        snap_idx = snap_idx + snap_due

        # --- termination / barriers / time advance ------------------------
        newly_done = active & (t >= np.float32(cfg.duration))
        done = done | newly_done
        d_next = (np.float32(cfg.base_compute + cfg.work_units *
                             cfg.work_unit_cost) *
                  self._step_factor(seed, steps))
        barrier_seq = u["barrier_seq"]
        last_release = u["last_release"]
        pending_saved = u["pending"]

        if barriered:
            if mode == AsyncMode.BARRIER_EVERY_STEP:
                due = active & ~newly_done
            elif mode == AsyncMode.ROLLING_BARRIER:
                due = active & ~newly_done & (
                    (t - last_release) >= np.float32(cfg.rolling_quantum))
            else:
                due = active & ~newly_done & (
                    t >= (barrier_seq + 1).astype(jnp.float32) *
                    np.float32(cfg.fixed_interval))
            waiting = waiting | due
            pending_saved = jnp.where(due, pending, pending_saved)
            t = jnp.where(active & ~newly_done & ~due,
                          t + d_next + pending, t)
            release_ready = jnp.all(waiting | done) & jnp.any(waiting)
            release_t = (jnp.max(jnp.where(waiting, t, -jnp.inf)) +
                         np.float32(self._barrier_cost()))
            rel = release_ready & waiting
            t = jnp.where(rel, release_t + d_next + pending_saved, t)
            last_release = jnp.where(rel, release_t, last_release)
            barrier_seq = barrier_seq + rel
            waiting = waiting & ~release_ready
        else:
            t = jnp.where(active & ~newly_done, t + d_next + pending, t)

        u = dict(u)
        u.update(k=u["k"] + 1, t=t, done=done, waiting=waiting,
                 barrier_seq=barrier_seq, last_release=last_release,
                 pending=pending_saved, snap=snap, snap_idx=snap_idx)
        return u

    # ------------------------------------------------------------------
    def _get_runner(self):
        if self._runner is None:
            body = (self._window_body_dense if self.layout == "dense"
                    else self._window_body)

            def chunk(carry):
                carry, _ = jax.lax.scan(body, carry, None,
                                        length=self.chunk)
                return carry
            # donation lets XLA reuse the ring/state buffers across chunks
            self._runner = jax.jit(jax.vmap(chunk), donate_argnums=0)
        return self._runner

    # ------------------------------------------------------------------
    def run(self) -> SimResult:
        return self.run_replicates([self.cfg.seed])[0]

    def run_replicates(self, seeds: Sequence[int]) -> List[SimResult]:
        """One replicate per seed, dispatched as a single vmapped scan."""
        carries = [self._init_carry(int(s)) for s in seeds]
        carry = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *carries)
        runner = self._get_runner()
        windows = 0
        prev_done = None
        while windows < self._max_windows:
            carry = runner(carry)
            windows += self.chunk
            # pipelined early-exit probe: enqueue this chunk's tiny done
            # reduction, but only *read* the previous chunk's — the host
            # blocks on a result whose chunk already finished while the
            # next chunk keeps the device busy, so the dispatch pipeline
            # never drains.  Costs one extra (state-invariant: every
            # process is inactive) chunk after the run completes.
            all_done = jnp.all(carry["done"])
            if prev_done is not None and bool(prev_done):
                break
            prev_done = all_done
        carry = jax.device_get(carry)
        return [self._assemble(carry, r) for r in range(len(seeds))]

    # ------------------------------------------------------------------
    def _assemble(self, carry, r: int) -> SimResult:
        """Numpy-vectorized QoS assembly: all report fields for all
        (process, window) samples come from whole-array ops over the
        snapshot deltas — the python loop only constructs the result
        objects.  The math mirrors ``core.qos.report`` exactly (same
        guards, same operation order), so values are bit-identical to the
        per-pair path it replaces."""
        cfg, n = self.cfg, self.n
        comm = cfg.mode != AsyncMode.NO_COMM
        deg = np.asarray(self._deg, np.int64)
        snap = np.asarray(carry["snap"][r], np.float64)      # (n, S, 8)
        snap_idx = np.asarray(carry["snap_idx"][r])
        steps = np.asarray(carry["steps"][r])

        nwin = np.maximum(snap_idx - 1, 0)                   # reports/proc
        d = snap[:, 1:, :] - snap[:, :-1, :]                 # (n, S-1, 8)
        dup, dtch, datt = d[..., 0], d[..., 1], d[..., 2]
        ddrop, dladen, dmsg, dwall = (d[..., 4], d[..., 5], d[..., 6],
                                      d[..., 7])
        period = dwall / np.maximum(dup, 1)
        lat = dup / np.maximum(dtch, 1)
        wall_lat = lat * period
        fail = np.where(datt > 0, ddrop / np.maximum(datt, 1), 0.0)
        dpull = dup * deg[:, None] if comm else np.zeros_like(dup)
        opp = np.minimum(dmsg, dpull)
        clump = np.where(
            opp > 0, 1.0 - np.minimum(dladen / np.maximum(opp, 1), 1.0),
            0.0)
        t0, t1 = snap[:, :-1, 7], snap[:, 1:, 7]

        qos_by_proc: Dict[int, List[QosReport]] = {}
        all_qos: List[QosReport] = []
        for p in range(n):
            reps = [QosReport(
                simstep_period=float(period[p, i]),
                simstep_latency=float(lat[p, i]),
                walltime_latency=float(wall_lat[p, i]),
                delivery_failure_rate=float(fail[p, i]),
                delivery_clumpiness=float(clump[p, i]),
                t_start=float(t0[p, i]), t_end=float(t1[p, i]))
                for i in range(int(nwin[p]))]
            qos_by_proc[p] = reps
            all_qos.extend(reps)

        app_state = jax.tree_util.tree_map(lambda x: x[r], carry["app"])
        return SimResult(
            updates=[int(u) for u in steps],
            horizon=cfg.duration,
            quality=self.bapp.quality(app_state),
            qos=all_qos,
            qos_by_process=qos_by_proc,
            dropped=int(np.sum(carry["c_drop"][r])),
            sent=int(np.sum(carry["c_att"][r])),
        )
