"""Best-effort ducts for the discrete-event runtime (paper-faithful).

Semantics mirror Conduit's MPI backend (paper §II-F2):
  - bounded send buffer: a send is DROPPED iff the buffer is full
    (messages that make it into the buffer are guaranteed delivery);
  - messages become pullable after a (jittered) link latency;
  - pulls bulk-drain everything available (MPI_Testsome semantics), which
    interrupts the producer-consumer feedback spiral the paper describes.

Counters feed the QoS metric suite (core/qos.py).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, List, Optional, Tuple

from repro.core.qos import Counters


@dataclasses.dataclass
class Message:
    payload: Any
    send_time: float
    avail_time: float
    touch: int


class Duct:
    """Unidirectional best-effort channel sender -> receiver."""

    def __init__(self, capacity: int, latency_fn, name: str = ""):
        self.capacity = capacity
        self.latency_fn = latency_fn  # (send_time) -> latency seconds
        self.name = name
        self.queue: deque = deque()
        self.inlet = Counters()   # sender-side counters
        self.outlet = Counters()  # receiver-side counters

    # -- sender side --------------------------------------------------------
    def try_send(self, payload, now: float, touch: int) -> bool:
        self.inlet.attempted_send_count += 1
        if len(self.queue) >= self.capacity:
            self.inlet.dropped_send_count += 1
            return False  # best-effort: drop, no retry
        self.inlet.successful_send_count += 1
        lat = self.latency_fn(now)
        self.queue.append(Message(payload, now, now + lat, touch))
        return True

    # -- receiver side ------------------------------------------------------
    def pull(self, now: float) -> List[Message]:
        """Bulk-drain all messages available by ``now``."""
        self.outlet.pull_attempt_count += 1
        out = []
        while self.queue and self.queue[0].avail_time <= now:
            out.append(self.queue.popleft())
        if out:
            self.outlet.laden_pull_count += 1
            self.outlet.message_count += len(out)
        return out

    def latest(self, now: float) -> Tuple[Optional[Message], int]:
        """Drain and return only the freshest message (+ count drained).

        Hot-path form of :meth:`pull`: identical counter semantics, but no
        intermediate list — the empty/nothing-arrived case is a single
        comparison.
        """
        self.outlet.pull_attempt_count += 1
        q = self.queue
        if not q or q[0].avail_time > now:
            return None, 0
        popleft = q.popleft
        msg = popleft()
        drained = 1
        while q and q[0].avail_time <= now:
            msg = popleft()
            drained += 1
        self.outlet.laden_pull_count += 1
        self.outlet.message_count += drained
        return msg, drained

    @property
    def backlog(self) -> int:
        return len(self.queue)
