"""Driver for the paper's four experiment families (DESIGN.md §5).

  modes          asynchronicity-mode sweep: update rate + solution quality
                 under barrier / rolling / fixed / best-effort / no-comm
                 (paper §III-A/B, claims C1 + C2)
  weak_scaling   QoS distributions while scaling the process count at fixed
                 work per process (paper §III-F, claim C3)
  intensivity    communication-intensivity sweep: simels per process from
                 maximal (1) down to the benchmark parameterization (2048)
                 (paper §III-C/E)
  faults         an apparently-faulty host: extreme degradation inside its
                 clique, stable global medians (paper §III-G, claim C4)

Every family reports per-process QoS *distributions* — median + tail
percentiles over (process, window) samples — because under best-effort
communication the distribution, not a scalar, is the result.

Every family runs on either simulation backend (``--engine event`` — the
discrete-event reference, or ``--engine jax`` — the vectorized windowed-time
engine, DESIGN.md §7); ``--replicates R`` sweeps R seeds, dispatched as one
vmapped scan on the jax engine.  ``--shards S`` partitions the population
over an S-device mesh (DESIGN.md §8) with the seed axis vmapped inside
each shard; any shard count reproduces the single-device trajectories
exactly.  ``--superstep-windows W`` fuses W windows per exchange (sharded:
one packed ppermute per superstep, DESIGN.md §9; unsharded: the W-fused
dense megakernel with one ring commit per superstep, DESIGN.md §13 —
bitwise-identical either way at W=1, and the unsharded fusion at any W),
``--scheduler pipelined`` double-buffers the sharded exchange so it
overlaps the next superstep's interior windows (boundary messages arrive
one superstep later — honest latency the QoS stream observes, DESIGN.md
§12 / docs/QOS.md), and ``--qos-interval`` pins the snapshot spacing of
the time-resolved ``qos_timeseries`` every row carries.

All of these axes travel as one frozen
:class:`~repro.runtime.config.RunConfig` (built from the CLI namespace by
``RunConfig.from_args``, stamped into every result row by ``to_dict``).

CLI::

    PYTHONPATH=src python -m repro.runtime.experiments \
        --topology torus --procs 64 256 --engine jax

runs weak scaling on a torus at 64 and 256 processes; ``--family all``
runs every family.  See EXPERIMENTS.md for the full matrix.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Dict, List, Optional, Sequence

from repro.core.modes import AsyncMode
from repro.core.qos import METRICS, aggregate_reports, aggregate_timeseries
from repro.core.slo import SloPolicy
from repro.runtime.config import RunConfig
from repro.runtime.engine import (ENGINES, make_engine, run_replicates,
                                  validate_run_config)
from repro.runtime.faults import (crashed_host, faulty_host, flapping_host,
                                  lossy_host)
from repro.runtime.service import default_timeline, run_service
from repro.runtime.simulator import SimConfig
from repro.runtime.topologies import TOPOLOGIES, Topology, make_topology

PERCENTILES = (50, 95)

_UNITS = {"simstep_period": ("us", 1e6), "simstep_latency": ("steps", 1.0),
          "walltime_latency": ("us", 1e6), "delivery_failure_rate": ("", 1.0),
          "delivery_clumpiness": ("", 1.0)}


def make_app(name: str, n: int, simels: int, topology: Optional[Topology],
             seed: int = 0, initial_state=None):
    if name == "graphcolor":
        from repro.apps.graphcolor import GraphColorApp, GraphColorConfig
        return GraphColorApp(
            GraphColorConfig(n_processes=n, nodes_per_process=simels,
                             seed=seed), topology=topology,
            initial_state=initial_state)
    if name == "evo":
        # evo carries no state across service epochs yet; it restarts fresh
        from repro.apps.evo import EvoApp, EvoConfig
        return EvoApp(EvoConfig(n_processes=n, cells_per_process=simels,
                                seed=seed), topology=topology)
    raise ValueError(f"unknown app {name!r} (graphcolor|evo)")


def _sim_config(args, n: int, mode: AsyncMode = AsyncMode.BEST_EFFORT,
                **overrides) -> SimConfig:
    # windows shrink with the horizon so every scale yields >= ~6 windows;
    # --qos-interval pins the snapshot spacing instead (time-resolved QoS)
    warmup = args.duration / 6
    interval = (args.qos_interval if args.qos_interval
                else args.duration / 12)
    base = dict(mode=mode, duration=args.duration,
                base_compute=args.base_compute,
                base_latency=args.base_latency,
                intra_node_latency=args.intra_latency,
                snapshot_warmup=warmup, snapshot_interval=interval,
                buffer_capacity=args.buffer, seed=args.seed,
                barrier_timeout=args.barrier_timeout)
    base.update(overrides)
    return SimConfig(**base)


def _distributions(res) -> Dict[str, Dict[str, float]]:
    return aggregate_reports(res.qos, percentiles=PERCENTILES)


def _print_distributions(dist, indent: str = "    "):
    for m in METRICS:
        unit, scale = _UNITS[m]
        parts = []
        for key, v in dist[m].items():
            if v is None:
                parts.append(f"{key}=n/a")
            else:
                parts.append(f"{key}={v * scale:.3f}{unit}")
        print(f"{indent}{m:<24} " + "  ".join(parts))


def _topology_for(args, n: int) -> Topology:
    kw = {}
    if args.topology == "cliques" and args.clique_size:
        kw["clique_size"] = args.clique_size
    return make_topology(args.topology, n, **kw)


def _run_config(args) -> RunConfig:
    """The frozen strategy selection every family launches with.

    One :class:`RunConfig` is built from the CLI namespace in ``main``
    (the flag names match the field names), validated once against the
    engine registry, and stamped into every result row via ``to_dict``.
    """
    return RunConfig.from_args(args)


# ---------------------------------------------------------------------------
# Families
# ---------------------------------------------------------------------------
def run_modes(args) -> List[dict]:
    n = args.procs[0]
    topo = _topology_for(args, n)
    print(f"[modes] app={args.app} topology={topo.name} n={n} "
          f"simels={args.simels} engine={args.engine}")
    rows = []
    for mode in AsyncMode:
        app = make_app(args.app, n, args.simels, topo, args.seed)
        res = make_engine(args.run, app,
                          _sim_config(args, n, mode=mode)).run()
        dist = _distributions(res)
        row = dict(family="modes", mode=int(mode), n=n,
                   topology=topo.name, engine=args.engine,
                   run=args.run.to_dict(),
                   rate_per_cpu=res.update_rate_per_cpu,
                   quality=res.quality,
                   delivery_failure_rate=res.delivery_failure_rate,
                   qos=dist)
        rows.append(row)
        print(f"  mode {int(mode)} ({mode.description}): "
              f"{res.update_rate_per_cpu:9.0f} upd/s/cpu  "
              f"quality={res.quality:.3f}  fail={res.delivery_failure_rate:.3f}")
    return rows


def run_weak_scaling(args) -> List[dict]:
    print(f"[weak_scaling] app={args.app} topology={args.topology} "
          f"simels={args.simels} duration={args.duration}s "
          f"engine={args.engine} replicates={args.replicates} "
          f"shards={args.shards} superstep={args.superstep_windows} "
          f"scheduler={args.scheduler}")
    rows = []
    for n in args.procs:
        topo = _topology_for(args, n)
        cfg = _sim_config(args, n)
        t0 = time.perf_counter()
        # seeds omitted: the RunConfig's replicates field sizes the sweep,
        # rooted at cfg.seed
        results = run_replicates(
            args.run,
            lambda s: make_app(args.app, n, args.simels, topo, s), cfg)
        wall = time.perf_counter() - t0
        # QoS distribution pools (process, window) samples over replicates
        all_qos = [q for res in results for q in res.qos]
        dist = aggregate_reports(all_qos, percentiles=PERCENTILES)
        # time-resolved stream: interval i pools every replicate's
        # processes' i-th observation window
        series = aggregate_timeseries(
            [reps for res in results for reps in res.qos_by_process.values()],
            percentiles=PERCENTILES)
        rate = sum(r.update_rate_per_cpu for r in results) / len(results)
        updates = sum(sum(r.updates) for r in results)
        rows.append(dict(family="weak_scaling", n=n, topology=topo.name,
                         simels=args.simels, engine=args.engine,
                         run=args.run.to_dict(),
                         shards=args.shards,
                         superstep_windows=args.superstep_windows,
                         scheduler=args.scheduler,
                         replicates=args.replicates, rate_per_cpu=rate,
                         wall_seconds=wall, qos=dist,
                         qos_timeseries=series))
        print(f"  n={n:<5} ({topo.name}, {updates} updates "
              f"in {wall:.1f}s wall, {len(series)} QoS intervals)")
        _print_distributions(dist)
    return rows


def run_intensivity(args) -> List[dict]:
    n = args.procs[0]
    topo = _topology_for(args, n)
    sweep = args.intensivity_simels
    print(f"[intensivity] app={args.app} topology={topo.name} n={n} "
          f"simels sweep={sweep} engine={args.engine}")
    rows = []
    for simels in sweep:
        # heavier blocks cost more virtual compute per update (2048 simels
        # ~ 200us, matching the benchmark parameterization)
        base = args.base_compute * (1 + simels / 160)
        app = make_app(args.app, n, simels, topo, args.seed)
        res = make_engine(args.run, app,
                          _sim_config(args, n, base_compute=base)).run()
        dist = _distributions(res)
        rows.append(dict(family="intensivity", n=n, simels=simels,
                         topology=topo.name, engine=args.engine,
                         run=args.run.to_dict(),
                         rate_per_cpu=res.update_rate_per_cpu, qos=dist))
        print(f"  simels/process={simels}")
        _print_distributions(dist)
    return rows


def _fault_model(args, topo, host):
    """Build the --fault-kind model for the faults family (DESIGN.md §14):
    slowdown = the paper's degraded host (compute + link factors), crash =
    the host's processes die without churn splicing (neighbors keep
    sending into dead ducts), lossy = clique links drop each message with
    probability --loss-prob, flap = clique links cycle down/up on the
    deterministic hash schedule with down fraction --loss-prob."""
    if args.fault_kind == "crash":
        return crashed_host(topo, host)
    if args.fault_kind == "lossy":
        return lossy_host(topo, host, args.loss_prob)
    if args.fault_kind == "flap":
        return flapping_host(topo, host, args.loss_prob)
    return faulty_host(topo, host, args.fault_compute, args.fault_link)


def run_faults(args) -> List[dict]:
    n = args.procs[0]
    topo = _topology_for(args, n)
    host = args.faulty_host if args.faulty_host is not None else topo.n_nodes // 2
    victims = set(topo.host_pids(host))
    clique = set()
    for p in victims:
        clique.update(topo.clique_of(p))
    print(f"[faults] app={args.app} topology={topo.name} n={n} "
          f"faulty host={host} kind={args.fault_kind} ({len(victims)} "
          f"procs, clique of {len(clique)}) engine={args.engine}")

    rows = []
    for label, faults in (("without_fault", None),
                          ("with_fault", _fault_model(args, topo, host))):
        app = make_app(args.app, n, args.simels, topo, args.seed)
        res = make_engine(args.run, app, _sim_config(args, n),
                          faults).run()
        groups = {
            "global": res.qos,
            "clique": [q for p in clique for q in res.qos_by_process[p]],
            "rest": [q for p in range(n) if p not in clique
                     for q in res.qos_by_process[p]],
        }
        by_proc = {
            "global": list(res.qos_by_process.values()),
            "clique": [res.qos_by_process[p] for p in sorted(clique)],
            "rest": [res.qos_by_process[p] for p in range(n)
                     if p not in clique],
        }
        row = dict(family="faults", label=label, n=n, topology=topo.name,
                   faulty_host=host, fault_kind=args.fault_kind,
                   engine=args.engine,
                   run=args.run.to_dict(),
                   qos={g: aggregate_reports(reps, PERCENTILES)
                        for g, reps in groups.items()},
                   qos_timeseries={
                       g: aggregate_timeseries(reps, PERCENTILES)
                       for g, reps in by_proc.items()})
        rows.append(row)
        print(f"  {label}:")
        for g in ("global", "clique", "rest"):
            print(f"   {g}:")
            _print_distributions(row["qos"][g], indent="      ")
    return rows


def run_serve(args) -> List[dict]:
    """Live-service scenario: open-loop traffic + churn + SLO verdicts.

    One long-running serve on the first ``--procs`` count: the
    ``--traffic`` arrival shape feeds every process's work queue at
    ``--arrival-rate``, ``--churn`` incidents (host fault/heal, process
    leave/join) split the run into epochs with patched topologies, and
    the per-interval QoS stream is scored against the ``--slo-*`` budgets
    (``runtime/service.py`` / ``core/slo.py``).
    """
    n = args.procs[0]
    topo = _topology_for(args, n)
    timeline = default_timeline(topo, args.churn, args.duration,
                                args.fault_compute, args.fault_link)
    policy = SloPolicy(latency_p99_budget=args.slo_latency,
                       failure_p99_budget=args.slo_failure,
                       burn_window=args.burn_window,
                       burn_threshold=args.burn_threshold)
    cfg = _sim_config(args, n, arrival_rate=args.arrival_rate,
                      arrival_shape=args.traffic)
    print(f"[serve] app={args.app} topology={topo.name} n={n} "
          f"traffic={args.traffic}@{args.arrival_rate:g}/s churn={args.churn} "
          f"engine={args.engine} slo=(lat_p99<={policy.latency_p99_budget}, "
          f"fail_p99<={policy.failure_p99_budget})")
    out = run_service(
        args.run,
        lambda topology, s, init_state=None: make_app(
            args.app, topology.n, args.simels, topology, s,
            initial_state=init_state),
        cfg, topo, timeline, policy)
    for ep in out["epochs"]:
        print(f"  epoch {ep['epoch']}: t=[{ep['t_start']:.4f}, "
              f"{ep['t_end']:.4f}) procs={ep['n_procs']} "
              f"absent={ep['absent_pids']} faulty={ep['faulty_hosts']} "
              f"({ep['intervals']} intervals)")
    s = out["slo"]["summary"]
    svc = out["service"]
    print(f"  slo: {s['intervals']} intervals, {s['breaches']} breaches, "
          f"{s['no_data']} no-data, max_burn={s['max_burn_rate']:.2f} "
          f"-> {'OK' if s['ok'] else 'BREACH'}")
    print(f"  service: {svc['arrivals']} arrivals, {svc['served']} served, "
          f"{svc['backlog']} backlogged")
    _print_distributions(out["qos"])
    row = dict(family="serve", n=n, topology=topo.name, engine=args.engine,
               run=args.run.to_dict(), traffic=args.traffic,
               arrival_rate=args.arrival_rate, churn=args.churn,
               policy=dataclasses.asdict(policy), **out)
    return [row]


FAMILIES = {
    "modes": run_modes,
    "weak_scaling": run_weak_scaling,
    "intensivity": run_intensivity,
    "faults": run_faults,
    "serve": run_serve,
}


# ---------------------------------------------------------------------------
def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.runtime.experiments",
        description="Run the paper's experiment families on the "
                    "discrete-event best-effort runtime.")
    p.add_argument("--family", default="weak_scaling",
                   choices=[*FAMILIES, "all"])
    p.add_argument("--engine", default="event", choices=sorted(ENGINES),
                   help="simulation backend: event (discrete-event "
                        "reference) or jax (vectorized windowed-time)")
    p.add_argument("--replicates", type=int, default=1,
                   help="seeds per weak-scaling point (one vmapped "
                        "dispatch on --engine jax)")
    p.add_argument("--shards", type=int, default=1,
                   help="partition the population over this many mesh "
                        "devices (--engine jax; the seed axis vmaps inside "
                        "each shard).  On CPU set XLA_FLAGS="
                        "--xla_force_host_platform_device_count=S")
    p.add_argument("--superstep-windows", type=int, default=1,
                   help="windows fused per exchange (self-paced "
                        "scheduler, DESIGN.md §9/§13).  Sharded: boundary "
                        "traffic batches into one packed ppermute per "
                        "superstep, cutting the collective count ~W x.  "
                        "Unsharded: the W-fused dense megakernel commits "
                        "ring writes once per superstep.  1 = per-window "
                        "exchange (bitwise-identical trajectories)")
    p.add_argument("--scheduler", default="auto",
                   choices=["auto", "window", "superstep", "pipelined"],
                   help="exchange cadence strategy (DESIGN.md §11/§12/"
                        "§13): window = exchange every lockstep window, "
                        "superstep = batched every --superstep-windows "
                        "windows (sharded: one collective per superstep; "
                        "unsharded: the W-fused dense megakernel, "
                        "bitwise-identical), pipelined = double-buffered "
                        "— superstep k's exchange overlaps superstep "
                        "k+1's interior windows, boundary messages arrive "
                        "one superstep later (honest added latency the "
                        "QoS stream observes; see docs/QOS.md; needs "
                        "--shards > 1).  superstep/pipelined need "
                        "--superstep-windows > 1; auto follows "
                        "--superstep-windows")
    p.add_argument("--layout", default="auto",
                   choices=["auto", "dense", "edge"],
                   help="duct ring layout for --engine jax (DESIGN.md "
                        "§10/§13): dense = the degree-bucketed "
                        "receiver-major fast path (zero segment/scatter "
                        "ops per window; exact-degree buckets on ring/"
                        "torus, padded power-of-two buckets on smallworld/"
                        "cliques), edge = the general edge-major path.  "
                        "auto resolves to dense on every built-in "
                        "topology.  Trajectories are bitwise identical "
                        "either way")
    p.add_argument("--qos-interval", type=float, default=None,
                   help="QoS snapshot spacing in virtual seconds for the "
                        "time-resolved stream (default: duration/12); "
                        "rows carry a qos_timeseries with per-interval "
                        "distributions")
    p.add_argument("--topology", default="torus", choices=sorted(TOPOLOGIES))
    p.add_argument("--procs", type=int, nargs="+", default=[64, 256],
                   help="process counts (weak_scaling sweeps them; other "
                        "families use the first)")
    p.add_argument("--app", default="graphcolor",
                   choices=["graphcolor", "evo"])
    p.add_argument("--simels", type=int, default=1,
                   help="simulation elements per process (1 = maximal "
                        "communication intensivity)")
    p.add_argument("--duration", type=float, default=0.05,
                   help="virtual seconds per run")
    p.add_argument("--base-compute", type=float, default=15e-6)
    p.add_argument("--base-latency", type=float, default=550e-6)
    p.add_argument("--intra-latency", type=float, default=None,
                   help="same-host link latency (enables the hierarchical "
                        "link model; default: flat)")
    p.add_argument("--buffer", type=int, default=64)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--clique-size", type=int, default=None)
    p.add_argument("--intensivity-simels", type=int, nargs="+",
                   default=[1, 64, 2048])
    p.add_argument("--faulty-host", type=int, default=None)
    p.add_argument("--fault-compute", type=float, default=30.0)
    p.add_argument("--fault-link", type=float, default=30.0)
    p.add_argument("--fault-kind", default="slowdown",
                   choices=["slowdown", "crash", "lossy", "flap"],
                   help="faults-family fault type (DESIGN.md §14): "
                        "slowdown = the paper's degraded host "
                        "(--fault-compute/--fault-link factors), crash = "
                        "the host's processes die mid-run (no churn "
                        "splicing — neighbors keep sending into dead "
                        "ducts), lossy = clique links drop messages with "
                        "probability --loss-prob, flap = clique links "
                        "cycle down/up deterministically with down "
                        "fraction --loss-prob")
    p.add_argument("--loss-prob", type=float, default=0.05,
                   help="per-send drop probability for --fault-kind lossy "
                        "(and the down fraction for flap)")
    p.add_argument("--barrier-timeout", type=float, default=0.0,
                   help="quarantine threshold tau in virtual seconds for "
                        "barrier modes (DESIGN.md §14): a process whose "
                        "next barrier arrival lags the cohort front by "
                        "more than tau is excluded from the release (and "
                        "readmitted with hysteresis once it catches up "
                        "within tau/2).  0 = plain barriers; crashed "
                        "processes are excluded under any finite tau")
    # --- live-service family (--family serve) ---------------------------
    p.add_argument("--traffic", default="poisson",
                   choices=["poisson", "bursty", "diurnal"],
                   help="open-loop arrival shape feeding each process's "
                        "work queue (runtime/service.py)")
    p.add_argument("--arrival-rate", type=float, default=1e5,
                   help="mean arrivals per process per virtual second")
    p.add_argument("--churn", type=int, default=0,
                   help="churn incidents spread over the run: even "
                        "incidents fault+heal a host, odd ones make a "
                        "process leave+rejoin (duct rings spliced via "
                        "patch_topology)")
    p.add_argument("--slo-latency", type=float, default=50.0,
                   help="per-interval p99 simstep-latency budget (updates "
                        "per one-way delivery)")
    p.add_argument("--slo-failure", type=float, default=0.35,
                   help="per-interval p99 delivery-failure-rate budget")
    p.add_argument("--burn-window", type=int, default=5,
                   help="trailing data-bearing intervals in the burn-rate "
                        "window")
    p.add_argument("--burn-threshold", type=float, default=0.5,
                   help="burn rate above which an interval is marked "
                        "burning (sustained breach)")
    p.add_argument("--json", default=None, help="write rows to this path")
    return p


def main(argv: Optional[Sequence[str]] = None) -> List[dict]:
    parser = build_parser()
    args = parser.parse_args(argv)
    # one frozen strategy carrier for every family; domain checks happen
    # in RunConfig, cross-axis rules once against the engine registry —
    # both before any app or JAX machinery is built
    try:
        args.run = _run_config(args)
        validate_run_config(args.run)
    except ValueError as e:
        parser.error(str(e))
    families = list(FAMILIES) if args.family == "all" else [args.family]
    rows: List[dict] = []
    t0 = time.perf_counter()
    for fam in families:
        rows.extend(FAMILIES[fam](args))
    print(f"done in {time.perf_counter() - t0:.1f}s wall")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1, default=float)
        print(f"wrote {args.json}")
    return rows


if __name__ == "__main__":
    main()
