"""Pluggable process topologies for the discrete-event runtime (DESIGN.md §3).

A :class:`Topology` is an immutable adjacency structure plus a host
assignment (``node_of``), so the simulator's hierarchical link model can
price intra-node and inter-node hops differently (Bienz et al.,
arXiv:1806.02030) and the fault injector can degrade a whole physical node
and its communication clique (the paper's lac-417 scenario, §III-G).

Four families cover the paper's experiments plus scaling stress shapes:

  ring          degree-2 cycle — cheapest per-process communication
  torus         near-square 2-D torus — the benchmark apps' native shape
  cliques       clique-of-cliques: full connectivity within a host, plus
                corresponding-member links to the neighboring hosts
  smallworld    ring lattice + deterministic long chords — dense, low
                diameter; stresses clumpiness under load

All builders are deterministic (counter-based splitmix64 hashing, no RNG
objects) and validated: symmetric, self-loop-free, connected.
"""
from __future__ import annotations

import dataclasses
import logging
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.runtime.faults import _splitmix64

logger = logging.getLogger(__name__)


#: Halo slot order shared by the apps and the vectorized engine.
DIRS = ("n", "s", "w", "e")
#: Opposite-slot index (n<->s, w<->e): the edge row a sender publishes for a
#: receiver whose halo slot for that sender is ``slot`` is ``OPP_IDX[slot]``.
OPP_IDX = (1, 0, 3, 2)


def halo_slot_map(neighbors) -> Dict[int, int]:
    """Round-robin halo-slot assignment for an injected topology.

    Numeric core of ``apps.graphcolor.direction_map``: sorted neighbors
    cycle over the four halo slots, so several neighbors may share a slot
    (last fresh message wins — best-effort staleness semantics).  Both the
    per-fragment apps and the vectorized engine derive their slot wiring
    from this one function.
    """
    return {nb: i % 4 for i, nb in enumerate(sorted(neighbors))}


def near_square(n: int) -> Tuple[int, int]:
    """Near-square factorization of ``n`` (rows <= cols)."""
    a = int(math.sqrt(n))
    while n % a:
        a -= 1
    return a, n // a


@dataclasses.dataclass(frozen=True)
class Topology:
    """Immutable communication graph with a physical-host assignment."""

    name: str
    n: int
    neighbors: Tuple[Tuple[int, ...], ...]   # adjacency, index = pid
    node_of: Tuple[int, ...]                 # pid -> physical host id

    def as_dict(self) -> Dict[int, List[int]]:
        return {i: list(nbs) for i, nbs in enumerate(self.neighbors)}

    def degree(self, pid: int) -> int:
        return len(self.neighbors[pid])

    @property
    def n_edges(self) -> int:
        return sum(len(nbs) for nbs in self.neighbors) // 2

    @property
    def n_nodes(self) -> int:
        return len(set(self.node_of))

    def same_node(self, a: int, b: int) -> bool:
        return self.node_of[a] == self.node_of[b]

    def host_pids(self, host: int) -> List[int]:
        return [p for p in range(self.n) if self.node_of[p] == host]

    def clique_of(self, pid: int) -> List[int]:
        """The pid's communication clique: itself plus direct neighbors."""
        return sorted({pid, *self.neighbors[pid]})

    def validate(self) -> "Topology":
        for i, nbs in enumerate(self.neighbors):
            assert i not in nbs, f"self-loop at {i}"
            assert len(set(nbs)) == len(nbs), f"duplicate edge at {i}"
            for j in nbs:
                assert i in self.neighbors[j], f"asymmetric edge {i}->{j}"
        if self.n > 1:
            seen = {0}
            frontier = [0]
            while frontier:
                nxt = []
                for p in frontier:
                    for q in self.neighbors[p]:
                        if q not in seen:
                            seen.add(q)
                            nxt.append(q)
                frontier = nxt
            assert len(seen) == self.n, "topology is disconnected"
        return self


def _freeze(adj: Sequence[Sequence[int]], name: str,
            node_of: Sequence[int]) -> Topology:
    neighbors = tuple(tuple(sorted(set(nbs))) for nbs in adj)
    return Topology(name, len(neighbors), neighbors,
                    tuple(node_of)).validate()


def _default_nodes(n: int, procs_per_node: int) -> List[int]:
    return [p // max(procs_per_node, 1) for p in range(n)]


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------
def ring(n: int, procs_per_node: int = 4) -> Topology:
    assert n >= 2, "ring needs >= 2 processes"
    adj = [[(i - 1) % n, (i + 1) % n] for i in range(n)]
    return _freeze(adj, f"ring{n}", _default_nodes(n, procs_per_node))


def torus(n: int, procs_per_node: int = 4) -> Topology:
    """Near-square 2-D torus — matches the apps' native halo structure."""
    assert n >= 2, "torus needs >= 2 processes"
    gh, gw = near_square(n)
    adj: List[List[int]] = [[] for _ in range(n)]
    for p in range(n):
        r, c = divmod(p, gw)
        for q in (((r - 1) % gh) * gw + c, ((r + 1) % gh) * gw + c,
                  r * gw + (c - 1) % gw, r * gw + (c + 1) % gw):
            if q != p:
                adj[p].append(q)
    return _freeze(adj, f"torus{gh}x{gw}", _default_nodes(n, procs_per_node))


def cliques(n: int, clique_size: int = 8) -> Topology:
    """Clique-of-cliques: each host's processes are fully connected, and
    member k of each clique links to member k of the two adjacent cliques
    (a ring over hosts).  ``node_of`` is the clique index, so the faulty-node
    experiment degrades exactly one clique."""
    assert n >= 2
    assert clique_size >= 1
    assert n % clique_size == 0, "n must be a multiple of clique_size"
    n_cliques = n // clique_size
    adj: List[List[int]] = [[] for _ in range(n)]
    for p in range(n):
        cq, k = divmod(p, clique_size)
        for k2 in range(clique_size):
            if k2 != k:
                adj[p].append(cq * clique_size + k2)
        if n_cliques > 1:
            for d in (-1, +1):
                q = ((cq + d) % n_cliques) * clique_size + k
                if q != p:
                    adj[p].append(q)
    return _freeze(adj, f"cliques{n_cliques}x{clique_size}",
                   [p // clique_size for p in range(n)])


def smallworld(n: int, k: int = 4, chords: int = 2, seed: int = 0,
               procs_per_node: int = 4) -> Topology:
    """Dense small-world: ring lattice (k nearest, k/2 each side) plus
    ``chords`` deterministic long-range links per process.  Chord endpoints
    come from splitmix64 hashing, so the graph is a pure function of
    (n, k, chords, seed)."""
    assert n >= 4, "smallworld needs >= 4 processes"
    k = max(2, min(k, n - 1)) // 2 * 2
    adj: List[set] = [set() for _ in range(n)]
    for p in range(n):
        for d in range(1, k // 2 + 1):
            adj[p].add((p + d) % n)
            adj[p].add((p - d) % n)
    for p in range(n):
        for c in range(chords):
            h = _splitmix64(_splitmix64(seed * 1_000_003 + p) ^ (c + 1))
            # offset in [k//2 + 1, n - k//2 - 1]: always a non-lattice edge
            span = n - k - 1
            if span <= 0:
                break
            q = (p + k // 2 + 1 + h % span) % n
            if q != p:
                adj[p].add(q)
                adj[q].add(p)
    return _freeze([sorted(s) for s in adj], f"smallworld{n}k{k}",
                   _default_nodes(n, procs_per_node))


# ---------------------------------------------------------------------------
# Shard partitioning (DESIGN.md §8)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Contiguous-block partition of a topology's processes over shards.

    ``perm`` is the reordering: position ``pos`` in the flat sharded layout
    holds original process ``perm[pos]``, and shard ``s`` owns positions
    ``[s*m, (s+1)*m)`` with ``m = n // n_shards``.  ``cut`` counts directed
    cross-shard edges — the boundary traffic the sharded engine exchanges
    per window; everything else stays shard-local.
    """

    n_shards: int
    perm: Tuple[int, ...]      # position -> original pid
    inv: Tuple[int, ...]       # original pid -> position
    shard_of: Tuple[int, ...]  # original pid -> shard
    cut: int                   # directed cross-shard edge count

    @property
    def procs_per_shard(self) -> int:
        return len(self.perm) // self.n_shards


def _cut_size(topo: Topology, order: Sequence[int], m: int) -> int:
    pos = [0] * topo.n
    for p_at, pid in enumerate(order):
        pos[pid] = p_at
    return sum(1 for src in range(topo.n) for dst in topo.neighbors[src]
               if pos[src] // m != pos[dst] // m)


def _bfs_order(topo: Topology) -> List[int]:
    """BFS ordering (sorted-neighbor tie-break) — clusters graph
    neighborhoods into consecutive positions for irregular topologies."""
    seen = [False] * topo.n
    order: List[int] = []
    for root in range(topo.n):
        if seen[root]:
            continue
        seen[root] = True
        frontier = [root]
        while frontier:
            order.extend(frontier)
            nxt = []
            for p in frontier:
                for q in topo.neighbors[p]:
                    if not seen[q]:
                        seen[q] = True
                        nxt.append(q)
            frontier = nxt
    return order


def contiguous_partition(topo: Topology, n_shards: int) -> ShardPlan:
    """Partition processes into ``n_shards`` contiguous equal blocks.

    Candidate orderings — identity (the builders' native row-major/clique
    order, already block-local for ring/torus/cliques) and BFS (clusters
    irregular graphs) — are scored by directed cross-shard edge count and
    the thinner cut wins (identity on ties, keeping the sharded layout
    aligned with the unsharded engine wherever possible).

    Reordering changes nothing about the simulated system — RNG streams
    and halo-scatter tie-breaks stay keyed by *original* pid / canonical
    edge id (DESIGN.md §8) — only about which shard owns which process.
    """
    n = topo.n
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if n % n_shards:
        raise ValueError(
            f"n_shards={n_shards} must divide the process count n={n}")
    m = n // n_shards
    identity = list(range(n))
    order = identity
    if n_shards > 1:
        bfs = _bfs_order(topo)
        if _cut_size(topo, bfs, m) < _cut_size(topo, identity, m):
            order = bfs
    inv = [0] * n
    for p_at, pid in enumerate(order):
        inv[pid] = p_at
    shard_of = tuple(inv[pid] // m for pid in range(n))
    return ShardPlan(n_shards=n_shards, perm=tuple(order), inv=tuple(inv),
                     shard_of=shard_of, cut=_cut_size(topo, order, m))


# ---------------------------------------------------------------------------
# Duct layout planning (DESIGN.md §10)
# ---------------------------------------------------------------------------
#: layouts a caller may request; "auto" resolves to dense on every topology
#: (the bucketed plan below covers irregular degrees); "edge" keeps the
#: fully general edge-major layout for comparison runs and parity tests
LAYOUTS = ("auto", "dense", "edge")


def regular_degree(topo: Topology) -> Optional[int]:
    """The common in-degree if every process has the same one, else None."""
    degs = {len(nbs) for nbs in topo.neighbors}
    return degs.pop() if len(degs) == 1 else None


def next_pow2(k: int) -> int:
    """Smallest power of two >= k (k >= 1)."""
    return 1 << (int(k) - 1).bit_length()


def canonical_edges(topo: Topology):
    """Source-major enumeration of directed edges — THE canonical edge id
    order every engine keys per-edge RNG streams and halo tie-breaks by
    (DESIGN.md §7/§8/§10).  Returns ``(esrc, edst, index)`` lists/dict with
    ``index[(src, dst)]`` the canonical id.  Single definition so the
    engines and the dense layout plan can never drift apart."""
    esrc: List[int] = []
    edst: List[int] = []
    index: Dict[Tuple[int, int], int] = {}
    for src in range(topo.n):
        for dst in topo.neighbors[src]:
            index[(src, dst)] = len(esrc)
            esrc.append(src)
            edst.append(dst)
    return esrc, edst, index


@dataclasses.dataclass(frozen=True, eq=False)
class DenseBucket:
    """One degree bucket of the dense plan: a contiguous slab of padded
    receiver row blocks.  Member ``i`` (ascending pid) owns flat rows
    ``start + i*deg .. start + (i+1)*deg - 1``."""

    deg: int                 # padded rows per member receiver
    start: int               # first flat row of this bucket's slab
    members: np.ndarray      # (nb,) member pids, ascending


@dataclasses.dataclass(frozen=True, eq=False)
class LayoutPlan:
    """How the vectorized engines lay duct rings out in memory.

    ``edge`` is the fully general edge-major layout: one ring per directed
    edge in canonical enumeration order, receiver bookkeeping via
    segment_sum/segment_max over edge rows.  ``dense`` is the
    degree-bucketed receiver-major layout (DESIGN.md §13): receivers are
    grouped by in-degree bucket — the smallest power of two >= their
    in-degree, clamped to the topology's max in-degree, so degree-regular
    topologies collapse to a single zero-padding bucket of exactly ``d``
    rows — and each receiver's row block is padded to its bucket degree
    with masked *dead* rows.  Live rows keep sorted-source order, which per
    receiver is canonical-edge-id order (canonical ids are source-major),
    so the edge-major halo tie-break "highest canonical edge id wins"
    stays "highest row ``j`` wins" and every receiver counter is a row
    reduction over the bucket's ``deg`` axis; no segment/scatter op
    survives on the regular fast path.

    Dense tables (``None`` for the edge layout), flat over the ``n_rows``
    padded rows:

      src        sender pid of the in-edge at flat row ``r``; sentinel
                 ``n`` on dead rows (gathers clamp, masks kill the value)
      dst        owner (receiver) pid of row ``r`` — defined on dead rows
      rev        flat row of the reverse edge; a self-involution that
                 doubles as the *out-edge table* (sender ``p``'s outgoing
                 rings are ``rev[rows of p]``); dead rows map to themselves
      eid        canonical edge id (keys the per-edge latency RNG stream
                 identically to edge-major); sentinel ``E`` on dead rows
      live       bool mask — False exactly on dead padding rows
      row_start  (n,) first flat row of each receiver's block
      bdeg       (n,) bucket degree of each receiver's block

    The halo slot of flat row ``r`` is ``(r - row_start[dst[r]]) % 4``
    (halo_slot_map round-robins sorted neighbors) and needs no table.
    Dead rows' rings are never staged into (the ``live`` mask gates the
    accept), so they stay empty forever and drain as no-ops.
    """

    kind: str
    degree: int                       # max bucket degree (0 for edge)
    n_rows: int = 0                   # total flat padded rows R
    buckets: Tuple[DenseBucket, ...] = ()
    src: Optional[np.ndarray] = None
    dst: Optional[np.ndarray] = None
    rev: Optional[np.ndarray] = None
    eid: Optional[np.ndarray] = None
    live: Optional[np.ndarray] = None
    row_start: Optional[np.ndarray] = None
    bdeg: Optional[np.ndarray] = None


def _dense_plan(topo: Topology) -> LayoutPlan:
    n = topo.n
    degs = [len(nbs) for nbs in topo.neighbors]
    dmax = max(degs)
    _, _, eindex = canonical_edges(topo)
    E = len(eindex)
    # bucket degree per receiver: next power of two, clamped to the max
    # in-degree (degree-regular topologies collapse to one exact-d bucket)
    bdeg = np.array([min(next_pow2(k), dmax) if k else 0 for k in degs],
                    np.int32)
    buckets: List[DenseBucket] = []
    row_start = np.zeros(n, np.int64)
    start = 0
    for bd in sorted(set(int(b) for b in bdeg if b)):
        members = np.where(bdeg == bd)[0]
        buckets.append(DenseBucket(deg=bd, start=start, members=members))
        row_start[members] = start + np.arange(len(members)) * bd
        start += len(members) * bd
    R = start
    src = np.full(R, n, np.int32)
    dst = np.empty(R, np.int32)
    eid = np.full(R, E, np.int32)
    rev = np.arange(R, dtype=np.int32)     # dead rows: self-involution
    live = np.zeros(R, bool)
    jindex: Dict[Tuple[int, int], int] = {}
    for b in buckets:
        for p in b.members.tolist():
            r0 = int(row_start[p])
            dst[r0:r0 + b.deg] = p
            for j, s in enumerate(sorted(topo.neighbors[p])):
                src[r0 + j] = s
                eid[r0 + j] = eindex[(s, p)]
                live[r0 + j] = True
                jindex[(s, p)] = j
    rows_live = np.where(live)[0]
    rev[rows_live] = (row_start[src[rows_live]]
                      + np.array([jindex[(int(dst[r]), int(src[r]))]
                                  for r in rows_live], np.int64))
    return LayoutPlan(kind="dense", degree=dmax, n_rows=R,
                      buckets=tuple(buckets), src=src, dst=dst, rev=rev,
                      eid=eid, live=live,
                      row_start=row_start.astype(np.int32), bdeg=bdeg)


def plan_layout(topo: Topology, layout: str = "auto") -> LayoutPlan:
    """Resolve a requested layout against a topology.

    ``auto`` resolves to the bucketed dense layout on every topology —
    irregular in-degrees land in power-of-two buckets with masked dead
    padding rows, degree-regular ones get a single exact-``d`` bucket —
    so only an explicit ``edge`` keeps the general edge-major path
    (comparison runs, parity tests).
    """
    if layout not in LAYOUTS:
        raise ValueError(
            f"unknown layout {layout!r}; choose from {LAYOUTS}")
    if layout == "edge":
        return LayoutPlan(kind="edge", degree=0)
    return _dense_plan(topo)


def patch_topology(topo: Topology,
                   absent: Sequence[int]) -> Tuple[Topology, Dict[int, int]]:
    """Remove ``absent`` pids and splice their duct rings closed.

    The elastic-churn patch-up (runtime/service.py): each absent process
    is excised one at a time, and its live neighbors are stitched into a
    cycle (consecutive members of its adjacency ring gain an edge), so the
    survivors keep a connected, symmetric graph without the departed hop.
    Sequential excision handles adjacent departures naturally — by the
    time the second of two neighboring processes leaves, it has already
    inherited splice edges from the first.

    Surviving pids are renumbered contiguously (host assignment carries
    over).  Returns the validated patched topology plus the
    ``original pid -> patched pid`` mapping.  Always patches from the
    pristine base, so a later rejoin is just a patch with a smaller
    absent set — rejoining every process reproduces ``topo`` exactly.
    """
    absent_set = set(absent)
    bad = sorted(p for p in absent_set if not 0 <= p < topo.n)
    if bad:
        raise ValueError(f"absent pids {bad} out of range for n={topo.n}")
    if len(absent_set) >= topo.n - 1:
        raise ValueError(
            f"cannot remove {len(absent_set)} of {topo.n} processes; "
            "at least 2 must survive")
    nbrs = [list(ns) for ns in topo.neighbors]
    alive = [True] * topo.n
    for a in sorted(absent_set):
        ring_members = [v for v in nbrs[a] if alive[v]]
        alive[a] = False
        for u in ring_members:
            nbrs[u] = [v for v in nbrs[u] if v != a]
        for i in range(len(ring_members)):
            u = ring_members[i]
            v = ring_members[(i + 1) % len(ring_members)]
            if u != v and v not in nbrs[u]:
                nbrs[u].append(v)
                nbrs[v].append(u)
    keep = [p for p in range(topo.n) if alive[p]]
    newid = {p: i for i, p in enumerate(keep)}
    adj = [sorted(newid[v] for v in nbrs[p]) for p in keep]
    node_of = [topo.node_of[p] for p in keep]
    name = (f"{topo.name}-{len(keep)}live" if absent_set else topo.name)
    return _freeze(adj, name, node_of), newid


TOPOLOGIES = {
    "ring": ring,
    "torus": torus,
    "cliques": cliques,
    "smallworld": smallworld,
}


def make_topology(name: str, n: int, **kwargs) -> Topology:
    """Build a registered topology by name for ``n`` processes."""
    try:
        builder = TOPOLOGIES[name]
    except KeyError:
        raise ValueError(
            f"unknown topology {name!r}; choose from {sorted(TOPOLOGIES)}")
    if name == "cliques":
        size = kwargs.pop("clique_size", None)
        if size is None:
            size = next(s for s in (8, 4, 2, 1) if n % s == 0)
        return builder(n, clique_size=size, **kwargs)
    return builder(n, **kwargs)
