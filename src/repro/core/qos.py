"""Quality-of-service metric suite (paper §II-D), computed from counter
snapshots taken before/after an unimpeded observation window.

Counters mirror the paper's Inlet/Outlet instrumentation:
  update_count           simulation updates completed
  touch_count            round-trip touch counter (+2 per completed round trip)
  attempted_send_count   messages pushed toward a duct
  successful_send_count  messages accepted by the duct (buffer not full)
  dropped_send_count     messages that failed delivery, counted at the drop
                         site (never derived as attempted - successful).
                         This is the TOTAL across all three drop causes;
                         the two subset counters below attribute it:
  loss_dropped_send_count   subset dropped by a lossy or flapping link
                            (deterministic per-send hash draw)
  dead_dropped_send_count   subset sent toward a crashed (dead) process
                         capacity drops (full duct) are the remainder:
                         dropped - loss_dropped - dead_dropped
  laden_pull_count       pull attempts that retrieved >= 1 fresh message
  message_count          messages received
  pull_attempt_count     pull attempts
  wall_time              seconds
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass
class Counters:
    update_count: int = 0
    touch_count: int = 0
    attempted_send_count: int = 0
    successful_send_count: int = 0
    dropped_send_count: int = 0
    loss_dropped_send_count: int = 0
    dead_dropped_send_count: int = 0
    laden_pull_count: int = 0
    message_count: int = 0
    pull_attempt_count: int = 0
    wall_time: float = 0.0

    def copy(self) -> "Counters":
        return dataclasses.replace(self)


@dataclasses.dataclass(frozen=True)
class QosReport:
    simstep_period: float          # seconds per update (lower is better)
    simstep_latency: float         # updates per one-way delivery
    walltime_latency: float        # seconds per one-way delivery
    delivery_failure_rate: float   # fraction of sends dropped
    delivery_clumpiness: float     # 1 - steadiness
    # observation-window bounds on the process's own virtual clock; stamp
    # the report so per-interval (time-resolved) aggregation needs no side
    # channel back to the engine's snapshot buffers
    t_start: float = 0.0
    t_end: float = 0.0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def simstep_period(before: Counters, after: Counters) -> float:
    """Seconds of wall time per completed update.

    A zero-update observation window (idle, barrier-parked, or churned-out
    process) reports an explicit ``inf`` sentinel rather than ``wall / 1``:
    the old clamp made a stalled process look like one *fast* update per
    window, which inverts SLO verdicts under churn.  Aggregators filter the
    sentinel deliberately (see :func:`aggregate_reports`)."""
    updates = after.update_count - before.update_count
    wall = after.wall_time - before.wall_time
    if updates <= 0:
        return float("inf")
    return wall / updates


def simstep_latency(before: Counters, after: Counters) -> float:
    """Updates elapsed per one-way message delivery.

    The touch counter increments by two per completed round trip; if no
    touches elapsed we make the paper's best-case assumption of one.
    """
    updates = after.update_count - before.update_count
    touches = after.touch_count - before.touch_count
    return updates / max(touches, 1)


def walltime_latency(before: Counters, after: Counters) -> float:
    """Seconds per one-way delivery; ``inf`` on a zero-update window (the
    guard keeps the sentinel from collapsing to ``0 * inf = nan``)."""
    if after.update_count - before.update_count <= 0:
        return float("inf")
    return simstep_latency(before, after) * simstep_period(before, after)


def delivery_failure_rate(before: Counters, after: Counters) -> float:
    """Fraction of sends dropped, from the explicit drop counter.

    Drops are counted at the drop site (``dropped_send_count``), not derived
    as attempted - successful: the two sender-side counters are snapshotted
    independently, so the derived form can go transiently negative or miss
    drops when a window boundary falls between the increments.
    """
    attempted = after.attempted_send_count - before.attempted_send_count
    dropped = after.dropped_send_count - before.dropped_send_count
    if attempted <= 0:
        return 0.0
    return dropped / attempted


def delivery_clumpiness(before: Counters, after: Counters) -> float:
    """1 - steadiness.  Zero when messages arrive as an even stream (every
    arrival in its own pull, or every pull laden once pigeonholed)."""
    laden = after.laden_pull_count - before.laden_pull_count
    messages = after.message_count - before.message_count
    pulls = after.pull_attempt_count - before.pull_attempt_count
    opportunities = min(messages, pulls)
    if opportunities <= 0:
        return 0.0
    steadiness = laden / opportunities
    return 1.0 - min(steadiness, 1.0)


def report(before: Counters, after: Counters) -> QosReport:
    return QosReport(
        simstep_period=simstep_period(before, after),
        simstep_latency=simstep_latency(before, after),
        walltime_latency=walltime_latency(before, after),
        delivery_failure_rate=delivery_failure_rate(before, after),
        delivery_clumpiness=delivery_clumpiness(before, after),
        t_start=before.wall_time,
        t_end=after.wall_time,
    )


# ---------------------------------------------------------------------------
# Canonical result signature for engine conformance (DESIGN.md §11).
# ---------------------------------------------------------------------------
def qos_signature(result) -> dict:
    """A canonical, exactly-comparable digest of a ``SimResult``.

    Flattens every per-process counter and every per-(process, window)
    ``QosReport`` field into plain Python lists of ints/floats, keyed by
    stable names.  Two engines are *bitwise conformant* on a scenario iff
    their signatures compare equal with ``==`` — no tolerance, no metric
    subset.  ``tests/test_engine_conformance.py`` asserts exactly this for
    every registered engine against the event-ordered oracle (and for
    every sharded configuration against ``shards=1``), and serializes the
    signature into the parity-table artifact, so a semantic drift in any
    engine shows up as a field-level diff rather than a tolerance breach.
    """
    sig = {
        "updates": [int(u) for u in result.updates],
        "sent": int(result.sent),
        "dropped": int(result.dropped),
        "dropped_loss": int(result.dropped_loss),
        "dropped_dead": int(result.dropped_dead),
        "quality": float(result.quality),
        "qos": {},
    }
    fields = METRICS + ("t_start", "t_end")
    for f in fields:
        sig["qos"][f] = {
            int(pid): [float(getattr(r, f)) for r in reps]
            for pid, reps in sorted(result.qos_by_process.items())
        }
    return sig


# ---------------------------------------------------------------------------
# Distribution aggregation across processes and windows (paper §III reports
# medians + tails, not means: under best-effort QoS the distribution IS the
# result).
# ---------------------------------------------------------------------------
METRICS = ("simstep_period", "simstep_latency", "walltime_latency",
           "delivery_failure_rate", "delivery_clumpiness")


def aggregate_reports(reports, percentiles=(50, 95)):
    """Per-metric percentile summary over (process, window) samples.

    Returns ``{metric: {"median": v, "p95": v, ...}}`` — percentile 50 is
    keyed ``"median"``, every other q as ``"p{q}"``.  Empty input yields
    empty per-metric dicts.

    Zero-update windows stamp ``inf`` sentinels into the period/latency
    metrics (see :func:`simstep_period`); percentiles are taken over the
    *finite* samples only, so one idle process cannot saturate a tail
    statistic — a metric whose every sample is the sentinel reports
    ``None``, the same as no data.
    """
    out = {}
    for m in METRICS:
        vals = [v for r in reports if math.isfinite(v := getattr(r, m))]
        summary = {}
        for q in percentiles:
            key = "median" if q == 50 else f"p{int(q)}"
            summary[key] = float(np.percentile(vals, q)) if vals else None
        out[m] = summary
    return out


def median_of_process_medians(qos_by_process, metric: str):
    """The paper's headline statistic: median over processes of each
    process's median over observation windows.  None if no windows.
    Idle-window ``inf`` sentinels are excluded per process; a process with
    only sentinel windows contributes no median."""
    meds = []
    for reps in qos_by_process.values():
        vals = [v for q in reps if math.isfinite(v := getattr(q, metric))]
        if vals:
            meds.append(np.median(vals))
    return float(np.median(meds)) if meds else None


# ---------------------------------------------------------------------------
# Time-resolved QoS stream: the paper argues that "a complete picture of
# scalability under the best-effort model requires analysis of how quality
# of service fares over time" — so beyond pooled (process, window)
# distributions, expose the per-interval trajectory.
# ---------------------------------------------------------------------------
def aggregate_timeseries(process_reports, percentiles=(50, 95)):
    """Per-interval QoS distributions over processes: the time axis.

    Snapshot thresholds are global (``warmup + i * interval`` on each
    process's own clock), so the i-th observation window of every process
    covers the same virtual-time interval; pooling column-wise yields a
    time-resolved stream instead of one end-of-run aggregate.

    ``process_reports`` is an iterable of per-process report lists — e.g.
    ``result.qos_by_process.values()``, or those of several replicates
    chained.  Ragged inputs are fine: a process that produced fewer
    windows simply stops contributing.  Returns one row per interval::

        {"interval": i, "t_start": ..., "t_end": ..., "n_samples": k,
         "complete": bool, "qos": {metric: {"median": ..., "p95": ...}}}

    where the t bounds are medians over the contributing processes' own
    snapshot clocks.  ``complete`` marks intervals every process
    contributed a window to; ragged-tail rows (a process finished early,
    left the service, or never reached the interval) pool whatever samples
    exist but carry ``complete: False`` so time-sliced SLO verdicts can
    flag rather than trust them.
    """
    columns = []
    n_procs = 0
    for reps in process_reports:
        n_procs += 1
        for i, r in enumerate(reps):
            if i >= len(columns):
                columns.append([])
            columns[i].append(r)
    rows = []
    for i, bucket in enumerate(columns):
        rows.append({
            "interval": i,
            "t_start": float(np.median([r.t_start for r in bucket])),
            "t_end": float(np.median([r.t_end for r in bucket])),
            "n_samples": len(bucket),
            "complete": len(bucket) == n_procs,
            "qos": aggregate_reports(bucket, percentiles),
        })
    return rows
