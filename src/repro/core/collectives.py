"""Best-effort collectives for the cross-pod gradient/parameter path.

These functions run inside ``jax.shard_map(..., axis_names={"pod"})`` bodies:
the pod axis is manual (explicit collectives below); data/model axes stay
auto (GSPMD).  They implement the paper's asynchronicity modes on the
gradient path (DESIGN.md §2):

  mode 0  — synchronous cross-pod pmean every step
  mode 1/2— no per-step cross-pod traffic; periodic parameter sync (outer opt)
  mode 3  — staleness-1 delayed cross-pod sum, overlapped with compute;
            optionally lossy-compressed (top-k / int8) with error feedback —
            the "message drop + no retry" analogue
  mode 4  — no cross-pod communication
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from repro.core.conduit import axis_size
from repro.core.modes import AsyncMode

POD_AXIS = "pod"


# ---------------------------------------------------------------------------
# Compressed cross-pod sums
# ---------------------------------------------------------------------------
def cross_pod_sum(tree, axis_name: str = POD_AXIS, compressor=None, residuals=None):
    """Sum a pytree across pods.

    Without a compressor this is a plain psum.  With one, each leaf is encoded
    (lossy, with error feedback), the compact payload is all-gathered across
    pods, and decoded+summed locally — collective bytes shrink by the
    compression ratio.  Returns (summed_tree, new_residuals).
    """
    if compressor is None:
        return lax.psum(tree, axis_name), residuals
    if residuals is None:
        residuals = jax.tree.map(jnp.zeros_like, tree)

    def leaf_sum(leaf, res):
        payload, new_res = compressor.encode(leaf + res)
        gathered = jax.tree.map(
            lambda p: lax.all_gather(p, axis_name, axis=0), payload)
        total = compressor.decode_sum(gathered, leaf.shape, leaf.dtype)
        return total, new_res

    flat, treedef = jax.tree.flatten(tree)
    res_flat = jax.tree.leaves(residuals)
    out = [leaf_sum(l, r) for l, r in zip(flat, res_flat)]
    summed = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_res = jax.tree.unflatten(treedef, [o[1] for o in out])
    return summed, new_res


# ---------------------------------------------------------------------------
# Gradient exchange per asynchronicity mode
# ---------------------------------------------------------------------------
def init_exchange_state(grads_like, mode: AsyncMode, compressor=None):
    state = {}
    if mode == AsyncMode.BEST_EFFORT:
        state["others"] = jax.tree.map(jnp.zeros_like, grads_like)
        if compressor is not None:
            state["residuals"] = jax.tree.map(jnp.zeros_like, grads_like)
    return state


def exchange_gradients(grads, state: dict, mode: AsyncMode,
                       axis_name: str = POD_AXIS, compressor=None):
    """grads: pod-local mean gradients.  Returns (effective_grads, new_state).

    BEST_EFFORT: effective grad at step t combines this pod's fresh gradient
    with the *other* pods' step t-1 gradients (staleness-1).  The cross-pod
    reduction issued here is consumed next step, so the scheduler overlaps it
    with the whole of this step's compute.
    """
    n = axis_size(axis_name)
    if mode == AsyncMode.BARRIER_EVERY_STEP:
        return jax.tree.map(lambda g: g / n, lax.psum(grads, axis_name)), state
    if mode in (AsyncMode.ROLLING_BARRIER, AsyncMode.FIXED_BARRIER,
                AsyncMode.NO_COMM):
        return grads, state  # cross-pod sync handled by the outer optimizer

    assert mode == AsyncMode.BEST_EFFORT
    others_prev = state["others"]
    eff = jax.tree.map(lambda g, o: (g + o) / n, grads, others_prev)
    total, new_res = cross_pod_sum(
        grads, axis_name, compressor, state.get("residuals"))
    others_new = jax.tree.map(lambda t, g: t - g, total, grads)
    new_state = dict(state, others=others_new)
    if compressor is not None:
        new_state["residuals"] = new_res
    return eff, new_state


# ---------------------------------------------------------------------------
# Periodic parameter sync (modes 1/2 outer step)
# ---------------------------------------------------------------------------
def pod_mean(tree, axis_name: str = POD_AXIS):
    n = axis_size(axis_name)
    return jax.tree.map(lambda x: lax.psum(x, axis_name) / n, tree)


def maybe_param_sync(params, do_sync, axis_name: str = POD_AXIS):
    """Average parameters across pods when ``do_sync`` (traced bool) is set.

    The psum always appears in the graph; ``where`` selects its result only on
    sync steps.  (A lax.cond would skip the flops but XLA still provisions the
    collective; measured cost on non-sync steps is the no-op select.)
    """
    mean = pod_mean(params, axis_name)
    return jax.tree.map(lambda m, p: jnp.where(do_sync, m, p), mean, params)
