"""Time-sliced SLO evaluation over the QoS timeseries (docs/QOS.md).

The paper characterizes quality of service instead of guaranteeing
delivery; a live serving posture turns that characterization into
budgets.  This module consumes the per-interval rows produced by
:func:`repro.core.qos.aggregate_timeseries` and renders a machine-readable
verdict per time slice — p99 simstep-latency and delivery-failure-rate
against fixed budgets, plus a burn-rate window for sustained-breach
detection — rather than one end-of-run aggregate that a transient brownout
would vanish into.

Conventions:

  * a metric breaches iff its p99 is strictly *greater* than the budget —
    a slice sitting exactly on budget passes (budgets are inclusive);
  * a slice with no finite samples for either metric (every process idle,
    churned out, or past its last window) yields ``verdict: "no_data"``
    and is excluded from burn-rate accounting — absence of evidence is
    flagged, not scored;
  * ``burn_rate`` is the breach fraction over the trailing
    ``burn_window`` data-bearing slices; ``burning`` marks slices where it
    exceeds ``burn_threshold`` (sustained breach, not a single spike).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional


@dataclasses.dataclass(frozen=True)
class SloPolicy:
    """Per-slice service-level objectives for an open-loop run.

    ``latency_p99_budget`` bounds p99 ``simstep_latency`` (updates per
    one-way delivery — the price axis); ``failure_p99_budget`` bounds p99
    ``delivery_failure_rate`` (fraction of sends dropped).  Budgets are
    inclusive: equality passes.
    """

    latency_p99_budget: float = 50.0
    failure_p99_budget: float = 0.35
    burn_window: int = 5
    burn_threshold: float = 0.5

    def __post_init__(self):
        # ValueError (not assert) so the checks survive ``python -O``
        if not self.latency_p99_budget > 0:
            raise ValueError(
                f"latency_p99_budget must be > 0, got "
                f"{self.latency_p99_budget}")
        if not 0 <= self.failure_p99_budget <= 1:
            raise ValueError(
                f"failure_p99_budget must be in [0, 1], got "
                f"{self.failure_p99_budget}")
        if self.burn_window < 1:
            raise ValueError(
                f"burn_window must be >= 1, got {self.burn_window}")
        if not 0 <= self.burn_threshold <= 1:
            raise ValueError(
                f"burn_threshold must be in [0, 1], got "
                f"{self.burn_threshold}")


def _p99(row: dict, metric: str) -> Optional[float]:
    summary = row["qos"].get(metric, {})
    v = summary.get("p99")
    if v is None:
        # fall back to the widest tail the aggregation carried
        v = summary.get("p95")
    return v


def evaluate_timeseries(rows: List[dict], policy: SloPolicy) -> dict:
    """Render one SLO verdict per timeseries row.

    ``rows`` are :func:`~repro.core.qos.aggregate_timeseries` rows
    (aggregated with a percentile set that includes 99; p95 is accepted as
    a fallback tail).  Returns::

        {"verdicts": [...], "summary": {...}}

    with one verdict dict per interval — ``verdict`` is ``"ok"``,
    ``"breach"`` (with the offending metrics in ``breached``), or
    ``"no_data"`` — and a run-level summary (breach/no-data counts, the
    worst burn rate, and ``ok: bool`` meaning zero breached slices).
    ``complete`` is carried through from the row so partial final
    intervals stay marked, not trusted (see ``aggregate_timeseries``).
    """
    verdicts = []
    recent: List[int] = []
    max_burn = 0.0
    for row in rows:
        lat = _p99(row, "simstep_latency")
        fail = _p99(row, "delivery_failure_rate")
        breached = []
        if lat is None and fail is None:
            verdict = "no_data"
        else:
            if lat is not None and lat > policy.latency_p99_budget:
                breached.append("simstep_latency")
            if fail is not None and fail > policy.failure_p99_budget:
                breached.append("delivery_failure_rate")
            verdict = "breach" if breached else "ok"
            recent.append(1 if breached else 0)
            if len(recent) > policy.burn_window:
                recent.pop(0)
        burn = (sum(recent) / len(recent)) if recent else 0.0
        max_burn = max(max_burn, burn)
        verdicts.append({
            "interval": row["interval"],
            "t_start": row["t_start"],
            "t_end": row["t_end"],
            "complete": row.get("complete", True),
            "metrics": {"simstep_latency_p99": lat,
                        "delivery_failure_rate_p99": fail},
            "breached": breached,
            "verdict": verdict,
            "burn_rate": burn,
            "burning": burn > policy.burn_threshold,
        })
    n_breach = sum(v["verdict"] == "breach" for v in verdicts)
    n_nodata = sum(v["verdict"] == "no_data" for v in verdicts)
    summary = {
        "intervals": len(verdicts),
        "breaches": n_breach,
        "no_data": n_nodata,
        "max_burn_rate": max_burn,
        "burning_intervals": sum(v["burning"] for v in verdicts),
        "ok": n_breach == 0,
    }
    return {"verdicts": verdicts, "summary": summary}
