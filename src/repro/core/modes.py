"""Asynchronicity modes (paper Table I), mapped to the TPU pod axis.

The paper's "CPUs" map to pods: cross-pod communication is the expensive,
jitter-exposed link (DESIGN.md §2).  Intra-pod data/model parallelism always
remains synchronous — it is inside one SPMD program.
"""
from __future__ import annotations

import enum


class AsyncMode(enum.IntEnum):
    BARRIER_EVERY_STEP = 0   # full sync every update (BSP baseline)
    ROLLING_BARRIER = 1      # work K steps, then sync (rolling local-SGD)
    FIXED_BARRIER = 2        # sync at predetermined step boundaries
    BEST_EFFORT = 3          # no barrier: staleness-1 delayed exchange
    NO_COMM = 4              # no cross-pod communication at all

    @property
    def description(self) -> str:
        return {
            0: "Barrier sync every update",
            1: "Rolling barrier sync",
            2: "Fixed barrier sync",
            3: "No barrier sync (best-effort)",
            4: "No inter-pod communication",
        }[int(self)]


def sync_due(mode: AsyncMode, step, period: int):
    """Whether an outer (cross-pod) sync fires at ``step``.

    Works on both python ints and traced values.  Mode 1 counts steps since
    the last sync (rolling); mode 2 uses absolute step boundaries — the paper
    aligns mode 2 to epoch-time boundaries, which on a lockstep SPMD runtime
    degenerates to fixed step indices (the race the paper observed between
    differently-phased workers cannot occur in-graph; see DESIGN.md).
    """
    if mode == AsyncMode.BARRIER_EVERY_STEP:
        return step == step  # always true, shaped like step
    if mode in (AsyncMode.ROLLING_BARRIER, AsyncMode.FIXED_BARRIER):
        return (step % period) == (period - 1)
    return step != step  # never
