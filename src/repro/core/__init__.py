# The paper's primary contribution: best-effort communication as a
# first-class JAX feature — asynchronicity modes, staleness-buffered
# conduits, best-effort gradient collectives, and the QoS metric suite.
from repro.core import collectives, conduit, modes, qos  # noqa: F401
from repro.core.modes import AsyncMode  # noqa: F401
