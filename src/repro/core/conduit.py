"""In-graph (SPMD) Conduit: best-effort neighbor exchange over mesh axes.

The TPU-native analogue of the paper's Inlet/Outlet ducts (DESIGN.md §2):
channels are double-buffered, so under ``BEST_EFFORT`` a fragment consumes the
values its neighbors sent on the *previous* step while the current
``ppermute`` is scheduled concurrently with compute — communication leaves the
critical path at the cost of one step of staleness, exactly the best-effort
trade.  Under ``BARRIER_EVERY_STEP`` the fresh values are consumed in-step
(BSP).  Designed for use inside ``shard_map`` bodies (see apps/graphcolor).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.modes import AsyncMode


def ring_perm(n: int, shift: int):
    return [(i, (i + shift) % n) for i in range(n)]


def axis_size(axis_name: str):
    """Version-compat ``lax.axis_size`` (older jax: the psum-of-1 idiom,
    which constant-folds to the axis size at trace time)."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)


def ring_exchange(x, axis_name: str, shift: int = 1):
    """Rotate ``x`` around the ring: device i receives device (i - shift)'s
    value (i.e. values travel ``shift`` steps forward)."""
    n = axis_size(axis_name)
    return lax.ppermute(x, axis_name, ring_perm(n, shift))


@dataclasses.dataclass(frozen=True)
class Conduit:
    """Best-effort channel over one mesh axis (ring topology).

    ``directions`` maps a name to a ring shift, e.g. {"fwd": +1, "bwd": -1}.
    State (the staleness buffers) is an ordinary pytree the caller threads
    through its step loop / scan carry.
    """

    axis_name: str
    directions: Dict[str, int]
    mode: AsyncMode = AsyncMode.BEST_EFFORT

    def init_buffers(self, example) -> Dict[str, jax.Array]:
        return {d: jnp.zeros_like(example) for d in self.directions}

    def exchange(self, value, buffers, *, flush=None) -> Tuple[dict, dict]:
        """One communication phase.

        value: the local payload to publish to every neighbor.
        buffers: previously received payloads (from ``init_buffers``/last call).
        flush: optional bool scalar — modes 1/2 consume fresh values when set.

        Returns (received, new_buffers): what this fragment should consume
        now, and the buffers to carry forward.
        """
        if self.mode == AsyncMode.NO_COMM:
            return buffers, buffers

        fresh = {d: ring_exchange(value, self.axis_name, s)
                 for d, s in self.directions.items()}

        if self.mode == AsyncMode.BARRIER_EVERY_STEP:
            return fresh, fresh
        if self.mode == AsyncMode.BEST_EFFORT:
            # consume stale, publish fresh: the permute's consumer is the
            # *next* step, so the scheduler overlaps it with this step's work
            return buffers, fresh
        # rolling / fixed barrier: stale between barriers, fresh at barriers
        assert flush is not None, "modes 1/2 need a flush predicate"
        received = jax.tree.map(
            lambda f, b: jnp.where(flush, f, b), fresh, buffers)
        return received, fresh


def torus_conduits(axis_names: Tuple[str, str], mode: AsyncMode):
    """N/S/E/W conduits for a 2-D toroidal fragment grid.

    ``received["north"]`` is the payload of the neighbor one row up
    (device i-1 along the row axis => shift +1), etc.
    """
    row = Conduit(axis_names[0], {"north": +1, "south": -1}, mode)
    col = Conduit(axis_names[1], {"west": +1, "east": -1}, mode)
    return row, col
