"""Distributed graph coloring (Leith et al. 2012 WLAN channel selection) —
the paper's communication-intensive benchmark (§II-B).

Nodes live on a global toroidal grid, 4 neighbors, C colors.  Each update a
node in conflict with any neighbor multiplicatively decays the probability of
its current color (factor b), renormalizes, and resamples; conflict-free
nodes keep their color.  Colors are exchanged with neighboring fragments via
best-effort channels (halo rows/cols) — stale halos are simply used as-is.

Two implementations share the same math:
  - numpy fragments for the discrete-event runtime (fast on CPU);
  - a jnp/shard_map SPMD step (``spmd_step``) using core.conduit — the
    in-graph TPU form (used by tests and examples).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np


def proc_grid(n: int):
    """Near-square factorization of the process count."""
    a = int(math.sqrt(n))
    while n % a:
        a -= 1
    return a, n // a


def block_shape(nodes_per_proc: int):
    a = int(math.sqrt(nodes_per_proc))
    while nodes_per_proc % a:
        a -= 1
    return a, nodes_per_proc // a


def direction_map(neighbors) -> Dict[int, str]:
    """Assign each injected-topology neighbor a halo direction slot.

    Arbitrary topologies (ring, cliques, small-world — runtime/topologies)
    don't carry grid directions, so neighbors round-robin over the four halo
    slots; several neighbors may feed one slot (last fresh message wins,
    which is exactly the best-effort staleness semantics).  The numeric slot
    assignment lives in ``runtime.topologies.halo_slot_map`` so the
    vectorized engine wires edges identically.
    """
    from repro.runtime.topologies import DIRS, halo_slot_map
    return {nb: DIRS[s] for nb, s in halo_slot_map(neighbors).items()}


@dataclasses.dataclass(frozen=True)
class GraphColorConfig:
    n_processes: int = 4
    nodes_per_process: int = 2048
    n_colors: int = 3
    b: float = 0.1
    seed: int = 0


def _update_block(colors, probs, halo, b, rng):
    """One CFL update (Leith et al.) on a (H,W) block given halo arrays.

    Success (no conflicting neighbor): probability concentrates on the
    current color.  Failure: the current color's probability decays and a
    b-fraction of mass is redistributed over the other colors, then the node
    resamples.  halo: {"n": (W,), "s": (W,), "w": (H,), "e": (H,)}.
    Returns (colors, probs, conflict_mask).
    """
    C = probs.shape[-1]
    up = np.vstack([halo["n"][None, :], colors[:-1]])
    down = np.vstack([colors[1:], halo["s"][None, :]])
    left = np.hstack([halo["w"][:, None], colors[:, :-1]])
    right = np.hstack([colors[:, 1:], halo["e"][:, None]])
    conflict = ((colors == up) | (colors == down)
                | (colors == left) | (colors == right))

    ok = ~conflict
    probs[ok] = 0.0
    probs[ok, colors[ok]] = 1.0

    if conflict.any():
        idx = np.where(conflict)
        cur = colors[idx]
        p = probs[idx]  # (k, C)
        onehot = np.zeros_like(p)
        onehot[np.arange(len(cur)), cur] = 1.0
        p = (1 - b) * p + b * (1 - onehot) / (C - 1)
        probs[idx] = p
        # resample
        u = rng.random(len(cur))
        cdf = np.cumsum(p, axis=1)
        new = (u[:, None] > cdf).sum(axis=1)
        colors[idx] = new
    return colors, probs, conflict


class _Fragment:
    def __init__(self, pid, cfg: GraphColorConfig, grid, block, self_wrap,
                 nbr_dirs: Optional[Dict[int, str]] = None):
        self.pid = pid
        self.cfg = cfg
        self.grid = grid
        H, W = block
        self.rng = np.random.default_rng((cfg.seed, pid))
        self.colors = self.rng.integers(0, cfg.n_colors, size=(H, W))
        self.probs = np.full((H, W, cfg.n_colors), 1.0 / cfg.n_colors)
        self.self_wrap = self_wrap  # {"ns": bool, "ew": bool}
        self.nbr_dirs = nbr_dirs    # injected topology: neighbor -> halo slot
        self.scalar = H == W == 1   # 1 simel/process: pure-python fast path
        # last-known halos (best-effort: start with own edges).  The scalar
        # path trades arrays for plain ints end-to-end: halos, payloads, and
        # probabilities stay python scalars, ~10x cheaper per update.
        if self.scalar:
            c = int(self.colors[0, 0])
            self.halo = {"n": c, "s": c, "w": c, "e": c}
            self._c = c
            self._p = self.probs[0, 0].tolist()
            self._onehot = False
        else:
            self.halo = {"n": self.colors[0].copy(), "s": self.colors[-1].copy(),
                         "w": self.colors[:, 0].copy(), "e": self.colors[:, -1].copy()}
        if nbr_dirs is not None:
            # slots no injected neighbor feeds (degree < 4, e.g. a ring)
            # would stay frozen at the initial self-copy and register phantom
            # conflicts forever; -1 is a color no node ever holds
            for d in set("nswe") - set(nbr_dirs.values()):
                self.halo[d] = -1 if self.scalar \
                    else np.full_like(self.halo[d], -1)

    def neighbors(self) -> Dict[str, int]:
        gh, gw = self.grid
        r, c = divmod(self.pid, gw)
        out = {}
        if not self.self_wrap["ns"]:
            out["n"] = ((r - 1) % gh) * gw + c
            out["s"] = ((r + 1) % gh) * gw + c
        if not self.self_wrap["ew"]:
            out["w"] = r * gw + (c - 1) % gw
            out["e"] = r * gw + (c + 1) % gw
        return out

    def update(self, inbox: Dict[int, Optional[np.ndarray]]):
        scalar = self.scalar
        halo = self.halo
        if self.nbr_dirs is not None:
            # injected topology: any neighbor can feed any halo slot
            nbr_dirs = self.nbr_dirs
            if scalar:
                for nb, payload in inbox.items():
                    if payload is not None:
                        halo[nbr_dirs[nb]] = payload
                self._update_scalar()
                c = self._c
                return {nb: c for nb in nbr_dirs}
            for nb, payload in inbox.items():
                if payload is not None:
                    d = nbr_dirs[nb]
                    halo[d] = payload[_OPP[d]]
            self.colors, self.probs, _ = _update_block(
                self.colors, self.probs, halo, self.cfg.b, self.rng)
            edges = self._edges()
            return {nb: edges for nb in nbr_dirs}

        nbs = self.neighbors()
        # refresh halos from any fresh messages (stale otherwise)
        if scalar:
            for d, nb in nbs.items():
                payload = inbox.get(nb)
                if payload is not None:
                    halo[d] = payload
            if self.self_wrap["ns"]:
                halo["n"] = halo["s"] = self._c
            if self.self_wrap["ew"]:
                halo["w"] = halo["e"] = self._c
            self._update_scalar()
            c = self._c
            return {nb: c for nb in set(nbs.values())}

        for d, nb in nbs.items():
            payload = inbox.get(nb)
            if payload is not None:
                halo[d] = payload[_OPP[d]]
        if self.self_wrap["ns"]:
            halo["n"] = self.colors[-1]
            halo["s"] = self.colors[0]
        if self.self_wrap["ew"]:
            halo["w"] = self.colors[:, -1]
            halo["e"] = self.colors[:, 0]

        self.colors, self.probs, _ = _update_block(
            self.colors, self.probs, halo, self.cfg.b, self.rng)

        edges = self._edges()
        return {nb: edges for nb in set(nbs.values())}

    def adopt(self, state):
        """Resume from a carried ``(colors, probs)`` snapshot (service
        epochs, runtime/service.py).  Halos restart from own edges exactly
        like a fresh init — with the -1 sentinel on unfed slots — so a
        survivor's first window is a pure function of the carried block
        state, identical across engines."""
        self.colors = np.array(state["colors"], dtype=self.colors.dtype)
        self.probs = np.array(state["probs"], dtype=self.probs.dtype)
        if self.scalar:
            c = int(self.colors[0, 0])
            self._c = c
            self._p = self.probs[0, 0].tolist()
            self._onehot = max(self._p) >= 1.0
            self.halo = {"n": c, "s": c, "w": c, "e": c}
        else:
            self.halo = {"n": self.colors[0].copy(),
                         "s": self.colors[-1].copy(),
                         "w": self.colors[:, 0].copy(),
                         "e": self.colors[:, -1].copy()}
        if self.nbr_dirs is not None:
            for d in set("nswe") - set(self.nbr_dirs.values()):
                self.halo[d] = -1 if self.scalar \
                    else np.full_like(self.halo[d], -1)

    def _edges(self):
        return {"n": self.colors[0].copy(), "s": self.colors[-1].copy(),
                "w": self.colors[:, 0].copy(), "e": self.colors[:, -1].copy()}

    def _update_scalar(self):
        """1x1-block CFL update on plain python scalars — what lets a
        1024-process maximal-intensity sweep finish in interactive time.
        Payloads are bare color ints; ``colors``/``probs`` arrays are kept
        in sync so ``quality()`` and inspection still work."""
        halo = self.halo
        c = self._c
        if (c != halo["n"] and c != halo["s"]
                and c != halo["w"] and c != halo["e"]):
            if not self._onehot:
                p = [0.0] * self.cfg.n_colors
                p[c] = 1.0
                self._p = p
                self._onehot = True
                self.probs[0, 0] = p
            return
        b = self.cfg.b
        C = self.cfg.n_colors
        spread = b / (C - 1)
        p = [(1.0 - b) * v + (0.0 if k == c else spread)
             for k, v in enumerate(self._p)]
        u = self.rng.random()
        acc = 0.0
        new = C - 1
        for k, v in enumerate(p):
            acc += v
            if u <= acc:
                new = k
                break
        self._p = p
        self._onehot = False
        self.probs[0, 0] = p
        if new != c:
            self._c = new
            self.colors[0, 0] = new


_OPP = {"n": "s", "s": "n", "w": "e", "e": "w"}


class GraphColorApp:
    def __init__(self, cfg: GraphColorConfig, topology=None,
                 initial_state=None):
        self.cfg = cfg
        self.n_processes = cfg.n_processes
        self.grid = proc_grid(cfg.n_processes)
        self.block = block_shape(cfg.nodes_per_process)
        self.self_wrap = {"ns": self.grid[0] == 1, "ew": self.grid[1] == 1}
        if topology is not None:
            assert topology.n == cfg.n_processes, \
                f"topology is for {topology.n} processes, app has {cfg.n_processes}"
        self.injected = topology  # runtime.topologies.Topology or None
        # {seed: {pid: {"colors","probs"}}} — carried state for service
        # epochs (runtime/service.py).  Keyed by replicate seed so one app
        # instance serves the vectorized engine's whole replicate batch;
        # pids absent from the dict initialize fresh (rejoin semantics).
        self.initial_state = initial_state

    def make_fragments(self) -> List[_Fragment]:
        if self.injected is not None:
            no_wrap = {"ns": False, "ew": False}
            frags = [_Fragment(i, self.cfg, self.grid, self.block, no_wrap,
                               nbr_dirs=direction_map(self.injected.neighbors[i]))
                     for i in range(self.cfg.n_processes)]
        else:
            frags = [_Fragment(i, self.cfg, self.grid, self.block,
                               self.self_wrap)
                     for i in range(self.cfg.n_processes)]
        carried = (self.initial_state or {}).get(self.cfg.seed) or {}
        for f in frags:
            state = carried.get(f.pid)
            if state is not None:
                f.adopt(state)
        return frags

    def export_state(self, fragments) -> Dict[int, dict]:
        """Snapshot each fragment's carriable state (service epoch carry)."""
        return {f.pid: {"colors": np.asarray(f.colors).copy(),
                        "probs": np.asarray(f.probs).copy()}
                for f in fragments}

    def topology(self):
        if self.injected is not None:
            return self.injected
        out = {}
        for i in range(self.cfg.n_processes):
            f = _Fragment.__new__(_Fragment)
            f.pid, f.grid, f.self_wrap = i, self.grid, self.self_wrap
            out[i] = sorted(set(f.neighbors().values()) - {i})
        return out

    def batched(self) -> "BatchedGraphColor":
        """Population-batched entry point for the vectorized engine."""
        return BatchedGraphColor(self)

    def quality(self, fragments) -> float:
        """Exact remaining conflict count on the assembled global grid."""
        gh, gw = self.grid
        H, W = self.block
        full = np.zeros((gh * H, gw * W), dtype=int)
        for f in fragments:
            r, c = divmod(f.pid, gw)
            full[r * H:(r + 1) * H, c * W:(c + 1) * W] = f.colors
        conflicts = ((full == np.roll(full, 1, 0)).sum()
                     + (full == np.roll(full, 1, 1)).sum())
        return float(conflicts)


# ---------------------------------------------------------------------------
# Population-batched form — what the vectorized engine scans (DESIGN.md §7)
# ---------------------------------------------------------------------------
class BatchedGraphColor:
    """All fragments' CFL updates as one vmapped step over flat arrays.

    The same math as ``_update_block`` (via its jnp twin), executed for the
    whole process population inside the vectorized engine's lockstep
    window.  Halo state lives in an ``(n, 4, L)`` array the engine scatters
    delivered edge payloads into; slots no injected neighbor feeds stay at
    the -1 sentinel (a color no node holds), matching ``_Fragment``.
    """

    def __init__(self, app: "GraphColorApp"):
        import jax.numpy as jnp
        from repro.runtime.topologies import halo_slot_map
        assert app.injected is not None, \
            "batched graphcolor needs an injected Topology"
        self.cfg = app.cfg
        self.app = app
        self.n = app.cfg.n_processes
        self.H, self.W = app.block
        self.L = max(self.H, self.W)
        self.payload_len = self.L
        self.payload_dtype = jnp.int32
        fed = np.zeros((self.n, 4), dtype=bool)
        for p in range(self.n):
            for s in halo_slot_map(app.injected.neighbors[p]).values():
                fed[p, s] = True
        self.fed = fed

    def _edges_np(self, colors: np.ndarray) -> np.ndarray:
        """(n, H, W) block colors -> (n, 4, L) n/s/w/e edge rows (0-padded)."""
        n, H, W = colors.shape
        out = np.zeros((n, 4, self.L), dtype=np.int32)
        out[:, 0, :W] = colors[:, 0, :]
        out[:, 1, :W] = colors[:, -1, :]
        out[:, 2, :H] = colors[:, :, 0]
        out[:, 3, :H] = colors[:, :, -1]
        return out

    def init(self, seed: int):
        import jax.numpy as jnp
        cfg, n, H, W = self.cfg, self.n, self.H, self.W
        colors = np.empty((n, H, W), np.int32)
        for p in range(n):
            rng = np.random.default_rng((seed, p))
            colors[p] = rng.integers(0, cfg.n_colors, size=(H, W))
        probs = np.full((n, H, W, cfg.n_colors), 1.0 / cfg.n_colors,
                        np.float32)
        carried = (self.app.initial_state or {}).get(int(seed)) or {}
        for p, state in carried.items():
            colors[p] = state["colors"]
            probs[p] = state["probs"]
        halo = np.where(self.fed[:, :, None], self._edges_np(colors),
                        np.int32(-1))
        state = dict(colors=jnp.asarray(colors), probs=jnp.asarray(probs))
        return state, jnp.asarray(halo)

    def export_state(self, state) -> Dict[int, dict]:
        """Per-pid numpy snapshot of one replicate's final app state, in the
        same layout :meth:`GraphColorApp.export_state` produces — so the
        service layer can carry state across epochs engine-agnostically."""
        colors = np.asarray(state["colors"])
        probs = np.asarray(state["probs"])
        return {p: {"colors": colors[p].copy(), "probs": probs[p].copy()}
                for p in range(self.n)}

    def step(self, state, halo, steps, seed, pids=None):
        """One population step.  ``pids`` are the *original* process ids of
        the rows in ``state`` — the sharded engine passes each shard's slice
        so counter-hash draws are identical under any shard layout; ``None``
        means the identity layout (rows 0..n-1)."""
        import jax
        import jax.numpy as jnp
        from repro.runtime.window_core import STREAM_APP, hash_uniform
        H, W, L = self.H, self.W, self.L
        b, C = self.cfg.b, self.cfg.n_colors
        colors, probs = state["colors"], state["probs"]
        hn, hs = halo[:, 0, :W], halo[:, 1, :W]
        hw, he = halo[:, 2, :H], halo[:, 3, :H]

        # batched jnp_update_block: population axis in front of (H, W)
        up = jnp.concatenate([hn[:, None, :], colors[:, :-1]], axis=1)
        down = jnp.concatenate([colors[:, 1:], hs[:, None, :]], axis=1)
        left = jnp.concatenate([hw[:, :, None], colors[:, :, :-1]], axis=2)
        right = jnp.concatenate([colors[:, :, 1:], he[:, :, None]], axis=2)
        conflict = ((colors == up) | (colors == down)
                    | (colors == left) | (colors == right))
        onehot = jax.nn.one_hot(colors, C)
        fail_p = (1 - b) * probs + b * (1 - onehot) / (C - 1)
        new_probs = jnp.where(conflict[..., None], fail_p, onehot)
        # counter-hash resample draw: ~10 integer ops per node, much
        # cheaper in the scan hot loop than per-process threefry folding.
        # cells are keyed by original pid so shard layouts draw identically
        if pids is None:
            pids = jnp.arange(colors.shape[0], dtype=jnp.int32)
        cell = (pids[:, None, None] * np.int32(H * W)
                + jnp.arange(H * W, dtype=jnp.int32).reshape(H, W))
        u = hash_uniform(seed, STREAM_APP, steps[:, None, None],
                         cell)[..., None]
        cdf = jnp.cumsum(new_probs, axis=-1)
        # clip: float32 cumsum can leave cdf[-1] a few ulps below 1
        sampled = jnp.minimum((u > cdf).sum(-1), C - 1)
        new_colors = jnp.where(conflict, sampled, colors)

        pad_w, pad_h = ((0, 0), (0, L - W)), ((0, 0), (0, L - H))
        edges = jnp.stack([
            jnp.pad(new_colors[:, 0, :], pad_w),
            jnp.pad(new_colors[:, -1, :], pad_w),
            jnp.pad(new_colors[:, :, 0], pad_h),
            jnp.pad(new_colors[:, :, -1], pad_h)], axis=1)
        return dict(colors=new_colors, probs=new_probs), edges

    def quality(self, state) -> float:
        """Same global-conflict count as ``GraphColorApp.quality``."""
        colors = np.asarray(state["colors"])
        gh, gw = self.app.grid
        H, W = self.H, self.W
        full = np.zeros((gh * H, gw * W), dtype=int)
        for p in range(self.n):
            r, c = divmod(p, gw)
            full[r * H:(r + 1) * H, c * W:(c + 1) * W] = colors[p]
        return float((full == np.roll(full, 1, 0)).sum()
                     + (full == np.roll(full, 1, 1)).sum())


# ---------------------------------------------------------------------------
# SPMD in-graph version (shard_map + Conduit) — the TPU-native form
# ---------------------------------------------------------------------------
def jnp_update_block(colors, probs, halo, b, key):
    """jnp twin of ``_update_block`` (same math, vectorized full-block)."""
    import jax
    import jax.numpy as jnp

    H, W = colors.shape
    up = jnp.concatenate([halo["n"][None, :], colors[:-1]], 0)
    down = jnp.concatenate([colors[1:], halo["s"][None, :]], 0)
    left = jnp.concatenate([halo["w"][:, None], colors[:, :-1]], 1)
    right = jnp.concatenate([colors[:, 1:], halo["e"][:, None]], 1)
    conflict = ((colors == up) | (colors == down)
                | (colors == left) | (colors == right))

    C = probs.shape[-1]
    onehot = jax.nn.one_hot(colors, C)
    # success: concentrate on current color
    success_p = onehot
    # failure: decay + redistribute a b-fraction over the other colors
    fail_p = (1 - b) * probs + b * (1 - onehot) / (C - 1)
    new_probs = jnp.where(conflict[..., None], fail_p, success_p)

    u = jax.random.uniform(key, (H, W, 1))
    cdf = jnp.cumsum(new_probs, axis=-1)
    # clip: float32 cumsum can leave cdf[-1] a few ulps below 1
    sampled = jnp.minimum((u > cdf).sum(-1), C - 1)
    new_colors = jnp.where(conflict, sampled, colors)
    return new_colors, new_probs, conflict


def spmd_step(state, row_conduit, col_conduit, b, flush=None):
    """One best-effort SPMD update for use inside shard_map over a 2-D mesh.

    state: {"colors","probs","bufs_row","bufs_col","key","step"} — each
    device holds one (H,W) block; halos travel over mesh-axis conduits with
    the conduit's asynchronicity-mode semantics.
    """
    import jax
    import jax.numpy as jnp

    colors, probs = state["colors"], state["probs"]
    # publish edges; conduits deliver per their mode (fresh/stale/never)
    row_payload = jnp.stack([colors[0], colors[-1]])       # my n/s edges
    col_payload = jnp.stack([colors[:, 0], colors[:, -1]])  # my w/e edges
    rec_row, bufs_row = row_conduit.exchange(row_payload, state["bufs_row"], flush=flush)
    rec_col, bufs_col = col_conduit.exchange(col_payload, state["bufs_col"], flush=flush)
    halo = {
        "n": rec_row["north"][1],  # north neighbor's south edge
        "s": rec_row["south"][0],
        "w": rec_col["west"][1],
        "e": rec_col["east"][0],
    }
    key, sub = jax.random.split(state["key"])
    new_colors, new_probs, conflict = jnp_update_block(colors, probs, halo, b, sub)
    return {
        "colors": new_colors, "probs": new_probs,
        "bufs_row": bufs_row, "bufs_col": bufs_col,
        "key": key, "step": state["step"] + 1,
    }, conflict.sum()
