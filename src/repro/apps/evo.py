"""Digital-evolution benchmark analogue (paper §II-A, DISHTINY-flavored).

A compute-heavy artificial-life workload: each fragment hosts a toroidal
grid of cells with genomes (fixed-length integer programs), resource levels,
and neighbor interactions.  Per update every cell "executes" its genome for
several rounds (vectorized integer arithmetic standing in for SignalGP
interpretation — the compute-heavy part), collects resource, shares resource
across fragment boundaries via best-effort channels, and reproduces into the
weakest neighboring cell when its resource exceeds a threshold.

Quality (the paper leaves open-ended-evolution quality undefined) is the mean
genome fitness toward a fixed target pattern — monotone-improving, so
fixed-time-budget comparisons across asynchronicity modes are meaningful.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.apps.graphcolor import _OPP, block_shape, direction_map, proc_grid


@dataclasses.dataclass(frozen=True)
class EvoConfig:
    n_processes: int = 4
    cells_per_process: int = 3600      # paper: 3600 cells per process
    genome_len: int = 16
    exec_rounds: int = 8               # genome interpretation rounds/update
    resource_inflow: float = 0.25
    spawn_threshold: float = 1.0
    share_frac: float = 0.1            # resource shared to each neighbor side
    mutation_rate: float = 0.05
    seed: int = 0


class _Fragment:
    def __init__(self, pid, cfg: EvoConfig, grid, block, self_wrap,
                 nbr_dirs: Optional[Dict[int, str]] = None):
        self.pid = pid
        self.cfg = cfg
        self.grid = grid
        self.self_wrap = self_wrap
        self.nbr_dirs = nbr_dirs  # injected topology: neighbor -> halo slot
        # halo slots no injected neighbor feeds behave reflectively (mirror
        # our own edge) instead of draining resource into phantom zeros
        self._unfed = (set("nswe") - set(nbr_dirs.values())
                       if nbr_dirs is not None else set())
        H, W = block
        self.rng = np.random.default_rng((cfg.seed, pid))
        self.genomes = self.rng.integers(0, 256, size=(H, W, cfg.genome_len),
                                         dtype=np.int64)
        self.resource = np.zeros((H, W))
        self.target = np.arange(cfg.genome_len, dtype=np.int64) * 16 % 256
        self.halo_res = {"n": np.zeros(W), "s": np.zeros(W),
                         "w": np.zeros(H), "e": np.zeros(H)}

    def neighbors(self) -> Dict[str, int]:
        gh, gw = self.grid
        r, c = divmod(self.pid, gw)
        out = {}
        if not self.self_wrap["ns"]:
            out["n"] = ((r - 1) % gh) * gw + c
            out["s"] = ((r + 1) % gh) * gw + c
        if not self.self_wrap["ew"]:
            out["w"] = r * gw + (c - 1) % gw
            out["e"] = r * gw + (c + 1) % gw
        return out

    # -- the compute-heavy part ---------------------------------------------
    def _execute_genomes(self):
        """Vectorized 'interpretation': repeated integer mixing rounds."""
        g = self.genomes
        acc = np.zeros(g.shape[:2], dtype=np.int64)
        state = g.sum(axis=-1)
        for r in range(self.cfg.exec_rounds):
            instr = g[..., r % self.cfg.genome_len]
            state = (state * 6364136223846793005 + instr * 1442695040888963407
                     ) & 0x7FFFFFFFFFFFFFFF
            acc ^= state >> 17
        return acc

    def fitness(self) -> np.ndarray:
        """Per-cell fitness in [0,1]: genome proximity to the target."""
        diff = np.abs(self.genomes - self.target[None, None, :])
        return 1.0 - diff.mean(axis=-1) / 128.0

    def update(self, inbox: Dict[int, Optional[dict]]):
        cfg = self.cfg
        if self.nbr_dirs is not None:
            for nb, payload in inbox.items():
                if payload is not None:
                    d = self.nbr_dirs[nb]
                    self.halo_res[d] = payload[_OPP[d]]
            r = self.resource
            own_edge = {"n": r[0], "s": r[-1], "w": r[:, 0], "e": r[:, -1]}
            for d in self._unfed:
                self.halo_res[d] = own_edge[d]
        else:
            nbs = self.neighbors()
            for d, nb in nbs.items():
                payload = inbox.get(nb)
                if payload is not None:
                    self.halo_res[d] = payload[_OPP[d]]

        self._execute_genomes()  # compute-heavy interpretation step

        fit = self.fitness()
        self.resource += cfg.resource_inflow * fit

        # resource sharing: diffuse with 4 neighbors (internal + halo)
        r = self.resource
        up = np.vstack([self.halo_res["n"][None], r[:-1]]) if not self.self_wrap["ns"] \
            else np.vstack([r[-1:], r[:-1]])
        down = np.vstack([r[1:], self.halo_res["s"][None]]) if not self.self_wrap["ns"] \
            else np.vstack([r[1:], r[:1]])
        left = np.hstack([self.halo_res["w"][:, None], r[:, :-1]]) if not self.self_wrap["ew"] \
            else np.hstack([r[:, -1:], r[:, :-1]])
        right = np.hstack([r[:, 1:], self.halo_res["e"][:, None]]) if not self.self_wrap["ew"] \
            else np.hstack([r[:, 1:], r[:, :1]])
        mean_nb = (up + down + left + right) / 4.0
        self.resource = (1 - cfg.share_frac) * r + cfg.share_frac * mean_nb

        # reproduction: spawners overwrite their weakest rolled neighbor
        spawners = self.resource > cfg.spawn_threshold
        if spawners.any():
            fit_rolled = np.stack([np.roll(fit, s, axis=a)
                                   for s, a in ((1, 0), (-1, 0), (1, 1), (-1, 1))])
            weakest_dir = fit_rolled.argmin(axis=0)
            shifts = [(1, 0), (-1, 0), (1, 1), (-1, 1)]
            new_genomes = self.genomes.copy()
            new_resource = self.resource.copy()
            ys, xs = np.where(spawners)
            H, W = fit.shape
            for y, x in zip(ys, xs):
                s, a = shifts[weakest_dir[y, x]]
                # np.roll(fit, s, a)[y, x] == fit[y-s, x] — the weakest
                # neighbor sits at the NEGATIVE offset
                ty = (y - (s if a == 0 else 0)) % H
                tx = (x - (s if a == 1 else 0)) % W
                child = self.genomes[y, x].copy()
                mut = self.rng.random(cfg.genome_len) < cfg.mutation_rate
                child[mut] = np.clip(
                    child[mut] + self.rng.integers(-16, 17, mut.sum()), 0, 255)
                # nudge toward target occasionally (selection pressure proxy)
                new_genomes[ty, tx] = child
                new_resource[y, x] *= 0.5
            self.genomes = new_genomes
            self.resource = new_resource

        edges = {"n": self.resource[0].copy(), "s": self.resource[-1].copy(),
                 "w": self.resource[:, 0].copy(), "e": self.resource[:, -1].copy()}
        if self.nbr_dirs is not None:
            return {nb: edges for nb in self.nbr_dirs}
        return {nb: edges for nb in set(nbs.values())}


# ---------------------------------------------------------------------------
# Population-batched form — what the vectorized engine scans (DESIGN.md §7)
# ---------------------------------------------------------------------------
class BatchedEvo:
    """All fragments' evolution updates as one step over flat arrays.

    Mirrors ``_Fragment.update``: genome interpretation (uint32 mixing
    rounds — the accumulator is carried in the state so the compute-heavy
    part cannot be dead-code-eliminated out of the scan), resource inflow +
    diffusion over halo rows, and reproduction into the weakest rolled
    neighbor (conflicting spawners resolve last-direction-wins instead of
    spawner-order — DESIGN.md §7).  Halo slots no injected neighbor feeds
    behave reflectively, as in the event-engine fragment.
    """

    _SHIFTS = ((1, 0), (-1, 0), (1, 1), (-1, 1))

    def __init__(self, app: "EvoApp"):
        import jax.numpy as jnp
        from repro.runtime.topologies import halo_slot_map
        assert app.injected is not None, \
            "batched evo needs an injected Topology"
        self.cfg = app.cfg
        self.n = app.cfg.n_processes
        self.H, self.W = app.block
        self.L = max(self.H, self.W)
        self.payload_len = self.L
        self.payload_dtype = jnp.float32
        self.target = (np.arange(app.cfg.genome_len, dtype=np.int32)
                       * 16 % 256)
        fed = np.zeros((self.n, 4), dtype=bool)
        for p in range(self.n):
            for s in halo_slot_map(app.injected.neighbors[p]).values():
                fed[p, s] = True
        self.fed = fed

    def init(self, seed: int):
        import jax.numpy as jnp
        cfg, n, H, W = self.cfg, self.n, self.H, self.W
        genomes = np.empty((n, H, W, cfg.genome_len), np.int32)
        for p in range(n):
            rng = np.random.default_rng((seed, p))
            genomes[p] = rng.integers(0, 256, size=(H, W, cfg.genome_len))
        state = dict(genomes=jnp.asarray(genomes),
                     resource=jnp.zeros((n, H, W), jnp.float32),
                     acc=jnp.zeros((n, H, W), jnp.uint32))
        return state, jnp.zeros((n, 4, self.L), jnp.float32)

    def _own_edges(self, r):
        import jax.numpy as jnp
        L, H, W = self.L, self.H, self.W
        pad_w, pad_h = ((0, 0), (0, L - W)), ((0, 0), (0, L - H))
        return jnp.stack([
            jnp.pad(r[:, 0, :], pad_w), jnp.pad(r[:, -1, :], pad_w),
            jnp.pad(r[:, :, 0], pad_h), jnp.pad(r[:, :, -1], pad_h)], axis=1)

    def step(self, state, halo, steps, seed, pids=None):
        """One population step; ``pids`` are the original process ids of the
        rows in ``state`` (the sharded engine passes its shard's slice so
        mutation draws are layout-independent; ``None`` = identity)."""
        import jax.numpy as jnp
        from repro.runtime.window_core import STREAM_MUT, hash_uniform
        cfg, H, W = self.cfg, self.H, self.W
        g, r = state["genomes"], state["resource"]
        G = cfg.genome_len

        # reflective unfed slots: mirror our own edge, never drain resource
        fed = jnp.asarray(self.fed)
        if pids is not None:
            fed = fed[pids]  # shard-local rows of the global (n, 4) mask
        halo_eff = jnp.where(fed[:, :, None], halo, self._own_edges(r))
        hn, hs = halo_eff[:, 0, :W], halo_eff[:, 1, :W]
        hw, he = halo_eff[:, 2, :H], halo_eff[:, 3, :H]

        # genome "interpretation": uint32 mixing rounds (compute-heavy)
        st = g.sum(axis=-1).astype(jnp.uint32)
        acc = state["acc"]
        for rr in range(cfg.exec_rounds):
            instr = g[..., rr % G].astype(jnp.uint32)
            st = st * np.uint32(2654435761) + instr * np.uint32(2246822519)
            acc = acc ^ (st >> np.uint32(17))

        fit = 1.0 - jnp.abs(g - self.target[None, None, None, :]
                            ).mean(axis=-1) / 128.0
        r = r + cfg.resource_inflow * fit

        # resource diffusion over internal cells + halo rows (no wrap)
        up = jnp.concatenate([hn[:, None, :], r[:, :-1]], axis=1)
        down = jnp.concatenate([r[:, 1:], hs[:, None, :]], axis=1)
        left = jnp.concatenate([hw[:, :, None], r[:, :, :-1]], axis=2)
        right = jnp.concatenate([r[:, :, 1:], he[:, :, None]], axis=2)
        mean_nb = (up + down + left + right) / 4.0
        r = (1 - cfg.share_frac) * r + cfg.share_frac * mean_nb

        # reproduction: spawners overwrite their weakest rolled neighbor
        spawn = r > cfg.spawn_threshold
        fit_rolled = jnp.stack([jnp.roll(fit, s, axis=a + 1)
                                for s, a in self._SHIFTS])
        weakest = fit_rolled.argmin(axis=0)
        # cells keyed by original pid: shard layouts draw identically
        if pids is None:
            pids = jnp.arange(g.shape[0], dtype=jnp.int32)
        cell = (pids[:, None, None, None] * np.int32(H * W * G)
                + jnp.arange(H * W * G, dtype=jnp.int32).reshape(H, W, G))
        step_k = steps[:, None, None, None]
        mut = hash_uniform(seed, STREAM_MUT, step_k, cell) < cfg.mutation_rate
        delta = jnp.floor(
            hash_uniform(seed, STREAM_MUT, step_k, cell, 7) * 33
        ).astype(jnp.int32) - 16
        child = jnp.clip(g + jnp.where(mut, delta, 0), 0, 255)
        new_g = g
        for d, (s, a) in enumerate(self._SHIFTS):
            lands = jnp.roll(spawn & (weakest == d), -s, axis=a + 1)
            new_g = jnp.where(lands[..., None],
                              jnp.roll(child, -s, axis=a + 1), new_g)
        r = jnp.where(spawn, r * 0.5, r)

        state = dict(genomes=new_g, resource=r, acc=acc)
        return state, self._own_edges(r)

    def quality(self, state) -> float:
        g = np.asarray(state["genomes"])
        diff = np.abs(g - self.target[None, None, None, :])
        return float((1.0 - diff.mean(axis=-1) / 128.0).mean())


class EvoApp:
    def __init__(self, cfg: EvoConfig, topology=None):
        self.cfg = cfg
        self.n_processes = cfg.n_processes
        self.grid = proc_grid(cfg.n_processes)
        self.block = block_shape(cfg.cells_per_process)
        self.self_wrap = {"ns": self.grid[0] == 1, "ew": self.grid[1] == 1}
        if topology is not None:
            assert topology.n == cfg.n_processes, \
                f"topology is for {topology.n} processes, app has {cfg.n_processes}"
        self.injected = topology  # runtime.topologies.Topology or None

    def make_fragments(self) -> List[_Fragment]:
        if self.injected is not None:
            no_wrap = {"ns": False, "ew": False}
            return [_Fragment(i, self.cfg, self.grid, self.block, no_wrap,
                              nbr_dirs=direction_map(self.injected.neighbors[i]))
                    for i in range(self.cfg.n_processes)]
        return [_Fragment(i, self.cfg, self.grid, self.block, self.self_wrap)
                for i in range(self.cfg.n_processes)]

    def topology(self):
        if self.injected is not None:
            return self.injected
        out = {}
        for i in range(self.cfg.n_processes):
            f = _Fragment.__new__(_Fragment)
            f.pid, f.grid, f.self_wrap = i, self.grid, self.self_wrap
            out[i] = sorted(set(f.neighbors().values()) - {i})
        return out

    def batched(self) -> "BatchedEvo":
        """Population-batched entry point for the vectorized engine."""
        return BatchedEvo(self)

    def quality(self, fragments) -> float:
        return float(np.mean([f.fitness().mean() for f in fragments]))
