"""Digital-evolution benchmark analogue (paper §II-A, DISHTINY-flavored).

A compute-heavy artificial-life workload: each fragment hosts a toroidal
grid of cells with genomes (fixed-length integer programs), resource levels,
and neighbor interactions.  Per update every cell "executes" its genome for
several rounds (vectorized integer arithmetic standing in for SignalGP
interpretation — the compute-heavy part), collects resource, shares resource
across fragment boundaries via best-effort channels, and reproduces into the
weakest neighboring cell when its resource exceeds a threshold.

Quality (the paper leaves open-ended-evolution quality undefined) is the mean
genome fitness toward a fixed target pattern — monotone-improving, so
fixed-time-budget comparisons across asynchronicity modes are meaningful.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.apps.graphcolor import _OPP, block_shape, direction_map, proc_grid


@dataclasses.dataclass(frozen=True)
class EvoConfig:
    n_processes: int = 4
    cells_per_process: int = 3600      # paper: 3600 cells per process
    genome_len: int = 16
    exec_rounds: int = 8               # genome interpretation rounds/update
    resource_inflow: float = 0.25
    spawn_threshold: float = 1.0
    share_frac: float = 0.1            # resource shared to each neighbor side
    mutation_rate: float = 0.05
    seed: int = 0


class _Fragment:
    def __init__(self, pid, cfg: EvoConfig, grid, block, self_wrap,
                 nbr_dirs: Optional[Dict[int, str]] = None):
        self.pid = pid
        self.cfg = cfg
        self.grid = grid
        self.self_wrap = self_wrap
        self.nbr_dirs = nbr_dirs  # injected topology: neighbor -> halo slot
        # halo slots no injected neighbor feeds behave reflectively (mirror
        # our own edge) instead of draining resource into phantom zeros
        self._unfed = (set("nswe") - set(nbr_dirs.values())
                       if nbr_dirs is not None else set())
        H, W = block
        self.rng = np.random.default_rng((cfg.seed, pid))
        self.genomes = self.rng.integers(0, 256, size=(H, W, cfg.genome_len),
                                         dtype=np.int64)
        self.resource = np.zeros((H, W))
        self.target = np.arange(cfg.genome_len, dtype=np.int64) * 16 % 256
        self.halo_res = {"n": np.zeros(W), "s": np.zeros(W),
                         "w": np.zeros(H), "e": np.zeros(H)}

    def neighbors(self) -> Dict[str, int]:
        gh, gw = self.grid
        r, c = divmod(self.pid, gw)
        out = {}
        if not self.self_wrap["ns"]:
            out["n"] = ((r - 1) % gh) * gw + c
            out["s"] = ((r + 1) % gh) * gw + c
        if not self.self_wrap["ew"]:
            out["w"] = r * gw + (c - 1) % gw
            out["e"] = r * gw + (c + 1) % gw
        return out

    # -- the compute-heavy part ---------------------------------------------
    def _execute_genomes(self):
        """Vectorized 'interpretation': repeated integer mixing rounds."""
        g = self.genomes
        acc = np.zeros(g.shape[:2], dtype=np.int64)
        state = g.sum(axis=-1)
        for r in range(self.cfg.exec_rounds):
            instr = g[..., r % self.cfg.genome_len]
            state = (state * 6364136223846793005 + instr * 1442695040888963407
                     ) & 0x7FFFFFFFFFFFFFFF
            acc ^= state >> 17
        return acc

    def fitness(self) -> np.ndarray:
        """Per-cell fitness in [0,1]: genome proximity to the target."""
        diff = np.abs(self.genomes - self.target[None, None, :])
        return 1.0 - diff.mean(axis=-1) / 128.0

    def update(self, inbox: Dict[int, Optional[dict]]):
        cfg = self.cfg
        if self.nbr_dirs is not None:
            for nb, payload in inbox.items():
                if payload is not None:
                    d = self.nbr_dirs[nb]
                    self.halo_res[d] = payload[_OPP[d]]
            r = self.resource
            own_edge = {"n": r[0], "s": r[-1], "w": r[:, 0], "e": r[:, -1]}
            for d in self._unfed:
                self.halo_res[d] = own_edge[d]
        else:
            nbs = self.neighbors()
            for d, nb in nbs.items():
                payload = inbox.get(nb)
                if payload is not None:
                    self.halo_res[d] = payload[_OPP[d]]

        self._execute_genomes()  # compute-heavy interpretation step

        fit = self.fitness()
        self.resource += cfg.resource_inflow * fit

        # resource sharing: diffuse with 4 neighbors (internal + halo)
        r = self.resource
        up = np.vstack([self.halo_res["n"][None], r[:-1]]) if not self.self_wrap["ns"] \
            else np.vstack([r[-1:], r[:-1]])
        down = np.vstack([r[1:], self.halo_res["s"][None]]) if not self.self_wrap["ns"] \
            else np.vstack([r[1:], r[:1]])
        left = np.hstack([self.halo_res["w"][:, None], r[:, :-1]]) if not self.self_wrap["ew"] \
            else np.hstack([r[:, -1:], r[:, :-1]])
        right = np.hstack([r[:, 1:], self.halo_res["e"][:, None]]) if not self.self_wrap["ew"] \
            else np.hstack([r[:, 1:], r[:, :1]])
        mean_nb = (up + down + left + right) / 4.0
        self.resource = (1 - cfg.share_frac) * r + cfg.share_frac * mean_nb

        # reproduction: spawners overwrite their weakest rolled neighbor
        spawners = self.resource > cfg.spawn_threshold
        if spawners.any():
            fit_rolled = np.stack([np.roll(fit, s, axis=a)
                                   for s, a in ((1, 0), (-1, 0), (1, 1), (-1, 1))])
            weakest_dir = fit_rolled.argmin(axis=0)
            shifts = [(1, 0), (-1, 0), (1, 1), (-1, 1)]
            new_genomes = self.genomes.copy()
            new_resource = self.resource.copy()
            ys, xs = np.where(spawners)
            H, W = fit.shape
            for y, x in zip(ys, xs):
                s, a = shifts[weakest_dir[y, x]]
                # np.roll(fit, s, a)[y, x] == fit[y-s, x] — the weakest
                # neighbor sits at the NEGATIVE offset
                ty = (y - (s if a == 0 else 0)) % H
                tx = (x - (s if a == 1 else 0)) % W
                child = self.genomes[y, x].copy()
                mut = self.rng.random(cfg.genome_len) < cfg.mutation_rate
                child[mut] = np.clip(
                    child[mut] + self.rng.integers(-16, 17, mut.sum()), 0, 255)
                # nudge toward target occasionally (selection pressure proxy)
                new_genomes[ty, tx] = child
                new_resource[y, x] *= 0.5
            self.genomes = new_genomes
            self.resource = new_resource

        edges = {"n": self.resource[0].copy(), "s": self.resource[-1].copy(),
                 "w": self.resource[:, 0].copy(), "e": self.resource[:, -1].copy()}
        if self.nbr_dirs is not None:
            return {nb: edges for nb in self.nbr_dirs}
        return {nb: edges for nb in set(nbs.values())}


class EvoApp:
    def __init__(self, cfg: EvoConfig, topology=None):
        self.cfg = cfg
        self.n_processes = cfg.n_processes
        self.grid = proc_grid(cfg.n_processes)
        self.block = block_shape(cfg.cells_per_process)
        self.self_wrap = {"ns": self.grid[0] == 1, "ew": self.grid[1] == 1}
        if topology is not None:
            assert topology.n == cfg.n_processes, \
                f"topology is for {topology.n} processes, app has {cfg.n_processes}"
        self.injected = topology  # runtime.topologies.Topology or None

    def make_fragments(self) -> List[_Fragment]:
        if self.injected is not None:
            no_wrap = {"ns": False, "ew": False}
            return [_Fragment(i, self.cfg, self.grid, self.block, no_wrap,
                              nbr_dirs=direction_map(self.injected.neighbors[i]))
                    for i in range(self.cfg.n_processes)]
        return [_Fragment(i, self.cfg, self.grid, self.block, self.self_wrap)
                for i in range(self.cfg.n_processes)]

    def topology(self):
        if self.injected is not None:
            return self.injected
        out = {}
        for i in range(self.cfg.n_processes):
            f = _Fragment.__new__(_Fragment)
            f.pid, f.grid, f.self_wrap = i, self.grid, self.self_wrap
            out[i] = sorted(set(f.neighbors().values()) - {i})
        return out

    def quality(self, fragments) -> float:
        return float(np.mean([f.fitness().mean() for f in fragments]))
