from repro.apps import evo, graphcolor  # noqa: F401
