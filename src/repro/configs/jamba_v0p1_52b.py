"""jamba-v0.1-52b [hybrid] — Mamba + attention 1:7 interleave, MoE 16e top-2 on
every other layer [arXiv:2403.19887].
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    num_experts=16,
    experts_per_tok=2,
    moe_d_ff=14336,
    moe_period=2,
    moe_offset=1,
    block_pattern=(
        "mamba", "mamba", "mamba", "mamba",
        "attn", "mamba", "mamba", "mamba",
    ),
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    # §Perf cell B: SP residual transitions cost more than they save in this
    # hybrid stack (period=8 => only 4 scan carries stored); disabling SP cut
    # memory 4.06->2.81s and collective 3.68->2.62s.  See EXPERIMENTS.md.
    seq_sharded_residual=False,
))
