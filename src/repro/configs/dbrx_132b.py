"""dbrx-132b [moe] — 16 experts top-4, per-expert hidden 10752
[hf:databricks/dbrx-base].
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    num_experts=16,
    experts_per_tok=4,
    moe_d_ff=10752,
    moe_period=1,
    rope_theta=5e5,
))
