"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6, fine-grained experts
(per-expert hidden 1408) [arXiv:2401.06066].
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    num_experts=64,
    experts_per_tok=6,
    num_shared_experts=2,
    moe_d_ff=1408,
    moe_period=1,
))
