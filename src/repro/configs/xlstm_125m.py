"""xlstm-125m [ssm] — sLSTM + mLSTM blocks, ratio 5:1 (xLSTM[7:1]-style mix),
d_ff=0 (block-internal projections) [arXiv:2405.04517].
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm", "mlstm", "slstm", "mlstm", "mlstm", "mlstm"),
    xlstm_proj_factor=2.0,
    tie_embeddings=True,
    # §Perf cell A: TP over 16 chips is counterproductive at d_model=768 /
    # 4 heads (replicated quadratic compute + activation all-reduces).
    # Pure DP cut the collective term 33x; see EXPERIMENTS.md §Perf.
    sharding_profile="dp_only",
))
