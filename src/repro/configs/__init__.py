"""Architecture registry — importing this package registers all configs."""
from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    ShapeConfig,
    get_config,
    list_configs,
    register,
    shape_applicable,
)

# importing each module registers its CONFIG
from repro.configs import (  # noqa: F401
    dbrx_132b,
    deepseek_moe_16b,
    jamba_v0p1_52b,
    llava_next_mistral_7b,
    minitron_8b,
    musicgen_large,
    qwen2_1p5b,
    qwen25_3b,
    qwen3_0p6b,
    xlstm_125m,
)

ARCHS = list_configs()
