"""llava-next-mistral-7b [vlm] — mistral-7b backbone; anyres vision tiling is a
STUB: ``input_specs()`` provides precomputed patch embeddings for the first
``frontend_len`` sequence positions [hf:llava-hf/llava-v1.6-mistral-7b-hf].
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    frontend="vision",
    frontend_len=576,
    rope_theta=1e6,
))
