"""Configuration dataclasses for architectures and input shapes.

Every assigned architecture is expressed as a ``ModelConfig``; benchmark input
shapes are ``ShapeConfig``.  Configs are plain frozen dataclasses so they can be
hashed into jit static args and printed into experiment logs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters (decoder-only LM backbone)."""

    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # --- mixture of experts -------------------------------------------------
    num_experts: int = 0
    experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0           # per-expert hidden dim (fine-grained MoE)
    moe_period: int = 1         # MoE FFN at layers where layer % moe_period == moe_offset
    moe_offset: int = 0

    # --- block pattern (tiled to num_layers): attn | mamba | mlstm | slstm --
    block_pattern: Tuple[str, ...] = ("attn",)

    # --- mamba (jamba) ------------------------------------------------------
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # --- xlstm --------------------------------------------------------------
    xlstm_proj_factor: float = 2.0

    # --- modality frontend (stub: precomputed embeddings) -------------------
    frontend: Optional[str] = None      # None | "audio" | "vision"
    frontend_len: int = 0               # prefix positions fed from the frontend

    # --- numerics / memory --------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    scan_layers: bool = True
    grad_accum: int = 1                 # microbatch count for train_step

    # --- distribution -------------------------------------------------------
    # "2d": FSDP over data axes x TP over model (default);
    # "dp_only": pure data parallelism over every non-pod axis, params
    #            replicated — right for small-width models where TP forces
    #            replicated compute + activation all-reduces (see §Perf).
    sharding_profile: str = "2d"
    # sequence-parallel residual stream (Megatron-SP): shards the residual's
    # seq dim over the model axis between blocks; trades gather/scatter
    # traffic for 1/TP residual memory (see §Perf cell B).
    seq_sharded_residual: bool = True

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def pattern_period(self) -> int:
        return len(self.block_pattern)

    def kind_at(self, layer: int) -> str:
        return self.block_pattern[layer % self.pattern_period]

    def moe_at(self, layer: int) -> bool:
        return self.num_experts > 0 and (layer % self.moe_period) == self.moe_offset

    @property
    def attention_free(self) -> bool:
        return all(k != "attn" for k in self.block_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can serve 500k-token contexts (SSM/hybrid state decode)."""
        return any(k in ("mamba", "mlstm", "slstm") for k in self.block_pattern)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """A benchmark input shape."""

    name: str
    kind: str           # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k needs sub-quadratic sequence mixing (see DESIGN.md §6)."""
    if shape.name == "long_500k":
        return cfg.sub_quadratic
    return True


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # populate registry lazily
    from repro import configs as _c  # noqa: F401

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list:
    from repro import configs as _c  # noqa: F401

    return sorted(_REGISTRY)
