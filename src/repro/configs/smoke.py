"""Reduced configs for CPU smoke tests: same family/structure, tiny sizes.

The reduced config preserves everything structural (block pattern, GQA-ness,
MoE periodicity, qk_norm/bias flags, frontend) while shrinking width, depth,
and vocab so one forward/train step runs in milliseconds on CPU.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    from repro.models.transformer import block_specs  # avoid import cycle

    period = len(block_specs(cfg))
    kv = 2 if cfg.num_kv_heads < cfg.num_heads else 4
    return cfg.replace(
        name=cfg.name + "-smoke",
        num_layers=period * (2 if period == 1 else 1),
        d_model=64,
        num_heads=4,
        num_kv_heads=kv,
        head_dim=16 if cfg.head_dim is not None else None,
        d_ff=128 if cfg.d_ff > 0 else 0,
        vocab_size=503,
        num_experts=min(8, cfg.num_experts),
        experts_per_tok=min(2, cfg.experts_per_tok),
        moe_d_ff=32 if cfg.num_experts else 0,
        frontend_len=8 if cfg.frontend else 0,
        grad_accum=1,
    )
