"""musicgen-large [audio] — decoder-only over EnCodec tokens [arXiv:2306.05284].

The EnCodec/conditioning frontend is a STUB: ``input_specs()`` provides
precomputed conditioning-frame embeddings occupying the first ``frontend_len``
positions of the sequence (see models/modality.py).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    frontend="audio",
    frontend_len=256,
))
