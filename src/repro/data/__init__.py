from repro.data.pipeline import Pipeline  # noqa: F401
from repro.data.synthetic import DataConfig, SyntheticLM  # noqa: F401
