"""Data pipeline: background prefetch + device placement with shardings.

The generator thread stays one step ahead of the training loop (host compute
overlaps device compute) — the data-side analogue of taking communication off
the critical path.
"""
from __future__ import annotations

import queue
import threading
from typing import Optional

import jax

from repro.data.synthetic import DataConfig, SyntheticLM
from repro.models import modality


class Pipeline:
    def __init__(self, data_cfg: DataConfig, model_cfg, start_step: int = 0,
                 shardings: Optional[dict] = None, prefetch: int = 2):
        self.source = SyntheticLM(data_cfg)
        self.model_cfg = model_cfg
        self.shardings = shardings
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _make_batch(self, step: int) -> dict:
        batch = self.source.batch_for_step(step)
        cfg = self.model_cfg
        if cfg.frontend:
            batch[modality.frontend_input_name(cfg)] = \
                self.source.frontend_for_step(step, cfg.frontend_len, cfg.d_model)
        if self.shardings:
            batch = {k: jax.device_put(v, self.shardings.get(k))
                     for k, v in batch.items()}
        return batch

    def _producer(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put(( step, self._make_batch(step)), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self):
        return self

    def __next__(self):
        step, batch = self._q.get()
        return step, batch

    def close(self):
        self._stop.set()
