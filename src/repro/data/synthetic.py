"""Deterministic synthetic LM data: Zipfian unigrams + Markov bigram
structure, generated per (seed, step) so any batch is reproducible on its
own — restart-after-failure resumes the exact stream (no data-order drift),
and each data shard can be generated independently on its host.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    markov_strength: float = 0.7   # prob of following the bigram chain


class SyntheticLM:
    """Batch generator. ``batch_for_step(k)`` is a pure function of (cfg, k)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size
        ranks = np.arange(1, V + 1, dtype=np.float64)
        self._unigram = ranks ** -cfg.zipf_a
        self._unigram /= self._unigram.sum()
        # a fixed random bigram successor table gives learnable structure
        self._successor = rng.integers(0, V, size=V)

    def batch_for_step(self, step: int, batch_slice=None) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B = cfg.global_batch if batch_slice is None else batch_slice
        S = cfg.seq_len + 1
        iid = rng.choice(cfg.vocab_size, size=(B, S), p=self._unigram)
        follow = rng.random((B, S)) < cfg.markov_strength
        toks = iid.copy()
        for t in range(1, S):
            chain = self._successor[toks[:, t - 1]]
            toks[:, t] = np.where(follow[:, t], chain, iid[:, t])
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def frontend_for_step(self, step: int, frontend_len: int, d_model: int,
                          batch=None) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step, 7))
        B = batch or cfg.global_batch
        return (rng.standard_normal((B, frontend_len, d_model)) * 0.02
                ).astype(np.float32)
