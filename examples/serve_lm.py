"""Serving driver: batched prefill + decode with KV caches.

Demonstrates the serve path the decode_32k / long_500k dry-run shapes lower:
prefill a batch of prompts, then step the decode loop, optionally through
the Pallas flash/decode kernels (interpret-mode on CPU).

Run: PYTHONPATH=src python examples/serve_lm.py --tokens 16
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--use-kernels", action="store_true",
                    help="run decode attention through the Pallas kernel "
                         "(interpret mode on CPU; slow but exercises it)")
    args = ap.parse_args()

    cfg = ModelConfig(name="serve-demo", family="dense", num_layers=4,
                      d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                      vocab_size=2048, tie_embeddings=True)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)

    prompts = jax.random.randint(key, (args.batch, args.prompt_len),
                                 0, cfg.vocab_size)
    t0 = time.perf_counter()
    logits, caches = jax.jit(
        lambda p, t: lm.prefill_step(p, t, cfg))(params, prompts)
    print(f"[serve] prefill {args.batch}x{args.prompt_len}: "
          f"{(time.perf_counter()-t0)*1e3:.0f} ms")

    # grow caches so decode can append
    caches = jax.tree.map(
        lambda a: jnp.pad(a, [(0, 0)] * 2 + [(0, args.tokens)]
                          + [(0, 0)] * 2) if a.ndim == 5 else a, caches)

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    outs = [tok]
    decode = jax.jit(
        lambda p, t, c, i: lm.decode_step(p, t, c, cfg, i),
        static_argnums=3)
    t0 = time.perf_counter()
    for i in range(args.tokens - 1):
        tok, _, caches = decode(params, tok, caches, args.prompt_len + i)
        outs.append(tok)
    dt = time.perf_counter() - t0
    seqs = jnp.concatenate(outs, axis=1)
    print(f"[serve] decoded {args.tokens} tokens/seq x {args.batch} seqs: "
          f"{dt/max(args.tokens-1,1)*1e3:.1f} ms/token")
    for b in range(min(args.batch, 2)):
        print(f"  seq{b}: {seqs[b].tolist()}")

    if args.use_kernels:
        from repro.kernels.decode_attention import (decode_attention,
                                                    decode_attention_ref)
        q = jax.random.normal(key, (8, 2, 64))
        k = jax.random.normal(key, (8, 1024, 64))
        v = jax.random.normal(key, (8, 1024, 64))
        out = decode_attention(q, k, v, bc=256)
        ref = decode_attention_ref(q, k, v)
        print(f"[serve] pallas decode kernel max err vs oracle: "
              f"{float(jnp.max(jnp.abs(out - ref))):.2e}")


if __name__ == "__main__":
    main()
