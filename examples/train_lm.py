"""End-to-end training driver: a small LM on the synthetic Markov stream,
with AdamW, checkpointing/restart, and selectable asynchronicity mode.

Default is a ~10M-param model sized to make visible loss progress on CPU in
a few minutes; pass --d-model/--layers/--steps to scale (the same driver
runs the ~100M config with --preset 100m on real hardware).

Run: PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse

from repro.configs.base import ModelConfig
from repro.core.modes import AsyncMode
from repro.data.synthetic import DataConfig
from repro.launch.train import TrainSpec, run_training
from repro.optim.adamw import AdamWConfig


def build_cfg(args):
    if args.preset == "100m":
        return ModelConfig(name="lm-100m", family="dense", num_layers=12,
                           d_model=768, num_heads=12, num_kv_heads=12,
                           d_ff=2048, vocab_size=32768, tie_embeddings=True)
    return ModelConfig(name="lm-10m", family="dense",
                       num_layers=args.layers, d_model=args.d_model,
                       num_heads=max(2, args.d_model // 64),
                       num_kv_heads=max(2, args.d_model // 128),
                       d_ff=args.d_model * 4, vocab_size=4096,
                       tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--preset", default="10m", choices=["10m", "100m"])
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--mode", type=int, default=0,
                    help="asynchronicity mode (cross-pod; needs n-pods > 1)")
    ap.add_argument("--n-pods", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    cfg = build_cfg(args)
    spec = TrainSpec(mode=AsyncMode(args.mode),
                     adamw=AdamWConfig(lr=args.lr, warmup_steps=20,
                                       total_steps=args.steps))
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch)
    state, history = run_training(cfg, spec, data_cfg, steps=args.steps,
                                  ckpt_dir=args.ckpt_dir,
                                  n_pods=args.n_pods)
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"[train] done: loss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
