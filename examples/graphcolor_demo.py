"""Paper reproduction demo: distributed graph coloring across all five
asynchronicity modes (Table I), with the QoS metric suite.

Run: PYTHONPATH=src python examples/graphcolor_demo.py
"""
import numpy as np

from repro.apps.graphcolor import GraphColorApp, GraphColorConfig
from repro.core.modes import AsyncMode
from repro.runtime.faults import faulty_node
from repro.runtime.simulator import SimConfig, Simulator


def mode_comparison(n=16):
    print(f"=== asynchronicity modes, {n} processes (weak scaling) ===")
    print(f"{'mode':40s} {'rate/cpu':>10s} {'conflicts':>10s}")
    for mode in AsyncMode:
        app = GraphColorApp(GraphColorConfig(n_processes=n, nodes_per_process=256))
        res = Simulator(app, SimConfig(mode=mode, duration=0.03,
                                       base_latency=100e-6,
                                       rolling_quantum=0.01,
                                       fixed_interval=0.01)).run()
        print(f"{int(mode)}: {mode.description:37s} "
              f"{res.update_rate_per_cpu:10.0f} {res.quality:10.0f}")


def qos_with_faulty_node(n=16):
    print(f"\n=== QoS with a faulty node (pid 5), {n} processes ===")
    app = GraphColorApp(GraphColorConfig(n_processes=n, nodes_per_process=64))
    faults = faulty_node(5, app.topology()[5], 30.0, 30.0)
    cfg = SimConfig(mode=AsyncMode.BEST_EFFORT, duration=0.6,
                    snapshot_warmup=0.1, snapshot_interval=0.1,
                    base_latency=100e-6)
    res = Simulator(app, cfg, faults).run()
    med = np.median([q.simstep_period for q in res.qos]) * 1e6
    faulty = np.median([q.simstep_period
                        for q in res.qos_by_process[5]]) * 1e6
    print(f"  global median simstep period: {med:8.1f} us")
    print(f"  faulty node simstep period:   {faulty:8.1f} us "
          f"({faulty/med:.0f}x worse — yet the median holds)")
    print(f"  updates: faulty={res.updates[5]}, "
          f"median={np.median(res.updates):.0f}")


if __name__ == "__main__":
    mode_comparison()
    qos_with_faulty_node()
