"""Quickstart: the library's three faces in under a minute on CPU.

1. Best-effort communication primitives (asynchronicity modes + QoS).
2. A tiny LM through train / prefill / decode.
3. The paper's graph-coloring benchmark under barrier vs best-effort modes.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.modes import AsyncMode
from repro.models import lm
from repro.runtime.simulator import SimConfig, Simulator
from repro.apps.graphcolor import GraphColorApp, GraphColorConfig


def demo_lm():
    print("=== tiny LM: train step, prefill, decode ===")
    cfg = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)
    toks = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
    loss, metrics = lm.loss_fn(params, {"tokens": toks, "labels": toks}, cfg)
    print(f"  loss at init: {float(loss):.3f} (ln V = {jnp.log(cfg.vocab_size):.3f})")

    logits, caches = lm.prefill_step(params, toks, cfg)
    caches = jax.tree.map(
        lambda a: jnp.pad(a, [(0, 0)] * 2 + [(0, 8)] + [(0, 0)] * 2)
        if a.ndim == 5 else a, caches)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(4):
        tok, _, caches = lm.decode_step(params, tok, caches, cfg, 32 + i)
    print(f"  decoded 4 tokens: {tok.ravel().tolist()}")


def demo_best_effort():
    print("=== best-effort vs barrier (graph coloring, 16 procs) ===")
    for mode in (AsyncMode.BARRIER_EVERY_STEP, AsyncMode.BEST_EFFORT):
        app = GraphColorApp(GraphColorConfig(n_processes=16, nodes_per_process=64))
        res = Simulator(app, SimConfig(mode=mode, duration=0.02,
                                       base_latency=100e-6)).run()
        print(f"  mode {int(mode)} ({mode.description}): "
              f"{res.update_rate_per_cpu:8.0f} updates/s/cpu, "
              f"{res.quality:4.0f} conflicts left")


if __name__ == "__main__":
    demo_lm()
    demo_best_effort()
