"""Hypothesis property tests on runtime/system invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional extra; skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.core.modes import AsyncMode
from repro.core.qos import Counters, report
from repro.runtime.simulator import SimConfig, Simulator
from repro.apps.graphcolor import GraphColorApp, GraphColorConfig


@given(mode=st.sampled_from([0, 1, 2, 3, 4]),
       n=st.sampled_from([2, 4, 8]),
       seed=st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_simulator_conservation_and_bounds(mode, n, seed):
    """Invariants for any mode/scale/seed:
    - messages: attempted = successful + dropped; received <= successful
    - every process clock ends within the horizon + one step
    - update counts are positive and (mode 0) lockstep
    """
    app = GraphColorApp(GraphColorConfig(n_processes=n, nodes_per_process=16,
                                         seed=seed))
    cfg = SimConfig(mode=AsyncMode(mode), duration=0.01, seed=seed,
                    base_latency=50e-6, buffer_capacity=4)
    sim = Simulator(app, cfg)
    res = sim.run()

    attempted = sum(d.inlet.attempted_send_count for d in sim.ducts.values())
    successful = sum(d.inlet.successful_send_count for d in sim.ducts.values())
    received = sum(d.outlet.message_count for d in sim.ducts.values())
    in_flight = sum(d.backlog for d in sim.ducts.values())
    assert attempted == successful + res.dropped
    assert received + in_flight == successful
    assert all(u > 0 for u in res.updates)
    if AsyncMode(mode) == AsyncMode.BARRIER_EVERY_STEP:
        assert max(res.updates) - min(res.updates) <= 1
    if AsyncMode(mode) == AsyncMode.NO_COMM:
        assert attempted == 0


@given(u=st.integers(1, 10_000), t=st.integers(0, 5_000),
       a=st.integers(0, 10_000), s=st.integers(0, 10_000),
       lp=st.integers(0, 1000), m=st.integers(0, 1000),
       p=st.integers(0, 1000), w=st.floats(1e-6, 100.0))
@settings(max_examples=50, deadline=None)
def test_qos_metrics_bounded(u, t, a, s, lp, m, p, w):
    """QoS metrics stay in their defined ranges for any counter deltas."""
    s = min(s, a)
    lp = min(lp, p, m)
    before = Counters()
    after = Counters(update_count=u, touch_count=t, attempted_send_count=a,
                     successful_send_count=s, laden_pull_count=lp,
                     message_count=m, pull_attempt_count=p, wall_time=w)
    r = report(before, after)
    assert r.simstep_period > 0
    assert r.simstep_latency >= 0
    assert r.walltime_latency >= 0
    assert 0.0 <= r.delivery_failure_rate <= 1.0
    assert 0.0 <= r.delivery_clumpiness <= 1.0


@given(seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_graphcolor_probs_stay_simplex(seed):
    """CFL probability rows remain a simplex through arbitrary updates."""
    app = GraphColorApp(GraphColorConfig(n_processes=1, nodes_per_process=16,
                                         seed=seed))
    f = app.make_fragments()[0]
    for _ in range(50):
        f.update({})
    assert (f.probs >= -1e-9).all()
    np.testing.assert_allclose(f.probs.sum(-1), 1.0, atol=1e-6)
    assert ((0 <= f.colors) & (f.colors < 3)).all()
