"""Mamba selective-scan kernel: interpret-mode vs oracle vs the model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional extra; skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.kernels.mamba_scan.kernel import mamba_scan_kernel
from repro.kernels.mamba_scan.ref import mamba_scan_ref

KEY = jax.random.PRNGKey(0)


def _inputs(Bb, S, di, N, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = (jax.random.normal(ks[0], (Bb, S, di)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bb, S, di)) - 1).astype(dtype)
    B = (jax.random.normal(ks[2], (Bb, S, N)) * 0.5).astype(dtype)
    C = (jax.random.normal(ks[3], (Bb, S, N)) * 0.5).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[4], (di, N)) * 0.3)
    return x, dt, B, C, A


@pytest.mark.parametrize("Bb,S,di,N,bdi,chunk", [
    (2, 128, 64, 16, 32, 64),
    (1, 256, 128, 8, 128, 128),
    (3, 64, 32, 4, 32, 64),      # single di-tile, single chunk
])
def test_mamba_kernel_matches_ref(Bb, S, di, N, bdi, chunk):
    x, dt, B, C, A = _inputs(Bb, S, di, N)
    y, h = mamba_scan_kernel(x, dt, B, C, A, bdi=bdi, chunk=chunk,
                             interpret=True)
    yr, hr = mamba_scan_ref(x, dt, B, C, A)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               rtol=1e-5, atol=1e-5)


@given(chunks=st.sampled_from([32, 64, 128]), tiles=st.sampled_from([16, 32, 64]))
@settings(max_examples=6, deadline=None)
def test_mamba_kernel_block_invariance(chunks, tiles):
    """Tile/chunk sizes must not change the scan result."""
    x, dt, B, C, A = _inputs(1, 128, 64, 8, seed=5)
    y1, h1 = mamba_scan_kernel(x, dt, B, C, A, bdi=tiles, chunk=chunks,
                               interpret=True)
    y2, h2 = mamba_scan_kernel(x, dt, B, C, A, bdi=64, chunk=128,
                               interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-5, atol=1e-5)


def test_mamba_kernel_matches_model_forward_core():
    """The kernel's recurrence equals the model's chunked associative scan
    (repro.models.ssm.mamba_forward internals)."""
    from repro.configs.base import ModelConfig
    from repro.models import ssm
    cfg = ModelConfig(name="m", family="hybrid", num_layers=2, d_model=32,
                      num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
                      block_pattern=("mamba",), dtype="float32",
                      param_dtype="float32")
    p = ssm.init_mamba(jax.random.PRNGKey(1), cfg, jnp.float32)
    xin = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 32)) * 0.3

    # reproduce the model's pre-scan projections
    cd = jnp.float32
    xz = xin @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(ssm._causal_conv(xi, p["conv_w"], p["conv_b"]))
    dt, Bm, Cm = ssm._ssm_params(p, xc, cfg, cd)
    A = -jnp.exp(p["A_log"])

    y_kernel, h = mamba_scan_kernel(xc.astype(jnp.float32), dt, Bm, Cm, A,
                                    bdi=32, chunk=32, interpret=True)
    # model output before gating/out_proj: y + D*x
    y_model_full = ssm.mamba_forward(p, xin, cfg, chunk=16)
    y_manual = (y_kernel + p["D"] * xc) * jax.nn.silu(z)
    y_manual = y_manual @ p["out_proj"]
    np.testing.assert_allclose(np.asarray(y_manual), np.asarray(y_model_full),
                               rtol=1e-4, atol=1e-5)
