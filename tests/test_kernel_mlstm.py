"""Fused mLSTM kernel: interpret-mode vs oracle vs the model's chunk math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional extra; skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.kernels.mlstm_attention.kernel import mlstm_attention_kernel
from repro.kernels.mlstm_attention.ops import mlstm_attention
from repro.kernels.mlstm_attention.ref import mlstm_attention_ref

KEY = jax.random.PRNGKey(0)


def _inputs(BH, S, hd, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (BH, S, hd), dtype)
    k = (jax.random.normal(ks[1], (BH, S, hd), dtype) * (hd ** -0.5)).astype(dtype)
    v = jax.random.normal(ks[2], (BH, S, hd), dtype)
    log_f = jax.nn.log_sigmoid(jax.random.normal(ks[3], (BH, S)) + 3.0)
    F = jnp.cumsum(log_f, axis=1)
    I = jax.random.normal(ks[4], (BH, S)) * 0.5
    return q, k, v, F, I


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("BH,S,hd,bq,bk", [
    (4, 256, 64, 128, 128),
    (2, 512, 128, 128, 64),
    (8, 128, 32, 128, 128),   # single block
])
def test_mlstm_kernel_matches_ref(BH, S, hd, bq, bk, dtype):
    q, k, v, F, I = _inputs(BH, S, hd, dtype)
    out = mlstm_attention_kernel(q, k, v, F, I, bq=bq, bk=bk, interpret=True)
    ref = mlstm_attention_ref(q, k, v, F, I)
    tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol)


def test_mlstm_kernel_matches_model_chunk_math():
    """The kernel reproduces models/ssm._mlstm_chunk (the production path)."""
    from repro.models.ssm import _mlstm_chunk
    B, S, H, hd = 2, 128, 4, 32
    q, k, v, F, I = _inputs(B * H, S, hd, seed=3)
    # model layout (B, S, H, hd)
    qm = q.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    km = k.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    vm = v.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    Fm = F.reshape(B, H, S).transpose(0, 2, 1)
    Im = I.reshape(B, H, S).transpose(0, 2, 1)
    pos = jnp.arange(S)
    h_model = _mlstm_chunk(qm, Fm, km, vm, Im, Fm, pos, pos)  # (B,S,H,hd)... returns (B,L,H,hd)
    h_kernel = mlstm_attention(qm, km, vm, Fm, Im, interpret=True)
    np.testing.assert_allclose(np.asarray(h_kernel), np.asarray(h_model),
                               rtol=1e-4, atol=1e-5)


@given(nq=st.integers(1, 3), nk=st.integers(1, 3))
@settings(max_examples=6, deadline=None)
def test_mlstm_kernel_block_invariance(nq, nk):
    """Block sizes must not change the result (online accumulation)."""
    q, k, v, F, I = _inputs(2, 256, 32, seed=7)
    a = mlstm_attention_kernel(q, k, v, F, I, bq=256 // nq if 256 % nq == 0
                               else 128, bk=128, interpret=True)
    b = mlstm_attention_kernel(q, k, v, F, I, bq=64, bk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-5)
