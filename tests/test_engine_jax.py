"""Vectorized-engine tests: duct-op parity, determinism, replicates.

The jax engine's conformance with the event engine — exact (dyadic
configs) and statistical (jittered configs) — lives in the registry-driven
suite ``tests/test_engine_conformance.py``; this file keeps what is
specific to the jax engine itself:

  - the duct op agrees slot-for-slot with the numpy oracle
    (``kernels/duct_exchange/ref.py``), including bounded-buffer drops;
  - runs are deterministic in the seed, and vmapped replicates are
    independent and identical to single runs.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from engine_cases import PARITY_RTOL, gc_app, jittered_cfg  # noqa: E402,F401
from repro.core.modes import AsyncMode  # noqa: E402
from repro.kernels.duct_exchange import (  # noqa: E402
    duct_exchange,
    duct_exchange_jnp,
    duct_exchange_ref,
)
from repro.runtime.engine_jax import JaxEngine  # noqa: E402

_app = gc_app
_cfg = jittered_cfg


# ---------------------------------------------------------------------------
# Duct op parity against the numpy oracle
# ---------------------------------------------------------------------------
def _random_duct_state(rng, E=41, C=8, cap=6):
    qa = np.full((E, C), np.inf, np.float32)
    qt = np.zeros((E, C), np.int32)
    head = rng.integers(0, C, E).astype(np.int32)
    size = np.zeros(E, np.int32)
    for e in range(E):
        s = rng.integers(0, cap + 1)
        size[e] = s
        for j in range(s):
            qa[e, (head[e] + j) % C] = rng.random() * 2
            qt[e, (head[e] + j) % C] = rng.integers(0, 50)
    return qa, qt, head, size


@pytest.mark.parametrize("impl", ["jnp", "pallas_interpret"])
def test_duct_exchange_matches_ref(impl):
    rng = np.random.default_rng(7)
    qa, qt, head, size = _random_duct_state(rng)
    E = qa.shape[0]
    args = (qa, qt, head, size,
            (rng.random(E) * 2).astype(np.float32), rng.random(E) < 0.8,
            (rng.random(E) * 2).astype(np.float32), rng.random(E) < 0.8,
            (rng.random(E) * 0.5).astype(np.float32),
            rng.integers(0, 50, E).astype(np.int32))
    kw = dict(capacity=6, max_pops=4)
    ref = duct_exchange_ref(*args, **kw)
    if impl == "jnp":
        out = duct_exchange_jnp(*map(jnp.asarray, args), **kw)
    else:
        out = duct_exchange(*map(jnp.asarray, args), **kw,
                            use_pallas=True, interpret=True)
    for name, a, b in zip(ref._fields, ref, out):
        np.testing.assert_allclose(
            np.asarray(b, dtype=np.float64), np.asarray(a, np.float64),
            err_msg=f"{impl}: field {name}")


def test_duct_exchange_drops_when_full():
    """Bounded-buffer drop parity: a full ring rejects the push."""
    C, cap = 8, 4
    qa = np.full((1, C), np.inf, np.float32)
    qt = np.zeros((1, C), np.int32)
    head = np.zeros(1, np.int32)
    for j in range(cap):
        qa[0, j] = 100.0  # queued but unavailable for a long time
    size = np.full(1, cap, np.int32)
    args = (qa, qt, head, size,
            np.zeros(1, np.float32), np.ones(1, bool),
            np.zeros(1, np.float32), np.ones(1, bool),
            np.full(1, 0.1, np.float32), np.zeros(1, np.int32))
    kw = dict(capacity=cap, max_pops=4)
    ref = duct_exchange_ref(*args, **kw)
    out = duct_exchange_jnp(*map(jnp.asarray, args), **kw)
    assert not bool(ref.accepted[0])
    assert not bool(out.accepted[0])
    assert int(out.size[0]) == cap
    np.testing.assert_array_equal(np.asarray(out.q_avail), ref.q_avail)


# ---------------------------------------------------------------------------
# Engine determinism / replicates
# ---------------------------------------------------------------------------
def test_same_seed_determinism():
    cfg = _cfg(0.02)
    r1 = JaxEngine(_app(16), cfg).run()
    r2 = JaxEngine(_app(16), cfg).run()
    assert r1.updates == r2.updates
    assert r1.quality == r2.quality
    assert r1.dropped == r2.dropped and r1.sent == r2.sent


def test_vmap_replicates_independent_and_match_single_runs():
    cfg = _cfg(0.02)
    eng = JaxEngine(_app(16), cfg)
    reps = eng.run_replicates([0, 1, 2, 3])
    single0 = JaxEngine(_app(16), cfg).run()
    assert reps[0].updates == single0.updates
    assert reps[0].dropped == single0.dropped
    # distinct seeds give distinct trajectories
    assert len({tuple(r.updates) for r in reps}) > 1
    # every replicate produces a full QoS distribution
    for r in reps:
        assert len(r.qos) >= 16 * 3


def test_engine_counter_consistency():
    res = JaxEngine(_app(16), _cfg(0.02)).run()
    assert res.sent > 0
    assert 0 <= res.dropped <= res.sent
    # explicit drop counter backs the failure rate
    assert res.delivery_failure_rate == res.dropped / res.sent


def test_no_comm_sends_nothing():
    res = JaxEngine(_app(16), _cfg(0.02, mode=AsyncMode.NO_COMM)).run()
    assert res.sent == 0 and res.dropped == 0
    for rep in res.qos:
        assert rep.delivery_failure_rate == 0.0


def test_best_effort_beats_barrier_rate_on_jax():
    r0 = JaxEngine(_app(16), _cfg(0.02, mode=AsyncMode.BARRIER_EVERY_STEP,
                                  base_latency=100e-6)).run()
    r3 = JaxEngine(_app(16), _cfg(0.02, mode=AsyncMode.BEST_EFFORT,
                                  base_latency=100e-6)).run()
    assert r3.update_rate_per_cpu > 2.0 * r0.update_rate_per_cpu
    # barrier-every-step stays in lockstep
    assert max(r0.updates) - min(r0.updates) <= 1
