"""Integration tests for the training loop: learning, fault tolerance,
asynchronicity-mode semantics on pod-stacked state (runs on 1 CPU device —
the pod dim is a real array dim, no mesh needed)."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.modes import AsyncMode
from repro.data.synthetic import DataConfig
from repro.launch.train import (TrainSpec, init_train_state, make_train_step,
                                run_training)
from repro.optim.adamw import AdamWConfig
from repro.optim.outer import OuterConfig

CFG = ModelConfig(name="it-lm", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                  tie_embeddings=True)
DATA = DataConfig(vocab_size=256, seq_len=64, global_batch=4)
FAST_ADAM = AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=100)


def _batch(source, k, n_pods):
    b = source.batch_for_step(k)
    return {key: jnp.asarray(v).reshape((n_pods, v.shape[0] // n_pods)
                                        + v.shape[1:]) for key, v in b.items()}


def _run(mode, steps=8, n_pods=2, compressor=None, sync_period=4):
    from repro.data.synthetic import SyntheticLM
    spec = TrainSpec(mode=mode, adamw=FAST_ADAM, compressor=compressor,
                     compress_ratio=0.25,
                     outer=OuterConfig(sync_period=sync_period))
    state = init_train_state(jax.random.PRNGKey(0), CFG, spec, n_pods)
    step_fn = jax.jit(make_train_step(CFG, spec, n_pods))
    src = SyntheticLM(DATA)
    losses = []
    for k in range(steps):
        state, m = step_fn(state, _batch(src, k, n_pods))
        losses.append(float(m["loss"]))
    return state, losses


def _pod_divergence(state):
    leaves = jax.tree.leaves(state["params"])
    return max(float(jnp.max(jnp.abs(l[0] - l[1]))) for l in leaves)


def test_training_reduces_loss():
    _, losses = _run(AsyncMode.BARRIER_EVERY_STEP, steps=30, n_pods=1)
    assert losses[-1] < losses[0] - 0.3, losses[::6]


def test_mode0_pods_stay_identical():
    state, _ = _run(AsyncMode.BARRIER_EVERY_STEP)
    assert _pod_divergence(state) < 1e-6


def test_mode4_pods_diverge():
    state, _ = _run(AsyncMode.NO_COMM)
    assert _pod_divergence(state) > 1e-4


def test_mode3_bounded_divergence_and_progress():
    state, losses = _run(AsyncMode.BEST_EFFORT, steps=20)
    div = _pod_divergence(state)
    assert div > 1e-7                # staleness-1 causes some divergence
    _, losses4 = _run(AsyncMode.NO_COMM, steps=20)
    # best-effort should track mode-0 loss closely
    _, losses0 = _run(AsyncMode.BARRIER_EVERY_STEP, steps=20)
    assert abs(losses[-1] - losses0[-1]) < 0.8


def test_mode1_syncs_on_period():
    # with sync_period=4, pods re-align every 4th step
    state, _ = _run(AsyncMode.ROLLING_BARRIER, steps=4, sync_period=4)
    assert _pod_divergence(state) < 1e-5   # just synced (outer step)
    state, _ = _run(AsyncMode.ROLLING_BARRIER, steps=6, sync_period=4)
    assert _pod_divergence(state) > 1e-6   # 2 inner steps since sync


@pytest.mark.parametrize("compressor", ["int8", "topk"])
def test_mode3_compressed_still_learns(compressor):
    _, losses = _run(AsyncMode.BEST_EFFORT, steps=20, compressor=compressor)
    assert losses[-1] < losses[0] - 0.2


def test_checkpoint_restart_is_bit_exact():
    """Crash/restore mid-run must reproduce the uninterrupted run exactly
    (deterministic data stream + saved state)."""
    with tempfile.TemporaryDirectory() as d1:
        spec = TrainSpec(adamw=FAST_ADAM)
        _, hist_full = run_training(CFG, spec, DATA, steps=10, ckpt_dir=None,
                                    log_every=1, log=lambda *_: None)
        # interrupted: 10 steps with ckpt at 5... run 5 then "crash"
        _, h1 = run_training(CFG, spec, DATA, steps=5, ckpt_dir=d1,
                             ckpt_every=5, log_every=1, log=lambda *_: None)
        # restart: resumes from step 5 automatically
        _, h2 = run_training(CFG, spec, DATA, steps=10, ckpt_dir=d1,
                             ckpt_every=5, log_every=1, log=lambda *_: None)
        full = {h["step"]: h["loss"] for h in hist_full}
        resumed = {h["step"]: h["loss"] for h in h2}
        for s in (6, 8, 10):
            np.testing.assert_allclose(resumed[s], full[s], rtol=1e-5)


def test_elastic_restore_across_pod_counts():
    """A 1-pod checkpoint restores onto a 2-pod layout (elastic rescale)."""
    from repro import checkpoint as ckpt_mod
    spec = TrainSpec(adamw=FAST_ADAM)
    state1 = init_train_state(jax.random.PRNGKey(0), CFG, spec, n_pods=1)
    with tempfile.TemporaryDirectory() as d:
        ckpt_mod.save(d, state1, step=3)
        like2 = jax.eval_shape(
            lambda: init_train_state(jax.random.PRNGKey(0), CFG, spec, 2))
        # broadcast pod-0 slice to the new pod count, then restore the rest
        src = ckpt_mod.restore(d, 3, jax.eval_shape(lambda: state1))
        state2 = jax.tree.map(
            lambda like, s: (jnp.broadcast_to(s[:1], like.shape)
                             if like.ndim > 0 and like.ndim == s.ndim
                             and like.shape[0] == 2
                             else jnp.asarray(s, like.dtype)),
            like2, src)
        assert jax.tree.structure(state2) == jax.tree.structure(like2)
        assert _pod_divergence(state2) < 1e-9
