"""Multi-device semantics of conduits and best-effort collectives.

Runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main test process keeps a single device (per the dry-run rules)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_md(script: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, env=env, timeout=300)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_conduit_staleness_semantics():
    out = run_md("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core.conduit import Conduit
        from repro.core.modes import AsyncMode
        from repro.launch.mesh import shard_map  # version-compat wrapper

        mesh = jax.make_mesh((8,), ("x",))

        def run(mode):
            cond = Conduit("x", {"fwd": 1}, mode)
            def body(rank):
                val = rank.astype(jnp.float32)
                bufs = cond.init_buffers(val)
                rec1, bufs = cond.exchange(val, bufs)
                rec2, bufs = cond.exchange(val + 100, bufs)
                return rec1["fwd"], rec2["fwd"]
            f = jax.jit(shard_map(body, mesh, in_specs=P("x"),
                                  out_specs=(P("x"), P("x"))))
            return f(jnp.arange(8))

        # mode 0: fresh values arrive in-step: rec1 = left neighbor rank
        r1, r2 = run(AsyncMode.BARRIER_EVERY_STEP)
        np.testing.assert_allclose(np.asarray(r1), np.roll(np.arange(8), 1))
        np.testing.assert_allclose(np.asarray(r2), np.roll(np.arange(8) + 100, 1))

        # mode 3: staleness-1: rec1 = zeros (init), rec2 = step-1 payload
        r1, r2 = run(AsyncMode.BEST_EFFORT)
        np.testing.assert_allclose(np.asarray(r1), np.zeros(8))
        np.testing.assert_allclose(np.asarray(r2), np.roll(np.arange(8), 1))

        # mode 4: nothing ever arrives
        r1, r2 = run(AsyncMode.NO_COMM)
        np.testing.assert_allclose(np.asarray(r1), np.zeros(8))
        np.testing.assert_allclose(np.asarray(r2), np.zeros(8))
        print("CONDUIT-OK")
    """)
    assert "CONDUIT-OK" in out


@pytest.mark.slow
def test_gradient_exchange_modes():
    out = run_md("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core import collectives
        from repro.core.modes import AsyncMode
        from repro.launch.mesh import shard_map  # version-compat wrapper

        mesh = jax.make_mesh((2, 4), ("pod", "data"))

        def run(mode):
            def body(g):
                state = collectives.init_exchange_state(g, mode)
                eff1, state = collectives.exchange_gradients(g, state, mode, "pod")
                eff2, state = collectives.exchange_gradients(g * 10, state, mode, "pod")
                return eff1, eff2
            f = jax.jit(shard_map(body, mesh,
                                  in_specs=P("pod"), out_specs=P("pod"),
                                  axis_names={"pod"}))
            g = jnp.array([1.0, 3.0])  # pod 0 grad=1, pod 1 grad=3
            return f(g)

        # mode 0: both steps give the cross-pod mean
        e1, e2 = run(AsyncMode.BARRIER_EVERY_STEP)
        np.testing.assert_allclose(np.asarray(e1), [2.0, 2.0])
        np.testing.assert_allclose(np.asarray(e2), [20.0, 20.0])

        # mode 3: step1 = own/2 (others stale=0); step2 = (own*10 + other_t1)/2
        e1, e2 = run(AsyncMode.BEST_EFFORT)
        np.testing.assert_allclose(np.asarray(e1), [0.5, 1.5])
        np.testing.assert_allclose(np.asarray(e2), [(10 + 3) / 2, (30 + 1) / 2])

        # mode 4 / local-sgd modes: grads pass through
        e1, e2 = run(AsyncMode.NO_COMM)
        np.testing.assert_allclose(np.asarray(e1), [1.0, 3.0])
        e1, e2 = run(AsyncMode.ROLLING_BARRIER)
        np.testing.assert_allclose(np.asarray(e1), [1.0, 3.0])
        print("EXCHANGE-OK")
    """)
    assert "EXCHANGE-OK" in out


@pytest.mark.slow
def test_compressed_cross_pod_sum():
    out = run_md("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core import collectives
        from repro.optim.compression import Int8Compressor, TopKCompressor
        from repro.launch.mesh import shard_map  # version-compat wrapper

        mesh = jax.make_mesh((2,), ("pod",))

        def run(comp, g):
            def body(g):
                tree = {"w": g.reshape(4, 8)}
                total, res = collectives.cross_pod_sum(tree, "pod", comp)
                return total["w"], res["w"]
            f = jax.jit(shard_map(body, mesh, in_specs=P("pod"),
                                  out_specs=P("pod"), axis_names={"pod"}))
            return f(g)

        g = jax.random.normal(jax.random.PRNGKey(0), (2 * 4, 8))
        exact = np.asarray(g.reshape(2, 4, 8).sum(0))

        total, res = run(Int8Compressor(block=8), g)
        total = np.asarray(total)
        # both pod shards hold the same total; int8 error is small
        np.testing.assert_allclose(total[:4], exact, rtol=0.15, atol=0.15)
        np.testing.assert_allclose(total[4:], exact, rtol=0.15, atol=0.15)

        # decoded + residual reconstructs each pod's contribution
        total, res = run(TopKCompressor(ratio=0.5), g)
        print("COMPRESS-OK")
    """)
    assert "COMPRESS-OK" in out
