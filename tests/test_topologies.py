"""Topology layer: adjacency invariants, hierarchical link model, injected
topologies in the apps, engine determinism, and the experiments driver."""
import numpy as np
import pytest

from repro.apps.evo import EvoApp, EvoConfig
from repro.apps.graphcolor import GraphColorApp, GraphColorConfig
from repro.core.modes import AsyncMode
from repro.runtime.faults import faulty_host
from repro.runtime.simulator import SimConfig, Simulator
from repro.runtime.topologies import (
    TOPOLOGIES, cliques, contiguous_partition, make_topology, near_square,
    ring, smallworld, torus,
)


# ---------------------------------------------------------------------------
# Adjacency invariants
# ---------------------------------------------------------------------------
ALL_CASES = [
    ring(8), ring(64),
    torus(16), torus(12), torus(64),
    cliques(32, 8), cliques(64, 4),
    smallworld(32), smallworld(64, k=6, chords=3),
]


@pytest.mark.parametrize("topo", ALL_CASES, ids=lambda t: t.name)
def test_symmetric_no_self_loops_connected(topo):
    for i, nbs in enumerate(topo.neighbors):
        assert i not in nbs
        assert len(set(nbs)) == len(nbs)
        for j in nbs:
            assert i in topo.neighbors[j]
    # connectivity: BFS from 0 reaches everyone
    seen, frontier = {0}, [0]
    while frontier:
        frontier = [q for p in frontier for q in topo.neighbors[p]
                    if q not in seen and not seen.add(q)]
    assert len(seen) == topo.n


def test_degree_invariants():
    assert all(ring(16).degree(p) == 2 for p in range(16))
    assert all(torus(64).degree(p) == 4 for p in range(64))
    # 3x4 torus: wrap along the 3-row axis still yields distinct neighbors
    assert all(torus(12).degree(p) == 4 for p in range(12))
    # clique-of-cliques: (size-1) in-clique + 2 inter-clique links
    t = cliques(32, 8)
    assert all(t.degree(p) == 7 + 2 for p in range(32))
    # small-world: at least the ring lattice
    t = smallworld(64, k=4, chords=2)
    assert all(t.degree(p) >= 4 for p in range(64))
    assert t.n_edges > 64 * 4 // 2   # chords added something


def test_node_assignment_and_cliques():
    t = cliques(32, 8)
    assert t.n_nodes == 4
    assert t.host_pids(1) == list(range(8, 16))
    assert t.same_node(8, 15) and not t.same_node(7, 8)
    # a clique member's communication clique covers its whole host
    assert set(t.host_pids(0)) <= set(t.clique_of(0))


def test_smallworld_deterministic_in_seed():
    a = smallworld(48, seed=3)
    b = smallworld(48, seed=3)
    c = smallworld(48, seed=4)
    assert a.neighbors == b.neighbors
    assert a.neighbors != c.neighbors


def test_make_topology_registry():
    assert set(TOPOLOGIES) == {"ring", "torus", "cliques", "smallworld"}
    t = make_topology("cliques", 24)   # picks a divisor clique size
    assert t.n == 24
    with pytest.raises(ValueError):
        make_topology("hypercube", 16)
    assert near_square(12) == (3, 4)


# ---------------------------------------------------------------------------
# Hierarchical link model
# ---------------------------------------------------------------------------
def test_intra_node_links_are_cheaper():
    topo = cliques(16, 4)
    app = GraphColorApp(GraphColorConfig(n_processes=16, nodes_per_process=4),
                        topology=topo)
    cfg = SimConfig(mode=AsyncMode.BEST_EFFORT, duration=0.01,
                    base_latency=500e-6, intra_node_latency=50e-6)
    sim = Simulator(app, cfg)
    assert sim._link_base(0, 1) == 50e-6      # same clique/host
    assert sim._link_base(0, 4) == 500e-6     # cross host
    # without the hierarchical model everything is flat
    sim_flat = Simulator(
        GraphColorApp(GraphColorConfig(n_processes=16, nodes_per_process=4),
                      topology=topo),
        SimConfig(mode=AsyncMode.BEST_EFFORT, duration=0.01,
                  base_latency=500e-6))
    assert sim_flat._link_base(0, 1) == 500e-6


def test_faulty_host_degrades_whole_clique():
    topo = cliques(32, 8)
    fm = faulty_host(topo, 1, compute_factor=20.0, link_factor=10.0)
    for p in range(8, 16):
        assert fm.compute_factor(p) == 20.0
    assert fm.compute_factor(0) == 1.0
    assert fm.link_factor(8, 9) == 10.0
    assert fm.link_factor(8, 0) == 10.0 and fm.link_factor(0, 8) == 10.0
    assert fm.link_factor(0, 1) == 1.0


# ---------------------------------------------------------------------------
# Injected topologies drive the apps end to end
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", sorted(TOPOLOGIES))
def test_graphcolor_runs_on_every_topology(name):
    topo = make_topology(name, 16)
    app = GraphColorApp(GraphColorConfig(n_processes=16, nodes_per_process=16),
                        topology=topo)
    cfg = SimConfig(mode=AsyncMode.BEST_EFFORT, duration=0.01,
                    base_latency=100e-6)
    res = Simulator(app, cfg).run()
    assert all(u > 0 for u in res.updates)
    assert res.sent > 0
    # messages travel exactly the topology's edges
    assert len(Simulator(app, cfg).ducts) == 2 * topo.n_edges


def test_evo_runs_on_injected_topology():
    topo = ring(8)
    app = EvoApp(EvoConfig(n_processes=8, cells_per_process=16),
                 topology=topo)
    res = Simulator(app, SimConfig(mode=AsyncMode.BEST_EFFORT, duration=0.005,
                                   base_latency=100e-6)).run()
    assert all(u > 0 for u in res.updates)
    assert np.isfinite(res.quality)


# ---------------------------------------------------------------------------
# Determinism: same seed -> identical trajectories and QoS
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["torus", "cliques"])
def test_simulator_deterministic(name):
    def go():
        topo = make_topology(name, 16)
        app = GraphColorApp(
            GraphColorConfig(n_processes=16, nodes_per_process=4, seed=7),
            topology=topo)
        cfg = SimConfig(mode=AsyncMode.BEST_EFFORT, duration=0.05, seed=7,
                        base_latency=200e-6, intra_node_latency=40e-6,
                        snapshot_warmup=0.01, snapshot_interval=0.01)
        return Simulator(app, cfg).run()

    a, b = go(), go()
    assert a.updates == b.updates
    assert a.sent == b.sent and a.dropped == b.dropped
    assert a.quality == b.quality
    assert a.qos == b.qos                      # QosReport is frozen/comparable
    assert a.qos_by_process == b.qos_by_process


def test_scalar_and_block_fragments_share_semantics():
    """1-simel fragments use the fast scalar path; colors stay in range and
    probs remain a simplex."""
    topo = torus(16)
    app = GraphColorApp(GraphColorConfig(n_processes=16, nodes_per_process=1),
                        topology=topo)
    frags = app.make_fragments()
    res = Simulator(app, SimConfig(mode=AsyncMode.BEST_EFFORT, duration=0.01,
                                   base_latency=100e-6)).run()
    for f in frags:
        assert 0 <= f.colors[0, 0] < 3
        np.testing.assert_allclose(f.probs.sum(), 1.0, atol=1e-6)
    assert np.isfinite(res.quality)


# ---------------------------------------------------------------------------
# Experiments driver (tiny end-to-end)
# ---------------------------------------------------------------------------
# ---------------------------------------------------------------------------
# Shard partitioning (DESIGN.md §8)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("topo,shards", [
    (ring(64), 8), (torus(64), 8), (torus(64), 4),
    (cliques(32, 8), 4), (smallworld(64), 8),
])
def test_contiguous_partition_invariants(topo, shards):
    plan = contiguous_partition(topo, shards)
    n, m = topo.n, topo.n // shards
    assert sorted(plan.perm) == list(range(n))          # a permutation
    assert all(plan.perm[plan.inv[p]] == p for p in range(n))
    # contiguity: shard s owns exactly positions [s*m, (s+1)*m)
    assert all(plan.shard_of[plan.perm[pos]] == pos // m
               for pos in range(n))
    assert plan.procs_per_shard == m
    # reported cut matches a direct recount of cross-shard directed edges
    cut = sum(1 for src in range(n) for dst in topo.neighbors[src]
              if plan.shard_of[src] != plan.shard_of[dst])
    assert plan.cut == cut


def test_contiguous_partition_thin_boundaries():
    # row-major torus blocks cut only the two block-boundary row pairs:
    # identity order must be kept and the cut stays O(rows), far below E
    topo = torus(64)  # 8x8, E = 256 directed
    plan = contiguous_partition(topo, 8)
    assert plan.perm == tuple(range(64))
    assert plan.cut == 128  # 8 rows x 8 cols x 2 dirs: every n/s edge cut
    plan4 = contiguous_partition(topo, 4)
    assert plan4.cut == 4 * 8 * 2  # one cut row-pair per block boundary
    # ring blocks touch only at their two endpoints
    assert contiguous_partition(ring(64), 8).cut == 2 * 8


def test_contiguous_partition_errors_and_identity():
    with pytest.raises(ValueError):
        contiguous_partition(ring(10), 4)   # 4 does not divide 10
    with pytest.raises(ValueError):
        contiguous_partition(ring(8), 0)
    plan = contiguous_partition(ring(8), 1)
    assert plan.perm == tuple(range(8)) and plan.cut == 0


def test_experiments_weak_scaling_cli(capsys):
    from repro.runtime.experiments import main
    rows = main(["--family", "weak_scaling", "--topology", "ring",
                 "--procs", "8", "16", "--duration", "0.01"])
    assert [r["n"] for r in rows] == [8, 16]
    for r in rows:
        for metric in ("simstep_period", "delivery_failure_rate"):
            assert r["qos"][metric]["median"] is not None
            assert r["qos"][metric]["p95"] is not None
    out = capsys.readouterr().out
    assert "median=" in out and "p95=" in out


def test_experiments_faults_family():
    from repro.runtime.experiments import main
    rows = main(["--family", "faults", "--topology", "cliques",
                 "--procs", "16", "--duration", "0.02",
                 "--clique-size", "4"])
    with_fault = next(r for r in rows if r["label"] == "with_fault")
    without = next(r for r in rows if r["label"] == "without_fault")
    # the faulty clique's tail is drastically worse than the healthy rest
    assert (with_fault["qos"]["clique"]["simstep_period"]["p95"]
            > 3 * with_fault["qos"]["rest"]["simstep_period"]["p95"])
    # global medians stay in the same ballpark (claim C4)
    assert (with_fault["qos"]["global"]["simstep_period"]["median"]
            < 2 * without["qos"]["global"]["simstep_period"]["median"])
