"""Registry-driven engine conformance suite (DESIGN.md §11).

One parametrized matrix replaces the hand-pinned engine-pair tests that
used to live in ``test_engine_jax.py`` / ``test_engine_sharded.py`` /
``test_layout_dense.py``: every *vectorized* engine in the registry is
exercised across layouts x topologies x modes x fault scenarios and
compared against the event-ordered oracle via
:func:`repro.core.qos.qos_signature` — full structural equality over every
per-process counter and every (process, window) QoS field, no metric
subset, no tolerance.  A newly registered engine is conformance-tested by
construction: the matrix enumerates ``engine_specs()``, not a hardcoded
list.

Four families:

  exact        dyadic configs (``engine_cases.dyadic_cfg``): power-of-two
               time constants make f32/f64 clock arithmetic exact, so the
               windowed engines must reproduce the oracle BITWISE
  statistical  jittered configs: clocks drift (the documented windowed-time
               approximation) — medians within ``PARITY_RTOL``
  variants     layout/scheduler strategy objects are pure implementation
               changes: dense vs edge-major must agree bitwise under
               jitter, faults, and block payloads
  sharded      (slow, subprocess, 8 forced host devices) every sharded
               configuration must reproduce ``shards=1`` bitwise — which,
               composed with the exact family, pins it to the oracle.
               The ``pipelined`` scheduler is the one deliberate
               exception: its double-buffered exchange delivers boundary
               messages one superstep late, so its rows are statistical
               (totals and QoS medians within rtol, the latency median
               allowed the +1-superstep shift); its conservation books
               are pinned exactly in ``test_engine_sharded.py``

Setting ``CONFORMANCE_TABLE=<path>`` writes the accumulated parity rows as
a JSON artifact (the CI ``conformance`` job uploads it).
"""
import json
import os
import textwrap

import pytest

jax = pytest.importorskip("jax")

from engine_cases import (  # noqa: E402
    EXACT_SCENARIOS,
    PARITY_RTOL,
    Scenario,
    case_seed,
    gc_app,
    jittered_cfg,
    oracle,
    run_case,
    run_md,
)
from repro.core.modes import AsyncMode  # noqa: E402
from repro.core.qos import aggregate_reports, qos_signature  # noqa: E402
from repro.runtime.engine import (  # noqa: E402
    engine_specs,
    get_engine_spec,
    make_engine,
)
from repro.runtime.faults import FaultModel  # noqa: E402

# ---------------------------------------------------------------------------
# Parity-table artifact
# ---------------------------------------------------------------------------
_TABLE = []


def _record(scenario: str, engine: str, variant: str, *, exact: bool,
            match: bool, detail: str = ""):
    _TABLE.append(dict(scenario=scenario, engine=engine, variant=variant,
                       exact=exact, match=bool(match), detail=detail))


@pytest.fixture(scope="session", autouse=True)
def _parity_table_artifact():
    yield
    path = os.environ.get("CONFORMANCE_TABLE")
    if path and _TABLE:
        with open(path, "w") as fh:
            json.dump(_TABLE, fh, indent=1, sort_keys=True)


# ---------------------------------------------------------------------------
# The registry drives the matrix
# ---------------------------------------------------------------------------
def _vectorized_specs():
    return [s for s in engine_specs() if s.vectorized]


def _exact_variants():
    """(engine, layout) cells: every vectorized engine x declared layout."""
    cells = []
    for spec in _vectorized_specs():
        for layout in spec.layouts or ("edge",):
            cells.append((spec.name, layout))
    return cells


def test_registry_covers_reference_and_vectorized_engines():
    names = [s.name for s in engine_specs()]
    assert "event" in names
    assert _vectorized_specs(), "no vectorized engine registered"
    spec = get_engine_spec("jax")
    assert spec.shardable and "dense" in spec.layouts
    assert "superstep" in spec.schedulers


# ---------------------------------------------------------------------------
# Family 1: exact bitwise conformance vs the event oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("engine,layout", _exact_variants(),
                         ids=[f"{e}-{lo}" for e, lo in _exact_variants()])
@pytest.mark.parametrize("scenario", EXACT_SCENARIOS,
                         ids=[s.name for s in EXACT_SCENARIOS])
def test_bitwise_conformance_vs_event_oracle(scenario, engine, layout):
    # the bucketed planner (DESIGN.md §13) gives every built-in topology a
    # dense plan, so the dense column runs the full scenario matrix —
    # including the irregular smallworld/cliques cells — with no skips.
    # quality is excluded from cross-backend comparison by design: the
    # event engine's app fragments draw decisions from a sequential numpy
    # RNG while the batched step uses counter-based hash draws, so color
    # choices differ while every timing/counter field must stay bitwise.
    # Within the vectorized family (family 3/4) quality IS compared.
    want = qos_signature(oracle(scenario))
    want.pop("quality")
    got = qos_signature(run_case(engine, scenario, layout=layout))
    got.pop("quality")
    _record(scenario.name, engine, f"layout={layout}", exact=True,
            match=got == want)
    assert got == want, (
        f"{engine}/{layout} diverged from the event oracle on "
        f"{scenario.name}")


def test_oracle_runs_are_nontrivial():
    """The exact matrix must exercise real traffic, not degenerate runs."""
    res = oracle(Scenario("ring-best-effort", "ring"))
    assert sum(res.updates) > 1000
    assert res.sent > 1000
    assert len(res.qos) >= 16 * 3
    res = oracle(Scenario("ring-no-comm", "ring", mode=AsyncMode.NO_COMM))
    assert res.sent == 0


# ---------------------------------------------------------------------------
# Family 2: statistical conformance under jittered configs
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("spec", _vectorized_specs(),
                         ids=[s.name for s in _vectorized_specs()])
def test_median_qos_parity_16_ring(spec):
    seed = case_seed("ring")
    cfg = jittered_cfg(0.1, seed=seed)
    res_e = make_engine("event", gc_app(16, "ring"), cfg).run()
    res_j = make_engine(spec.name, gc_app(16, "ring"), cfg).run()
    med_e = aggregate_reports(res_e.qos)
    med_j = aggregate_reports(res_j.qos)
    ok = True
    for metric, rtol in PARITY_RTOL.items():
        a, b = med_e[metric]["median"], med_j[metric]["median"]
        assert a is not None and b is not None
        ok &= abs(b - a) <= rtol * max(abs(a), 1e-12)
        assert abs(b - a) <= rtol * max(abs(a), 1e-12), \
            f"{metric}: event={a} {spec.name}={b} rtol={rtol}"
    # total progress agrees tightly
    du = abs(sum(res_j.updates) - sum(res_e.updates))
    assert du <= 0.02 * sum(res_e.updates)
    _record("ring-jittered", spec.name, "layout=auto", exact=False, match=ok,
            detail="medians within PARITY_RTOL")


def test_drops_with_tiny_buffer_and_slow_consumer():
    faults = FaultModel(compute_slowdown={1: 20.0})
    seed = case_seed("ring")
    cfg = jittered_cfg(0.05, seed=seed, buffer_capacity=2,
                       base_latency=20e-6)
    res_j = make_engine("jax", gc_app(2, "ring"), cfg, faults).run()
    res_e = make_engine("event", gc_app(2, "ring"), cfg, faults).run()
    assert res_j.dropped > 0
    assert abs(res_j.delivery_failure_rate - res_e.delivery_failure_rate) \
        < 0.15


def test_block_simels_run_and_quality_definition_matches():
    """simels > 1 exercises the batched block path on both engines."""
    seed = case_seed("torus")
    cfg = jittered_cfg(0.01, seed=seed)
    res_e = make_engine("event", gc_app(4, "torus", simels=16), cfg).run()
    res_j = make_engine("jax", gc_app(4, "torus", simels=16), cfg).run()
    assert sum(res_j.updates) > 0
    # same quality metric (global conflict count), same order of magnitude
    assert res_j.quality >= 0 and res_e.quality >= 0
    assert abs(sum(res_j.updates) - sum(res_e.updates)) \
        <= 0.05 * sum(res_e.updates)


# ---------------------------------------------------------------------------
# Family 3: layout strategy variants agree bitwise under jitter
# ---------------------------------------------------------------------------
VARIANT_MODES = [AsyncMode.BEST_EFFORT, AsyncMode.BARRIER_EVERY_STEP,
                 AsyncMode.ROLLING_BARRIER, AsyncMode.FIXED_BARRIER]


def _signature_match(label, res_a, res_b, engine="jax", variant=""):
    a, b = qos_signature(res_a), qos_signature(res_b)
    _record(label, engine, variant, exact=True, match=a == b)
    assert a == b, label


@pytest.mark.parametrize("mode", VARIANT_MODES, ids=lambda m: m.name.lower())
@pytest.mark.parametrize("topology", ["ring", "torus", "cliques",
                                      "smallworld"])
def test_dense_matches_edge_bitwise(topology, mode):
    seed = case_seed(topology)
    cfg = jittered_cfg(0.02, seed=seed, mode=mode)
    res_edge = make_engine("jax", gc_app(16, topology), cfg,
                           layout="edge").run()
    res_dense = make_engine("jax", gc_app(16, topology), cfg,
                            layout="dense").run()
    _signature_match(f"{topology}-{mode.name.lower()}-jittered", res_edge,
                     res_dense, variant="layout=dense vs edge")


@pytest.mark.parametrize("topology", ["ring", "torus"])
def test_dense_matches_edge_under_faults(topology):
    faults = FaultModel(
        compute_slowdown={1: 20.0, 3: 5.0},
        link_slowdown={(1, 2): 10.0, (2, 1): 10.0},
    )
    seed = case_seed(topology)
    cfg = jittered_cfg(0.02, seed=seed, buffer_capacity=4)
    res_edge = make_engine("jax", gc_app(16, topology), cfg, faults,
                           layout="edge").run()
    res_dense = make_engine("jax", gc_app(16, topology), cfg, faults,
                            layout="dense").run()
    assert res_dense.dropped > 0  # the tiny buffer under faults drops
    _signature_match(f"{topology}-faults-jittered", res_edge, res_dense,
                     variant="layout=dense vs edge")


def test_dense_matches_edge_with_block_simels():
    """Payload length > 1 exercises the megakernel's payload lanes."""
    seed = case_seed("torus")
    cfg = jittered_cfg(0.01, seed=seed)
    res_edge = make_engine("jax", gc_app(16, "torus", simels=9), cfg,
                           layout="edge").run()
    res_dense = make_engine("jax", gc_app(16, "torus", simels=9), cfg,
                            layout="dense").run()
    _signature_match("torus-simels9-jittered", res_edge, res_dense,
                     variant="layout=dense vs edge")


# ---------------------------------------------------------------------------
# Family 4: sharded configurations reproduce shards=1 bitwise
# (subprocess: the main test process keeps a single XLA device)
# ---------------------------------------------------------------------------
_SHARD_SCRIPT = textwrap.dedent("""
    import json
    from engine_cases import (EXACT_SCENARIOS, case_seed, gc_app,
                              jittered_cfg, oracle, run_case)
    from repro.core.qos import qos_signature
    from repro.runtime.engine import make_engine

    rows = []

    def check(label, variant, sig_a, sig_b):
        rows.append(dict(scenario=label, engine="jax", variant=variant,
                         exact=True, match=sig_a == sig_b))
        assert sig_a == sig_b, (label, variant)

    # dyadic exact matrix at 8 shards: transitively pins every sharded
    # configuration to the event oracle (family 1 pinned shards=1).
    # quality is cross-backend-excluded (different app decision RNG
    # streams by design); the jittered rows below compare it fully.
    for s in EXACT_SCENARIOS:
        want = qos_signature(oracle(s))
        want.pop("quality")
        got = qos_signature(run_case("jax", s, shards=8))
        got.pop("quality")
        check(s.name, "shards=8", got, want)

    # jittered sharding stays bitwise too: draws are keyed by original
    # pid / canonical edge id, so sharding is a pure layout change
    for topology, n in (("ring", 16), ("torus", 64), ("cliques", 32),
                        ("smallworld", 32)):
        cfg = jittered_cfg(0.02, seed=case_seed(topology))
        r1 = make_engine("jax", gc_app(n, topology), cfg).run()
        r8 = make_engine("jax", gc_app(n, topology), cfg, shards=8).run()
        check(f"{topology}{n}-jittered", "shards=8 vs 1",
              qos_signature(r8), qos_signature(r1))

    # strategy seams compose: dense layout and the superstep scheduler
    # (W=1) under the mesh reproduce the 8-shard edge-major run bitwise
    cfg = jittered_cfg(0.02, seed=case_seed("torus"))
    base = qos_signature(
        make_engine("jax", gc_app(64, "torus"), cfg, shards=8,
                    layout="edge").run())
    rd = make_engine("jax", gc_app(64, "torus"), cfg, shards=8,
                     layout="dense").run()
    check("torus64-jittered", "shards=8 layout=dense", qos_signature(rd),
          base)
    # (explicit scheduler="superstep" demands W > 1 — the degenerate W=1
    # batch rides the auto-resolved scheduler, as on the CLI)
    rw = make_engine("jax", gc_app(64, "torus"), cfg, shards=8,
                     superstep_windows=1).run()
    check("torus64-jittered", "shards=8 superstep W=1", qos_signature(rw),
          base)

    # pipelined scheduler: the double-buffered exchange delivers boundary
    # messages one superstep late, so trajectories are NOT bitwise vs the
    # superstep scheduler — its family is statistical: totals within a
    # tight tolerance, QoS medians within rtol, the latency median
    # additionally allowed the +1-superstep shift (conservation is pinned
    # exactly in test_engine_sharded.py).
    from repro.core.qos import aggregate_reports
    W = 4
    cfg = jittered_cfg(0.02, seed=case_seed("torus"))
    rs = make_engine("jax", gc_app(64, "torus"), cfg, shards=8,
                     superstep_windows=W).run()
    rp = make_engine("jax", gc_app(64, "torus"), cfg, shards=8,
                     superstep_windows=W, scheduler="pipelined").run()
    ok = True
    # the staging delay can shift each process by at most the one window
    # straddling a boundary decision — anything more is a scheduler bug
    du = max(abs(a - b) for a, b in zip(rp.updates, rs.updates))
    ok &= du <= 1
    assert du <= 1, ("pipelined updates drift", du)
    assert abs(rp.sent - rs.sent) <= 0.02 * rs.sent, (rp.sent, rs.sent)
    assert abs(rp.dropped - rs.dropped) <= 0.10 * max(rs.dropped, 1), (
        rp.dropped, rs.dropped)
    ms, mp = aggregate_reports(rs.qos), aggregate_reports(rp.qos)
    for metric, rtol in (("simstep_period", 0.05),
                         ("delivery_clumpiness", 0.05),
                         ("delivery_failure_rate", 0.10)):
        a, b = ms[metric]["median"], mp[metric]["median"]
        drift = abs(b - a) <= rtol * max(abs(a), 1e-9)
        ok &= drift
        assert drift, ("pipelined", metric, a, b)
    # latency is measured in sender steps: the shifted delivery may cost
    # up to one superstep of steps on top of the statistical tolerance
    a = ms["simstep_latency"]["median"]
    b = mp["simstep_latency"]["median"]
    assert abs(b - a) <= 0.05 * max(abs(a), 1e-9) + W, (
        "pipelined latency", a, b)
    rows.append(dict(scenario="torus64-jittered", engine="jax",
                     variant=f"pipelined W={W} vs superstep", exact=False,
                     match=bool(ok)))

    # rolling-barrier pipelined runs, by contrast, are EXACTLY W-invariant:
    # the quantum is metered on the work clock (compute + degree-fixed pull
    # cost — window_core.close_window), so the update schedule is a
    # function of (seed, release times) alone and the double-buffered
    # staging delay is invisible to it.  Per-process update counts and the
    # send total must match the per-window unsharded engine bitwise — no
    # drift tolerated.
    from repro.core.modes import AsyncMode
    cfgr = jittered_cfg(0.02, seed=case_seed("torus"),
                        mode=AsyncMode.ROLLING_BARRIER)
    rb = make_engine("jax", gc_app(64, "torus"), cfgr).run()
    rpr = make_engine("jax", gc_app(64, "torus"), cfgr, shards=8,
                      superstep_windows=W, scheduler="pipelined").run()
    assert rpr.updates == rb.updates, "rolling pipelined update drift"
    assert rpr.sent == rb.sent, (rpr.sent, rb.sent)
    rows.append(dict(scenario="torus64-rolling", engine="jax",
                     variant=f"pipelined W={W} exact W-invariance",
                     exact=True, match=True))

    # float32-payload bitcast boundary hop (evo app)
    from repro.apps.evo import EvoApp, EvoConfig
    from repro.runtime.topologies import make_topology
    topo = make_topology("torus", 16)
    def evo():
        return EvoApp(EvoConfig(n_processes=16, cells_per_process=4,
                                seed=case_seed("torus")),
                      topology=topo)
    cfg = jittered_cfg(0.02, seed=case_seed("torus"))
    r1 = make_engine("jax", evo(), cfg).run()
    r8 = make_engine("jax", evo(), cfg, shards=8).run()
    check("evo-torus16-jittered", "shards=8 vs 1", qos_signature(r8),
          qos_signature(r1))

    # replicates vmap inside each shard and stay independent
    cfg = jittered_cfg(0.02, seed=case_seed("ring"))
    reps1 = make_engine("jax", gc_app(16, "ring"),
                        cfg).run_replicates([0, 1, 2])
    reps8 = make_engine("jax", gc_app(16, "ring"), cfg,
                        shards=8).run_replicates([0, 1, 2])
    for i, (a, b) in enumerate(zip(reps1, reps8)):
        check(f"ring16-replicate{i}", "shards=8 vs 1", qos_signature(b),
              qos_signature(a))
    assert len({tuple(r.updates) for r in reps8}) > 1

    print("ROWS " + json.dumps(rows))
    print("SHARDED-OK")
""")


@pytest.mark.slow
def test_sharded_conformance_8_shards():
    out = run_md(_SHARD_SCRIPT)
    assert "SHARDED-OK" in out
    for line in out.splitlines():
        if line.startswith("ROWS "):
            _TABLE.extend(json.loads(line[5:]))


# ---------------------------------------------------------------------------
# Negative paths: every bad combination is one actionable ValueError
# raised by the registry or the layout planner — never a JAX trace error
# ---------------------------------------------------------------------------
def _cfg01():
    return jittered_cfg(0.01)


def test_unknown_names_raise_actionable_errors():
    with pytest.raises(ValueError, match="unknown engine"):
        make_engine("nope", gc_app(4), _cfg01())
    with pytest.raises(ValueError, match="unknown scheduler"):
        make_engine("jax", gc_app(4), _cfg01(), scheduler="bogus")
    with pytest.raises(ValueError, match="unknown layout"):
        make_engine("jax", gc_app(4), _cfg01(), layout="banana")


def test_event_engine_rejects_vectorized_strategies():
    with pytest.raises(ValueError, match="single-device"):
        make_engine("event", gc_app(16), _cfg01(), shards=8)
    with pytest.raises(ValueError, match="engine jax"):
        make_engine("event", gc_app(8), _cfg01(), layout="dense")
    with pytest.raises(ValueError, match="superstep"):
        make_engine("event", gc_app(8), _cfg01(), superstep_windows=8)
    with pytest.raises(ValueError, match="superstep"):
        make_engine("event", gc_app(8), _cfg01(), scheduler="superstep")


def test_scheduler_combinations_validate():
    # superstep needs a batch size; unsharded it is the W-fused dense
    # megakernel (DESIGN.md §13), so it composes with every layout except
    # an explicit edge-major request
    with pytest.raises(ValueError, match="superstep_windows > 1"):
        make_engine("jax", gc_app(8), _cfg01(), scheduler="superstep")
    with pytest.raises(ValueError, match="dense"):
        make_engine("jax", gc_app(8), _cfg01(), scheduler="superstep",
                    superstep_windows=8, layout="edge")
    eng = make_engine("jax", gc_app(8), _cfg01(), superstep_windows=8)
    assert eng.scheduler == "superstep" and eng.layout == "dense"
    # window scheduler contradicts a batched-exchange request
    with pytest.raises(ValueError, match="scheduler='superstep'"):
        make_engine("jax", gc_app(16), _cfg01(), scheduler="window",
                    shards=2, superstep_windows=8)
    # pipelined needs a superstep depth AND a populated mesh, like
    # superstep — and the event engine has no such scheduler at all
    with pytest.raises(ValueError, match="superstep_windows > 1"):
        make_engine("jax", gc_app(8), _cfg01(), scheduler="pipelined")
    with pytest.raises(ValueError, match="shards"):
        make_engine("jax", gc_app(8), _cfg01(), scheduler="pipelined",
                    superstep_windows=8)
    with pytest.raises(ValueError, match="pipelined"):
        make_engine("event", gc_app(8), _cfg01(), scheduler="pipelined")
    # W must be a positive count once it reaches the engine, and the
    # engine itself re-checks the pipelined depth (direct construction)
    from repro.runtime.engine_sharded import ShardedJaxEngine
    with pytest.raises(ValueError, match=">= 1"):
        ShardedJaxEngine(gc_app(8), _cfg01(), shards=1, superstep_windows=0)
    with pytest.raises(ValueError, match="superstep_windows > 1"):
        ShardedJaxEngine(gc_app(8), _cfg01(), shards=1,
                         scheduler="pipelined")


def test_dense_on_irregular_topology_buckets_instead_of_raising():
    # irregular topologies used to be rejected with a "degree-regular"
    # error; the bucketed planner now pads them into power-of-two degree
    # buckets, so forcing dense simply works (and auto resolves to it)
    eng = make_engine("jax", gc_app(16, "smallworld"), _cfg01(),
                      layout="dense")
    assert eng.layout == "dense"
    auto = make_engine("jax", gc_app(16, "smallworld"), _cfg01())
    assert auto.layout == "dense"


def test_shard_partition_errors_are_actionable():
    # the partition check fires before the device-count check, so this
    # fails the same way on any machine
    with pytest.raises(ValueError, match="divide"):
        make_engine("jax", gc_app(10), _cfg01(), shards=4)
    if len(jax.devices()) < 8:
        with pytest.raises(ValueError, match="xla_force_host_platform"):
            make_engine("jax", gc_app(16), _cfg01(), shards=8)
