"""Dense duct-layout tests: the planner and the fused megakernel.

The dense receiver-major layout is a pure memory-layout change; its
bitwise parity with the edge-major path — across topologies, modes, fault
injection, and block payloads — is asserted by the registry-driven suite
(``tests/test_engine_conformance.py``, family 3).  This file keeps what is
specific to the layout machinery itself: the planner's auto/fallback
rules, interpret-mode Pallas parity for the ``duct_window`` megakernel,
and the dense path's replicate plumbing.
"""

import logging

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from engine_cases import gc_app, jittered_cfg  # noqa: E402
from repro.kernels.duct_exchange import (  # noqa: E402
    duct_window,
    duct_window_jnp,
    duct_window_ref,
)
from repro.runtime.engine import make_engine  # noqa: E402
from repro.runtime.engine_jax import JaxEngine  # noqa: E402
from repro.runtime.topologies import make_topology, plan_layout, regular_degree  # noqa: E402

_app = gc_app
_cfg = jittered_cfg


# ---------------------------------------------------------------------------
# Layout planner
# ---------------------------------------------------------------------------
def test_plan_dense_for_regular_topologies():
    for name, n, want_d in (("ring", 16, 2), ("torus", 16, 4)):
        topo = make_topology(name, n)
        plan = plan_layout(topo, "auto")
        assert plan.kind == "dense"
        assert plan.degree == want_d
        assert regular_degree(topo) == want_d
        # row (p, j) holds in-edge j of receiver p in sorted-source order
        for p in range(n):
            assert list(plan.src[p]) == sorted(topo.neighbors[p])
        # rev is an involution: the reverse of the reverse is the row itself
        flat_rev = plan.rev.reshape(-1)
        np.testing.assert_array_equal(flat_rev[flat_rev], np.arange(n * want_d))


def test_plan_auto_falls_back_with_actionable_log(caplog):
    # WARNING level: visible on stderr via logging's last-resort handler
    # even when the caller never configures logging
    with caplog.at_level(logging.WARNING, logger="repro.runtime.topologies"):
        plan = plan_layout(make_topology("smallworld", 16), "auto")
    assert plan.kind == "edge"
    assert "irregular" in caplog.text and "edge-major" in caplog.text
    caplog.clear()
    with caplog.at_level(logging.WARNING, logger="repro.runtime.topologies"):
        plan = plan_layout(make_topology("cliques", 16), "auto")
    assert plan.kind == "edge"
    assert "halo" in caplog.text and "layout='dense'" in caplog.text


def test_plan_forced_dense_raises_on_irregular():
    with pytest.raises(ValueError, match="degree-regular"):
        plan_layout(make_topology("smallworld", 16), "dense")
    with pytest.raises(ValueError, match="unknown layout"):
        plan_layout(make_topology("ring", 8), "banana")


# ---------------------------------------------------------------------------
# Megakernel parity: jnp twin and interpret-mode Pallas vs the numpy ref
# ---------------------------------------------------------------------------
def _random_window_state(rng, n=6, d=3, C=5, L=2, cap=5):
    qa = np.full((n, d, C), np.inf, np.float32)
    qt = np.zeros((n, d, C), np.int32)
    qp = np.zeros((n, d, C, L), np.int32)
    head = rng.integers(0, C, (n, d)).astype(np.int32)
    size = np.zeros((n, d), np.int32)
    for p in range(n):
        for j in range(d):
            s = rng.integers(0, cap)
            size[p, j] = s
            for k in range(s):
                pos = (head[p, j] + k) % C
                qa[p, j, pos] = rng.random() * 2
                qt[p, j, pos] = rng.integers(0, 50)
                qp[p, j, pos] = rng.integers(0, 99, L)
    # staged push, engine-style: eager drop-iff-full against carried size
    pacc = (rng.random((n, d)) < 0.7) & (size < cap)
    ppos = ((head + size) % C).astype(np.int32)
    size = (size + pacc).astype(np.int32)
    pav = (rng.random((n, d)) * 2).astype(np.float32)
    ptch = rng.integers(0, 50, (n, d)).astype(np.int32)
    ppay = rng.integers(0, 99, (n, d, L)).astype(np.int32)
    rnow = (rng.random(n) * 2).astype(np.float32)
    ract = rng.random(n) < 0.8
    return (qa, qt, qp, head, size, ppos, pacc, pav, ptch, ppay, rnow, ract)


@pytest.mark.parametrize("impl", ["jnp", "pallas_interpret"])
def test_duct_window_matches_ref(impl):
    rng = np.random.default_rng(11)
    args = _random_window_state(rng)
    ref = duct_window_ref(*args, max_pops=3)
    if impl == "jnp":
        out = duct_window_jnp(*map(jnp.asarray, args), max_pops=3)
    else:
        out = duct_window(
            *map(jnp.asarray, args),
            max_pops=3,
            use_pallas=True,
            interpret=True,
        )
    for name, a, b in zip(ref._fields, ref, out):
        np.testing.assert_array_equal(
            np.asarray(b),
            np.asarray(a),
            err_msg=f"{impl}: field {name}",
        )


def test_duct_window_degree_one_and_empty_rings():
    rng = np.random.default_rng(5)
    args = _random_window_state(rng, n=3, d=1, C=1, L=1, cap=1)
    ref = duct_window_ref(*args, max_pops=1)
    out = duct_window_jnp(*map(jnp.asarray, args), max_pops=1)
    for name, a, b in zip(ref._fields, ref, out):
        np.testing.assert_array_equal(np.asarray(b), np.asarray(a), err_msg=name)


# ---------------------------------------------------------------------------
# Replicate plumbing and auto-layout resolution on the dense path
# ---------------------------------------------------------------------------
def test_dense_engine_replicates_and_registry():
    cfg = _cfg(0.01)
    eng = make_engine("jax", _app(16, "torus"), cfg, layout="dense")
    assert eng.layout == "dense"
    reps = eng.run_replicates([0, 1])
    base = make_engine("jax", _app(16, "torus"), cfg, layout="edge")
    singles = base.run_replicates([0, 1])
    for rd, re_ in zip(reps, singles):
        assert rd.updates == re_.updates
    # distinct seeds give distinct trajectories on the dense path too
    assert reps[0].updates != reps[1].updates


def test_auto_layout_resolves_per_topology():
    cfg = _cfg(0.01)
    assert JaxEngine(_app(16, "torus"), cfg).layout == "dense"
    assert JaxEngine(_app(16, "smallworld"), cfg).layout == "edge"
    assert JaxEngine(_app(16, "cliques"), cfg).layout == "edge"
