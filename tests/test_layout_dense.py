"""Dense duct-layout tests: the bucketed planner and the fused megakernel.

The dense receiver-major layout is a pure memory-layout change; its
bitwise parity with the edge-major path — across topologies, modes, fault
injection, and block payloads — is asserted by the registry-driven suite
(``tests/test_engine_conformance.py``, family 3).  This file keeps what is
specific to the layout machinery itself: the degree-bucketed planner's
tables, interpret-mode Pallas parity for the ``duct_window`` /
``duct_commit`` megakernel family, the W-fused superstep scheduler's
bitwise parity on every topology, and the dense path's replicate plumbing.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from engine_cases import case_seed, gc_app, jittered_cfg  # noqa: E402
from repro.core.qos import qos_signature  # noqa: E402
from repro.kernels.duct_exchange import (  # noqa: E402
    duct_commit,
    duct_commit_jnp,
    duct_commit_ref,
    duct_window,
    duct_window_jnp,
    duct_window_ref,
)
from repro.runtime.engine import make_engine  # noqa: E402
from repro.runtime.engine_jax import JaxEngine  # noqa: E402
from repro.runtime.topologies import (  # noqa: E402
    canonical_edges,
    make_topology,
    next_pow2,
    plan_layout,
    regular_degree,
)

_app = gc_app
_cfg = jittered_cfg

TOPOLOGIES = ("ring", "torus", "smallworld", "cliques")


# ---------------------------------------------------------------------------
# Layout planner
# ---------------------------------------------------------------------------
def test_plan_dense_for_regular_topologies():
    for name, n, want_d in (("ring", 16, 2), ("torus", 16, 4)):
        topo = make_topology(name, n)
        plan = plan_layout(topo, "auto")
        assert plan.kind == "dense"
        assert plan.degree == want_d
        assert regular_degree(topo) == want_d
        # degree-regular topologies collapse to ONE exact-d bucket: no
        # padding, every flat row live, receiver p's block at p*d
        assert len(plan.buckets) == 1 and plan.buckets[0].deg == want_d
        assert plan.n_rows == n * want_d
        assert plan.live.all()
        np.testing.assert_array_equal(plan.row_start,
                                      np.arange(n) * want_d)
        np.testing.assert_array_equal(plan.bdeg, np.full(n, want_d))
        # row (p, j) holds in-edge j of receiver p in sorted-source order
        for p in range(n):
            rows = slice(p * want_d, (p + 1) * want_d)
            assert list(plan.src[rows]) == sorted(topo.neighbors[p])
            assert (plan.dst[rows] == p).all()
        # rev is an involution: the reverse of the reverse is the row
        np.testing.assert_array_equal(plan.rev[plan.rev],
                                      np.arange(n * want_d))


@pytest.mark.parametrize("name", ["smallworld", "cliques"])
def test_plan_buckets_irregular_topologies(name):
    topo = make_topology(name, 16)
    n = topo.n
    degs = [len(nbs) for nbs in topo.neighbors]
    dmax = max(degs)
    plan = plan_layout(topo, "auto")
    assert plan.kind == "dense"
    assert plan.degree == dmax
    # bucket degree = next power of two, clamped to the max in-degree
    np.testing.assert_array_equal(
        plan.bdeg, [min(next_pow2(k), dmax) for k in degs])
    assert plan.n_rows == int(plan.bdeg.sum())
    # each receiver's block: live prefix of its true in-degree in
    # sorted-source (= canonical-edge-id) order, dead padding after
    _, _, eindex = canonical_edges(topo)
    E = len(eindex)
    for p in range(n):
        rows = slice(plan.row_start[p], plan.row_start[p] + plan.bdeg[p])
        live = plan.live[rows]
        assert live.sum() == degs[p] and live[:degs[p]].all()
        assert (plan.dst[rows] == p).all()
        srcs = plan.src[rows]
        assert list(srcs[:degs[p]]) == sorted(topo.neighbors[p])
        # dead rows carry sentinels: src == n, eid == E
        assert (srcs[degs[p]:] == n).all()
        assert (plan.eid[rows][degs[p]:] == E).all()
        eids = plan.eid[rows][:degs[p]]
        assert list(eids) == [eindex[(s, p)] for s in sorted(
            topo.neighbors[p])]
    # rev is a full involution; dead rows map to themselves
    np.testing.assert_array_equal(plan.rev[plan.rev],
                                  np.arange(plan.n_rows))
    dead = ~plan.live
    np.testing.assert_array_equal(plan.rev[dead],
                                  np.arange(plan.n_rows)[dead])
    # bucket slabs tile the flat row space with ascending members
    covered = 0
    for b in plan.buckets:
        assert b.start == covered
        assert (np.diff(b.members) > 0).all() or len(b.members) == 1
        covered += b.deg * len(b.members)
    assert covered == plan.n_rows


def test_plan_forced_layouts_and_unknown_layout():
    # forcing dense on an irregular topology now buckets instead of
    # raising; forcing edge still yields the fully general layout
    assert plan_layout(make_topology("smallworld", 16), "dense").kind \
        == "dense"
    assert plan_layout(make_topology("smallworld", 16), "edge").kind \
        == "edge"
    with pytest.raises(ValueError, match="unknown layout"):
        plan_layout(make_topology("ring", 8), "banana")


# ---------------------------------------------------------------------------
# Megakernel parity: jnp twin and interpret-mode Pallas vs the numpy ref
# ---------------------------------------------------------------------------
def _random_window_state(rng, n=6, d=3, C=5, L=2, cap=5):
    qa = np.full((n, d, C), np.inf, np.float32)
    qt = np.zeros((n, d, C), np.int32)
    qp = np.zeros((n, d, C, L), np.int32)
    head = rng.integers(0, C, (n, d)).astype(np.int32)
    size = np.zeros((n, d), np.int32)
    for p in range(n):
        for j in range(d):
            s = rng.integers(0, cap)
            size[p, j] = s
            for k in range(s):
                pos = (head[p, j] + k) % C
                qa[p, j, pos] = rng.random() * 2
                qt[p, j, pos] = rng.integers(0, 50)
                qp[p, j, pos] = rng.integers(0, 99, L)
    # staged push, engine-style: eager drop-iff-full against carried size
    pacc = (rng.random((n, d)) < 0.7) & (size < cap)
    ppos = ((head + size) % C).astype(np.int32)
    size = (size + pacc).astype(np.int32)
    pav = (rng.random((n, d)) * 2).astype(np.float32)
    ptch = rng.integers(0, 50, (n, d)).astype(np.int32)
    ppay = rng.integers(0, 99, (n, d, L)).astype(np.int32)
    rnow = (rng.random(n) * 2).astype(np.float32)
    ract = rng.random(n) < 0.8
    return (qa, qt, qp, head, size, ppos, pacc, pav, ptch, ppay, rnow, ract)


@pytest.mark.parametrize("impl", ["jnp", "pallas_interpret"])
def test_duct_window_matches_ref(impl):
    rng = np.random.default_rng(11)
    args = _random_window_state(rng)
    ref = duct_window_ref(*args, max_pops=3)
    if impl == "jnp":
        out = duct_window_jnp(*map(jnp.asarray, args), max_pops=3)
    else:
        out = duct_window(
            *map(jnp.asarray, args),
            max_pops=3,
            use_pallas=True,
            interpret=True,
        )
    for name, a, b in zip(ref._fields, ref, out):
        np.testing.assert_array_equal(
            np.asarray(b),
            np.asarray(a),
            err_msg=f"{impl}: field {name}",
        )


def test_duct_window_degree_one_and_empty_rings():
    rng = np.random.default_rng(5)
    args = _random_window_state(rng, n=3, d=1, C=1, L=1, cap=1)
    ref = duct_window_ref(*args, max_pops=1)
    out = duct_window_jnp(*map(jnp.asarray, args), max_pops=1)
    for name, a, b in zip(ref._fields, ref, out):
        np.testing.assert_array_equal(np.asarray(b), np.asarray(a), err_msg=name)


def _random_commit_state(rng, R=24, C=6, L=2, W=5):
    qa = (rng.random((R, C)) * 2).astype(np.float32)
    qt = rng.integers(0, 50, (R, C)).astype(np.int32)
    qp = rng.integers(0, 99, (R, C, L)).astype(np.int32)
    head = rng.integers(0, C, R).astype(np.int32)
    size0 = rng.integers(0, C, R).astype(np.int32)
    # the engine guarantees pb_cnt pushes fit behind the frozen tail
    cnt = np.minimum(rng.integers(0, W + 1, R), C - size0).astype(np.int32)
    pa = (rng.random((R, W)) * 2).astype(np.float32)
    pt = rng.integers(0, 50, (R, W)).astype(np.int32)
    pp = rng.integers(0, 99, (R, W, L)).astype(np.int32)
    return (qa, qt, qp, head, size0, cnt, pa, pt, pp)


@pytest.mark.parametrize("impl", ["jnp", "pallas_interpret"])
def test_duct_commit_matches_ref(impl):
    """The superstep commit is slot-exact across all three backends:
    push j of ring r lands at (head + size0 + j) % C, untouched slots
    keep their frozen base values bit-for-bit."""
    rng = np.random.default_rng(17)
    args = _random_commit_state(rng)
    ref = duct_commit_ref(*args)
    if impl == "jnp":
        out = duct_commit_jnp(*map(jnp.asarray, args))
    else:
        out = duct_commit(*map(jnp.asarray, args), use_pallas=True,
                          interpret=True)
    for name, a, b in zip(ref._fields, ref, out):
        np.testing.assert_array_equal(np.asarray(b), np.asarray(a),
                                      err_msg=f"{impl}: field {name}")


# ---------------------------------------------------------------------------
# W-fused superstep scheduler: bitwise vs per-window dense on EVERY topology
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("topology", TOPOLOGIES)
def test_superstep_fusion_bitwise_per_topology(topology):
    """Fusing W windows into one launch (frozen rings + compact pushbuf +
    one duct_commit) is a pure execution-strategy change: the full QoS
    signature must match the per-window dense engine bit-for-bit — on the
    padded bucketed rows of the irregular topologies too."""
    cfg = _cfg(0.02, seed=case_seed(topology))
    base = make_engine("jax", _app(16, topology), cfg).run()
    for w in (2, 4):
        fused = make_engine("jax", _app(16, topology), cfg,
                            superstep_windows=w).run()
        assert qos_signature(fused) == qos_signature(base), \
            f"{topology}: W={w} fused diverged from per-window dense"


# ---------------------------------------------------------------------------
# Replicate plumbing and auto-layout resolution on the dense path
# ---------------------------------------------------------------------------
def test_dense_engine_replicates_and_registry():
    cfg = _cfg(0.01)
    eng = make_engine("jax", _app(16, "torus"), cfg, layout="dense")
    assert eng.layout == "dense"
    reps = eng.run_replicates([0, 1])
    base = make_engine("jax", _app(16, "torus"), cfg, layout="edge")
    singles = base.run_replicates([0, 1])
    for rd, re_ in zip(reps, singles):
        assert rd.updates == re_.updates
    # distinct seeds give distinct trajectories on the dense path too
    assert reps[0].updates != reps[1].updates


def test_auto_layout_resolves_dense_everywhere():
    cfg = _cfg(0.01)
    for topology in TOPOLOGIES:
        assert JaxEngine(_app(16, topology), cfg).layout == "dense", topology
