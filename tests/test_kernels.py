"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracle,
swept over shapes, dtypes, and block sizes (+ hypothesis property tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional extra; skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.kernels.decode_attention import decode_attention, decode_attention_ref
from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.quantize import dequantize, quantize, quantize_ref
from repro.kernels.topk_compress import topk_compress, topk_compress_ref

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("BK,G,S,hd,bq,bk", [
    (2, 1, 256, 64, 128, 128),
    (2, 2, 256, 128, 64, 128),
    (1, 4, 512, 64, 128, 64),
    (3, 1, 128, 32, 128, 128),   # single block (bq=bk=S)
])
def test_flash_attention_matches_ref(BK, G, S, hd, bq, bk, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (BK, G, S, hd), dtype)
    k = jax.random.normal(ks[1], (BK, S, hd), dtype)
    v = jax.random.normal(ks[2], (BK, S, hd), dtype)
    out = flash_attention_kernel(q, k, v, causal=True, bq=bq, bk=bk,
                                 interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_non_causal():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 2, 256, 64))
    k = jax.random.normal(ks[1], (2, 256, 64))
    v = jax.random.normal(ks[2], (2, 256, 64))
    out = flash_attention_kernel(q, k, v, causal=False, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_model_layout_wrapper():
    """ops.flash_attention agrees with the model's chunked jnp attention."""
    from repro.configs.base import ModelConfig
    from repro.models import attention, lm
    cfg = ModelConfig(name="t", family="dense", num_layers=1, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                      dtype="float32", param_dtype="float32")
    params = lm.init_params(KEY, cfg)["stack"][0]
    attn_p = jax.tree.map(lambda a: a[0], params)["mixer"]
    x = jax.random.normal(KEY, (2, 128, 64))
    pos = jnp.broadcast_to(jnp.arange(128, dtype=jnp.int32)[None], (2, 128))
    y_ref, (k, v) = attention.attention_forward(attn_p, x, cfg, pos)
    q, k2, v2 = attention._project_qkv(attn_p, x, cfg, pos)
    o = flash_attention(q, k2, v2, causal=True, interpret=True)
    o = o.reshape(2, 128, -1) @ attn_p["wo"]
    np.testing.assert_allclose(np.asarray(o), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


@given(s_blocks=st.integers(1, 4), hd_pow=st.integers(5, 7))
@settings(max_examples=8, deadline=None)
def test_flash_attention_property_blocks(s_blocks, hd_pow):
    S, hd = 128 * s_blocks, 2 ** hd_pow
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 1, S, hd))
    k = jax.random.normal(ks[1], (1, S, hd))
    v = jax.random.normal(ks[2], (1, S, hd))
    out = flash_attention_kernel(q, k, v, causal=True, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("BK,G,S,hd,bc", [
    (4, 2, 1024, 64, 256),
    (2, 1, 2048, 128, 512),
    (1, 8, 512, 64, 512),     # single chunk
])
def test_decode_attention_matches_ref(BK, G, S, hd, bc, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (BK, G, hd), dtype)
    k = jax.random.normal(ks[1], (BK, S, hd), dtype)
    v = jax.random.normal(ks[2], (BK, S, hd), dtype)
    out = decode_attention(q, k, v, bc=bc, interpret=True)
    ref = decode_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_decode_partials_combine_invariance():
    """Chunk size must not change the combined result (flash-decoding)."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 2, 64))
    k = jax.random.normal(ks[1], (2, 1024, 64))
    v = jax.random.normal(ks[2], (2, 1024, 64))
    a = decode_attention(q, k, v, bc=128, interpret=True)
    b = decode_attention(q, k, v, bc=1024, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-6, atol=2e-6)


# ---------------------------------------------------------------------------
# topk compress
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,block,ratio", [
    (4096, 512, 0.05), (1000, 256, 0.1), (128, 128, 0.5),
])
def test_topk_matches_ref(n, block, ratio, dtype):
    x = (jax.random.normal(KEY, (n,)) * 3).astype(dtype)
    vals, gidx, nb = topk_compress(x, ratio=ratio, block=block, interpret=True)
    pad = (-n) % block
    padded = jnp.pad(x.astype(jnp.float32), (0, pad)).reshape(-1, block)
    rvals, ridx = topk_compress_ref(padded, max(1, int(block * ratio)))
    # same magnitudes selected per block (order may differ on ties)
    np.testing.assert_allclose(
        np.sort(np.abs(np.asarray(vals, np.float32)), axis=-1),
        np.sort(np.abs(np.asarray(rvals)), axis=-1), rtol=1e-5, atol=1e-5)
    # global indices address the right values
    flat = np.asarray(jnp.pad(x.astype(jnp.float32), (0, pad)))
    np.testing.assert_allclose(flat[np.asarray(gidx).reshape(-1)],
                               np.asarray(vals, np.float32).reshape(-1),
                               rtol=1e-5, atol=1e-5)


@given(seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_topk_property_selected_dominate(seed):
    """Every selected |value| >= every unselected |value| in its block."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (512,))
    vals, gidx, nb = topk_compress(x, ratio=0.1, block=256, interpret=True)
    xa = np.asarray(x)
    for b in range(2):
        sel = np.asarray(gidx[b]) - b * 256
        blockv = np.abs(xa[b * 256:(b + 1) * 256])
        thresh = np.abs(np.asarray(vals[b])).min()
        unselected = np.delete(blockv, sel)
        assert (unselected <= thresh + 1e-6).all()


# ---------------------------------------------------------------------------
# quantize
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,block", [(1000, 256), (4096, 1024), (64, 128)])
def test_quantize_roundtrip_error_bounded(n, block, dtype):
    x = (jax.random.normal(KEY, (n,)) * 5).astype(dtype)
    q, s, size = quantize(x, block=block, interpret=True)
    assert q.dtype == jnp.int8
    xr = dequantize(q, s, size, interpret=True)
    err = np.abs(np.asarray(x, np.float32) - np.asarray(xr)[:n])
    # elementwise error bounded by half a step of that element's block scale
    scales = np.asarray(s).reshape(-1)
    bound = np.repeat(scales, block)[:n] * 0.5 + 1e-6
    assert (err <= bound).all()


def test_quantize_matches_ref():
    x = jax.random.normal(KEY, (8, 256)) * 2
    q, s = quantize_ref(x)
    q2, s2, _ = quantize(x.reshape(-1), block=256, interpret=True)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))
    np.testing.assert_allclose(np.asarray(s), np.asarray(s2), rtol=1e-6)
