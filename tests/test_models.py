"""Model substrate tests: forward/loss/prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import lm

KEY = jax.random.PRNGKey(1)

DENSE = ModelConfig(name="t-dense", family="dense", num_layers=2, d_model=64,
                    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97,
                    qk_norm=True, qkv_bias=True, dtype="float32", param_dtype="float32")
XLSTM = ModelConfig(name="t-xlstm", family="ssm", num_layers=2, d_model=64,
                    num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=97,
                    block_pattern=("mlstm", "slstm"), dtype="float32", param_dtype="float32")
JAMBA = ModelConfig(name="t-jamba", family="hybrid", num_layers=4, d_model=64,
                    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97,
                    block_pattern=("mamba", "attn"), dtype="float32", param_dtype="float32")
MOE = ModelConfig(name="t-moe", family="moe", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=4, d_ff=32, vocab_size=97,
                  num_experts=4, experts_per_tok=2, num_shared_experts=2,
                  moe_d_ff=32, dtype="float32", param_dtype="float32")


def _pad_kv(caches):
    def pad(path, a):
        names = [str(getattr(p, "key", "")) for p in path]
        if names and names[-1] in ("k", "v") and a.ndim == 5:
            return jnp.pad(a, ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0)))
        return a
    return jax.tree_util.tree_map_with_path(pad, caches)


@pytest.mark.parametrize("cfg", [DENSE, XLSTM, JAMBA, MOE], ids=lambda c: c.name)
def test_forward_loss_finite(cfg):
    params = lm.init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    logits, aux = lm.forward(params, toks, cfg)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    loss, metrics = lm.loss_fn(params, {"tokens": toks, "labels": toks}, cfg)
    assert np.isfinite(float(loss))
    # random init: loss should be near ln(V)
    assert abs(float(metrics["ce"]) - np.log(cfg.vocab_size)) < 1.0


@pytest.mark.parametrize("cfg", [DENSE, XLSTM, JAMBA], ids=lambda c: c.name)
def test_decode_matches_forward(cfg):
    S, B = 12, 2
    params = lm.init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full_logits, _ = lm.forward(params, toks, cfg)
    lgt_pre, caches = lm.prefill_step(params, toks[:, :S - 1], cfg)
    np.testing.assert_allclose(np.asarray(lgt_pre[:, 0]),
                               np.asarray(full_logits[:, S - 2]), rtol=2e-4, atol=2e-4)
    caches = _pad_kv(caches)
    _, lgt_dec, _ = lm.decode_step(params, toks[:, S - 1:S], caches, cfg, S - 1)
    np.testing.assert_allclose(np.asarray(lgt_dec[:, 0]),
                               np.asarray(full_logits[:, S - 1]), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("cfg", [DENSE, XLSTM, JAMBA, MOE], ids=lambda c: c.name)
def test_grads_finite(cfg):
    params = lm.init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    grads = jax.grad(lambda p: lm.loss_fn(p, {"tokens": toks, "labels": toks}, cfg)[0])(params)
    for leaf in jax.tree.leaves(grads):
        assert not bool(jnp.isnan(leaf).any())


def test_scan_matches_unrolled():
    cfg = DENSE
    params = lm.init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    a, _ = lm.forward(params, toks, cfg)
    b, _ = lm.forward(params, toks, cfg.replace(scan_layers=False))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_chunked_attention_matches_single_chunk():
    from repro.models import attention
    cfg = DENSE
    params = lm.init_params(KEY, cfg)
    attn_p = jax.tree.map(lambda a: a[0], params["stack"][0])["mixer"]
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(16, dtype=jnp.int32)[None], (2, 16))
    y1, _ = attention.attention_forward(attn_p, x, cfg, pos, q_chunk=4)
    y2, _ = attention.attention_forward(attn_p, x, cfg, pos, q_chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)


def test_mamba_chunk_invariance():
    from repro.models import ssm
    cfg = JAMBA
    key = jax.random.PRNGKey(3)
    p = ssm.init_mamba(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 16, cfg.d_model)) * 0.1
    y1 = ssm.mamba_forward(p, x, cfg, chunk=4)
    y2 = ssm.mamba_forward(p, x, cfg, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-5)


def test_mlstm_chunk_invariance():
    from repro.models import ssm
    cfg = XLSTM
    key = jax.random.PRNGKey(4)
    p = ssm.init_mlstm(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 16, cfg.d_model)) * 0.1
    y1 = ssm.mlstm_forward(p, x, cfg, q_chunk=4)
    y2 = ssm.mlstm_forward(p, x, cfg, q_chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-5)


def test_moe_capacity_drops_are_bounded():
    """With generous capacity, MoE output should match a dense-dispatch oracle."""
    from repro.models import moe as moe_mod
    cfg = MOE
    key = jax.random.PRNGKey(5)
    p = moe_mod.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 16, cfg.d_model)) * 0.5
    y, aux = moe_mod.apply_moe(p, x, cfg, capacity_factor=4.0)  # no drops
    # oracle: dense compute of all experts, weighted by router
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    w, idx = jax.lax.top_k(probs, cfg.experts_per_tok)
    w = w / w.sum(-1, keepdims=True)
    dense = jnp.einsum("bsd,edf->bsef", x, p["gate"])
    up = jnp.einsum("bsd,edf->bsef", x, p["up"])
    ye = jnp.einsum("bsef,efd->bsed", jax.nn.silu(dense) * up, p["down"])
    full_w = jnp.zeros(probs.shape).at[
        jnp.arange(2)[:, None, None], jnp.arange(16)[None, :, None], idx].set(w)
    y_oracle = jnp.einsum("bse,bsed->bsd", full_w, ye)
    if cfg.num_shared_experts:
        from repro.models import layers
        y_oracle = y_oracle + layers.apply_mlp(p["shared"], x, x.dtype)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_oracle), rtol=1e-4, atol=1e-5)
