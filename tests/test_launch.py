"""Launch-layer tests: sharding rules, mesh construction, and a reduced
dry-run on an 8-device debug mesh (subprocess)."""
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.sharding import param_specs, with_pod_dim
from repro.models import lm
from repro.models.partitioning import MeshRules

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class _FakeMesh:
    """Duck-typed mesh for spec-rule tests (axis sizes only)."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def _rules(shape={"data": 16, "model": 16}):
    mesh = _FakeMesh(shape)
    return MeshRules.__new__(MeshRules), mesh


def test_param_specs_shard_big_dims():
    cfg = get_config("qwen3-0.6b")
    like = lm.abstract_params(cfg)
    mesh = _FakeMesh({"data": 16, "model": 16})
    rules = MeshRules.__new__(MeshRules)
    rules.mesh = mesh
    rules.roles = {"dp": ("data",), "tp": "model", "sp": "model"}
    specs = param_specs(like, rules)
    # embedding (V, d): vocab over model, d over data
    assert specs["embed"] == P("model", ("data",))
    # attention projections in the scanned stack: leading scan dim None
    stack0 = specs["stack"][0]
    assert stack0["mixer"]["wq"] == P(None, ("data",), "model")
    assert stack0["mixer"]["wo"] == P(None, "model", ("data",))
    # norms replicated
    assert specs["final_norm"] == P(None)


def test_param_specs_fall_back_on_indivisible_dims():
    cfg = get_config("xlstm-125m")  # H=4 heads, small dims
    like = lm.abstract_params(cfg)
    mesh = _FakeMesh({"data": 16, "model": 16})
    rules = MeshRules.__new__(MeshRules)
    rules.mesh = mesh
    rules.roles = {"dp": ("data",), "tp": "model", "sp": "model"}
    specs = param_specs(like, rules)
    for spec, leaf in zip(jax.tree.leaves(specs,
                                          is_leaf=lambda x: isinstance(x, P)),
                          jax.tree.leaves(like)):
        for dim, axes in zip(leaf.shape, spec):
            if axes is None:
                continue
            n = 1
            for a in (axes if isinstance(axes, tuple) else (axes,)):
                n *= mesh.shape[a]
            assert dim % n == 0, (leaf.shape, spec)


def test_with_pod_dim():
    tree = {"a": P("model"), "b": P(None, ("data",))}
    out = with_pod_dim(tree)
    assert out["a"] == P("pod", "model")
    assert out["b"] == P("pod", None, ("data",))


def test_input_specs_shapes():
    """input_specs covers every model input, spec-compliant shapes."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    script = textwrap.dedent("""
        from repro.launch.dryrun import input_specs
        s = input_specs("qwen2.5-3b", "train_4k", multi_pod=True)
        assert s["tokens"].shape == (2, 128, 4096), s["tokens"].shape
        s = input_specs("llava-next-mistral-7b", "prefill_32k")
        assert s["tokens"].shape == (32, 32768)
        assert s["patch_embeds"].shape == (32, 576, 4096)
        s = input_specs("jamba-v0.1-52b", "decode_32k")
        assert s["tokens"].shape == (128, 1)
        assert "caches" in s
        print("SPECS-OK")
    """)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=300)
    assert r.returncode == 0, r.stderr
    assert "SPECS-OK" in r.stdout


@pytest.mark.slow
def test_reduced_dryrun_on_debug_mesh():
    """Lower+compile a reduced config on a (2,2,2) mesh — validates the
    full dry-run path (pod-stacked train + decode) without 512 devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    script = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_config
        from repro.configs.smoke import reduce_for_smoke
        from repro.core.modes import AsyncMode
        from repro.launch import serve as serve_mod, train as train_mod
        from repro.launch.sharding import (param_specs, shardings_from_specs,
                                           with_pod_dim)
        from repro.models import lm, partitioning
        from repro.models.partitioning import MeshRules

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        rules = MeshRules(mesh, dp=("data",), tp="model")
        cfg = reduce_for_smoke(get_config("deepseek-moe-16b"))
        spec = train_mod.TrainSpec(mode=AsyncMode.BEST_EFFORT)
        with partitioning.use_rules(rules):
            state_like = train_mod.abstract_train_state(cfg, spec, 2)
            pspecs = with_pod_dim(param_specs(lm.abstract_params(cfg), rules))
            s_specs = {"params": pspecs,
                       "opt": {"m": pspecs, "v": pspecs, "step": P("pod")},
                       "others": pspecs, "step": P()}
            batch = {
                "tokens": jax.ShapeDtypeStruct((2, 4, 32), jnp.int32),
                "labels": jax.ShapeDtypeStruct((2, 4, 32), jnp.int32),
            }
            b_specs = {"tokens": P("pod", "data", None),
                       "labels": P("pod", "data", None)}
            fn = train_mod.make_train_step(cfg, spec, 2)
            lowered = jax.jit(
                fn,
                in_shardings=(shardings_from_specs(s_specs, mesh),
                              shardings_from_specs(b_specs, mesh)),
            ).lower(state_like, batch)
            compiled = lowered.compile()
            ca = compiled.cost_analysis()
            ca = ca[0] if isinstance(ca, list) else ca  # older-jax shape
            assert ca.get("flops", 0) > 0
        print("DRYRUN-SMALL-OK")
    """)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=540)
    assert r.returncode == 0, f"STDOUT:{r.stdout}\nSTDERR:{r.stderr[-3000:]}"
    assert "DRYRUN-SMALL-OK" in r.stdout
