"""Sharded-engine tests: the sharded-vs-unsharded parity contract.

The mesh-sharded engine (DESIGN.md §8) keys every stochastic draw by
original pid / canonical edge id and resolves halo-scatter ties by
canonical edge id, so sharding is a pure layout change: the same
``(config, seed)`` must agree between 1 shard and 8 shards on **total
updates exactly** and on median QoS within ``SHARD_PARITY_RTOL`` (in
practice the trajectories are bitwise identical; the tolerance only
absorbs float aggregation noise).

Multi-device cases run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the main test
process keeps a single device, like ``tests/test_core_multidevice.py``.
"""
import os
import subprocess
import sys
import textwrap

import pytest

jax = pytest.importorskip("jax")

from repro.runtime.engine import make_engine  # noqa: E402
from repro.runtime.engine_jax import JaxEngine  # noqa: E402
from repro.runtime.engine_sharded import ShardedJaxEngine  # noqa: E402
from repro.runtime.simulator import SimConfig  # noqa: E402
from repro.runtime.topologies import make_topology  # noqa: E402
from repro.apps.graphcolor import GraphColorApp, GraphColorConfig  # noqa: E402

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: documented sharded-vs-unsharded bound on median QoS (DESIGN.md §8)
SHARD_PARITY_RTOL = 1e-6

#: documented superstep (W>1) bound on median QoS vs W=1 (DESIGN.md §9):
#: batching boundary deliveries to superstep boundaries perturbs drop
#: patterns and per-message handling costs, never the virtual-time stamps
SUPERSTEP_QOS_RTOL = 0.15


def run_md(script: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


_PARITY_HELPERS = textwrap.dedent("""
    import numpy as np
    from repro.core.qos import aggregate_reports
    from repro.runtime.simulator import SimConfig
    from repro.runtime.engine_jax import JaxEngine
    from repro.runtime.engine_sharded import ShardedJaxEngine
    from repro.runtime.topologies import make_topology
    from repro.apps.graphcolor import GraphColorApp, GraphColorConfig

    RTOL = {rtol}

    def gc_app(n, topology):
        topo = make_topology(topology, n)
        return GraphColorApp(GraphColorConfig(n_processes=n,
                                              nodes_per_process=1),
                             topology=topo)

    def cfgf(dur=0.02, **kw):
        return SimConfig(duration=dur, snapshot_warmup=dur / 6,
                         snapshot_interval=dur / 12, **kw)

    def check(label, r1, r8):
        assert r1.updates == r8.updates, label  # exact, per process
        assert (r1.sent, r1.dropped) == (r8.sent, r8.dropped), label
        m1 = aggregate_reports(r1.qos)
        m8 = aggregate_reports(r8.qos)
        for metric, stats in m1.items():
            a, b = stats["median"], m8[metric]["median"]
            assert (a is None) == (b is None), (label, metric)
            if a is not None:
                assert abs(b - a) <= RTOL * max(abs(a), 1e-12), (
                    label, metric, a, b)
""").format(rtol=SHARD_PARITY_RTOL)


def _app(n, topology="ring"):
    topo = make_topology(topology, n)
    return GraphColorApp(
        GraphColorConfig(n_processes=n, nodes_per_process=1), topology=topo)


def _cfg(duration=0.02, **kw):
    base = dict(duration=duration, snapshot_warmup=duration / 6,
                snapshot_interval=duration / 12)
    base.update(kw)
    return SimConfig(**base)


# ---------------------------------------------------------------------------
# Single-device cases (shards=1 mesh): run in-process
# ---------------------------------------------------------------------------
def test_one_shard_matches_unsharded_exactly():
    cfg = _cfg()
    r_plain = JaxEngine(_app(16), cfg).run()
    r_shard = ShardedJaxEngine(_app(16), cfg, shards=1).run()
    assert r_plain.updates == r_shard.updates
    assert r_plain.sent == r_shard.sent
    assert r_plain.dropped == r_shard.dropped
    assert r_plain.quality == r_shard.quality
    periods1 = sorted(q.simstep_period for q in r_plain.qos)
    periods8 = sorted(q.simstep_period for q in r_shard.qos)
    assert periods1 == periods8


def test_registry_builds_sharded_engine():
    eng = make_engine("jax", _app(8), _cfg(0.01), shards=1)
    assert isinstance(eng, JaxEngine) and not isinstance(eng,
                                                         ShardedJaxEngine)
    # shards > available devices: actionable error, not a crash
    if len(jax.devices()) < 8:
        with pytest.raises(ValueError, match="xla_force_host_platform"):
            make_engine("jax", _app(16), _cfg(0.01), shards=8)
    with pytest.raises(ValueError, match="event engine"):
        make_engine("event", _app(16), _cfg(0.01), shards=8)


def test_shards_must_divide_population():
    # the partition check fires before the device-count check, so this
    # fails the same way on any machine
    with pytest.raises(ValueError, match="divide"):
        ShardedJaxEngine(_app(10), _cfg(0.01), shards=4)


def test_superstep_requires_sharded_jax_engine():
    with pytest.raises(ValueError, match="shards"):
        make_engine("jax", _app(8), _cfg(0.01), superstep_windows=8)
    with pytest.raises(ValueError, match="superstep"):
        make_engine("event", _app(8), _cfg(0.01), superstep_windows=8)
    with pytest.raises(ValueError, match=">= 1"):
        ShardedJaxEngine(_app(8), _cfg(0.01), shards=1, superstep_windows=0)


def test_superstep_one_shard_is_exact():
    # with one shard every edge is interior: nothing is staged, so any W
    # must reproduce the W=1 trajectories exactly
    cfg = _cfg()
    r_plain = JaxEngine(_app(16), cfg).run()
    r_w4 = ShardedJaxEngine(_app(16), cfg, shards=1,
                            superstep_windows=4).run()
    assert r_plain.updates == r_w4.updates
    assert (r_plain.sent, r_plain.dropped) == (r_w4.sent, r_w4.dropped)
    assert r_plain.quality == r_w4.quality


# ---------------------------------------------------------------------------
# Multi-device parity (8 forced host devices, subprocess)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_sharded_parity_best_effort_and_replicates():
    out = run_md(_PARITY_HELPERS + textwrap.dedent("""
        # thin-boundary torus, boundary-heavy ring (half the edges cut),
        # and the two irregular families (multi-offset ppermute routing)
        for topology, n in (("ring", 16), ("torus", 64),
                            ("cliques", 32), ("smallworld", 32)):
            cfg = cfgf()
            r1 = JaxEngine(gc_app(n, topology), cfg).run()
            r8 = ShardedJaxEngine(gc_app(n, topology), cfg, shards=8).run()
            check(f"{topology}{n}", r1, r8)

        # the replicate axis vmaps inside each shard and stays independent
        reps1 = JaxEngine(gc_app(16, "ring"), cfgf()).run_replicates(
            [0, 1, 2])
        reps8 = ShardedJaxEngine(gc_app(16, "ring"), cfgf(),
                                 shards=8).run_replicates([0, 1, 2])
        for i, (a, b) in enumerate(zip(reps1, reps8)):
            check(f"replicate{i}", a, b)
        assert len({tuple(r.updates) for r in reps8}) > 1
        print("PARITY-OK")
    """))
    assert "PARITY-OK" in out


@pytest.mark.slow
def test_sharded_dense_layout_parity():
    """Dense duct layout under the mesh (DESIGN.md §10): the receiver-major
    interior rows plus the unchanged packed-ppermute boundary path must
    reproduce the edge-major 8-shard run bitwise on ring and torus, and the
    unsharded edge-major trajectories transitively."""
    out = run_md(_PARITY_HELPERS + textwrap.dedent("""
        for topology, n in (("ring", 16), ("torus", 64)):
            cfg = cfgf()
            r1 = JaxEngine(gc_app(n, topology), cfg, layout="edge").run()
            rd = ShardedJaxEngine(gc_app(n, topology), cfg, shards=8,
                                  layout="dense").run()
            check(f"dense-{topology}{n}", r1, rd)
            re_ = ShardedJaxEngine(gc_app(n, topology), cfg, shards=8,
                                   layout="edge").run()
            check(f"edge-{topology}{n}", rd, re_)
        # dense composes with the superstep scheduler (W=1 stays bitwise)
        cfg = cfgf()
        r1 = JaxEngine(gc_app(64, "torus"), cfg).run()
        rw = ShardedJaxEngine(gc_app(64, "torus"), cfg, shards=8,
                              layout="dense", superstep_windows=1).run()
        check("dense-superstep-w1", r1, rw)
        print("DENSE-OK")
    """))
    assert "DENSE-OK" in out


@pytest.mark.slow
def test_sharded_parity_barriers_faults_and_evo():
    out = run_md(_PARITY_HELPERS + textwrap.dedent("""
        from repro.core.modes import AsyncMode
        from repro.runtime.faults import FaultModel
        from repro.apps.evo import EvoApp, EvoConfig

        # barrier release needs exact cross-shard pmin/pmax reductions;
        # rolling/fixed exercise the last_release / barrier_seq due-logic
        for mode in (AsyncMode.BARRIER_EVERY_STEP, AsyncMode.ROLLING_BARRIER,
                     AsyncMode.FIXED_BARRIER):
            # fixed_interval < duration so fixed-barrier releases do fire
            cfg = cfgf(mode=mode, base_latency=100e-6,
                       rolling_quantum=0.004, fixed_interval=0.005)
            r1 = JaxEngine(gc_app(16, "ring"), cfg).run()
            r8 = ShardedJaxEngine(gc_app(16, "ring"), cfg, shards=8).run()
            check(str(mode), r1, r8)
            if mode == AsyncMode.BARRIER_EVERY_STEP:
                assert max(r8.updates) - min(r8.updates) <= 1  # lockstep

        # faults key compute slowdown by original pid, not shard position
        cfg = cfgf(buffer_capacity=2, base_latency=20e-6)
        fm = FaultModel(compute_slowdown={3: 20.0})
        r1 = JaxEngine(gc_app(16, "ring"), cfg, fm).run()
        r8 = ShardedJaxEngine(gc_app(16, "ring"), cfg, fm, shards=8).run()
        check("faults", r1, r8)
        assert r8.dropped > 0

        # evo exercises the float32-payload bitcast boundary hop
        topo = make_topology("torus", 16)
        def evo():
            return EvoApp(EvoConfig(n_processes=16, cells_per_process=4),
                          topology=topo)
        cfg = cfgf()
        r1 = JaxEngine(evo(), cfg).run()
        r8 = ShardedJaxEngine(evo(), cfg, shards=8).run()
        check("evo", r1, r8)
        assert abs(r1.quality - r8.quality) < 1e-9
        print("MODES-OK")
    """))
    assert "MODES-OK" in out


@pytest.mark.slow
def test_superstep_parity_and_amortization():
    """Acceptance contract for the self-paced superstep scheduler:

    - W=1 reproduces the unsharded trajectories bitwise across all 4
      topologies AND under fault injection (same helpers as the per-window
      parity tests: exact per-process updates, sent/dropped, medians);
    - W=8 stays within SUPERSTEP_QOS_RTOL on median QoS with matching
      total updates;
    - the traced collective count per superstep does not grow with W, so
      collectives per *window* drop by ~W x;
    - barrier modes release on superstep-granular reductions without
      changing update counts (waiting clocks freeze).
    """
    snippet = _PARITY_HELPERS + f"\nW_RTOL = {SUPERSTEP_QOS_RTOL}\n"
    out = run_md(snippet + textwrap.dedent("""
        import jax
        from repro.core.modes import AsyncMode
        from repro.runtime.faults import FaultModel

        def median_close(ra, rb, label):
            ma, mb = aggregate_reports(ra.qos), aggregate_reports(rb.qos)
            for metric, stats in ma.items():
                a, b = stats["median"], mb[metric]["median"]
                assert (a is None) == (b is None), (label, metric)
                if a is not None:
                    assert abs(b - a) <= W_RTOL * max(abs(a), 1e-9), (
                        label, metric, a, b)

        calls = [0]
        real = jax.lax.ppermute
        def counting(*a, **k):
            calls[0] += 1
            return real(*a, **k)
        jax.lax.ppermute = counting

        for topology, n in (("ring", 16), ("torus", 64),
                            ("cliques", 32), ("smallworld", 32)):
            cfg = cfgf()
            r1 = JaxEngine(gc_app(n, topology), cfg).run()
            calls[0] = 0
            rw1 = ShardedJaxEngine(gc_app(n, topology), cfg, shards=8,
                                   superstep_windows=1).run()
            c1 = calls[0]
            check(f"{topology}{n}-W1", r1, rw1)
            calls[0] = 0
            rw8 = ShardedJaxEngine(gc_app(n, topology), cfg, shards=8,
                                   superstep_windows=8).run()
            c8 = calls[0]
            # same collectives per traced superstep while covering 8x the
            # windows: the ~W x amortization
            assert c8 == c1 and c1 > 0, (topology, c1, c8)
            du = (abs(sum(rw8.updates) - sum(r1.updates))
                  / max(sum(r1.updates), 1))
            assert du < 0.01, (topology, du)
            median_close(r1, rw8, topology)
        jax.lax.ppermute = real

        # fault injection: W=1 exact, W=8 within tolerance.  Paper-scale
        # latency (the default 500us ~ 30 windows) keeps the 8-window
        # superstep span below the wire latency, where amortization is
        # QoS-neutral (DESIGN.md 9)
        fm = FaultModel(compute_slowdown={3: 20.0})
        cfg = cfgf()
        r1 = JaxEngine(gc_app(16, "ring"), cfg, fm).run()
        rw1 = ShardedJaxEngine(gc_app(16, "ring"), cfg, fm, shards=8,
                               superstep_windows=1).run()
        check("faults-W1", r1, rw1)
        rw8 = ShardedJaxEngine(gc_app(16, "ring"), cfg, fm, shards=8,
                               superstep_windows=8).run()
        median_close(r1, rw8, "faults-W8")

        # barrier releases land on superstep boundaries but release TIMES
        # are computed from frozen waiting clocks: update counts stay equal
        for mode in (AsyncMode.BARRIER_EVERY_STEP,
                     AsyncMode.ROLLING_BARRIER):
            cfg = cfgf(mode=mode, base_latency=100e-6,
                       rolling_quantum=0.004)
            r1 = JaxEngine(gc_app(16, "ring"), cfg).run()
            rw4 = ShardedJaxEngine(gc_app(16, "ring"), cfg, shards=8,
                                   superstep_windows=4).run()
            assert r1.updates == rw4.updates, mode
        print("SUPERSTEP-OK")
    """))
    assert "SUPERSTEP-OK" in out
