"""Sharded-engine tests: what the conformance suite doesn't already pin.

Sharded-vs-unsharded bitwise parity (all topologies, modes, faults, dense
layout, W=1 superstep, replicates) lives in the registry-driven suite
``tests/test_engine_conformance.py`` (family 4), as do the negative-path
registry checks.  This file keeps the sharded engine's own seams:

  - the 1-shard mesh path (shard_map plumbing with every edge interior)
    reproduces the unsharded engine in-process;
  - the self-paced superstep scheduler at W>1: QoS within the documented
    tolerance, collective count amortized ~W x, barrier releases unmoved;
  - the pipelined scheduler's double-buffer bookkeeping: sender counters
    fold one boundary late, the epilogue flush closes the books, and the
    conservation identities hold exactly at run end.

Multi-device cases run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the main test
process keeps a single device, like ``tests/test_core_multidevice.py``.
"""
import textwrap

import pytest

jax = pytest.importorskip("jax")

from engine_cases import case_seed, gc_app, jittered_cfg, run_md  # noqa: E402
from repro.runtime.engine_jax import JaxEngine  # noqa: E402
from repro.runtime.engine_sharded import ShardedJaxEngine  # noqa: E402

#: documented superstep (W>1) bound on median QoS vs W=1 (DESIGN.md §9):
#: batching boundary deliveries to superstep boundaries perturbs drop
#: patterns and per-message handling costs, never the virtual-time stamps
SUPERSTEP_QOS_RTOL = 0.15

_HELPERS = textwrap.dedent("""
    from engine_cases import case_seed, gc_app, jittered_cfg
    from repro.core.qos import aggregate_reports
    from repro.runtime.engine_jax import JaxEngine
    from repro.runtime.engine_sharded import ShardedJaxEngine

    def cfgf(topology, **kw):
        return jittered_cfg(0.02, seed=case_seed(topology), **kw)

    def check(label, r1, r8):
        assert r1.updates == r8.updates, label  # exact, per process
        assert (r1.sent, r1.dropped) == (r8.sent, r8.dropped), label
        m1 = aggregate_reports(r1.qos)
        m8 = aggregate_reports(r8.qos)
        for metric, stats in m1.items():
            a, b = stats["median"], m8[metric]["median"]
            assert (a is None) == (b is None), (label, metric)
            if a is not None:
                assert abs(b - a) <= 1e-6 * max(abs(a), 1e-12), (
                    label, metric, a, b)
""")


def _app(n, topology="ring"):
    return gc_app(n, topology)


def _cfg(**kw):
    return jittered_cfg(0.02, seed=case_seed("ring"), **kw)


# ---------------------------------------------------------------------------
# Single-device cases (shards=1 mesh): run in-process
# ---------------------------------------------------------------------------
def test_one_shard_matches_unsharded_exactly():
    cfg = _cfg()
    r_plain = JaxEngine(_app(16), cfg).run()
    r_shard = ShardedJaxEngine(_app(16), cfg, shards=1).run()
    assert r_plain.updates == r_shard.updates
    assert r_plain.sent == r_shard.sent
    assert r_plain.dropped == r_shard.dropped
    assert r_plain.quality == r_shard.quality
    periods1 = sorted(q.simstep_period for q in r_plain.qos)
    periods8 = sorted(q.simstep_period for q in r_shard.qos)
    assert periods1 == periods8


def test_superstep_one_shard_is_exact():
    # with one shard every edge is interior: nothing is staged, so any W
    # must reproduce the W=1 trajectories exactly
    cfg = _cfg()
    r_plain = JaxEngine(_app(16), cfg).run()
    r_w4 = ShardedJaxEngine(_app(16), cfg, shards=1,
                            superstep_windows=4).run()
    assert r_plain.updates == r_w4.updates
    assert (r_plain.sent, r_plain.dropped) == (r_w4.sent, r_w4.dropped)
    assert r_plain.quality == r_w4.quality


# ---------------------------------------------------------------------------
# Superstep scheduler at W>1 (8 forced host devices, subprocess)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_superstep_parity_and_amortization():
    """Acceptance contract for the self-paced superstep scheduler:

    - W=1 reproduces the unsharded trajectories bitwise across all 4
      topologies AND under fault injection;
    - W=8 stays within SUPERSTEP_QOS_RTOL on median QoS with matching
      total updates;
    - the traced collective count per superstep does not grow with W, so
      collectives per *window* drop by ~W x;
    - barrier modes release on superstep-granular reductions without
      changing update counts (waiting clocks freeze).
    """
    snippet = _HELPERS + f"\nW_RTOL = {SUPERSTEP_QOS_RTOL}\n"
    out = run_md(snippet + textwrap.dedent("""
        import jax
        from repro.core.modes import AsyncMode
        from repro.runtime.faults import FaultModel

        def median_close(ra, rb, label):
            ma, mb = aggregate_reports(ra.qos), aggregate_reports(rb.qos)
            for metric, stats in ma.items():
                a, b = stats["median"], mb[metric]["median"]
                assert (a is None) == (b is None), (label, metric)
                if a is not None:
                    assert abs(b - a) <= W_RTOL * max(abs(a), 1e-9), (
                        label, metric, a, b)

        calls = [0]
        real = jax.lax.ppermute
        def counting(*a, **k):
            calls[0] += 1
            return real(*a, **k)
        jax.lax.ppermute = counting

        for topology, n in (("ring", 16), ("torus", 64),
                            ("cliques", 32), ("smallworld", 32)):
            cfg = cfgf(topology)
            r1 = JaxEngine(gc_app(n, topology), cfg).run()
            calls[0] = 0
            rw1 = ShardedJaxEngine(gc_app(n, topology), cfg, shards=8,
                                   superstep_windows=1).run()
            c1 = calls[0]
            check(f"{topology}{n}-W1", r1, rw1)
            calls[0] = 0
            rw8 = ShardedJaxEngine(gc_app(n, topology), cfg, shards=8,
                                   superstep_windows=8).run()
            c8 = calls[0]
            # same collectives per traced superstep while covering 8x the
            # windows: the ~W x amortization
            assert c8 == c1 and c1 > 0, (topology, c1, c8)
            du = (abs(sum(rw8.updates) - sum(r1.updates))
                  / max(sum(r1.updates), 1))
            assert du < 0.01, (topology, du)
            median_close(r1, rw8, topology)
        jax.lax.ppermute = real

        # fault injection: W=1 exact, W=8 within tolerance.  Paper-scale
        # latency (the default 500us ~ 30 windows) keeps the 8-window
        # superstep span below the wire latency, where amortization is
        # QoS-neutral (DESIGN.md 9)
        fm = FaultModel(compute_slowdown={3: 20.0})
        cfg = cfgf("ring")
        r1 = JaxEngine(gc_app(16, "ring"), cfg, fm).run()
        rw1 = ShardedJaxEngine(gc_app(16, "ring"), cfg, fm, shards=8,
                               superstep_windows=1).run()
        check("faults-W1", r1, rw1)
        rw8 = ShardedJaxEngine(gc_app(16, "ring"), cfg, fm, shards=8,
                               superstep_windows=8).run()
        median_close(r1, rw8, "faults-W8")

        # barrier releases land on superstep boundaries but release TIMES
        # are computed from frozen waiting clocks, and a release reaching
        # the horizon snaps every member's clock to the horizon under any
        # W (window_core.close_window / simulator._try_release_barriers),
        # so with lockstep barriers the W=4 trajectories are EXACTLY the
        # per-window trajectories at paper-scale wire latency
        cfg = cfgf("ring", mode=AsyncMode.BARRIER_EVERY_STEP)
        r1 = JaxEngine(gc_app(16, "ring"), cfg).run()
        rw4 = ShardedJaxEngine(gc_app(16, "ring"), cfg, shards=8,
                               superstep_windows=4).run()
        assert r1.updates == rw4.updates, "barrier-every-step W-invariance"
        # rolling barriers meter their quantum on the WORK clock (compute
        # + degree-fixed pull cost; per-message handling rides in barrier
        # slack — window_core.close_window), so the update schedule is a
        # function of (seed, release times) alone: boundary staging may
        # perturb drop patterns but can never drift the update counts.
        # Rolling runs are therefore EXACTLY W-invariant, horizon
        # straddles included
        cfg = cfgf("ring", mode=AsyncMode.ROLLING_BARRIER,
                   rolling_quantum=0.004)
        r1 = JaxEngine(gc_app(16, "ring"), cfg).run()
        rw4 = ShardedJaxEngine(gc_app(16, "ring"), cfg, shards=8,
                               superstep_windows=4).run()
        assert r1.updates == rw4.updates, "rolling-barrier W-invariance"
        assert r1.sent == rw4.sent, "rolling-barrier W-invariance (sent)"
        # the pipelined scheduler's staging delay is equally invisible to
        # the work clock: exact W-invariance, no drift tolerated
        rp4 = ShardedJaxEngine(gc_app(16, "ring"), cfg, shards=8,
                               superstep_windows=4,
                               scheduler="pipelined").run()
        assert r1.updates == rp4.updates, "rolling pipelined W-invariance"
        assert r1.sent == rp4.sent, "rolling pipelined W-invariance (sent)"
        print("SUPERSTEP-OK")
    """))
    assert "SUPERSTEP-OK" in out


@pytest.mark.slow
def test_pipelined_conservation_across_flush():
    """Conservation seam of the pipelined scheduler (DESIGN.md §12).

    Sender counters for a boundary send staged at superstep i fold only
    at boundary i+2, and the epilogue flush closes whatever is still in
    the double buffers at the horizon — so at run end the books must
    balance EXACTLY: attempted == accepted + dropped (per-process sums),
    accepted == delivered + in-ring, ``SimResult.sent``/``dropped``
    consistent with the folded counters, and every fly_* buffer zeroed.
    """
    out = run_md(_HELPERS + textwrap.dedent("""
        import numpy as np
        from repro.core.modes import AsyncMode

        for mode in (AsyncMode.BEST_EFFORT, AsyncMode.ROLLING_BARRIER):
            for W in (2, 4):
                cfg = cfgf("torus", mode=mode, rolling_quantum=0.004)
                eng = ShardedJaxEngine(gc_app(64, "torus"), cfg, shards=8,
                                       superstep_windows=W,
                                       scheduler="pipelined")
                eng.debug_keep_carry = True
                res = eng.run()
                c = eng._final_carry
                att = int(np.sum(c["c_att"]))
                ok = int(np.sum(c["c_ok"]))
                drop = int(np.sum(c["c_drop"]))
                msgs = int(np.sum(c["c_msgs"]))
                inring = int(np.sum(c["q_size"]))
                tag = (mode.name, W)
                assert att == ok + drop, (tag, att, ok, drop)
                assert ok == msgs + inring, (tag, ok, msgs, inring)
                assert res.sent == att and res.dropped == drop, tag
                for key in c:
                    if key.startswith("fly_"):
                        assert not np.asarray(c[key]).any(), (tag, key)
        print("PIPELINED-CONSERVATION-OK")
    """))
    assert "PIPELINED-CONSERVATION-OK" in out
