"""Tests for perf/analysis machinery: replica-group classification,
param pre-cast, MoE dispatch positions."""
import sys
import os

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "benchmarks"))

from hlo_tools import group_spans_pods  # noqa: E402


def test_group_spans_pods_iota_transposed():
    # [256,2]<=[2,256]T(1,0): groups pair device i with i+256 => cross-pod
    line = 'x = f32[8] all-reduce(%y), replica_groups=[256,2]<=[2,256]T(1,0)'
    assert group_spans_pods(line, pod_stride=256)


def test_group_spans_pods_intra_pod_pairs():
    # [256,2]<=[512]: consecutive pairs (model axis) => intra-pod
    line = 'x = f32[8] all-gather(%y), replica_groups=[256,2]<=[512]'
    assert not group_spans_pods(line, pod_stride=256)


def test_group_spans_pods_data_axis_groups():
    # FSDP gathers over data within a pod: [32,16]<=[2,16,16]T(0,2,1)
    line = 'x = f32[8] all-gather(%y), replica_groups=[32,16]<=[2,16,16]T(0,2,1)'
    assert not group_spans_pods(line, pod_stride=256)


def test_group_spans_pods_explicit_list():
    line = 'x = f32[8] all-reduce(%y), replica_groups={{0,256},{1,257}}'
    assert group_spans_pods(line)
    line2 = 'x = f32[8] all-reduce(%y), replica_groups={{0,1},{2,3}}'
    assert not group_spans_pods(line2)


def test_cast_params_for_compute_rules():
    from repro.configs.base import ModelConfig
    from repro.models import lm
    cfg = ModelConfig(name="c", family="moe", num_layers=2, d_model=32,
                      num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
                      num_experts=4, experts_per_tok=2, moe_d_ff=32)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    cast = lm.cast_params_for_compute(params, cfg)
    # 2-D+ weights cast to bf16
    assert cast["embed"].dtype == jnp.bfloat16
    # norm scales (1-D) stay fp32
    assert cast["final_norm"].dtype == jnp.float32
    # router stays fp32 for top-k stability
    routers = [l for p, l in jax.tree_util.tree_flatten_with_path(cast)[0]
               if any("router" in str(getattr(k, "key", "")) for k in p)]
    assert routers and all(r.dtype == jnp.float32 for r in routers)


def test_moe_positions_in_expert():
    from repro.models.moe import _positions_in_expert
    idx = jnp.array([[0, 1], [0, 0], [1, 2]])  # (T=3, k=2)
    pos = np.asarray(_positions_in_expert(idx, 4))
    # expert 0 chosen 3x -> positions {0,1,2}; expert 1 twice -> {0,1}
    e0 = sorted(pos[idx == 0].tolist())
    e1 = sorted(pos[np.asarray(idx) == 1].tolist())
    assert e0 == [0, 1, 2]
    assert e1 == [0, 1]
    assert pos[2, 1] == 0  # expert 2's only token


def test_moe_capacity_drop_is_best_effort():
    """Tokens over capacity are dropped (no retry); the residual path still
    carries them — loss stays finite and finite-grad."""
    from repro.configs.base import ModelConfig
    from repro.models import moe as moe_mod
    cfg = ModelConfig(name="c", family="moe", num_layers=2, d_model=32,
                      num_heads=2, num_kv_heads=2, d_ff=32, vocab_size=64,
                      num_experts=4, experts_per_tok=2, moe_d_ff=32,
                      dtype="float32", param_dtype="float32")
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 32))
    # capacity_factor tiny -> heavy drops
    y, aux = moe_mod.apply_moe(p, x, cfg, capacity_factor=0.1)
    assert np.isfinite(np.asarray(y)).all()
    yfull, _ = moe_mod.apply_moe(p, x, cfg, capacity_factor=4.0)
    # dropped tokens mean output differs from the no-drop compute
    assert not np.allclose(np.asarray(y), np.asarray(yfull))
