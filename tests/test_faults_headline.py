"""Regression pin for the paper's robustness headline (§III-G, claim C4).

Under best-effort communication, an apparently-faulty host degrades its own
clique severely while the rest of the population's QoS medians hold: "the
median holds".  Both sides are asserted — the stability of the non-faulty
cohort AND the degradation of the faulty one — so a regression in either
direction (fault injection silently weakening, or fault bleed-through)
fails the test.

Uses the event engine: the reference semantics, fast at this scale, and no
jit warmup.  The numbers are deterministic for a fixed (config, seed).
"""

import numpy as np
import pytest

pytest.importorskip("jax")  # the graphcolor fragments import jax

from repro.core.qos import median_of_process_medians
from repro.runtime.faults import crashed_host, faulty_host
from repro.runtime.simulator import SimConfig, Simulator
from repro.runtime.topologies import make_topology
from repro.apps.graphcolor import GraphColorApp, GraphColorConfig

N = 64
#: non-faulty cohort medians must stay within this of the fault-free run
REST_RTOL = 0.10
#: the faulty host's own processes must degrade at least this much
VICTIM_FACTOR = 10.0


@pytest.fixture(scope="module")
def headline_runs():
    topo = make_topology("torus", N)
    host = topo.n_nodes // 2
    victims = sorted(set(topo.host_pids(host)))
    clique = set()
    for p in victims:
        clique.update(topo.clique_of(p))

    def run(faults):
        app = GraphColorApp(GraphColorConfig(n_processes=N, nodes_per_process=1), topology=topo)
        cfg = SimConfig(
            duration=0.05,
            snapshot_warmup=0.05 / 6,
            snapshot_interval=0.05 / 12,
            base_latency=550e-6,
        )
        return Simulator(app, cfg, faults).run()

    fault_free = run(None)
    faulty = run(faulty_host(topo, host, 30.0, 30.0))
    return fault_free, faulty, victims, sorted(clique)


def _med(res, pids, metric):
    return median_of_process_medians({p: res.qos_by_process[p] for p in pids}, metric)


def test_non_faulty_medians_hold(headline_runs):
    fault_free, faulty, _victims, clique = headline_runs
    rest = [p for p in range(N) if p not in clique]
    for metric in ("simstep_period", "simstep_latency", "delivery_failure_rate"):
        base = _med(fault_free, range(N), metric)
        held = _med(faulty, rest, metric)
        assert held == pytest.approx(base, rel=REST_RTOL), metric


def test_faulty_clique_degrades(headline_runs):
    fault_free, faulty, victims, clique = headline_runs
    rest = [p for p in range(N) if p not in clique]
    # the host's own processes crawl: simstep period blows up ~30x
    victim_period = _med(faulty, victims, "simstep_period")
    rest_period = _med(faulty, rest, "simstep_period")
    assert victim_period > VICTIM_FACTOR * rest_period
    assert victim_period > VICTIM_FACTOR * _med(fault_free, victims, "simstep_period")
    # their clique pays in delivery failure, the rest does not
    clique_fail = _med(faulty, clique, "delivery_failure_rate")
    rest_fail = _med(faulty, rest, "delivery_failure_rate")
    assert clique_fail > 1.3 * rest_fail
    # yet every process keeps making progress (best-effort never deadlocks)
    assert all(u > 0 for u in faulty.updates)
    # and the victims did fall far behind the population median
    assert max(faulty.updates[p] for p in victims) < 0.2 * float(np.median(faulty.updates))


# ---------------------------------------------------------------------------
# The same C4 claim under the crash fault kind (DESIGN.md §14): a crashed
# host is the harsher regime — its processes stop dead, their neighbors
# keep sending into dead ducts — and the median must STILL hold.
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def crash_runs():
    topo = make_topology("torus", N)
    host = topo.n_nodes // 2
    victims = sorted(set(topo.host_pids(host)))
    clique = set()
    for p in victims:
        clique.update(topo.clique_of(p))

    def run(faults):
        app = GraphColorApp(GraphColorConfig(n_processes=N, nodes_per_process=1), topology=topo)
        cfg = SimConfig(
            duration=0.05,
            snapshot_warmup=0.05 / 6,
            snapshot_interval=0.05 / 12,
            base_latency=550e-6,
        )
        return Simulator(app, cfg, faults).run()

    fault_free = run(None)
    crashed = run(crashed_host(topo, host))
    return fault_free, crashed, victims, sorted(clique)


def test_crash_non_faulty_medians_hold(crash_runs):
    fault_free, crashed, _victims, clique = crash_runs
    rest = [p for p in range(N) if p not in clique]
    for metric in ("simstep_period", "simstep_latency", "delivery_failure_rate"):
        base = _med(fault_free, range(N), metric)
        held = _med(crashed, rest, metric)
        assert held == pytest.approx(base, rel=REST_RTOL), metric


def test_crashed_clique_degrades(crash_runs):
    fault_free, crashed, victims, clique = crash_runs
    rest = [p for p in range(N) if p not in clique]
    survivors = [p for p in clique if p not in victims]
    # crashed processes make zero progress and attribution says why: every
    # drop beyond the fault-free capacity baseline is a dead-destination kill
    assert all(crashed.updates[p] == 0 for p in victims)
    assert crashed.dropped_dead > 0
    assert crashed.dropped >= crashed.dropped_dead
    assert crashed.dropped_loss == 0
    # the crashed host's clique keeps sending into dead ducts: its
    # survivors' failure rate degrades well past the rest's
    surv_fail = _med(crashed, survivors, "delivery_failure_rate")
    rest_fail = _med(crashed, rest, "delivery_failure_rate")
    assert surv_fail > 1.3 * max(rest_fail, 1e-9)
    assert surv_fail > 1.3 * _med(fault_free, survivors,
                                  "delivery_failure_rate")
    # the rest of the population never stalls
    assert all(crashed.updates[p] > 0 for p in rest)
