"""Live-service harness units: arrival streams, churn patch-up, SLO.

Covers the serve layer (runtime/service.py, core/slo.py) plus the idle-
window sentinel and ragged-tail bugfixes in core/qos.py:

  * arrival tables are pure functions of (cfg, seed) and rate-conserving
    per traffic shape;
  * every engine injects the identical stream — exact cross-engine QoS
    parity on dyadic configs where clocks stay lockstep, exact service
    accounting even where windowed-time clocks legitimately drift;
  * topology patch-up keeps the duct tables involutive and restores the
    pristine graph on rejoin;
  * SLO verdicts handle empty slices, all-breach streams, and
    boundary-equal budgets (inclusive).
"""
import dataclasses
import math

import numpy as np
import pytest

from engine_cases import EXACT_MAX_POPS, case_seed, dyadic_cfg, gc_app
from repro.apps.graphcolor import GraphColorApp, GraphColorConfig
from repro.core.modes import AsyncMode
from repro.core.qos import (Counters, QosReport, aggregate_reports,
                            aggregate_timeseries, qos_signature, report,
                            simstep_period, walltime_latency)
from repro.core.slo import SloPolicy, evaluate_timeseries
from repro.runtime.config import RunConfig
from repro.runtime.engine import make_engine
from repro.runtime.faults import FaultTimeline, TimelineEvent
from repro.runtime.service import (arrival_table, cum_arrivals,
                                   default_timeline, n_bins, run_service)
from repro.runtime.simulator import SimConfig
from repro.runtime.topologies import (canonical_edges, make_topology,
                                      patch_topology)


def _arrival_cfg(mode=AsyncMode.BEST_EFFORT, shape="poisson", **kw):
    """Dyadic serve config: every cost and bin edge is a power of two, so
    event (float64) and windowed (float32) clocks agree bitwise."""
    base = dict(arrival_rate=2e5, arrival_shape=shape, arrival_bin=2 ** -11,
                arrival_period=2 ** -9, per_item_cost=2 ** -19,
                service_chunk=4)
    base.update(kw)
    return dyadic_cfg(mode=mode, seed=case_seed("torus"), **base)


# ---------------------------------------------------------------------------
# Arrival streams
# ---------------------------------------------------------------------------
def test_arrival_table_deterministic_and_seed_sensitive():
    cfg = _arrival_cfg()
    a = cum_arrivals(cfg, 7, 16)
    b = cum_arrivals(cfg, 7, 16)
    assert a.dtype == np.int32 and a.shape == (16, n_bins(cfg) + 1)
    assert np.array_equal(a, b), "same (cfg, seed) must give same table"
    c = cum_arrivals(cfg, 8, 16)
    assert not np.array_equal(a, c), "different seed must perturb the table"
    # zero-prefixed cumulative: column 0 is 0, columns nondecreasing
    assert not a[:, 0].any()
    assert (np.diff(a, axis=1) >= 0).all()


@pytest.mark.parametrize("shape", ["poisson", "bursty", "diurnal"])
def test_arrival_rate_conservation(shape):
    # long horizon + many processes: the empirical mean rate must sit
    # within a few percent of the configured rate for every shape (the
    # bursty surge is normalized, the diurnal swing integrates out).  The
    # 8s horizon matters for bursty: its gates are global (one per bin),
    # so gate-sampling noise shrinks only with the bin count
    cfg = SimConfig(duration=8.0, arrival_rate=5e3, arrival_shape=shape,
                    arrival_bin=1e-3, arrival_period=0.02)
    counts = arrival_table(cfg, seed=3, n=16)
    measured = counts.sum() / (16 * cfg.duration)
    assert measured == pytest.approx(5e3, rel=0.05), (shape, measured)


def test_arrival_small_mean_branch_is_poisson_like():
    # mean-per-bin far below the normal cutoff: variance ~= mean
    cfg = SimConfig(duration=1.0, arrival_rate=2e3, arrival_bin=1e-3)
    counts = arrival_table(cfg, seed=11, n=32).astype(float)
    assert counts.mean() == pytest.approx(2.0, rel=0.05)
    assert counts.var() == pytest.approx(2.0, rel=0.10)


def test_cross_engine_arrival_parity_exact():
    """Event and jax engines inject the identical stream: on dyadic
    lockstep configs the full QoS signature and the per-process service
    accounting agree bitwise (poisson keeps clocks synchronized under
    saturation; rolling barriers pin bursty too)."""
    for shape, mode in (("poisson", AsyncMode.BEST_EFFORT),
                        ("diurnal", AsyncMode.BEST_EFFORT),
                        ("bursty", AsyncMode.ROLLING_BARRIER)):
        cfg = _arrival_cfg(mode=mode, shape=shape)
        re = make_engine("event", gc_app(16, "torus"), cfg).run()
        rj = make_engine("jax", gc_app(16, "torus"), cfg,
                         max_pops=EXACT_MAX_POPS).run()
        assert re.service is not None and rj.service is not None
        assert re.service == rj.service, (shape, mode)
        assert qos_signature(re) == qos_signature(rj), (shape, mode)
        assert sum(re.service["served"]) > 0


def test_cross_engine_service_totals_where_clocks_drift():
    # bursty best-effort legitimately desynchronizes the windowed clocks
    # (the documented windowed-vs-event semantic family), but the serve
    # recurrence reads only each process's own clock — totals stay exact
    cfg = _arrival_cfg(shape="bursty")
    re = make_engine("event", gc_app(16, "torus"), cfg).run()
    rj = make_engine("jax", gc_app(16, "torus"), cfg,
                     max_pops=EXACT_MAX_POPS).run()
    assert re.service is not None
    assert re.service == rj.service


def test_service_accounting_conserves():
    cfg = _arrival_cfg()
    res = make_engine("event", gc_app(16, "torus"), cfg).run()
    svc = res.service
    table = cum_arrivals(cfg, cfg.seed, 16)
    assert svc["arrivals"] == [int(x) for x in table[:, -1]]
    for a, s, b in zip(svc["arrivals"], svc["served"], svc["backlog"]):
        assert a == s + b and s >= 0 and b >= 0
    # serving capacity is bounded by chunk x updates
    for s, u in zip(svc["served"], res.updates):
        assert s <= cfg.service_chunk * u


def test_no_arrivals_keeps_service_off():
    cfg = dyadic_cfg(seed=case_seed("ring"))
    res = make_engine("jax", gc_app(16, "ring"), cfg,
                      max_pops=EXACT_MAX_POPS).run()
    assert res.service is None


# ---------------------------------------------------------------------------
# Churn topology patch-up
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("topology,n", [("ring", 16), ("torus", 16),
                                        ("cliques", 16), ("smallworld", 16)])
def test_patch_topology_invariants(topology, n):
    topo = make_topology(topology, n)
    patched, newid = patch_topology(topo, [3])
    # symmetric / connected / self-loop-free is asserted by validate()
    # inside patch_topology; pin the duct-table involution on top: the
    # canonical edge enumeration must pair every directed edge with its
    # reverse exactly once
    assert patched.n == n - 1
    esrc, edst, index = canonical_edges(patched)
    for s, d in zip(esrc, edst):
        assert (d, s) in index, f"edge ({s},{d}) has no reverse"
        rev = index[(d, s)]
        assert (esrc[rev], edst[rev]) == (d, s), "rev table not involutive"
        assert index[(esrc[rev], edst[rev])] == rev
    # the departed pid is gone, survivors renumber contiguously
    assert 3 not in newid
    assert sorted(newid.values()) == list(range(n - 1))


def test_patch_topology_rejoin_restores_pristine():
    topo = make_topology("torus", 16)
    # leave then rejoin = patch with the empty absent set = the original
    patched, newid = patch_topology(topo, [])
    assert patched.neighbors == topo.neighbors
    assert patched.node_of == topo.node_of
    assert newid == {p: p for p in range(16)}


def test_patch_topology_adjacent_departures():
    # two neighboring processes leave: sequential excision must still
    # produce a valid connected graph (validate() runs inside)
    topo = make_topology("ring", 8)
    patched, newid = patch_topology(topo, [2, 3])
    assert patched.n == 6
    # the ring splices closed: former neighbors 1 and 4 are now adjacent
    assert newid[4] in patched.neighbors[newid[1]]


def test_patch_topology_rejects_degenerate():
    topo = make_topology("ring", 4)
    with pytest.raises(ValueError):
        patch_topology(topo, [0, 1, 2])     # fewer than 2 survivors
    with pytest.raises(ValueError):
        patch_topology(topo, [9])           # out of range


def test_fault_timeline_state_queries():
    tl = FaultTimeline((
        TimelineEvent(t=0.2, kind="fault", host=1),
        TimelineEvent(t=0.4, kind="leave", pid=5),
        TimelineEvent(t=0.6, kind="heal", host=1),
        TimelineEvent(t=0.8, kind="join", pid=5),
    ))
    assert tl.boundaries(1.0) == [0.2, 0.4, 0.6, 0.8]
    assert tl.boundaries(0.5) == [0.2, 0.4]
    assert tl.absent_pids(0.1) == frozenset()
    assert tl.absent_pids(0.4) == frozenset({5})    # closed on the left
    assert tl.absent_pids(0.9) == frozenset()
    assert tl.faulty_hosts(0.3) == frozenset({1})
    assert tl.faulty_hosts(0.7) == frozenset()
    topo = make_topology("torus", 16)
    fm = tl.fault_model(topo, 0.3)
    assert set(fm.compute_slowdown) == set(topo.host_pids(1))
    assert tl.fault_model(topo, 0.7) is None


def test_default_timeline_alternates_kinds():
    topo = make_topology("torus", 16)
    tl = default_timeline(topo, churn=3, duration=0.7)
    kinds = [e.kind for e in tl.events]
    assert kinds == ["fault", "heal", "leave", "join", "fault", "heal"]
    assert all(0 < e.t < 0.7 for e in tl.events)
    assert default_timeline(topo, 0, 0.7).events == ()


# ---------------------------------------------------------------------------
# SLO evaluation
# ---------------------------------------------------------------------------
def _slo_row(i, lat, fail, complete=True):
    qos = {"simstep_latency": {"p99": lat},
           "delivery_failure_rate": {"p99": fail}}
    return {"interval": i, "t_start": i * 1.0, "t_end": i + 1.0,
            "n_samples": 4 if lat is not None else 0,
            "complete": complete, "qos": qos}


def test_slo_empty_slice_is_no_data():
    policy = SloPolicy(latency_p99_budget=10, failure_p99_budget=0.5)
    out = evaluate_timeseries([_slo_row(0, None, None)], policy)
    v = out["verdicts"][0]
    assert v["verdict"] == "no_data" and v["breached"] == []
    assert v["burn_rate"] == 0.0 and not v["burning"]
    assert out["summary"]["no_data"] == 1 and out["summary"]["ok"]


def test_slo_all_breach_saturates_burn():
    policy = SloPolicy(latency_p99_budget=10, failure_p99_budget=0.5,
                       burn_window=3, burn_threshold=0.5)
    rows = [_slo_row(i, 99.0, 0.9) for i in range(5)]
    out = evaluate_timeseries(rows, policy)
    assert all(v["verdict"] == "breach" for v in out["verdicts"])
    assert all(set(v["breached"]) ==
               {"simstep_latency", "delivery_failure_rate"}
               for v in out["verdicts"])
    assert out["summary"]["max_burn_rate"] == 1.0
    assert out["summary"]["burning_intervals"] == 5
    assert not out["summary"]["ok"]


def test_slo_boundary_equal_budget_passes():
    # budgets are inclusive: a slice sitting exactly on budget is OK
    policy = SloPolicy(latency_p99_budget=10.0, failure_p99_budget=0.5)
    out = evaluate_timeseries([_slo_row(0, 10.0, 0.5)], policy)
    assert out["verdicts"][0]["verdict"] == "ok"
    out = evaluate_timeseries(
        [_slo_row(0, math.nextafter(10.0, 11), 0.5)], policy)
    assert out["verdicts"][0]["verdict"] == "breach"
    assert out["verdicts"][0]["breached"] == ["simstep_latency"]


def test_slo_burn_rate_window_and_no_data_exclusion():
    policy = SloPolicy(latency_p99_budget=10, failure_p99_budget=0.5,
                       burn_window=2, burn_threshold=0.5)
    rows = [_slo_row(0, 99.0, 0.0),        # breach
            _slo_row(1, None, None),       # no_data: excluded from burn
            _slo_row(2, 1.0, 0.0),         # ok
            _slo_row(3, 99.0, 0.0)]        # breach
    out = evaluate_timeseries(rows, policy)
    burns = [v["burn_rate"] for v in out["verdicts"]]
    # window holds (breach), (breach), (breach, ok), (ok, breach)
    assert burns == [1.0, 1.0, 0.5, 0.5]
    assert [v["burning"] for v in out["verdicts"]] == [
        True, True, False, False]


# ---------------------------------------------------------------------------
# QoS sentinel + ragged-tail bugfixes (satellites)
# ---------------------------------------------------------------------------
def _ctr(updates, wall, touches=1):
    return Counters(update_count=updates, touch_count=touches,
                    wall_time=wall)


def test_idle_window_reports_inf_sentinel():
    before, after = _ctr(10, 1.0), _ctr(10, 2.0)
    assert simstep_period(before, after) == float("inf")
    assert walltime_latency(before, after) == float("inf")
    r = report(before, after)
    assert math.isinf(r.simstep_period) and math.isinf(r.walltime_latency)
    assert not math.isnan(r.walltime_latency), "0 * inf must not leak nan"
    # a live window still reports finite values
    assert simstep_period(_ctr(0, 0.0), _ctr(4, 1.0)) == 0.25


def test_aggregate_filters_idle_sentinels():
    live = report(_ctr(0, 0.0), _ctr(4, 1.0))
    idle = report(_ctr(4, 1.0), _ctr(4, 2.0))
    dist = aggregate_reports([live, idle, live])
    assert dist["simstep_period"]["median"] == 0.25
    # all-sentinel input yields None, same as no data
    dist = aggregate_reports([idle, idle])
    assert dist["simstep_period"]["median"] is None
    assert dist["delivery_failure_rate"]["median"] == 0.0


def test_timeseries_complete_flag_marks_ragged_tails():
    full = [QosReport(1e-5, 1.0, 1e-5, 0.0, 0.0, t_start=i * 1.0,
                      t_end=i + 1.0) for i in range(3)]
    short = full[:2]
    rows = aggregate_timeseries([full, full, short])
    assert [r["complete"] for r in rows] == [True, True, False]
    assert [r["n_samples"] for r in rows] == [3, 3, 2]


# ---------------------------------------------------------------------------
# End-to-end serve orchestration
# ---------------------------------------------------------------------------
def test_run_service_epochs_and_slo():
    topo = make_topology("torus", 16)
    cfg = dataclasses.replace(_arrival_cfg(), arrival_rate=5e4)
    tl = FaultTimeline((
        TimelineEvent(t=cfg.duration / 3, kind="leave", pid=5),
        TimelineEvent(t=2 * cfg.duration / 3, kind="join", pid=5),
    ))
    def app_builder(topology, s):
        # build on the patched epoch topology, not a pristine one
        return GraphColorApp(
            GraphColorConfig(n_processes=topology.n, nodes_per_process=1,
                             seed=s), topology=topology)

    out = run_service(RunConfig(engine="event"), app_builder, cfg, topo,
                      tl, SloPolicy())
    assert [e["n_procs"] for e in out["epochs"]] == [16, 15, 16]
    assert out["epochs"][1]["absent_pids"] == [5]
    assert out["service"]["arrivals"] == (out["service"]["served"]
                                          + out["service"]["backlog"])
    assert out["service"]["served"] > 0
    # verdict stream covers the whole run in order, intervals renumbered
    verdicts = out["slo"]["verdicts"]
    assert [v["interval"] for v in verdicts] == list(range(len(verdicts)))
    assert all(v["verdict"] in ("ok", "breach", "no_data")
               for v in verdicts)
    ts = [r["t_start"] for r in out["qos_timeseries"]]
    assert ts == sorted(ts)


# ---------------------------------------------------------------------------
# App-state carry across service epochs
# ---------------------------------------------------------------------------
def _carry_timeline(cfg):
    return FaultTimeline((
        TimelineEvent(t=cfg.duration / 3, kind="leave", pid=5),
        TimelineEvent(t=2 * cfg.duration / 3, kind="join", pid=5),
    ))


def _carry_builder(captured):
    def build(topology, s, init_state=None):
        captured.append(init_state)
        return GraphColorApp(
            GraphColorConfig(n_processes=topology.n, nodes_per_process=1,
                             seed=s), topology=topology,
            initial_state=init_state)
    return build


@pytest.mark.parametrize("engine", ["event", "jax"])
def test_app_state_carries_across_epochs(engine):
    """Survivors of a membership change resume from their previous
    epoch's final state; a departed-then-rejoined pid re-initializes
    fresh.  Checked functionally on both engine families: the state the
    epoch-1 builder receives is bit-identical to a standalone epoch-0
    run's export, re-keyed through the patch pid map."""
    if engine == "jax":
        pytest.importorskip("jax")
    from repro.runtime.engine import run_replicates

    topo = make_topology("torus", 16)
    cfg = _arrival_cfg()
    tl = _carry_timeline(cfg)
    captured = []
    run = RunConfig(engine=engine, replicates=2)
    out = run_service(run, _carry_builder(captured), cfg, topo, tl)
    assert [e["n_procs"] for e in out["epochs"]] == [16, 15, 16]

    # the event path builds one app per replicate, jax one per epoch
    per_epoch = len(captured) // 3
    e1, e2 = captured[per_epoch], captured[2 * per_epoch]
    assert captured[0] is None
    ep1_seeds = run.seeds(cfg.seed + 7919)
    ep2_seeds = run.seeds(cfg.seed + 2 * 7919)
    # epoch 1: every surviving patched pid carried, keyed by replicate seed
    assert sorted(e1) == sorted(ep1_seeds)
    for st in e1.values():
        assert sorted(st) == list(range(15))
    # epoch 2: rejoined pid 5 is NOT carried — it re-initializes fresh
    assert sorted(e2) == sorted(ep2_seeds)
    for st in e2.values():
        assert sorted(st) == sorted(set(range(16)) - {5})

    # functional carry: epoch-1 initial state == epoch-0 final state
    ep0_cfg = dataclasses.replace(
        cfg, duration=cfg.duration / 3,
        snapshot_warmup=min(cfg.snapshot_warmup, cfg.duration / 3 / 6),
        seed=cfg.seed, carry_app_state=True)
    res0 = run_replicates(
        run, lambda s: GraphColorApp(
            GraphColorConfig(n_processes=16, nodes_per_process=1, seed=s),
            topology=topo), ep0_cfg)
    _, pid_map = patch_topology(topo, {5})
    for i, s in enumerate(ep1_seeds):
        want = res0[i].app_state
        got = e1[s]
        assert want is not None
        for orig, patched in pid_map.items():
            np.testing.assert_array_equal(got[patched]["colors"],
                                          want[orig]["colors"])
            np.testing.assert_array_equal(got[patched]["probs"],
                                          want[orig]["probs"])


def test_service_carry_vectorized_layout_parity():
    """With state carried across epochs, the vectorized layouts must stay
    a pure implementation detail: edge-major and bucketed-dense service
    runs agree on the entire output dict, bit for bit."""
    pytest.importorskip("jax")
    topo = make_topology("torus", 16)
    cfg = _arrival_cfg()
    tl = _carry_timeline(cfg)
    outs = {}
    for layout in ("edge", "dense"):
        run = RunConfig(engine="jax", layout=layout, replicates=2)
        outs[layout] = run_service(run, _carry_builder([]), cfg, topo, tl)
    assert outs["edge"] == outs["dense"]
