"""Shared engine-conformance cases: one source of truth for parity tests.

Every parity test in the suite — event-oracle conformance, sharded vs
unsharded, dense vs edge-major — builds its application and ``SimConfig``
through the helpers here, so both sides of any comparison are keyed by the
same ``(topology, seed)`` pair via :func:`case_seed` (historically each
test file hardcoded its own seeds, and a drifted copy compared run A
against an unrelated run B).

Two config families:

``dyadic_cfg``
    Every time constant is a power of two and every stochastic time source
    is disabled (``jitter_sigma=0``, ``stall_prob=0``, ``latency_sigma=0``).
    Dyadic arithmetic is exact in BOTH float32 (vectorized engines) and
    float64 (event oracle), so process clocks never drift and the windowed
    engines reproduce the event-ordered reference *bitwise* — including
    every clock-valued QoS field.  ``tests/test_engine_conformance.py``
    asserts full :func:`repro.core.qos.qos_signature` equality on this
    family.  Fault slowdown factors must stay dyadic (2.0, 8.0) and, under
    BEST_EFFORT, uniform across processes (heterogeneous compute under
    best-effort lets clocks drift apart, which is exactly the documented
    windowed-time approximation).

``jittered_cfg``
    The realistic defaults (lognormal jitter, stalls, latency noise).
    Clocks drift, so conformance is statistical: medians of (process,
    window) QoS samples within the documented ``PARITY_RTOL``.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import subprocess
import sys
import textwrap
import zlib
from typing import Optional

from repro.core.modes import AsyncMode
from repro.runtime.engine import make_engine
from repro.runtime.faults import (FaultModel, crashed_host, flapping_host,
                                  lossy_host)
from repro.runtime.simulator import SimConfig
from repro.runtime.topologies import make_topology
from repro.apps.graphcolor import GraphColorApp, GraphColorConfig

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: documented statistical parity bound (DESIGN.md §7): relative tolerance
#: on medians of (process, window) QoS samples under jittered configs
PARITY_RTOL = {
    "simstep_period": 0.10,
    "simstep_latency": 0.25,
    "walltime_latency": 0.25,
    "delivery_failure_rate": 0.25,
    "delivery_clumpiness": 0.30,   # most sensitive to event ordering
}

#: ring-pop bound for exact cases: large enough that a lockstep window
#: always drains every arrival, so no backlog survives to reorder later
#: windows (16 is plenty for the jittered family's drifting clocks, but
#: the dyadic family's perfectly synchronized bursts need headroom)
EXACT_MAX_POPS = 64


def case_seed(topology: str, seed: int = 0) -> int:
    """The shared seed for a parity pair, keyed by ``(topology, seed)``.

    Both the application RNG and ``SimConfig.seed`` of BOTH sides of a
    comparison must come from here; tests never hardcode a raw seed next
    to a topology name.
    """
    return ((zlib.crc32(topology.encode("ascii")) & 0x7F) << 8) | (seed & 0xFF)


def gc_app(n: int, topology: str = "ring", simels: int = 1,
           seed: Optional[int] = None) -> GraphColorApp:
    if seed is None:
        seed = case_seed(topology)
    topo = make_topology(topology, n)
    return GraphColorApp(
        GraphColorConfig(n_processes=n, nodes_per_process=simels, seed=seed),
        topology=topo)


_DYADIC = dict(
    duration=2.0 ** -7,
    base_compute=2.0 ** -16,
    per_message_cost=2.0 ** -23,
    per_pull_cost=2.0 ** -22,
    base_latency=2.0 ** -13,
    barrier_base=2.0 ** -15,
    barrier_per_log2=2.0 ** -16,
    rolling_quantum=2.0 ** -11,
    fixed_interval=2.0 ** -10,
    snapshot_warmup=2.0 ** -10,
    snapshot_interval=2.0 ** -11,
    jitter_sigma=0.0,
    stall_prob=0.0,
    latency_sigma=0.0,
)


def dyadic_cfg(mode: AsyncMode = AsyncMode.BEST_EFFORT, seed: int = 0,
               **kw) -> SimConfig:
    base = dict(_DYADIC, mode=mode, seed=seed)
    base.update(kw)
    return SimConfig(**base)


def jittered_cfg(duration: float = 0.05, seed: int = 0, **kw) -> SimConfig:
    base = dict(duration=duration, snapshot_warmup=duration / 6,
                snapshot_interval=duration / 12, seed=seed)
    base.update(kw)
    return SimConfig(**base)


#: dyadic quarantine timeout for crash-under-barrier scenarios: one
#: latency quantum above the dyadic barrier skew, so only the crashed
#: clique (+inf arrivals) is ever excluded from a release
QUARANTINE_TAU = 2.0 ** -10


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One conformance scenario: topology x mode x fault injection.

    ``faults`` is a symbolic tag (hashable, subprocess-serializable):

      none      no fault injection
      uniform2  every process computes 2x slower — clocks stay lockstep,
                so BEST_EFFORT remains exact under dyadic configs
      victim8   process 1 computes 8x slower — only exact under barrier
                modes, whose releases re-synchronize the victim
      crash0    every process on host 0 is crashed (dead-destination
                drops; under barrier modes pair with ``barrier_timeout``
                or the cohort never releases)
      lossy25   host 0's links drop each message w.p. 0.25 (hash-drawn
                per canonical edge id x send count, DESIGN.md §14)
      flap50    host 0's links are down half of each flap period on the
                deterministic hash schedule

    Loss and flap kill decisions are clock-free hash draws, so they stay
    exact wherever the underlying (topology, mode) cell is exact; cells
    where best-effort clock skew would reorder *send counts* (cliques
    flap, anything on smallworld) are pinned under barrier modes only —
    the same windowed-time approximation that keeps victim8 off
    best-effort.
    """
    name: str
    topology: str
    mode: AsyncMode = AsyncMode.BEST_EFFORT
    faults: str = "none"
    n: int = 16
    barrier_timeout: float = 0.0

    def seed(self) -> int:
        return case_seed(self.topology)

    def app(self) -> GraphColorApp:
        return gc_app(self.n, self.topology, seed=self.seed())

    def config(self) -> SimConfig:
        return dyadic_cfg(mode=self.mode, seed=self.seed(),
                          barrier_timeout=self.barrier_timeout)

    def fault_model(self) -> Optional[FaultModel]:
        if self.faults == "none":
            return None
        if self.faults == "uniform2":
            return FaultModel(
                compute_slowdown={p: 2.0 for p in range(self.n)})
        if self.faults == "victim8":
            return FaultModel(compute_slowdown={1: 8.0})
        topo = make_topology(self.topology, self.n)
        if self.faults == "crash0":
            return crashed_host(topo, 0)
        if self.faults == "lossy25":
            return lossy_host(topo, 0, 0.25)
        if self.faults == "flap50":
            return flapping_host(topo, 0, 0.5)
        raise ValueError(f"unknown fault tag {self.faults!r}")


#: the exact-conformance matrix: >= 3 topologies x >= 2 modes x
#: fault/no-fault, every cell validated bitwise against the event oracle
EXACT_SCENARIOS = (
    Scenario("ring-best-effort", "ring"),
    Scenario("torus-best-effort", "torus"),
    Scenario("cliques-best-effort", "cliques"),
    Scenario("ring-best-effort-uniform-fault", "ring", faults="uniform2"),
    Scenario("torus-best-effort-uniform-fault", "torus", faults="uniform2"),
    Scenario("ring-barrier-victim-fault", "ring",
             mode=AsyncMode.BARRIER_EVERY_STEP, faults="victim8"),
    Scenario("cliques-barrier-victim-fault", "cliques",
             mode=AsyncMode.BARRIER_EVERY_STEP, faults="victim8"),
    Scenario("smallworld-barrier-victim-fault", "smallworld",
             mode=AsyncMode.BARRIER_EVERY_STEP, faults="victim8"),
    Scenario("torus-barrier", "torus", mode=AsyncMode.BARRIER_EVERY_STEP),
    Scenario("ring-no-comm", "ring", mode=AsyncMode.NO_COMM),
    Scenario("ring-rolling-barrier", "ring", mode=AsyncMode.ROLLING_BARRIER),
    Scenario("torus-fixed-barrier", "torus", mode=AsyncMode.FIXED_BARRIER),
    # crash / lossy / flap (DESIGN.md §14) across all four topologies.
    # Best-effort cells are limited to (topology, fault) pairs whose send
    # counts are skew-invariant; the rest ride barrier modes, and every
    # crash-under-barrier cell quarantines (a zero timeout never releases)
    Scenario("ring-best-effort-lossy", "ring", faults="lossy25"),
    Scenario("torus-best-effort-lossy", "torus", faults="lossy25"),
    Scenario("cliques-best-effort-lossy", "cliques", faults="lossy25"),
    Scenario("smallworld-barrier-lossy", "smallworld",
             mode=AsyncMode.BARRIER_EVERY_STEP, faults="lossy25"),
    Scenario("ring-best-effort-flap", "ring", faults="flap50"),
    Scenario("torus-barrier-flap", "torus",
             mode=AsyncMode.BARRIER_EVERY_STEP, faults="flap50"),
    Scenario("cliques-barrier-flap", "cliques",
             mode=AsyncMode.BARRIER_EVERY_STEP, faults="flap50"),
    Scenario("smallworld-barrier-flap", "smallworld",
             mode=AsyncMode.BARRIER_EVERY_STEP, faults="flap50"),
    Scenario("torus-best-effort-crash", "torus", faults="crash0"),
    Scenario("ring-barrier-crash-quarantine", "ring",
             mode=AsyncMode.BARRIER_EVERY_STEP, faults="crash0",
             barrier_timeout=QUARANTINE_TAU),
    Scenario("cliques-rolling-crash-quarantine", "cliques",
             mode=AsyncMode.ROLLING_BARRIER, faults="crash0",
             barrier_timeout=QUARANTINE_TAU),
    Scenario("torus-fixed-crash-quarantine", "torus",
             mode=AsyncMode.FIXED_BARRIER, faults="crash0",
             barrier_timeout=QUARANTINE_TAU),
    Scenario("smallworld-barrier-crash-quarantine", "smallworld",
             mode=AsyncMode.BARRIER_EVERY_STEP, faults="crash0",
             barrier_timeout=QUARANTINE_TAU),
)

#: scenario name -> Scenario, for subprocess scripts that receive names
SCENARIOS_BY_NAME = {s.name: s for s in EXACT_SCENARIOS}


def run_case(engine: str, scenario: Scenario, **engine_kwargs):
    """Run ``scenario`` on a registered engine and return its SimResult.

    Vectorized engines get ``max_pops=EXACT_MAX_POPS`` so a window always
    fully drains (required for exact conformance; harmless otherwise).
    """
    if engine != "event":
        engine_kwargs.setdefault("max_pops", EXACT_MAX_POPS)
    return make_engine(engine, scenario.app(), scenario.config(),
                       scenario.fault_model(), **engine_kwargs).run()


@functools.lru_cache(maxsize=None)
def oracle(scenario: Scenario):
    """The event-ordered reference run for ``scenario`` (cached: every
    engine variant compares against the same oracle instance)."""
    return run_case("event", scenario)


def run_md(script: str, devices: int = 8, timeout: int = 560) -> str:
    """Run ``script`` in a subprocess with ``devices`` forced host devices.

    The main test process keeps a single device (XLA fixes the platform
    device count at first use), so anything needing a populated mesh runs
    here.  ``engine_cases`` itself is importable in the child.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO, "src"), os.path.join(REPO, "tests")])
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout
