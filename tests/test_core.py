"""Core best-effort library tests: QoS metrics, compressors, optimizers.

Multi-device conduit/collective semantics are tested in
test_core_multidevice.py (subprocess with forced host device count)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import qos
from repro.core.modes import AsyncMode, sync_due
from repro.optim import adamw, compression, outer


# ---------------------------------------------------------------------------
# QoS metrics (paper §II-D formulas)
# ---------------------------------------------------------------------------
def _counters(**kw):
    return qos.Counters(**kw)


def test_simstep_period():
    b = _counters(update_count=0, wall_time=0.0)
    a = _counters(update_count=100, wall_time=2.0)
    assert qos.simstep_period(b, a) == pytest.approx(0.02)


def test_simstep_latency_and_walltime():
    b = _counters()
    a = _counters(update_count=100, touch_count=25, wall_time=1.0)
    assert qos.simstep_latency(b, a) == pytest.approx(4.0)
    assert qos.walltime_latency(b, a) == pytest.approx(4.0 * 0.01)


def test_simstep_latency_no_touches_best_case():
    b = _counters()
    a = _counters(update_count=50, touch_count=0, wall_time=1.0)
    assert qos.simstep_latency(b, a) == 50.0  # best-case: one elapsed touch


def test_delivery_failure_rate():
    b = _counters()
    a = _counters(attempted_send_count=100, successful_send_count=70,
                  dropped_send_count=30)
    assert qos.delivery_failure_rate(b, a) == pytest.approx(0.3)
    assert qos.delivery_failure_rate(b, b) == 0.0
    # the rate comes from the explicit drop counter, never the
    # attempted - successful derivation (which can straddle a window edge)
    mid = _counters(attempted_send_count=100, successful_send_count=70)
    assert qos.delivery_failure_rate(b, mid) == 0.0


def test_clumpiness_even_stream_is_zero():
    # every message in its own laden pull
    b = _counters()
    a = _counters(laden_pull_count=10, message_count=10, pull_attempt_count=50)
    assert qos.delivery_clumpiness(b, a) == pytest.approx(0.0)


def test_clumpiness_pigeonhole_zero():
    # more messages than pulls, every pull laden
    b = _counters()
    a = _counters(laden_pull_count=20, message_count=100, pull_attempt_count=20)
    assert qos.delivery_clumpiness(b, a) == pytest.approx(0.0)


def test_clumpiness_single_burst_near_one():
    b = _counters()
    a = _counters(laden_pull_count=1, message_count=100, pull_attempt_count=100)
    assert qos.delivery_clumpiness(b, a) == pytest.approx(0.99)


def test_report_bundle():
    b = _counters()
    a = _counters(update_count=10, touch_count=5, attempted_send_count=10,
                  successful_send_count=10, laden_pull_count=5, message_count=5,
                  pull_attempt_count=10, wall_time=1.0)
    r = qos.report(b, a)
    assert set(r.as_dict()) == {"simstep_period", "simstep_latency",
                                "walltime_latency", "delivery_failure_rate",
                                "delivery_clumpiness", "t_start", "t_end"}
    # the observation-window bounds ride along for the time-resolved stream
    assert (r.t_start, r.t_end) == (b.wall_time, a.wall_time)


def test_aggregate_timeseries_pools_interval_columns():
    def reports(periods):
        # one report per interval, with simstep_period == updates' inverse
        out = []
        for i, per in enumerate(periods):
            b = _counters(update_count=i * 10, wall_time=i * per * 10)
            a = _counters(update_count=(i + 1) * 10,
                          wall_time=(i + 1) * per * 10)
            out.append(qos.report(b, a))
        return out

    # two processes with three intervals, one straggler with a single one
    series = qos.aggregate_timeseries([
        reports([1.0, 2.0, 3.0]),
        reports([3.0, 4.0, 5.0]),
        reports([10.0]),
    ])
    assert [row["interval"] for row in series] == [0, 1, 2]
    assert [row["n_samples"] for row in series] == [3, 2, 2]
    # interval 1 pools only the two full processes: median of (2, 4)
    assert series[1]["qos"]["simstep_period"]["median"] == pytest.approx(3.0)
    # time bounds are medians of the contributing processes' own clocks
    assert series[0]["t_start"] == 0.0
    assert series[1]["t_end"] == pytest.approx(
        (2 * 2.0 * 10 + 2 * 4.0 * 10) / 2)


# ---------------------------------------------------------------------------
# Modes
# ---------------------------------------------------------------------------
def test_sync_due():
    assert bool(sync_due(AsyncMode.BARRIER_EVERY_STEP, 3, 10))
    assert bool(sync_due(AsyncMode.ROLLING_BARRIER, 9, 10))
    assert not bool(sync_due(AsyncMode.ROLLING_BARRIER, 5, 10))
    assert not bool(sync_due(AsyncMode.BEST_EFFORT, 9, 10))
    assert not bool(sync_due(AsyncMode.NO_COMM, 9, 10))


# ---------------------------------------------------------------------------
# Compressors (error feedback invariants)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("comp", [compression.TopKCompressor(ratio=0.25),
                                  compression.Int8Compressor(block=16)],
                         ids=["topk", "int8"])
def test_compressor_error_feedback_identity(comp):
    """payload-decoded + residual must equal the input (lossless split)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 33))
    payload, residual = comp.encode(x)
    gathered = jax.tree.map(lambda p: p[None], payload)  # 1 "pod"
    decoded = comp.decode_sum(gathered, x.shape, x.dtype)
    np.testing.assert_allclose(np.asarray(decoded + residual), np.asarray(x),
                               rtol=1e-5, atol=1e-5)


def test_topk_keeps_largest():
    comp = compression.TopKCompressor(ratio=0.1)
    x = jnp.zeros((100,)).at[7].set(5.0).at[42].set(-9.0)
    payload, residual = comp.encode(x)
    kept = set(np.asarray(payload["indices"]).tolist())
    assert {7, 42} <= kept or 42 in kept  # k=10, both fit
    assert float(jnp.abs(residual).max()) == 0.0


def test_int8_quantization_error_bounded():
    comp = compression.Int8Compressor(block=64)
    x = jax.random.normal(jax.random.PRNGKey(1), (256,))
    payload, residual = comp.encode(x)
    # error bounded by half a quantization step per block
    scale = np.asarray(payload["scale"]).reshape(-1)
    err = np.abs(np.asarray(residual)).reshape(-1, 64).max(axis=1)
    assert (err <= scale * 0.5 + 1e-6).all()


# ---------------------------------------------------------------------------
# AdamW + outer optimizer
# ---------------------------------------------------------------------------
def test_adamw_descends_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                            total_steps=100, grad_clip=1e9)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw.init_opt_state(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, m = adamw.apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_outer_step_moves_anchor_toward_workers():
    params = {"w": jnp.array([1.0])}
    ostate = outer.init_outer_state(params)
    # workers drifted to anchor - delta => mean_delta = anchor - params
    drifted = {"w": jnp.array([0.0])}
    delta = jax.tree.map(lambda a, p: a - p, ostate["anchor"], drifted)
    cfg = outer.OuterConfig(outer_lr=1.0, outer_momentum=0.0, nesterov=False)
    new_params, new_state = outer.outer_step(drifted, ostate, delta, cfg)
    # anchor moves from 1.0 toward 0.0 by outer_lr * delta
    assert float(new_state["anchor"]["w"][0]) == pytest.approx(0.0)
    assert float(new_params["w"][0]) == pytest.approx(0.0)
