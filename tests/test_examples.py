"""Smoke tests for the example entry points, run in-process via runpy.

The examples are the first thing a reader executes; these tests pin that
they run to completion (no exception == exit 0) and that each section
prints its expected result lines, so a refactor that silently breaks a
demo path fails CI instead of a reader's first session.
"""

import os
import runpy

import pytest

pytest.importorskip("jax")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
EXAMPLES = os.path.join(REPO, "examples")


def run_example(name: str, capsys) -> str:
    runpy.run_path(os.path.join(EXAMPLES, name), run_name="__main__")
    return capsys.readouterr().out


def test_quickstart_runs_and_reports(capsys):
    out = run_example("quickstart.py", capsys)
    assert "tiny LM: train step, prefill, decode" in out
    assert "loss at init" in out
    assert "decoded 4 tokens" in out
    assert "best-effort vs barrier" in out
    # both modes ran and reported a rate
    assert out.count("updates/s/cpu") == 2
    assert "conflicts left" in out


@pytest.mark.slow
def test_graphcolor_demo_runs_and_reports(capsys):
    out = run_example("graphcolor_demo.py", capsys)
    assert "asynchronicity modes" in out
    # all five AsyncMode rows printed
    for mode in range(5):
        assert f"\n{mode}: " in out
    assert "QoS with a faulty node" in out
    assert "global median simstep period" in out
    assert "median holds" in out
    assert "updates: faulty=" in out
