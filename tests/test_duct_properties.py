"""Property tests for the duct_exchange ring ops (DESIGN.md §7).

Random op sequences over a batch of bounded FIFO rings, checked two ways
each step: slot-exact agreement between the jnp ops and the numpy oracle
(``ref.duct_exchange_ref``), and model-level invariants against a python
mirror queue per ring:

  drop-iff-full   a send is accepted iff the post-drain ring has room
  FIFO order      drains pop in push order, never jumping a
                  not-yet-available head, at most ``max_pops`` per window
  conservation    accepted == delivered + in-flight and
                  attempted == accepted + dropped, per ring, every step

Runs under hypothesis when installed (the CI test matrix installs it);
falls back to a fixed seed/shape sweep otherwise, so the invariants are
exercised in either environment.
"""

import collections

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.kernels.duct_exchange.ops import duct_exchange_jnp, duct_window_jnp
from repro.kernels.duct_exchange.ref import duct_exchange_ref, duct_window_ref
from repro.runtime.simulator import SimConfig
from repro.runtime.window_core import BucketSlab, DenseSpec, WindowCore

try:
    from hypothesis import given, settings, strategies as hyp_st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def run_sequence(seed: int, E: int, C: int, max_pops: int, steps: int):
    """Drive both implementations through one random op sequence."""
    rng = np.random.default_rng(seed)
    q_avail = np.full((E, C), np.inf, np.float32)
    q_touch = np.zeros((E, C), np.int32)
    head = np.zeros(E, np.int32)
    size = np.zeros(E, np.int32)
    # mirror[e]: FIFO of (availability, touch) for every in-flight message
    mirror = [collections.deque() for _ in range(E)]
    accepted_tot = np.zeros(E, np.int64)
    attempted_tot = np.zeros(E, np.int64)
    dropped_tot = np.zeros(E, np.int64)
    drained_tot = np.zeros(E, np.int64)
    now = np.zeros(E, np.float32)

    for _ in range(steps):
        now = (now + rng.uniform(0.5, 1.5, E)).astype(np.float32)
        recv_active = rng.random(E) < 0.8
        send_active = rng.random(E) < 0.8
        send_lat = rng.uniform(0.0, 4.0, E).astype(np.float32)
        send_touch = rng.integers(1, 100, E).astype(np.int32)

        r = duct_exchange_ref(
            q_avail,
            q_touch,
            head,
            size,
            now,
            recv_active,
            now,
            send_active,
            send_lat,
            send_touch,
            capacity=C,
            max_pops=max_pops,
        )
        j = duct_exchange_jnp(
            jnp.asarray(q_avail),
            jnp.asarray(q_touch),
            jnp.asarray(head),
            jnp.asarray(size),
            jnp.asarray(now),
            jnp.asarray(recv_active),
            jnp.asarray(now),
            jnp.asarray(send_active),
            jnp.asarray(send_lat),
            jnp.asarray(send_touch),
            capacity=C,
            max_pops=max_pops,
        )
        for name in r._fields:
            got = np.asarray(getattr(j, name))
            np.testing.assert_array_equal(got, getattr(r, name), err_msg=name)

        for e in range(E):
            # FIFO + head-blocking: the pops the oracle reports must equal
            # a front-of-queue walk of the mirror, stopping at the first
            # not-yet-available message, bounded by max_pops
            if recv_active[e]:
                expect = 0
                for avail, _tch in list(mirror[e])[: min(size[e], max_pops)]:
                    if avail <= now[e]:
                        expect += 1
                    else:
                        break
                assert r.drained[e] == expect, (e, r.drained[e], expect)
            else:
                assert r.drained[e] == 0
            popped_touch = None
            for _ in range(int(r.drained[e])):
                _avail, popped_touch = mirror[e].popleft()
            if r.drained[e] > 0:
                # the freshest popped message is the one whose touch stamp
                # (and ring slot payload) the engine consumes
                assert r.recv_touch[e] == popped_touch
            # drop-iff-full, judged against post-drain occupancy
            room = size[e] - r.drained[e] < C
            assert bool(r.accepted[e]) == bool(send_active[e] and room)
            if r.accepted[e]:
                mirror[e].append((now[e] + send_lat[e], send_touch[e]))
            assert len(mirror[e]) == r.size[e]

        drained_tot += r.drained
        accepted_tot += r.accepted
        attempted_tot += send_active
        dropped_tot += send_active & ~r.accepted
        q_avail, q_touch, head, size = r.q_avail, r.q_touch, r.head, r.size
        # conservation: every message is delivered, dropped, or in flight
        assert np.all(accepted_tot == drained_tot + size)
        assert np.all(attempted_tot == accepted_tot + dropped_tot)


# a sweep that exercises capacity-1 rings, single-pop drains, single-ring
# batches, and a larger mixed case — always runs, hypothesis or not
FALLBACK_CASES = [
    (0, 1, 1, 1, 20),
    (1, 3, 1, 2, 20),
    (2, 1, 4, 1, 20),
    (3, 4, 2, 3, 15),
    (4, 2, 4, 4, 25),
    (5, 4, 4, 2, 15),
]


@pytest.mark.parametrize("seed,E,C,max_pops,steps", FALLBACK_CASES)
def test_duct_properties_seeded(seed, E, C, max_pops, steps):
    run_sequence(seed, E, C, max_pops, steps)


def run_window_sequence(seed: int, n: int, d: int, C: int, max_pops: int, steps: int):
    """Drive the fused dense-layout window op (DESIGN.md §10) through an
    engine-style staging cycle: the send decision (drop-iff-full, slot,
    occupancy bump) is made eagerly each step, the ring writes ride into the
    *next* step's ``duct_window`` pass.  Checks jnp-vs-ref slot-exact
    agreement plus mirror-queue invariants every step:

      drop-iff-full   a staged send is accepted iff the post-drain ring
                      has room at stage time
      FIFO order      drains pop in push order, never past a
                      not-yet-available head, at most ``max_pops``
      halo select     slot ``s`` carries the freshest payload of the
                      highest delivering row ``j`` with ``j % 4 == s``
      conservation    accepted == drained + in-flight (staged included)
                      and attempted == accepted + dropped, every step
    """
    rng = np.random.default_rng(seed)
    qa = np.full((n, d, C), np.inf, np.float32)
    qt = np.zeros((n, d, C), np.int32)
    qp = np.zeros((n, d, C, 1), np.int32)
    head = np.zeros((n, d), np.int32)
    size = np.zeros((n, d), np.int32)
    stage = dict(
        pos=np.zeros((n, d), np.int32),
        acc=np.zeros((n, d), bool),
        avail=np.zeros((n, d), np.float32),
        touch=np.zeros((n, d), np.int32),
        pay=np.zeros((n, d, 1), np.int32),
    )
    # mirror[p][j]: FIFO of (availability, touch, payload) per ring
    mirror = [[collections.deque() for _ in range(d)] for _ in range(n)]
    accepted_tot = np.zeros((n, d), np.int64)
    attempted_tot = np.zeros((n, d), np.int64)
    dropped_tot = np.zeros((n, d), np.int64)
    drained_tot = np.zeros((n, d), np.int64)
    now = np.zeros(n, np.float32)

    for _ in range(steps):
        now = (now + rng.uniform(0.5, 1.5, n)).astype(np.float32)
        ract = rng.random(n) < 0.8
        args = (
            qa,
            qt,
            qp,
            head,
            size,
            stage["pos"],
            stage["acc"],
            stage["avail"],
            stage["touch"],
            stage["pay"],
            now,
            ract,
        )
        r = duct_window_ref(*args, max_pops=max_pops)
        j = duct_window_jnp(*(jnp.asarray(a) for a in args), max_pops=max_pops)
        for name in r._fields:
            got = np.asarray(getattr(j, name))
            np.testing.assert_array_equal(got, getattr(r, name), err_msg=name)

        # the staged pushes enter the mirror queues (accepted at stage time)
        for p in range(n):
            for q in range(d):
                if stage["acc"][p, q]:
                    entry = (stage["avail"][p, q], stage["touch"][p, q], stage["pay"][p, q, 0])
                    mirror[p][q].append(entry)
        for p in range(n):
            fresh_pay = {}
            for q in range(d):
                # FIFO + head-blocking: pops must equal a front-of-queue
                # walk stopping at the first unavailable message
                if ract[p]:
                    expect = 0
                    for avail, _tch, _pay in list(mirror[p][q])[:max_pops]:
                        if avail <= now[p]:
                            expect += 1
                        else:
                            break
                    assert r.drained[p, q] == expect, (p, q, r.drained[p, q], expect)
                else:
                    assert r.drained[p, q] == 0
                last = None
                for _ in range(int(r.drained[p, q])):
                    last = mirror[p][q].popleft()
                if r.drained[p, q] > 0:
                    assert r.recv_touch[p, q] == last[1]
                    fresh_pay[q] = last[2]
                assert len(mirror[p][q]) == r.size[p, q]
            # halo select: the highest delivering row of each slot wins
            for s in range(4):
                js = [q for q in range(s, d, 4) if r.drained[p, q] > 0]
                assert bool(r.halo_win[p, s]) == bool(js)
                if js:
                    assert r.halo_pay[p, s, 0] == fresh_pay[max(js)]

        qa, qt, qp = r.q_avail, r.q_touch, r.q_pay
        head, size = r.head, r.size
        drained_tot += r.drained

        # stage the next step's sends, engine-style: decide drop-iff-full
        # against the post-drain occupancy NOW, write next step
        sact = rng.random((n, d)) < 0.8
        sacc = sact & (size < C)
        attempted_tot += sact
        accepted_tot += sacc
        dropped_tot += sact & ~sacc
        stage = dict(
            pos=((head + size) % C).astype(np.int32),
            acc=sacc,
            avail=(now[:, None] + rng.uniform(0.0, 4.0, (n, d))).astype(np.float32),
            touch=rng.integers(1, 100, (n, d)).astype(np.int32),
            pay=rng.integers(0, 99, (n, d, 1)).astype(np.int32),
        )
        size = (size + sacc).astype(np.int32)
        # conservation: every accepted message is drained, staged, or queued
        assert np.all(accepted_tot == drained_tot + size)
        assert np.all(attempted_tot == accepted_tot + dropped_tot)


# capacity-1 rings, degree 1 and 5 (slot aliasing), single-pop drains
WINDOW_FALLBACK_CASES = [
    (0, 1, 1, 1, 1, 20),
    (1, 2, 2, 1, 2, 20),
    (2, 1, 4, 4, 1, 20),
    (3, 3, 2, 3, 2, 15),
    (4, 2, 5, 4, 4, 25),
    (5, 2, 4, 2, 3, 15),
]


@pytest.mark.parametrize("seed,n,d,C,max_pops,steps", WINDOW_FALLBACK_CASES)
def test_duct_window_properties_seeded(seed, n, d, C, max_pops, steps):
    run_window_sequence(seed, n, d, C, max_pops, steps)


# ---------------------------------------------------------------------------
# WindowCore phase properties (DESIGN.md §11): the same mirror-queue oracle
# driven through the *engine-facing* phase methods — drain + send_edge on
# the edge-major layout, window_dense + stage_dense on the dense layout —
# instead of the raw ops, so the shared core's counter bookkeeping, halo
# merge, and sentinel-free paths are themselves under property test.
# ---------------------------------------------------------------------------
class _StubApp:
    """Minimal batched-app surface for a WindowCore under phase test."""

    payload_len = 1
    payload_dtype = np.int32


def _make_core(n, C, max_pops):
    cfg = SimConfig(buffer_capacity=C, duration=1.0,
                    snapshot_warmup=0.25, snapshot_interval=0.25)
    return WindowCore(cfg, _StubApp(), n, max_pops=max_pops)


def run_core_edge_sequence(seed: int, n: int, d: int, C: int,
                           max_pops: int, steps: int):
    """Drive ``WindowCore.drain`` / ``send_edge`` through a random op
    sequence over ``n*d`` edge-major rings (receiver ``r // d``), checked
    per step against the mirror queues:

      drop-iff-full   send accepted iff the post-drain ring has room
      FIFO order      drains walk the queue front, head-blocked, bounded
      halo winner     slot ``s`` carries the freshest payload of the
                      highest delivering row with ``row % d % 4 == s``
      conservation    per-ring and per-process counter identities
    """
    rng = np.random.default_rng(seed)
    core = _make_core(n, C, max_pops)
    E = n * d
    dst = (np.arange(E) // d).astype(np.int32)
    halo_key = (dst * 4 + (np.arange(E) % d) % 4).astype(np.int32)
    src = ((np.arange(E) * 7 + 3) % n).astype(np.int32)
    carry = {k: v for k, v in core.edge_rings(E).items()}
    carry.update(halo=jnp.zeros((n, 4, 1), jnp.int32),
                 c_msgs=jnp.zeros(n, jnp.int32),
                 c_laden=jnp.zeros(n, jnp.int32),
                 c_touch=jnp.zeros(n, jnp.int32))
    mirror = [collections.deque() for _ in range(E)]
    ptouch_m = np.zeros(E, np.int64)
    acc_tot = np.zeros(E, np.int64)
    att_tot = np.zeros(E, np.int64)
    drop_tot = np.zeros(E, np.int64)
    drain_tot = np.zeros(E, np.int64)
    now = np.zeros(n, np.float32)

    for _ in range(steps):
        now = (now + rng.uniform(0.5, 1.5, n)).astype(np.float32)
        ract = rng.random(n) < 0.8
        prev = {k: np.asarray(v) for k, v in carry.items()}
        upd, drained_r = core.drain(
            carry, jnp.asarray(now)[jnp.asarray(dst)],
            jnp.asarray(ract)[jnp.asarray(dst)],
            halo_key=jnp.asarray(halo_key), n_halo=n * 4,
            dst=jnp.asarray(dst), n_dst=n)
        u = dict(carry)
        u.update(upd)
        drained = np.zeros(E, np.int64)
        fresh = {}
        for e in range(E):
            p = dst[e]
            expect = 0
            if ract[p]:
                for avail, _t, _pay in list(mirror[e])[:max_pops]:
                    if avail <= now[p]:
                        expect += 1
                    else:
                        break
            drained[e] = expect
            last = None
            for _ in range(expect):
                last = mirror[e].popleft()
            if expect:
                assert int(np.asarray(u["ptouch"])[e]) == last[1] + 1, e
                ptouch_m[e] = last[1] + 1
                fresh[e] = last[2]
            assert int(np.asarray(u["q_size"])[e]) == len(mirror[e]), e
        drain_tot += drained
        # receiver-side counters sum per process
        halo = np.asarray(u["halo"])
        for p in range(n):
            rows = np.arange(p * d, (p + 1) * d)
            assert int(np.asarray(drained_r)[p]) == drained[rows].sum()
            dm = (np.asarray(u["c_msgs"]) - prev["c_msgs"])[p]
            assert dm == drained[rows].sum(), p
            dl = (np.asarray(u["c_laden"]) - prev["c_laden"])[p]
            assert dl == (drained[rows] > 0).sum(), p
            # halo winner: highest delivering row per (receiver, slot)
            for s in range(4):
                js = [e for e in rows
                      if (e % d) % 4 == s and drained[e] > 0]
                if js:
                    assert halo[p, s, 0] == fresh[max(js)], (p, s)

        # send attempt through the core, against post-drain occupancy
        sact = rng.random(E) < 0.8
        lat = rng.uniform(0.0, 4.0, E).astype(np.float32)
        touch = rng.integers(1, 100, E).astype(np.int32)
        pay = rng.integers(0, 99, (E, 1)).astype(np.int32)
        sp = core.send_edge(u, jnp.asarray(now)[jnp.asarray(src)],
                            jnp.asarray(sact), jnp.asarray(lat),
                            jnp.asarray(touch), jnp.asarray(pay),
                            jnp.asarray(src), n)
        acc = np.asarray(sp.accepted)
        sums = np.asarray(sp.sums)
        u.update(sp.rings)
        for e in range(E):
            room = len(mirror[e]) < C
            assert bool(acc[e]) == bool(sact[e] and room), e
            if acc[e]:
                mirror[e].append((now[src[e]] + lat[e], touch[e],
                                  pay[e, 0]))
            assert int(np.asarray(u["q_size"])[e]) == len(mirror[e]), e
        att_tot += sact
        acc_tot += acc
        drop_tot += sact & ~acc
        for p in range(n):
            mine = src == p
            assert sums[p, 0] == sact[mine].sum(), p
            assert sums[p, 1] == (sact & acc)[mine].sum(), p
            assert sums[p, 2] == (sact & ~acc)[mine].sum(), p
        sizes = np.array([len(q) for q in mirror])
        assert np.all(acc_tot == drain_tot + sizes)
        assert np.all(att_tot == acc_tot + drop_tot)
        carry = u


def run_core_dense_sequence(seed: int, n: int, d: int, C: int,
                            max_pops: int, steps: int):
    """Drive ``WindowCore.window_dense`` / ``stage_dense`` through a random
    op sequence on the flat bucketed dense layout (DESIGN.md §13) with
    self-loop out-edge tables (flat row ``p*d + q`` is both process p's
    in-ring q and its q-th out-edge), checking the same mirror-queue
    invariants plus the staged send-decision counters (att/ok/drop per
    process, every step) on the identity single-bucket spec."""
    rng = np.random.default_rng(seed)
    core = _make_core(n, C, max_pops)
    R = n * d
    spec = DenseSpec(n_dst=n, n_rows=R,
                     buckets=(BucketSlab(start=0, nb=n, deg=d,
                                         members=None),))
    carry = {k: v for k, v in core.dense_rings(R).items()}
    carry.update(halo=jnp.zeros((n, 4, 1), jnp.int32),
                 c_msgs=jnp.zeros(n, jnp.int32),
                 c_laden=jnp.zeros(n, jnp.int32),
                 c_touch=jnp.zeros(n, jnp.int32),
                 c_att=jnp.zeros(n, jnp.int32),
                 c_ok=jnp.zeros(n, jnp.int32),
                 c_drop=jnp.zeros(n, jnp.int32))
    src = (np.arange(R, dtype=np.int32) // d).astype(np.int32)
    rev = np.arange(R, dtype=np.int32)
    out_slot = np.zeros(R, np.int32)
    live = np.ones(R, bool)
    deg = np.full(n, d, np.int32)
    mirror = [[collections.deque() for _ in range(d)] for _ in range(n)]
    staged = None   # python twin of the carried stage_* buffers
    acc_tot = np.zeros((n, d), np.int64)
    att_tot = np.zeros((n, d), np.int64)
    drop_tot = np.zeros((n, d), np.int64)
    drain_tot = np.zeros((n, d), np.int64)
    now = np.zeros(n, np.float32)

    def by_ring(x):
        return np.asarray(x).reshape((n, d) + np.asarray(x).shape[1:])

    for _ in range(steps):
        now = (now + rng.uniform(0.5, 1.5, n)).astype(np.float32)
        ract = rng.random(n) < 0.8
        prev = {k: np.asarray(v) for k, v in carry.items()}
        upd, drained_r = core.window_dense(carry, jnp.asarray(now),
                                           jnp.asarray(ract), spec=spec)
        u = dict(carry)
        u.update(upd)
        # last window's staged pushes enter the mirror first (accepted at
        # stage time), then this window's drain walks the queue front
        if staged is not None:
            for p in range(n):
                for q in range(d):
                    if staged["acc"][p, q]:
                        mirror[p][q].append(
                            (staged["avail"][p, q], staged["touch"][p, q],
                             staged["pay"][p, q]))
        halo = np.asarray(u["halo"])
        ptouch2 = by_ring(u["ptouch"])
        qsize2 = by_ring(u["q_size"])
        for p in range(n):
            fresh = {}
            drained = np.zeros(d, np.int64)
            for q in range(d):
                expect = 0
                if ract[p]:
                    for avail, _t, _pay in list(mirror[p][q])[:max_pops]:
                        if avail <= now[p]:
                            expect += 1
                        else:
                            break
                drained[q] = expect
                last = None
                for _ in range(expect):
                    last = mirror[p][q].popleft()
                if expect:
                    assert int(ptouch2[p, q]) == last[1] + 1, (p, q)
                    fresh[q] = last[2]
                assert int(qsize2[p, q]) == len(mirror[p][q]), (p, q)
            drain_tot[p] += drained
            assert int(np.asarray(drained_r)[p]) == drained.sum()
            assert (np.asarray(u["c_msgs"]) - prev["c_msgs"])[p] == \
                drained.sum()
            assert (np.asarray(u["c_laden"]) - prev["c_laden"])[p] == \
                (drained > 0).sum()
            for s in range(4):
                js = [q for q in range(s, d, 4) if drained[q] > 0]
                if js:
                    assert halo[p, s, 0] == fresh[max(js)], (p, s)

        # stage this window's sends through the core (self-loop tables)
        sact = rng.random(n) < 0.8
        lat = rng.uniform(0.0, 4.0, (n, d)).astype(np.float32)
        pay = rng.integers(0, 99, (n, 1, 1)).astype(np.int32)
        st = core.stage_dense(
            u, u, jnp.asarray(now), jnp.asarray(sact),
            jnp.asarray(pay), jnp.asarray(lat.reshape(R)),
            src=jnp.asarray(src), rev=jnp.asarray(rev),
            out_slot=jnp.asarray(out_slot), live=jnp.asarray(live),
            deg=jnp.asarray(deg), spec=spec)
        u.update(st)
        sizes = np.array([[len(mirror[p][q]) for q in range(d)]
                          for p in range(n)])
        exp_acc = sact[:, None] & (sizes < C)
        assert np.array_equal(by_ring(u["stage_acc"]), exp_acc)
        assert np.array_equal(by_ring(u["q_size"]), sizes + exp_acc)
        att = np.where(sact, d, 0)
        assert np.array_equal(
            np.asarray(u["c_att"]) - prev["c_att"], att)
        assert np.array_equal(
            np.asarray(u["c_ok"]) - prev["c_ok"], exp_acc.sum(axis=1))
        assert np.array_equal(
            np.asarray(u["c_drop"]) - prev["c_drop"],
            att - exp_acc.sum(axis=1))
        att_tot += sact[:, None]
        acc_tot += exp_acc
        drop_tot += sact[:, None] & ~exp_acc
        staged = dict(acc=exp_acc,
                      avail=now[:, None] + lat,
                      touch=by_ring(u["stage_touch"]),
                      pay=by_ring(u["stage_pay"])[:, :, 0])
        # conservation: accepted == drained + queued + staged-not-applied
        assert np.all(acc_tot == drain_tot + sizes + exp_acc)
        assert np.all(att_tot == acc_tot + drop_tot)
        carry = u


def run_shadow_sequence(seed: int, n: int, d: int, C: int,
                        max_pops: int, steps: int):
    """The pipelined scheduler's shadow-buffer exchange at the ring-op
    level (DESIGN.md §12): sends staged in superstep i ride a shadow
    buffer and are pushed through ``send_edge`` only in superstep i+1,
    with availability stamps drawn at STAGE time.  The mirror-queue
    oracle enters each message one superstep late with its original
    stamp, pinning the double-buffer contract:

      +1 delay        a staged message is invisible to the drain of its
                      own superstep (ring sizes match a mirror that
                      excludes the current shadow buffer)
      drop-iff-full   accept is decided at PUSH time — one superstep
                      after staging — against the post-drain ring
      stamp honesty   delivery eligibility uses the stage-time stamp, so
                      the delay never rewrites virtual time
      conservation    staged == attempted + in-shadow,
                      attempted == accepted + dropped, and
                      accepted == drained + in-ring, every superstep
    """
    rng = np.random.default_rng(seed)
    core = _make_core(n, C, max_pops)
    E = n * d
    dst = (np.arange(E) // d).astype(np.int32)
    halo_key = (dst * 4 + (np.arange(E) % d) % 4).astype(np.int32)
    src = ((np.arange(E) * 7 + 3) % n).astype(np.int32)
    carry = dict(core.edge_rings(E))
    carry.update(halo=jnp.zeros((n, 4, 1), jnp.int32),
                 c_msgs=jnp.zeros(n, jnp.int32),
                 c_laden=jnp.zeros(n, jnp.int32),
                 c_touch=jnp.zeros(n, jnp.int32))
    mirror = [collections.deque() for _ in range(E)]
    shadow = None   # the in-flight buffer staged last superstep
    att_tot = np.zeros(E, np.int64)
    acc_tot = np.zeros(E, np.int64)
    drop_tot = np.zeros(E, np.int64)
    drain_tot = np.zeros(E, np.int64)
    staged_tot = np.zeros(E, np.int64)
    now = np.zeros(n, np.float32)

    for _ in range(steps):
        now = (now + rng.uniform(0.5, 1.5, n)).astype(np.float32)
        ract = rng.random(n) < 0.8
        upd, _ = core.drain(
            carry, jnp.asarray(now)[jnp.asarray(dst)],
            jnp.asarray(ract)[jnp.asarray(dst)],
            halo_key=jnp.asarray(halo_key), n_halo=n * 4,
            dst=jnp.asarray(dst), n_dst=n)
        u = dict(carry)
        u.update(upd)
        for e in range(E):
            p = dst[e]
            expect = 0
            if ract[p]:
                for avail, _tch in list(mirror[e])[:max_pops]:
                    if avail <= now[p]:
                        expect += 1
                    else:
                        break
            for _ in range(expect):
                mirror[e].popleft()
            drain_tot[e] += expect
            # +1 delay: the drain sees a ring WITHOUT the current shadow
            assert int(np.asarray(u["q_size"])[e]) == len(mirror[e]), e

        # push LAST superstep's shadow buffer: stamps were drawn against
        # the stage-time clock, so some may already be in the past —
        # honest added latency, never a rewritten stamp
        if shadow is not None:
            sp = core.send_edge(
                u, jnp.asarray(shadow["avail"]), jnp.asarray(shadow["act"]),
                jnp.float32(0.0), jnp.asarray(shadow["touch"]),
                jnp.asarray(shadow["pay"]), jnp.asarray(src), n)
            acc = np.asarray(sp.accepted)
            u.update(sp.rings)
            for e in range(E):
                room = len(mirror[e]) < C
                assert bool(acc[e]) == bool(shadow["act"][e] and room), e
                if acc[e]:
                    mirror[e].append((shadow["avail"][e],
                                      shadow["touch"][e]))
                assert int(np.asarray(u["q_size"])[e]) == len(mirror[e])
            att_tot += shadow["act"]
            acc_tot += acc
            drop_tot += shadow["act"] & ~acc

        # stage a fresh shadow buffer, pushed next superstep
        act = rng.random(E) < 0.8
        shadow = dict(
            act=act,
            avail=(now[src] + rng.uniform(0.0, 4.0, E)).astype(np.float32),
            touch=rng.integers(1, 100, E).astype(np.int32),
            pay=rng.integers(0, 99, (E, 1)).astype(np.int32))
        staged_tot += act
        sizes = np.array([len(q) for q in mirror])
        assert np.all(staged_tot == att_tot + shadow["act"])
        assert np.all(att_tot == acc_tot + drop_tot)
        assert np.all(acc_tot == drain_tot + sizes)
        carry = u


CORE_EDGE_CASES = [
    (0, 1, 1, 1, 1, 15),
    (1, 2, 3, 2, 2, 15),
    (2, 3, 2, 4, 3, 12),
    (3, 2, 5, 3, 4, 12),
]


@pytest.mark.parametrize("seed,n,d,C,max_pops,steps", CORE_EDGE_CASES)
def test_window_core_edge_phases_seeded(seed, n, d, C, max_pops, steps):
    run_core_edge_sequence(seed, n, d, C, max_pops, steps)


@pytest.mark.parametrize("seed,n,d,C,max_pops,steps", CORE_EDGE_CASES)
def test_window_core_dense_phases_seeded(seed, n, d, C, max_pops, steps):
    run_core_dense_sequence(seed, n, d, C, max_pops, steps)


@pytest.mark.parametrize("seed,n,d,C,max_pops,steps", CORE_EDGE_CASES)
def test_shadow_buffer_properties_seeded(seed, n, d, C, max_pops, steps):
    run_shadow_sequence(seed, n, d, C, max_pops, steps)


def run_fault_mask_sequence(seed: int, n: int, d: int, C: int,
                            max_pops: int, steps: int):
    """Typed-fault send kills (``WindowCore.fault_masks``, DESIGN.md §14)
    composed with the edge-major phases, under the mirror-queue oracle
    with full drop-attribution books:

      determinism    the masks are pure counter hashes — the same
                     (seed, clock, step count, edge id) inputs reproduce
                     them bitwise on a second call
      disjointness   loss_kill and dead_kill never overlap (dead wins);
                     clean live edges (loss == flap == 0) are never
                     loss-killed
      totality       dead edges kill every attempt; loss == 1 edges kill
                     every attempt that isn't already dead
      conservation   attempted == delivered + in-flight +
                     capacity_dropped + loss_dropped + dead_dropped,
                     per edge, every step — killed sends never enter a
                     ring, so they can neither deliver nor occupy slots
    """
    rng = np.random.default_rng(seed)
    core = _make_core(n, C, max_pops)
    E = n * d
    dst = (np.arange(E) // d).astype(np.int32)
    halo_key = (dst * 4 + (np.arange(E) % d) % 4).astype(np.int32)
    src = ((np.arange(E) * 7 + 3) % n).astype(np.int32)
    eids = jnp.arange(E, dtype=jnp.int32)
    # per-edge fault assignment: clean / lossy / certain-loss / flapping /
    # dead edges all present (modulo tiny E) so every branch is exercised
    loss_e = rng.choice(np.float32([0.0, 0.35, 1.0]), E,
                        p=[0.5, 0.3, 0.2]).astype(np.float32)
    flap_e = np.where(rng.random(E) < 0.3, np.float32(0.5),
                      np.float32(0.0))
    dead_e = rng.random(E) < 0.25
    flap_period = 2.0
    fseed = seed ^ 0x5EED

    carry = dict(core.edge_rings(E))
    carry.update(halo=jnp.zeros((n, 4, 1), jnp.int32),
                 c_msgs=jnp.zeros(n, jnp.int32),
                 c_laden=jnp.zeros(n, jnp.int32),
                 c_touch=jnp.zeros(n, jnp.int32))
    mirror = [collections.deque() for _ in range(E)]
    att_tot = np.zeros(E, np.int64)
    acc_tot = np.zeros(E, np.int64)
    cap_tot = np.zeros(E, np.int64)
    loss_tot = np.zeros(E, np.int64)
    dead_tot = np.zeros(E, np.int64)
    drain_tot = np.zeros(E, np.int64)
    steps_n = np.zeros(n, np.int32)
    now = np.zeros(n, np.float32)

    for _ in range(steps):
        now = (now + rng.uniform(0.5, 1.5, n)).astype(np.float32)
        ract = rng.random(n) < 0.8
        upd, _ = core.drain(
            carry, jnp.asarray(now)[jnp.asarray(dst)],
            jnp.asarray(ract)[jnp.asarray(dst)],
            halo_key=jnp.asarray(halo_key), n_halo=n * 4,
            dst=jnp.asarray(dst), n_dst=n)
        u = dict(carry)
        u.update(upd)
        for e in range(E):
            p = dst[e]
            expect = 0
            if ract[p]:
                for avail, _tch in list(mirror[e])[:max_pops]:
                    if avail <= now[p]:
                        expect += 1
                    else:
                        break
            for _ in range(expect):
                mirror[e].popleft()
            drain_tot[e] += expect
            assert int(np.asarray(u["q_size"])[e]) == len(mirror[e]), e

        sact = rng.random(E) < 0.8
        t_src = jnp.asarray(now[src])
        st_src = jnp.asarray(steps_n[src])
        l_k, d_k = core.fault_masks(
            fseed, t_src, st_src, eids, jnp.asarray(loss_e),
            jnp.asarray(flap_e), flap_period, jnp.asarray(dead_e))
        l2, d2 = core.fault_masks(
            fseed, t_src, st_src, eids, jnp.asarray(loss_e),
            jnp.asarray(flap_e), flap_period, jnp.asarray(dead_e))
        l_k, d_k = np.asarray(l_k), np.asarray(d_k)
        np.testing.assert_array_equal(l_k, np.asarray(l2))
        np.testing.assert_array_equal(d_k, np.asarray(d2))
        assert not (l_k & d_k).any()
        np.testing.assert_array_equal(d_k, dead_e)
        clean = (loss_e == 0) & (flap_e == 0) & ~dead_e
        assert not l_k[clean].any()
        assert l_k[(loss_e == 1.0) & ~dead_e].all()

        kill = l_k | d_k
        send_act = sact & ~kill
        lat = rng.uniform(0.0, 4.0, E).astype(np.float32)
        touch = rng.integers(1, 100, E).astype(np.int32)
        pay = rng.integers(0, 99, (E, 1)).astype(np.int32)
        sp = core.send_edge(u, jnp.asarray(now)[jnp.asarray(src)],
                            jnp.asarray(send_act), jnp.asarray(lat),
                            jnp.asarray(touch), jnp.asarray(pay),
                            jnp.asarray(src), n)
        acc = np.asarray(sp.accepted)
        u.update(sp.rings)
        for e in range(E):
            room = len(mirror[e]) < C
            assert bool(acc[e]) == bool(send_act[e] and room), e
            if acc[e]:
                mirror[e].append((now[src[e]] + lat[e], touch[e]))
        att_tot += sact
        acc_tot += acc
        cap_tot += send_act & ~acc
        loss_tot += sact & l_k
        dead_tot += sact & d_k
        sizes = np.array([len(q) for q in mirror])
        assert np.all(acc_tot == drain_tot + sizes)
        assert np.all(
            att_tot == drain_tot + sizes + cap_tot + loss_tot + dead_tot)
        steps_n += 1
        carry = u


@pytest.mark.parametrize("seed,n,d,C,max_pops,steps", CORE_EDGE_CASES)
def test_fault_mask_properties_seeded(seed, n, d, C, max_pops, steps):
    run_fault_mask_sequence(seed, n, d, C, max_pops, steps)


if HAVE_HYPOTHESIS:
    @given(
        seed=hyp_st.integers(0, 2**31 - 1),
        E=hyp_st.integers(1, 4),
        C=hyp_st.integers(1, 4),
        max_pops=hyp_st.integers(1, 3),
        steps=hyp_st.integers(2, 15),
    )
    @settings(max_examples=12, deadline=None)
    def test_duct_properties_hypothesis(seed, E, C, max_pops, steps):
        run_sequence(seed, E, C, max_pops, steps)

    @given(
        seed=hyp_st.integers(0, 2**31 - 1),
        n=hyp_st.integers(1, 3),
        d=hyp_st.integers(1, 5),
        C=hyp_st.integers(1, 4),
        max_pops=hyp_st.integers(1, 3),
        steps=hyp_st.integers(2, 12),
    )
    @settings(max_examples=12, deadline=None)
    def test_duct_window_properties_hypothesis(seed, n, d, C, max_pops, steps):
        run_window_sequence(seed, n, d, C, max_pops, steps)

    @given(
        seed=hyp_st.integers(0, 2**31 - 1),
        n=hyp_st.integers(1, 3),
        d=hyp_st.integers(1, 4),
        C=hyp_st.integers(1, 4),
        max_pops=hyp_st.integers(1, 3),
        steps=hyp_st.integers(2, 12),
    )
    @settings(max_examples=10, deadline=None)
    def test_shadow_buffer_properties_hypothesis(seed, n, d, C, max_pops,
                                                 steps):
        run_shadow_sequence(seed, n, d, C, max_pops, steps)

    @given(
        seed=hyp_st.integers(0, 2**31 - 1),
        n=hyp_st.integers(1, 3),
        d=hyp_st.integers(1, 4),
        C=hyp_st.integers(1, 4),
        max_pops=hyp_st.integers(1, 3),
        steps=hyp_st.integers(2, 12),
    )
    @settings(max_examples=10, deadline=None)
    def test_fault_mask_properties_hypothesis(seed, n, d, C, max_pops,
                                              steps):
        run_fault_mask_sequence(seed, n, d, C, max_pops, steps)


# ---------------------------------------------------------------------------
# Bucketed layout planner properties (DESIGN.md §13)
# ---------------------------------------------------------------------------
from repro.kernels.duct_exchange import dense_stage  # noqa: E402
from repro.runtime.topologies import (  # noqa: E402
    Topology,
    canonical_edges,
    next_pow2,
    plan_layout,
)


def random_irregular_topology(seed: int, n: int) -> Topology:
    """Random connected symmetric graph: a ring spine plus random chords,
    so in-degrees vary and the planner must genuinely bucket."""
    rng = np.random.default_rng(seed)
    nbrs = [set() for _ in range(n)]
    for i in range(n):
        nbrs[i].add((i + 1) % n)
        nbrs[(i + 1) % n].add(i)
    for _ in range(int(rng.integers(1, 2 * n))):
        a, b = (int(x) for x in rng.integers(0, n, 2))
        if a != b:
            nbrs[a].add(b)
            nbrs[b].add(a)
    return Topology("randgraph", n,
                    tuple(tuple(sorted(s)) for s in nbrs),
                    tuple(0 for _ in range(n))).validate()


def check_bucketed_plan(topo: Topology):
    """Structural invariants of the degree-bucketed dense plan:

      bucket assignment   bdeg[p] = min(next_pow2(deg_p), dmax), exact
      row blocks          live prefix of deg_p rows in sorted-source
                          (= canonical-edge-id) order, dead padding after
      sentinels           dead rows carry src == n, eid == E
      rev involution      rev[rev] = id on ALL rows; dead rows are fixed
                          points; live rows map edge (s, p) to (p, s)
      dead rows           never accept a stage, even with room and every
                          sender active — the live mask gates the push
    """
    plan = plan_layout(topo, "dense")
    n = topo.n
    degs = [topo.degree(p) for p in range(n)]
    dmax = max(degs)
    _, _, eindex = canonical_edges(topo)
    E = len(eindex)
    assert plan.kind == "dense" and plan.degree == dmax
    np.testing.assert_array_equal(
        plan.bdeg, [min(next_pow2(k), dmax) for k in degs])
    assert plan.n_rows == int(plan.bdeg.sum())
    rows = np.arange(plan.n_rows)
    live, dead = plan.live, ~plan.live
    np.testing.assert_array_equal(plan.rev[plan.rev], rows)
    np.testing.assert_array_equal(plan.rev[dead], rows[dead])
    np.testing.assert_array_equal(plan.src[plan.rev][live],
                                  plan.dst[live])
    np.testing.assert_array_equal(plan.dst[plan.rev][live],
                                  plan.src[live])
    for p in range(n):
        sl = slice(int(plan.row_start[p]),
                   int(plan.row_start[p]) + int(plan.bdeg[p]))
        assert live[sl].sum() == degs[p] and live[sl][:degs[p]].all()
        assert (plan.dst[sl] == p).all()
        assert list(plan.src[sl][:degs[p]]) == sorted(topo.neighbors[p])
        assert (plan.src[sl][degs[p]:] == n).all()
        assert (plan.eid[sl][degs[p]:] == E).all()
        assert list(plan.eid[sl][:degs[p]]) == [
            eindex[(s, p)] for s in sorted(topo.neighbors[p])]
    # dead rows never receive: the stage accept mask is gated by `live`
    # (window_core.WindowCore.stage_dense), so with empty rings and every
    # sender active only live rows accept
    head = jnp.zeros(plan.n_rows, jnp.int32)
    size = jnp.zeros(plan.n_rows, jnp.int32)
    _, acc = dense_stage(head, size, jnp.asarray(plan.live), capacity=2)
    acc = np.asarray(acc)
    assert not acc[dead].any() and acc[live].all()


PLANNER_CASES = [(0, 6), (1, 9), (2, 12), (3, 16), (4, 24), (5, 7)]


@pytest.mark.parametrize("seed,n", PLANNER_CASES)
def test_bucketed_planner_properties_seeded(seed, n):
    check_bucketed_plan(random_irregular_topology(seed, n))


def test_bucketed_planner_properties_builtin_topologies():
    from repro.runtime.topologies import make_topology

    for name in ("ring", "torus", "smallworld", "cliques"):
        check_bucketed_plan(make_topology(name, 16))


@pytest.mark.parametrize("seed,n", [(0, 8), (3, 12)])
def test_bucketed_dense_matches_edge_on_random_graphs(seed, n):
    """End-to-end closure of the padding argument: on a random irregular
    graph the bucketed dense engine reproduces the edge-major engine's
    full QoS signature bitwise — dead rows contribute nothing, ever."""
    from engine_cases import jittered_cfg
    from repro.apps.graphcolor import GraphColorApp, GraphColorConfig
    from repro.core.qos import qos_signature
    from repro.runtime.engine import make_engine

    topo = random_irregular_topology(seed, n)
    cfg = jittered_cfg(0.02, seed=seed)

    def app():
        return GraphColorApp(
            GraphColorConfig(n_processes=n, nodes_per_process=1),
            topology=topo)

    res_e = make_engine("jax", app(), cfg, layout="edge").run()
    res_d = make_engine("jax", app(), cfg, layout="dense").run()
    assert qos_signature(res_d) == qos_signature(res_e)


if HAVE_HYPOTHESIS:
    @given(
        seed=hyp_st.integers(0, 2**31 - 1),
        n=hyp_st.integers(4, 24),
    )
    @settings(max_examples=15, deadline=None)
    def test_bucketed_planner_properties_hypothesis(seed, n):
        check_bucketed_plan(random_irregular_topology(seed, n))
