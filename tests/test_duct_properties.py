"""Property tests for the duct_exchange ring ops (DESIGN.md §7).

Random op sequences over a batch of bounded FIFO rings, checked two ways
each step: slot-exact agreement between the jnp ops and the numpy oracle
(``ref.duct_exchange_ref``), and model-level invariants against a python
mirror queue per ring:

  drop-iff-full   a send is accepted iff the post-drain ring has room
  FIFO order      drains pop in push order, never jumping a
                  not-yet-available head, at most ``max_pops`` per window
  conservation    accepted == delivered + in-flight and
                  attempted == accepted + dropped, per ring, every step

Runs under hypothesis when installed (the CI test matrix installs it);
falls back to a fixed seed/shape sweep otherwise, so the invariants are
exercised in either environment.
"""

import collections

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.kernels.duct_exchange.ops import duct_exchange_jnp
from repro.kernels.duct_exchange.ref import duct_exchange_ref

try:
    from hypothesis import given, settings, strategies as hyp_st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def run_sequence(seed: int, E: int, C: int, max_pops: int, steps: int):
    """Drive both implementations through one random op sequence."""
    rng = np.random.default_rng(seed)
    q_avail = np.full((E, C), np.inf, np.float32)
    q_touch = np.zeros((E, C), np.int32)
    head = np.zeros(E, np.int32)
    size = np.zeros(E, np.int32)
    # mirror[e]: FIFO of (availability, touch) for every in-flight message
    mirror = [collections.deque() for _ in range(E)]
    accepted_tot = np.zeros(E, np.int64)
    attempted_tot = np.zeros(E, np.int64)
    dropped_tot = np.zeros(E, np.int64)
    drained_tot = np.zeros(E, np.int64)
    now = np.zeros(E, np.float32)

    for _ in range(steps):
        now = (now + rng.uniform(0.5, 1.5, E)).astype(np.float32)
        recv_active = rng.random(E) < 0.8
        send_active = rng.random(E) < 0.8
        send_lat = rng.uniform(0.0, 4.0, E).astype(np.float32)
        send_touch = rng.integers(1, 100, E).astype(np.int32)

        r = duct_exchange_ref(
            q_avail,
            q_touch,
            head,
            size,
            now,
            recv_active,
            now,
            send_active,
            send_lat,
            send_touch,
            capacity=C,
            max_pops=max_pops,
        )
        j = duct_exchange_jnp(
            jnp.asarray(q_avail),
            jnp.asarray(q_touch),
            jnp.asarray(head),
            jnp.asarray(size),
            jnp.asarray(now),
            jnp.asarray(recv_active),
            jnp.asarray(now),
            jnp.asarray(send_active),
            jnp.asarray(send_lat),
            jnp.asarray(send_touch),
            capacity=C,
            max_pops=max_pops,
        )
        for name in r._fields:
            got = np.asarray(getattr(j, name))
            np.testing.assert_array_equal(got, getattr(r, name), err_msg=name)

        for e in range(E):
            # FIFO + head-blocking: the pops the oracle reports must equal
            # a front-of-queue walk of the mirror, stopping at the first
            # not-yet-available message, bounded by max_pops
            if recv_active[e]:
                expect = 0
                for avail, _tch in list(mirror[e])[: min(size[e], max_pops)]:
                    if avail <= now[e]:
                        expect += 1
                    else:
                        break
                assert r.drained[e] == expect, (e, r.drained[e], expect)
            else:
                assert r.drained[e] == 0
            popped_touch = None
            for _ in range(int(r.drained[e])):
                _avail, popped_touch = mirror[e].popleft()
            if r.drained[e] > 0:
                # the freshest popped message is the one whose touch stamp
                # (and ring slot payload) the engine consumes
                assert r.recv_touch[e] == popped_touch
            # drop-iff-full, judged against post-drain occupancy
            room = size[e] - r.drained[e] < C
            assert bool(r.accepted[e]) == bool(send_active[e] and room)
            if r.accepted[e]:
                mirror[e].append((now[e] + send_lat[e], send_touch[e]))
            assert len(mirror[e]) == r.size[e]

        drained_tot += r.drained
        accepted_tot += r.accepted
        attempted_tot += send_active
        dropped_tot += send_active & ~r.accepted
        q_avail, q_touch, head, size = r.q_avail, r.q_touch, r.head, r.size
        # conservation: every message is delivered, dropped, or in flight
        assert np.all(accepted_tot == drained_tot + size)
        assert np.all(attempted_tot == accepted_tot + dropped_tot)


# a sweep that exercises capacity-1 rings, single-pop drains, single-ring
# batches, and a larger mixed case — always runs, hypothesis or not
FALLBACK_CASES = [
    (0, 1, 1, 1, 20),
    (1, 3, 1, 2, 20),
    (2, 1, 4, 1, 20),
    (3, 4, 2, 3, 15),
    (4, 2, 4, 4, 25),
    (5, 4, 4, 2, 15),
]


@pytest.mark.parametrize("seed,E,C,max_pops,steps", FALLBACK_CASES)
def test_duct_properties_seeded(seed, E, C, max_pops, steps):
    run_sequence(seed, E, C, max_pops, steps)


if HAVE_HYPOTHESIS:
    @given(
        seed=hyp_st.integers(0, 2**31 - 1),
        E=hyp_st.integers(1, 4),
        C=hyp_st.integers(1, 4),
        max_pops=hyp_st.integers(1, 3),
        steps=hyp_st.integers(2, 15),
    )
    @settings(max_examples=12, deadline=None)
    def test_duct_properties_hypothesis(seed, E, C, max_pops, steps):
        run_sequence(seed, E, C, max_pops, steps)
