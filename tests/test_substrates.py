"""Data pipeline + checkpoint tests (incl. property-based invariants)."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional extra; skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.checkpoint import ckpt
from repro.data.synthetic import DataConfig, SyntheticLM


def test_data_deterministic_per_step():
    src = SyntheticLM(DataConfig(vocab_size=100, seq_len=32, global_batch=4))
    a = src.batch_for_step(7)
    b = src.batch_for_step(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = src.batch_for_step(8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_labels_are_next_tokens():
    src = SyntheticLM(DataConfig(vocab_size=100, seq_len=32, global_batch=4))
    b = src.batch_for_step(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_data_has_learnable_structure():
    """Markov chain: successor bigrams occur far above chance."""
    cfg = DataConfig(vocab_size=1000, seq_len=256, global_batch=8)
    src = SyntheticLM(cfg)
    b = src.batch_for_step(0)
    toks = b["tokens"]
    hits = (src._successor[toks[:, :-1]] == toks[:, 1:]).mean()
    assert hits > 0.3  # markov_strength=0.7 minus unigram collisions


@given(step=st.integers(min_value=0, max_value=10_000),
       vocab=st.integers(min_value=10, max_value=5000))
@settings(max_examples=20, deadline=None)
def test_data_tokens_in_range(step, vocab):
    src = SyntheticLM(DataConfig(vocab_size=vocab, seq_len=16, global_batch=2))
    b = src.batch_for_step(step)
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < vocab


def test_pipeline_prefetch():
    from repro.data.pipeline import Pipeline
    from repro.configs.base import ModelConfig
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                      num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=50)
    pipe = Pipeline(DataConfig(vocab_size=50, seq_len=16, global_batch=2), cfg)
    steps = [next(pipe)[0] for _ in range(3)]
    assert steps == [0, 1, 2]
    pipe.close()


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------
def _state():
    return {
        "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                   "stack": (jnp.ones((2, 5)), jnp.zeros((3,)))},
        "opt": {"m": jnp.full((3, 4), 0.5), "step": jnp.array(7, jnp.int32)},
    }


def test_checkpoint_roundtrip():
    state = _state()
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, state, step=7)
        assert ckpt.latest_step(d) == 7
        like = jax.eval_shape(lambda: state)
        restored = ckpt.restore(d, 7, like)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_and_prunes():
    state = _state()
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4):
            ckpt.save(d, state, step=s)
        ckpt.prune(d, keep=2)
        remaining = sorted(os.listdir(d))
        assert remaining == ["step_00000003", "step_00000004"]


def test_checkpoint_restore_dtype_cast():
    """Restore targets the abstract tree's dtype (e.g. bf16 params saved,
    fp32 requested after a precision policy change)."""
    state = {"w": jnp.ones((4,), jnp.bfloat16)}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, state, step=0)
        like = {"w": jax.ShapeDtypeStruct((4,), jnp.float32)}
        restored = ckpt.restore(d, 0, like)
        assert restored["w"].dtype == jnp.float32


def test_checkpoint_shape_mismatch_raises():
    state = {"w": jnp.ones((4,))}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, state, step=0)
        like = {"w": jax.ShapeDtypeStruct((5,), jnp.float32)}
        with pytest.raises(AssertionError):
            ckpt.restore(d, 0, like)
