"""Per-architecture smoke tests: reduced config of the same family runs one
forward + train-grad step (and a decode step) on CPU; shapes + no NaNs.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.configs.smoke import reduce_for_smoke
from repro.models import lm, modality, transformer

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = reduce_for_smoke(get_config(arch))
    params = lm.init_params(KEY, cfg)
    B, S = 2, 16
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.frontend:
        batch[modality.frontend_input_name(cfg)] = (
            jax.random.normal(KEY, (B, cfg.frontend_len, cfg.d_model)) * 0.02)

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: lm.loss_fn(p, batch, cfg), has_aux=True)(params)
    assert np.isfinite(float(loss)), arch
    assert abs(float(metrics["ce"]) - np.log(cfg.vocab_size)) < 1.5
    for leaf in jax.tree.leaves(grads):
        assert not bool(jnp.isnan(leaf).any()), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = reduce_for_smoke(get_config(arch))
    params = lm.init_params(KEY, cfg)
    B, S = 2, 16
    caches = transformer.init_caches(cfg, B, S, jnp.bfloat16)
    tok = jax.random.randint(KEY, (B, 1), 0, cfg.vocab_size)
    nt, logits, nc = lm.decode_step(params, tok, caches, cfg, S - 1)
    assert nt.shape == (B, 1)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), arch
    # cache structure preserved
    assert jax.tree.structure(nc) == jax.tree.structure(caches)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_abstract_shapes(arch):
    """Full config param tree builds abstractly (no allocation) and the
    parameter count is in the expected family ballpark."""
    cfg = get_config(arch)
    n = lm.param_count(cfg)
    expected = {
        "musicgen-large": (2.5e9, 4e9),
        "qwen2.5-3b": (2e9, 4e9),
        "qwen3-0.6b": (0.4e9, 0.9e9),
        "qwen2-1.5b": (1e9, 2.2e9),
        "minitron-8b": (7e9, 10.5e9),
        "deepseek-moe-16b": (12e9, 20e9),
        "dbrx-132b": (110e9, 150e9),
        "llava-next-mistral-7b": (6.5e9, 8.5e9),
        "xlstm-125m": (0.08e9, 0.2e9),
        "jamba-v0.1-52b": (44e9, 60e9),
    }[arch]
    assert expected[0] < n < expected[1], (arch, n)
