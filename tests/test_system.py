"""End-to-end system behaviour: public API surface, deliverable structure,
and the quickstart path."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_all_ten_architectures_registered():
    from repro.configs import ARCHS
    assert set(ARCHS) == {
        "musicgen-large", "qwen2.5-3b", "qwen3-0.6b", "qwen2-1.5b",
        "minitron-8b", "deepseek-moe-16b", "dbrx-132b",
        "llava-next-mistral-7b", "xlstm-125m", "jamba-v0.1-52b"}


def test_public_api_imports():
    import repro.core as core
    from repro.core import AsyncMode, collectives, conduit, qos  # noqa: F401
    from repro.launch.mesh import make_production_mesh  # noqa: F401
    from repro.launch.train import TrainSpec, make_train_step  # noqa: F401
    from repro.runtime import SimConfig, Simulator  # noqa: F401
    from repro.kernels.flash_attention import flash_attention  # noqa: F401
    assert len(list(AsyncMode)) == 5
    assert core is not None


def test_shape_applicability_matrix():
    """40 assigned cells = 32 runnable + 8 documented long_500k skips."""
    from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
    runnable = skipped = 0
    for a in ARCHS:
        for s in SHAPES.values():
            if shape_applicable(get_config(a), s):
                runnable += 1
            else:
                skipped += 1
                assert s.name == "long_500k"
    assert runnable == 32
    assert skipped == 8
    # the sub-quadratic archs DO run long_500k
    assert shape_applicable(get_config("xlstm-125m"), SHAPES["long_500k"])
    assert shape_applicable(get_config("jamba-v0.1-52b"), SHAPES["long_500k"])


def test_deliverable_structure_present():
    for path in ("DESIGN.md", "EXPERIMENTS.md", "README.md",
                 "src/repro/launch/dryrun.py", "src/repro/launch/mesh.py",
                 "benchmarks/run.py", "benchmarks/roofline.py",
                 "examples/quickstart.py"):
        assert os.path.exists(os.path.join(REPO, path)), path
    # dryrun.py sets XLA_FLAGS before any other import (spec requirement)
    src = open(os.path.join(REPO, "src/repro/launch/dryrun.py")).read()
    assert src.index("XLA_FLAGS") < src.index("import jax")


def test_quickstart_example_runs():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable,
                        os.path.join(REPO, "examples", "quickstart.py")],
                       capture_output=True, text=True, env=env, timeout=420)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "best-effort" in r.stdout
