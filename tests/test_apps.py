"""Application tests: graph coloring (CFL) and digital evolution."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.apps.evo import EvoApp, EvoConfig
from repro.apps.graphcolor import (
    GraphColorApp, GraphColorConfig, _update_block, jnp_update_block,
    block_shape, proc_grid,
)


def test_proc_grid_near_square():
    assert proc_grid(64) == (8, 8)
    assert proc_grid(16) == (4, 4)
    assert proc_grid(2) == (1, 2)
    assert block_shape(2048) == (32, 64)


def test_cfl_converges_single_process():
    app = GraphColorApp(GraphColorConfig(n_processes=1, nodes_per_process=256))
    frags = app.make_fragments()
    q0 = app.quality(frags)
    for _ in range(3000):
        frags[0].update({})
    q1 = app.quality(frags)
    assert q0 > 100          # random 3-coloring starts heavily conflicted
    assert q1 < 0.1 * q0     # CFL drives conflicts way down


def test_quality_counts_every_edge_once():
    app = GraphColorApp(GraphColorConfig(n_processes=1, nodes_per_process=16))
    frags = app.make_fragments()
    # all same color: every edge conflicts; 4x4 torus has 2*16 = 32 edges
    frags[0].colors[:] = 1
    assert app.quality(frags) == 32.0


def test_numpy_and_jnp_updates_agree_on_deterministic_parts():
    rng = np.random.default_rng(0)
    H, W, C = 8, 8, 3
    colors = rng.integers(0, C, (H, W))
    probs = np.full((H, W, C), 1.0 / C)
    halo = {"n": colors[-1].copy(), "s": colors[0].copy(),
            "w": colors[:, -1].copy(), "e": colors[:, 0].copy()}
    np_colors, np_probs, np_conf = _update_block(
        colors.copy(), probs.copy(), halo, 0.1, rng)
    j_colors, j_probs, j_conf = jnp_update_block(
        jnp.asarray(colors), jnp.asarray(probs),
        {k: jnp.asarray(v) for k, v in halo.items()}, 0.1,
        jax.random.PRNGKey(0))
    # conflict masks are deterministic and must agree exactly
    np.testing.assert_array_equal(np.asarray(j_conf), np_conf)
    # non-conflicted cells keep their colors in both
    keep = ~np_conf
    np.testing.assert_array_equal(np.asarray(j_colors)[keep], np_colors[keep])
    # prob updates agree (success: one-hot; failure: mixed) regardless of rng
    np.testing.assert_allclose(np.asarray(j_probs), np_probs, atol=1e-6)


def test_evo_fitness_improves():
    app = EvoApp(EvoConfig(n_processes=1, cells_per_process=100))
    frags = app.make_fragments()
    q0 = app.quality(frags)
    for _ in range(300):
        frags[0].update({})
    assert app.quality(frags) > q0 + 0.2


def test_evo_multiprocess_resource_flows_across_boundaries():
    app = EvoApp(EvoConfig(n_processes=4, cells_per_process=64))
    frags = app.make_fragments()
    # run a few rounds with direct (fresh) message passing
    payloads = {f.pid: None for f in frags}
    for _ in range(5):
        outs = {}
        for f in frags:
            inbox = {nb: payloads[nb] for nb in app.topology()[f.pid]}
            outs[f.pid] = f.update(inbox)
        payloads = {pid: outs[pid][pid2] for pid in outs
                    for pid2 in app.topology() if pid in app.topology()[pid2]}
        payloads = {pid: next(iter(outs[pid].values())) for pid in outs}
    total = sum(f.resource.sum() for f in frags)
    assert np.isfinite(total) and total > 0


def test_spmd_graphcolor_multidevice():
    """The in-graph shard_map + Conduit version runs and reduces conflicts."""
    import os
    import subprocess
    import sys
    import textwrap
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    script = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core.conduit import torus_conduits
        from repro.core.modes import AsyncMode
        from repro.apps.graphcolor import spmd_step
        from repro.launch.mesh import shard_map  # version-compat wrapper

        mesh = jax.make_mesh((2, 2), ("row", "col"))
        rowc, colc = torus_conduits(("row", "col"), AsyncMode.BEST_EFFORT)
        H = W = 16

        def body(keys):
            key = keys[0][0]
            colors = jax.random.randint(key, (H, W), 0, 3)
            state = {
                "colors": colors, "probs": jnp.full((H, W, 3), 1/3.),
                "bufs_row": rowc.init_buffers(jnp.zeros((2, W), colors.dtype)),
                "bufs_col": colc.init_buffers(jnp.zeros((2, H), colors.dtype)),
                "key": key, "step": jnp.zeros((), jnp.int32),
            }
            def _vary(x):
                # vma tagging only exists on current jax; older releases
                # run with replication checking off and don't need it
                if not hasattr(jax, "typeof"):
                    return x
                missing = tuple(a for a in ("row", "col")
                                if a not in jax.typeof(x).vma)
                return jax.lax.pvary(x, missing) if missing else x
            state = jax.tree.map(_vary, state)
            def step(state, _):
                state, conf = spmd_step(state, rowc, colc, 0.1)
                return state, conf
            state, confs = jax.lax.scan(step, state, None, length=400)
            return confs

        keys = jax.random.split(jax.random.PRNGKey(0), 4).reshape(2, 2, 2)
        f = jax.jit(shard_map(body, mesh, in_specs=P("row", "col"),
                              out_specs=P(("row", "col"))))
        confs = np.asarray(f(keys))  # (400*4?) -> per-device concat
        per_dev = confs.reshape(4, -1) if confs.ndim == 1 else confs
        start = per_dev[..., :10].mean()
        end = per_dev[..., -10:].mean()
        assert end < 0.3 * start, (start, end)
        print("SPMD-GC-OK", start, end)
    """)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=300)
    assert r.returncode == 0, f"STDOUT:{r.stdout}\nSTDERR:{r.stderr}"
    assert "SPMD-GC-OK" in r.stdout
